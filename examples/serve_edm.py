"""Serving smoke: concurrent HTTP clients ≡ direct sessions, one append tick.

The CI job for the serving subsystem (docs/ARCHITECTURE.md "Serving"):

* start an ``EDMServer`` behind the stdlib HTTP front end on an
  ephemeral port and register a panel over the wire;
* drive N concurrent client threads issuing compatible CCM requests
  (the scheduler coalesces them into group launches) plus ``optimal_E``
  and ``xmap`` panel ops, and assert every response **bit-matches** a
  direct in-process ``EDM`` session on the same panel — the served-
  answer contract: batching and transport never change bits
  (``EDM.ccm_batch`` on a singleton pair is the quiesced CCM oracle);
* submit one **append tick** through the server and assert post-append
  answers bit-match a COLD session built on the grown panel — the
  incremental kNN-master merge is indistinguishable from a rebuild;
* record the whole run to a telemetry JSONL sink and assert it is
  schema-valid and contains the serve spans/metrics CI expects.

Run: ``PYTHONPATH=src python examples/serve_edm.py [out_dir]``

With ``out_dir``, the event log lands at
``<out_dir>/serve/telemetry/events.jsonl`` so CI can schema-validate and
upload it; without, a tempdir is used.
"""

import json
import os
import sys
import tempfile
import threading
import urllib.request

import numpy as np

from repro import telemetry
from repro.data import timeseries as ts
from repro.edm import EDM, EDMConfig
from repro.serving import EDMServer, serve_http
from repro.telemetry import schema

N_CLIENTS = 6
E_REQ = 3
CFG = dict(E_max=4, cache=True)


def _post(port: int, op: str, **body) -> dict:
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/{op}",
        data=json.dumps(body).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req) as resp:
        return json.loads(resp.read())


def _get(port: int, path: str) -> str:
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as resp:
        return resp.read().decode()


def _bit_match(served, oracle: np.float32, what: str) -> None:
    got = np.float32(np.nan if served is None else served)
    ok = (got == oracle) or (np.isnan(got) and np.isnan(oracle))
    assert ok, f"{what}: served {got!r} != direct {oracle!r}"


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else tempfile.mkdtemp()
    log = os.path.join(out, "serve", "telemetry", "events.jsonl")
    sink = telemetry.JsonlSink(log)
    telemetry.add_sink(sink)

    panel, _ = ts.forced_network_panel(8, 300, seed=33)
    panel = np.asarray(panel, np.float32)
    rng = np.random.default_rng(5)
    delta = rng.standard_normal((panel.shape[0], 6)).astype(np.float32)

    # Direct oracles: the same answers with no server in the loop.
    direct = EDM(panel, EDMConfig(**CFG))
    direct_grown = EDM(np.concatenate([panel, delta], axis=1),
                       EDMConfig(**CFG))
    pairs = [(i, (i + 1) % panel.shape[0]) for i in range(panel.shape[0])]
    oracle = {p: direct.ccm_batch([p], E=E_REQ)[0] for p in pairs}
    oracle_grown = {p: direct_grown.ccm_batch([p], E=E_REQ)[0] for p in pairs}

    srv = EDMServer()
    httpd = serve_http(srv)
    port = httpd.server_address[1]
    try:
        _post(port, "register", panel="smoke", data=panel.tolist(), **CFG)

        # --- N concurrent clients, compatible CCM requests -> coalesced
        errors: list[BaseException] = []

        def client(cid: int) -> None:
            try:
                for lib, tgt in pairs[cid::2]:
                    r = _post(port, "ccm", panel="smoke",
                              lib=lib, target=tgt, E=E_REQ)["result"]
                    _bit_match(r, oracle[(lib, tgt)],
                               f"client {cid} ccm{(lib, tgt)}")
            except BaseException as exc:  # surface in the parent
                errors.append(exc)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(N_CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]

        # --- panel ops over the wire match the direct session
        e_direct, rho_direct = direct.optimal_E()
        e_srv, rho_srv = _post(port, "optimal_E", panel="smoke")["result"]
        assert np.array_equal(np.asarray(e_srv, np.int32), e_direct)
        # JSON None -> NaN; float32 -> float64 repr -> float32 is exact,
        # so equality below is still bitwise.
        assert np.array_equal(np.asarray(rho_srv, np.float32),
                              np.asarray(rho_direct, np.float32),
                              equal_nan=True)
        x_srv = _post(port, "xmap", panel="smoke")["result"]
        assert np.array_equal(np.asarray(x_srv, np.float32),
                              np.asarray(direct.xmap(), np.float32),
                              equal_nan=True)

        # --- one append tick: server == COLD session on the grown panel
        info = _post(port, "append", panel="smoke",
                     delta=delta.tolist())["result"]
        assert info["L"] == panel.shape[1] + delta.shape[1], info
        for p in pairs:
            r = _post(port, "ccm", panel="smoke",
                      lib=p[0], target=p[1], E=E_REQ)["result"]
            _bit_match(r, oracle_grown[p], f"post-append ccm{p}")

        # --- observability surfaces
        prom = _get(port, "/metrics")
        for needle in ("serve_requests", "serve_batches",
                       "serve_latency_ms_ccm", "edm_knn_master_appends"):
            assert needle in prom, f"{needle} missing from /metrics"
        panels = json.loads(_get(port, "/panels"))["panels"]
        assert panels[0]["name"] == "smoke" and panels[0]["version"] == 1
    finally:
        httpd.shutdown()
        srv.close()
        telemetry.remove_sink(sink)
        sink.close()

    errs = schema.validate_events_file(log)
    assert not errs, f"telemetry schema violations: {errs[:5]}"
    names = {json.loads(line)["name"]
             for line in open(log) if line.strip()}
    for needle in ("serve.register", "serve.batch", "serve.request",
                   "session.append", "session.master_append"):
        assert needle in names, f"{needle} missing from {log}"
    print(f"telemetry log: {log}")
    print("SERVE SMOKE OK")


if __name__ == "__main__":
    main()
