"""Serving smoke + phase-2 soak: HTTP clients ≡ direct sessions, always.

The CI job for the serving subsystem (docs/ARCHITECTURE.md "Serving").
Part 1, the single-panel smoke:

* start an ``EDMServer`` behind the stdlib HTTP front end on an
  ephemeral port and register a panel over the wire;
* drive N concurrent client threads issuing compatible CCM requests
  (the scheduler coalesces them into group launches) plus ``optimal_E``
  and ``xmap`` panel ops, and assert every response **bit-matches** a
  direct in-process ``EDM`` session on the same panel — the served-
  answer contract: batching and transport never change bits
  (``EDM.ccm_batch`` on a singleton pair is the quiesced CCM oracle);
* submit one **append tick** through the server and assert post-append
  answers bit-match a COLD session built on the grown panel — the
  incremental kNN-master merge is indistinguishable from a rebuild;
* record the whole run to a telemetry JSONL sink and assert it is
  schema-valid and contains the serve spans/metrics CI expects.

Part 2, the multi-panel soak (~1 min wall budget): three panels behind
the worker pool with an LRU master byte budget sized to ~1.5 masters,
so round-robin load keeps evicting cold masters while concurrent HTTP
clients query all panels and per-panel append ticks stream through a
subscription. Every answer and every subscription tick must bit-match
the per-version direct-session oracle, ``/healthz`` must stay OK with
all workers alive, the registry must respect the byte budget, and at
least one eviction must actually have happened (else the soak proved
nothing).

Part 3, the durability smoke (PR 10): a CHILD process runs a durable
server (``state_dir=``) behind HTTP; the parent registers a panel and
streams append ticks over the wire, then **kill -9**'s the child
mid-stream. A restarted child recovers from the WAL and must serve
answers **bit-identical** to a cold session at the last acked version;
one more append then lands on the recovered log, SIGTERM drains the
child gracefully (exit 0), and a final in-process ``EDMServer.recover``
proves the whole history — pre-kill appends + post-recovery append —
replays to the same bits.

Run: ``PYTHONPATH=src python examples/serve_edm.py [out_dir]``

With ``out_dir``, the event log lands at
``<out_dir>/serve/telemetry/events.jsonl`` so CI can schema-validate and
upload it; without, a tempdir is used. (``--child <state_dir>`` is the
internal durability-smoke entry point.)
"""

import json
import os
import signal
import subprocess
import sys
import tempfile
import threading
import urllib.request

import numpy as np

from repro import telemetry
from repro.data import timeseries as ts
from repro.edm import EDM, EDMConfig
from repro.serving import EDMServer, serve_http
from repro.telemetry import schema

N_CLIENTS = 6
E_REQ = 3
CFG = dict(E_max=4, cache=True)


def _post(port: int, op: str, **body) -> dict:
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/{op}",
        data=json.dumps(body).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req) as resp:
        return json.loads(resp.read())


def _get(port: int, path: str) -> str:
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as resp:
        return resp.read().decode()


def _bit_match(served, oracle: np.float32, what: str) -> None:
    got = np.float32(np.nan if served is None else served)
    ok = (got == oracle) or (np.isnan(got) and np.isnan(oracle))
    assert ok, f"{what}: served {got!r} != direct {oracle!r}"


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else tempfile.mkdtemp()
    log = os.path.join(out, "serve", "telemetry", "events.jsonl")
    sink = telemetry.JsonlSink(log)
    telemetry.add_sink(sink)

    panel, _ = ts.forced_network_panel(8, 300, seed=33)
    panel = np.asarray(panel, np.float32)
    rng = np.random.default_rng(5)
    delta = rng.standard_normal((panel.shape[0], 6)).astype(np.float32)

    # Direct oracles: the same answers with no server in the loop.
    direct = EDM(panel, EDMConfig(**CFG))
    direct_grown = EDM(np.concatenate([panel, delta], axis=1),
                       EDMConfig(**CFG))
    pairs = [(i, (i + 1) % panel.shape[0]) for i in range(panel.shape[0])]
    oracle = {p: direct.ccm_batch([p], E=E_REQ)[0] for p in pairs}
    oracle_grown = {p: direct_grown.ccm_batch([p], E=E_REQ)[0] for p in pairs}

    srv = EDMServer()
    httpd = serve_http(srv)
    port = httpd.server_address[1]
    try:
        _post(port, "register", panel="smoke", data=panel.tolist(), **CFG)

        # --- N concurrent clients, compatible CCM requests -> coalesced
        errors: list[BaseException] = []

        def client(cid: int) -> None:
            try:
                for lib, tgt in pairs[cid::2]:
                    r = _post(port, "ccm", panel="smoke",
                              lib=lib, target=tgt, E=E_REQ)["result"]
                    _bit_match(r, oracle[(lib, tgt)],
                               f"client {cid} ccm{(lib, tgt)}")
            except BaseException as exc:  # surface in the parent
                errors.append(exc)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(N_CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]

        # --- panel ops over the wire match the direct session
        e_direct, rho_direct = direct.optimal_E()
        e_srv, rho_srv = _post(port, "optimal_E", panel="smoke")["result"]
        assert np.array_equal(np.asarray(e_srv, np.int32), e_direct)
        # JSON None -> NaN; float32 -> float64 repr -> float32 is exact,
        # so equality below is still bitwise.
        assert np.array_equal(np.asarray(rho_srv, np.float32),
                              np.asarray(rho_direct, np.float32),
                              equal_nan=True)
        x_srv = _post(port, "xmap", panel="smoke")["result"]
        assert np.array_equal(np.asarray(x_srv, np.float32),
                              np.asarray(direct.xmap(), np.float32),
                              equal_nan=True)

        # --- one append tick: server == COLD session on the grown panel
        info = _post(port, "append", panel="smoke",
                     delta=delta.tolist())["result"]
        assert info["L"] == panel.shape[1] + delta.shape[1], info
        for p in pairs:
            r = _post(port, "ccm", panel="smoke",
                      lib=p[0], target=p[1], E=E_REQ)["result"]
            _bit_match(r, oracle_grown[p], f"post-append ccm{p}")

        # --- observability surfaces
        prom = _get(port, "/metrics")
        for needle in ("serve_requests", "serve_batches",
                       "serve_latency_ms_ccm", "edm_knn_master_appends"):
            assert needle in prom, f"{needle} missing from /metrics"
        panels = json.loads(_get(port, "/panels"))["panels"]
        assert panels[0]["name"] == "smoke" and panels[0]["version"] == 1
    finally:
        httpd.shutdown()
        srv.close()
        telemetry.remove_sink(sink)
        sink.close()

    errs = schema.validate_events_file(log)
    assert not errs, f"telemetry schema violations: {errs[:5]}"
    names = {json.loads(line)["name"]
             for line in open(log) if line.strip()}
    for needle in ("serve.register", "serve.batch", "serve.request",
                   "session.append", "session.master_append"):
        assert needle in names, f"{needle} missing from {log}"
    print(f"telemetry log: {log}")
    print("SERVE SMOKE OK")


# ---------------------------------------------------------------- soak

SOAK_PANELS = 3
SOAK_TICKS = 2
SOAK_SERIES, SOAK_L, SOAK_DT = 8, 240, 6


def soak() -> None:
    """Multi-panel worker pool + LRU eviction + subscriptions, ~60 s."""
    rng = np.random.default_rng(77)
    full = {f"soak{i}": rng.standard_normal(
        (SOAK_SERIES, SOAK_L + SOAK_TICKS * SOAK_DT)).astype(np.float32)
        for i in range(SOAK_PANELS)}
    pairs = [(i, (i + 3) % SOAK_SERIES) for i in range(SOAK_SERIES)]
    watch = pairs[:4]

    # Per-version direct oracles (and the size of one warm master, which
    # calibrates the byte budget to ~1.5 masters so LRU churn is forced).
    oracle: dict[str, list[dict]] = {}
    one_master = 0
    for name, x in full.items():
        per_v = []
        for v in range(SOAK_TICKS + 1):
            sess = EDM(x[:, : SOAK_L + v * SOAK_DT], EDMConfig(**CFG))
            per_v.append({p: sess.ccm_batch([p], E=E_REQ)[0]
                          for p in pairs})
            one_master = max(one_master, sess.master_nbytes())
        oracle[name] = per_v
    budget_mb = 1.5 * one_master / 2**20

    srv = EDMServer(workers=SOAK_PANELS, master_budget_mb=budget_mb)
    httpd = serve_http(srv)
    port = httpd.server_address[1]
    evictions0 = telemetry.counter("serve_evictions").value
    try:
        for name, x in full.items():
            _post(port, "register", panel=name,
                  data=x[:, :SOAK_L].tolist(), **CFG)
        subs = {name: _post(port, "subscribe", panel=name,
                            pairs=[list(p) for p in watch],
                            E=E_REQ)["result"] for name in full}
        for name, sub in subs.items():  # baseline tick = version 0
            _bit_match_vec(sub["rho"], [oracle[name][0][p] for p in watch],
                           f"{name} subscribe baseline")

        for tick in range(SOAK_TICKS + 1):
            # Concurrent clients sweep every panel at the current version.
            errors: list[BaseException] = []

            def client(cid: int, v=tick) -> None:
                try:
                    for name in full:
                        for lib, tgt in pairs[cid::2]:
                            r = _post(port, "ccm", panel=name, lib=lib,
                                      target=tgt, E=E_REQ)["result"]
                            _bit_match(r, oracle[name][v][(lib, tgt)],
                                       f"soak v{v} {name} ccm{(lib, tgt)}")
                except BaseException as exc:
                    errors.append(exc)

            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(N_CLIENTS)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            if errors:
                raise errors[0]

            h = json.loads(_get(port, "/healthz"))
            assert h["ok"] and all(w["alive"] for w in h["workers"]), h
            assert h["master_bytes"] <= h["master_budget_bytes"], h

            if tick == SOAK_TICKS:
                break
            for name, x in full.items():  # one append tick per panel
                lo = SOAK_L + tick * SOAK_DT
                _post(port, "append", panel=name,
                      delta=x[:, lo:lo + SOAK_DT].tolist())
            for name, sub in subs.items():  # the tick streams out
                got = _post_poll(port, sub["id"])
                assert got and got[-1]["version"] == tick + 1, got
                _bit_match_vec(got[-1]["rho"],
                               [oracle[name][tick + 1][p] for p in watch],
                               f"{name} tick v{tick + 1}")

        # One explicit evict: the rebuilt master answers identically.
        _post(port, "ccm", panel="soak0", lib=pairs[1][0],
              target=pairs[1][1], E=E_REQ)  # warm (LRU may have evicted)
        freed = srv.evict_panel("soak0")
        assert freed > 0, "explicit evict freed nothing"
        r = _post(port, "ccm", panel="soak0", lib=pairs[0][0],
                  target=pairs[0][1], E=E_REQ)["result"]
        _bit_match(r, oracle["soak0"][SOAK_TICKS][pairs[0]],
                   "post-explicit-evict ccm")

        churn = telemetry.counter("serve_evictions").value - evictions0
        assert churn >= 1, "LRU budget never evicted - soak proved nothing"
        print(f"soak: {churn} evictions under "
              f"{budget_mb:.2f} MiB budget, "
              f"{SOAK_PANELS} panels x {SOAK_TICKS} ticks")
    finally:
        httpd.shutdown()
        srv.close()
    print("SERVE SOAK OK")


def _post_poll(port: int, sid: str) -> list:
    body = _get(port, f"/v1/subscriptions/{sid}?timeout=10")
    return json.loads(body)["ticks"]


def _bit_match_vec(served, oracles, what: str) -> None:
    for j, (s, o) in enumerate(zip(served, oracles)):
        _bit_match(s, np.float32(o), f"{what}[{j}]")


# ---------------------------------------------------- durability smoke

DUR_PAIRS = [(0, 1), (2, 3), (4, 5)]


def child(state_dir: str) -> None:
    """The durable server process: recover-or-create, serve until
    terminated (SIGTERM → drain → exit 0; SIGKILL → the WAL's job)."""
    from repro.serving import run_until_terminated
    panels = os.path.join(state_dir, "panels")
    if os.path.isdir(panels) and os.listdir(panels):
        srv = EDMServer.recover(state_dir)
    else:
        srv = EDMServer(state_dir=state_dir)
    httpd = serve_http(srv)
    print(f"PORT {httpd.server_address[1]}", flush=True)
    sys.exit(run_until_terminated(srv, httpd, poll_s=0.05))


def durability_smoke() -> None:
    """kill -9 → recover → bit-match → append → graceful drain."""
    state_dir = tempfile.mkdtemp(prefix="edm-dur-")
    panel, _ = ts.forced_network_panel(6, 260, seed=21)
    panel = np.asarray(panel, np.float32)
    rng = np.random.default_rng(9)
    deltas = [rng.standard_normal((6, 5)).astype(np.float32)
              for _ in range(4)]

    def spawn():
        p = subprocess.Popen([sys.executable, __file__, "--child",
                              state_dir], stdout=subprocess.PIPE,
                             text=True)
        line = p.stdout.readline()
        assert line.startswith("PORT"), f"child never came up: {line!r}"
        return p, int(line.split()[1])

    def oracle_at(k: int):
        return EDM(np.concatenate([panel] + deltas[:k], axis=1),
                   EDMConfig(**CFG))

    p1, port = spawn()
    try:
        _post(port, "register", panel="dur", data=panel.tolist(), **CFG)
        acked = 0
        for d in deltas[:3]:  # acked == durably logged (WAL-then-ack)
            acked = _post(port, "append", panel="dur",
                          delta=d.tolist())["result"]["version"]
        assert acked == 3, acked
    finally:
        os.kill(p1.pid, signal.SIGKILL)  # mid-stream, no goodbye
        p1.wait(timeout=30)

    # Restart: the child recovers from the WAL and serves the same bits
    # a never-crashed session would at version 3.
    p2, port = spawn()
    try:
        o3 = oracle_at(3)
        for pr in DUR_PAIRS:
            r = _post(port, "ccm", panel="dur", lib=pr[0], target=pr[1],
                      E=E_REQ)["result"]
            _bit_match(r, o3.ccm_batch([pr], E=E_REQ)[0],
                       f"post-kill9 ccm{pr}")
        # the recovered WAL keeps accepting appends...
        info = _post(port, "append", panel="dur",
                     delta=deltas[3].tolist())["result"]
        assert info["version"] == 4, info
    finally:
        # ...and SIGTERM drains gracefully: admission stops, queues
        # empty, WALs fsync, exit code 0.
        p2.send_signal(signal.SIGTERM)
        rc = p2.wait(timeout=60)
    assert rc == 0, f"graceful drain exited {rc}, want 0"

    rec = EDMServer.recover(state_dir, autostart=False)
    try:
        assert rec.recovery_report["dur"]["version"] == 4, \
            rec.recovery_report
        o4 = oracle_at(4)
        futs = rec.submit_many(
            "ccm", "dur", [{"lib": l, "target": t, "E": E_REQ}
                           for l, t in DUR_PAIRS])
        while rec.scheduler.drain_once():
            pass
        for pr, f in zip(DUR_PAIRS, futs):
            _bit_match(float(f.result()),
                       o4.ccm_batch([pr], E=E_REQ)[0],
                       f"final recover ccm{pr}")
    finally:
        rec.close()
    print("SERVE DURABILITY OK "
          "(kill -9 -> recover bit-match -> drain exit 0)")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        child(sys.argv[2])
    else:
        main()
        soak()
        durability_smoke()
