"""Batched serving demo: prefill + decode with KV caches.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch llama3-8b]

Loads a (smoke-sized) model, submits a ragged batch of prompts, and
generates greedily + at temperature through the ServeEngine — the same
decode_step the decode_32k / long_500k dry-run cells lower at scale.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, SKIP_CELLS, get_config
from repro.models import transformer as tf
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b",
                    choices=[a for a in ARCHS
                             if "decode_32k" not in SKIP_CELLS.get(a, set())])
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = tf.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, s_max=128)

    rng = np.random.default_rng(7)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (5, 9, 3, 7)]
    print(f"arch={cfg.name}: serving {len(prompts)} ragged prompts, "
          f"max_new={args.max_new}")

    t0 = time.time()
    res = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    toks = sum(len(o) - len(p) for o, p in zip(res.tokens, prompts))
    print(f"greedy: {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. prefill+compile)")
    for p, o in zip(prompts, res.tokens):
        print(f"  prompt[{len(p)}] → {o[len(p):][:10]}...")

    res_t = engine.generate(prompts, max_new=args.max_new, temperature=0.8,
                            seed=3)
    diff = sum(a != b for a, b in zip(res.tokens[0], res_t.tokens[0]))
    print(f"temperature=0.8 differs from greedy at {diff} positions "
          "(sampling live)")


if __name__ == "__main__":
    main()
