"""Resume smoke: run → SIGTERM → resume → assert bit-identical parity.

The CI job for the fault-tolerance layer (docs/ARCHITECTURE.md "Fault
tolerance and resumable runs"): a child process runs a journaled
``EDM.xmap(run_dir=...)`` and is SIGTERM'd mid-run (the child's engine
launches are wrapped to self-deliver the signal after a fixed tile —
deterministic fault injection, no timing races), the parent asserts the
preemption ABI (exit code ``PREEMPTED_EXIT`` = 17, journal status
"preempted"), then a second child resumes and the parent asserts:

* the resumed matrix is **bit-identical** to an uninterrupted run;
* no journaled tile was recomputed (engine launch count = remaining
  tiles only).

Run: ``PYTHONPATH=src python examples/resume_smoke.py [out_dir]``

With ``out_dir``, the run dirs land at ``<out_dir>/run`` and
``<out_dir>/fresh`` instead of tempdirs — CI passes one so it can
upload ``<out_dir>/run/telemetry/events.jsonl`` as a build artifact.
The smoke also asserts the telemetry span log exists, is schema-valid,
and records both attempts of the interrupted run.
"""

import json
import os
import subprocess
import sys
import tempfile

CHILD = """
import os, signal, sys
import numpy as np, jax.numpy as jnp
from repro.core import ccm
from repro.data import timeseries as ts
from repro.edm import EDM, EDMConfig

mode, run_dir = sys.argv[1], sys.argv[2]
panel, _ = ts.forced_network_panel(8, 260, seed=21)
cfg = EDMConfig(E=3, batch_libs=2)   # 4 tiles of 2 library rows

orig = ccm._group_step
n = {"launches": 0}
def wrapped(*a, **k):
    n["launches"] += 1
    if mode == "kill" and n["launches"] == 2:
        os.kill(os.getpid(), signal.SIGTERM)   # preempt mid-run
    return orig(*a, **k)
ccm._group_step = wrapped

rho = EDM(jnp.asarray(panel), cfg).xmap(run_dir=run_dir)
np.save(os.path.join(run_dir, mode + ".npy"), rho)
print("LAUNCHES=" + str(n["launches"]))
"""


def _child(mode: str, run_dir: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    return subprocess.run([sys.executable, "-c", CHILD, mode, run_dir],
                          env=env, capture_output=True, text=True,
                          timeout=600)


def main() -> None:
    from repro.edm import PREEMPTED_EXIT

    if len(sys.argv) > 1:
        base = os.path.abspath(sys.argv[1])
        run = os.path.join(base, "run")
        fresh = os.path.join(base, "fresh")
        os.makedirs(run, exist_ok=True)
        os.makedirs(fresh, exist_ok=True)
    else:
        run = tempfile.mkdtemp(prefix="resume_smoke_")
        fresh = tempfile.mkdtemp(prefix="resume_smoke_ref_")

    kill = _child("kill", run)
    assert kill.returncode == PREEMPTED_EXIT, (
        f"expected exit {PREEMPTED_EXIT}, got {kill.returncode}:\n"
        f"{kill.stderr}")
    with open(os.path.join(run, "report.json")) as f:
        report = json.load(f)
    assert report["status"] == "preempted", report
    done = report["rows_done"]
    assert 0 < done < 8, report
    print(f"preempted cleanly: exit {kill.returncode}, "
          f"{done}/8 rows journaled")

    resume = _child("resume", run)
    assert resume.returncode == 0, resume.stderr
    launches = int(resume.stdout.strip().split("LAUNCHES=")[1])
    assert launches == 4 - done // 2, (
        f"resume recomputed journaled tiles: {launches} launches for "
        f"{8 - done} remaining rows")

    ref = _child("fresh", fresh)
    assert ref.returncode == 0, ref.stderr

    import numpy as np
    a = np.load(os.path.join(run, "resume.npy"))
    b = np.load(os.path.join(fresh, "fresh.npy"))
    assert np.array_equal(a, b), "resumed run is not bit-identical"
    print(f"resumed with {launches} launches (4 fresh), bit-identical")

    from repro.telemetry.schema import validate_events_file
    log = os.path.join(run, "telemetry", "events.jsonl")
    assert os.path.exists(log), "journaled run wrote no telemetry log"
    errs = validate_events_file(log)
    assert not errs, "telemetry log fails schema:\n" + "\n".join(errs)
    with open(log) as f:
        names = [json.loads(line)["name"] for line in f]
    assert "run.start" in names and "run.resume" in names, names
    print(f"telemetry log schema-valid ({len(names)} events, "
          f"both attempts recorded)")
    print("RESUME_SMOKE_OK")


if __name__ == "__main__":
    main()
