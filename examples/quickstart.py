"""Quickstart: the paper's EDM toolkit in five minutes — session API.

Run:  PYTHONPATH=src python examples/quickstart.py

Covers the full kEDM surface on synthetic chaotic systems through ONE
``repro.edm.EDM`` session per dataset: simplex forecasting, optimal
embedding dimension, the S-Map nonlinearity test, and convergent cross
mapping with its convergence-in-library-size causality criterion. Note
what never happens below: no E/tau/Tp re-threading between calls, and no
neighbor table is ever computed twice — the session's plan layer caches
the multi-E kNN state and every method reuses it.
"""

import jax.numpy as jnp
import numpy as np

from repro.data import timeseries as ts
from repro.edm import EDM, EDMConfig


def main():
    print("=" * 64)
    print("1. Simplex projection: forecasting deterministic chaos")
    x = jnp.asarray(ts.logistic_map(500))
    for tp in (1, 2, 5, 10):
        rho = float(EDM(x, EDMConfig(E=2, Tp=tp)).simplex()[0])
        print(f"   horizon Tp={tp:2d}: forecast skill ρ = {rho:.4f}")
    print("   (skill decays with horizon — the signature of chaos)")

    print("=" * 64)
    print("2. Optimal embedding dimension (Lorenz-63, true dim ≈ 3)")
    lz = EDM(ts.lorenz63(800)[0], EDMConfig(E_max=8, tau=2))
    E_opt, rhos = lz.optimal_E()
    for E, r in enumerate(rhos[0], start=1):
        marker = " ← chosen" if E == int(E_opt[0]) else ""
        print(f"   E={E}: ρ={float(r):.4f}{marker}")

    print("=" * 64)
    print("3. S-Map nonlinearity test (ρ rising with θ ⇒ nonlinear)")
    thetas = (0.0, 0.5, 2.0, 8.0)
    sess = EDM(x, EDMConfig(E=2, thetas=thetas))
    for t, r in zip(thetas, sess.smap()[0]):
        print(f"   θ={t:4.1f}: ρ={r:.4f}")

    print("=" * 64)
    print("4. CCM: who causes whom? (X forces Y, not vice versa)")
    xs, ys = ts.coupled_logistic(900, b_xy=0.0, b_yx=0.32, seed=3)
    from repro.edm import Dataset
    pair = EDM(Dataset(np.stack([xs, ys]), names=["X", "Y"]),
               EDMConfig(E=2, Tp_cross=0))
    sizes = (60, 200, 500, 880)
    x_from_y = pair.ccm("Y", "X", lib_sizes=sizes)
    y_from_x = pair.ccm("X", "Y", lib_sizes=sizes)
    print("   lib size | X̂|M_Y (X→Y evidence) | Ŷ|M_X (Y→X evidence)")
    for s, a, b in zip(sizes, x_from_y, y_from_x):
        print(f"   {s:8d} | {a:20.4f} | {b:19.4f}")
    print("   (left column converges high: X causes Y; right stays low)")
    # a score alone is not evidence — gate it on a surrogate ensemble
    # (50 shuffled nulls, cross-mapped as ONE batched program)
    sig = pair.surrogate_test("Y", "X", num_surrogates=50, seed=0)
    rev = pair.surrogate_test("X", "Y", num_surrogates=50, seed=0)
    print(f"   vs 50 shuffle nulls: X→Y p = {sig.pvalue:.3f}, "
          f"Y→X p = {rev.pvalue:.3f}")
    print("   (a shuffle null rejects 'no dependence at all'; the "
          "direction verdict is the convergence asymmetry above)")

    print("=" * 64)
    print("5. One session, every method — state shared, plans visible")
    panel, _ = ts.forced_network_panel(6, 400, n_drivers=1, seed=7)
    sess = EDM(panel, EDMConfig(E_max=5))
    print("   plan:", sess.plan("optimal_E").describe())
    E_opt, _ = sess.optimal_E()
    print(f"   optimal E per series: {E_opt.tolist()}")
    print("   plan:", sess.plan("xmap").describe())
    rho = sess.xmap()  # reuses the kNN master built by optimal_E
    print(f"   cross-map matrix mean skill: {rho.mean():.3f}  "
          f"(stats: {dict(sess.stats)})")


if __name__ == "__main__":
    main()
