"""Quickstart: the paper's EDM toolkit in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py

Covers the full kEDM surface on synthetic chaotic systems:
simplex forecasting, optimal embedding dimension, the S-Map
nonlinearity test, and convergent cross mapping with its
convergence-in-library-size causality criterion.
"""

import jax.numpy as jnp
import numpy as np

from repro import core
from repro.data import timeseries as ts


def main():
    print("=" * 64)
    print("1. Simplex projection: forecasting deterministic chaos")
    x = jnp.asarray(ts.logistic_map(500))
    for tp in (1, 2, 5, 10):
        rho = float(core.simplex_skill(x, E=2, Tp=tp))
        print(f"   horizon Tp={tp:2d}: forecast skill ρ = {rho:.4f}")
    print("   (skill decays with horizon — the signature of chaos)")

    print("=" * 64)
    print("2. Optimal embedding dimension (Lorenz-63, true dim ≈ 3)")
    lz = jnp.asarray(ts.lorenz63(800)[0])
    best, rhos = core.optimal_E(lz, E_max=8, tau=2)
    for E, r in enumerate(np.asarray(rhos), start=1):
        marker = " ← chosen" if E == best else ""
        print(f"   E={E}: ρ={float(r):.4f}{marker}")

    print("=" * 64)
    print("3. S-Map nonlinearity test (ρ rising with θ ⇒ nonlinear)")
    thetas = (0.0, 0.5, 2.0, 8.0)
    rhos = np.asarray(core.nonlinearity_test(x, E=2, thetas=thetas))
    for t, r in zip(thetas, rhos):
        print(f"   θ={t:4.1f}: ρ={r:.4f}")

    print("=" * 64)
    print("4. CCM: who causes whom? (X forces Y, not vice versa)")
    xs, ys = ts.coupled_logistic(900, b_xy=0.0, b_yx=0.32, seed=3)
    sizes = (60, 200, 500, 880)
    x_from_y = np.asarray(core.cross_map(jnp.asarray(ys), jnp.asarray(xs),
                                         E=2, lib_sizes=sizes))
    y_from_x = np.asarray(core.cross_map(jnp.asarray(xs), jnp.asarray(ys),
                                         E=2, lib_sizes=sizes))
    print("   lib size | X̂|M_Y (X→Y evidence) | Ŷ|M_X (Y→X evidence)")
    for s, a, b in zip(sizes, x_from_y, y_from_x):
        print(f"   {s:8d} | {a:20.4f} | {b:19.4f}")
    print("   (left column converges high: X causes Y; right stays low)")


if __name__ == "__main__":
    main()
