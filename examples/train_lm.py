"""End-to-end training driver: a small LM for a few hundred steps.

Run:  PYTHONPATH=src python examples/train_lm.py                # ~15M params
      PYTHONPATH=src python examples/train_lm.py --preset 100m  # real HW
      (re-run the same command after a kill: it resumes from the latest
       checkpoint automatically)

Exercises the full production loop on synthetic structured data:
deterministic sharded pipeline, AdamW + warmup-cosine, microbatch
accumulation, checkpoint/auto-resume, SIGTERM-safe preemption,
straggler flagging.
"""

import argparse
import dataclasses

from repro.configs import TrainConfig, get_config
from repro.data.pipeline import TokenPipeline
from repro.training import train

PRESETS = {
    # ~15M params: a few hundred steps in minutes on one CPU core
    "15m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                d_ff=1024, vocab_size=2048, batch=8, seq=256),
    # ~124M: the "train ~100M for a few hundred steps" configuration —
    # sized for a real accelerator, runs (slowly) on CPU too
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=32768, batch=32, seq=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="15m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("llama3-8b", smoke=True),  # llama-family block stack
        name=f"lm-{args.preset}",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], d_head=0,
        dtype="float32", param_dtype="float32",
    )
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({p['n_layers']}L × {p['d_model']}d, vocab {p['vocab_size']})")

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                       total_steps=args.steps, microbatch=args.microbatch,
                       weight_decay=0.01)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=p["batch"],
                         seq_len=p["seq"], seed=0)
    state, history = train(cfg, tcfg, pipe, workdir=args.workdir,
                           num_steps=args.steps, ckpt_every=50, log_every=10)
    first = sum(h["loss"] for h in history[:5]) / max(len(history[:5]), 1)
    last = sum(h["loss"] for h in history[-5:]) / max(len(history[-5:]), 1)
    print(f"loss: {first:.3f} → {last:.3f} over {len(history)} steps "
          f"({'LEARNING' if last < first else 'check config'})")


if __name__ == "__main__":
    main()
