"""All-pairs causal mapping, zebrafish-brain style (paper's headline use).

Run:  PYTHONPATH=src python examples/ccm_brain.py [--series 24] [--steps 600]
      PYTHONPATH=src python examples/ccm_brain.py --sharded --devices 8

Builds a panel of coupled "neurons" where a few driver units force the
rest, then runs the whole workload through ONE ``repro.edm.EDM`` session:
per-series optimal embedding dimension, and the full N×N cross-map skill
matrix (grouped by E, exactly kEDM §3.4) reusing the optimal-E pass's
kNN master tables. ``--sharded`` hands the SAME session a device mesh —
the plan layer then routes the matrix through the E-grouped
zero-collective shard_map engine instead, no other code change.
"""

import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--series", type=int, default=24)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--drivers", type=int, default=2)
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()

    if args.sharded:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import numpy as np

    from repro.data import timeseries as ts
    from repro.edm import EDM, EDMConfig

    panel_np, adj = ts.forced_network_panel(
        args.series, args.steps, n_drivers=args.drivers, coupling=0.1,
        seed=11)
    N = args.series

    print(f"panel: {N} series × {args.steps} steps, "
          f"{args.drivers} hidden drivers")

    cfg = EDMConfig(E_max=5)
    if args.sharded:
        from repro.distributed import make_ccm_mesh
        cfg = cfg.replace(mesh=make_ccm_mesh((args.devices // 2, 2),
                                             ("data", "model")))
    sess = EDM(panel_np, cfg)

    t0 = time.time()
    E_opt, _ = sess.optimal_E()
    # CCM needs E ≥ 2: an E=1 'manifold' is a line and cross-map skill
    # from it is degenerate (biases the asymmetry statistic)
    E_opt = np.maximum(E_opt, 2)
    print(f"optimal-E search [{sess.plan('optimal_E').placement}]: "
          f"{time.time() - t0:.1f}s, E histogram: {np.bincount(E_opt)[1:]}")

    t0 = time.time()
    print(f"xmap plan: {sess.plan('xmap').describe()}")
    rho = sess.xmap(E_opt=E_opt)
    where = (f"sharded, {args.devices} devices, E-grouped"
             if args.sharded else "local, cached-kNN E-groups")
    print(f"CCM matrix ({where}): {time.time() - t0:.1f}s")

    # driver detection: evidence that unit d forces unit j is rho[j, d]
    # (cross-map the driver from the follower's manifold). The standard
    # CCM statistic is the ASYMMETRY rho[j, d] − rho[d, j]: common-drive
    # synchrony among followers is symmetric and cancels.
    drive_score = (rho - rho.T).mean(axis=0)
    ranked = np.argsort(-drive_score)
    print("units ranked by outgoing causal influence "
          f"(true drivers: {list(range(args.drivers))}):")
    for r, u in enumerate(ranked[: args.drivers + 3]):
        mark = " ← true driver" if u < args.drivers else ""
        print(f"  #{r + 1}: unit {u:3d} score {drive_score[u]:.3f}{mark}")
    top = args.drivers + 2  # common-drive confounds cost a rank or two
    hits = sum(1 for u in ranked[:top] if u < args.drivers)
    print(f"drivers recovered in top-{top}: {hits}/{args.drivers} "
          "(follower-follower links from shared forcing are a known CCM "
          "confound; the asymmetry statistic bounds, not eliminates, them)")

    # The whole-brain study gates every score on convergence + surrogate
    # significance; do the same for the strongest detected link. The 40
    # shuffled nulls run as ONE batched curve-grid program per test.
    d = int(ranked[0])
    follower = int(np.argmax(rho[:, d] - np.eye(N)[d] * 2))
    t0 = time.time()
    sig = sess.surrogate_test(follower, d, num_surrogates=40,
                              lib_sizes=(args.steps // 8, args.steps // 2,
                                         args.steps - 10), seed=0)
    print(f"link unit{d}→unit{follower}: convergence curve "
          f"{np.round(sig.rho, 3).tolist()}, surrogate p per size "
          f"{np.round(sig.pvalue, 3).tolist()} "
          f"({time.time() - t0:.1f}s, 40 shuffle nulls)")
    return 0 if hits == args.drivers else 1


if __name__ == "__main__":
    sys.exit(main())
