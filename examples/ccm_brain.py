"""All-pairs causal mapping, zebrafish-brain style (paper's headline use).

Run:  PYTHONPATH=src python examples/ccm_brain.py [--series 24] [--steps 600]
      PYTHONPATH=src python examples/ccm_brain.py --sharded --devices 8

Builds a panel of coupled "neurons" where a few driver units force the
rest, determines each series' optimal embedding dimension (simplex),
computes the full N×N cross-map skill matrix (grouped by E, exactly
kEDM §3.4), and reports how well the known driver topology is recovered.
``--sharded`` re-runs the matrix through the shard_map engine on emulated
devices — the same code path the 512-chip dry-run lowers.
"""

import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--series", type=int, default=24)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--drivers", type=int, default=2)
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()

    if args.sharded:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import core
    from repro.data import timeseries as ts

    panel_np, adj = ts.forced_network_panel(
        args.series, args.steps, n_drivers=args.drivers, coupling=0.1,
        seed=11)
    panel = jnp.asarray(panel_np)
    N = args.series

    print(f"panel: {N} series × {args.steps} steps, "
          f"{args.drivers} hidden drivers")

    t0 = time.time()
    E_opt, _ = core.optimal_E_batch(panel, E_max=5)
    # CCM needs E ≥ 2: an E=1 'manifold' is a line and cross-map skill
    # from it is degenerate (biases the asymmetry statistic)
    E_opt = np.maximum(np.asarray(E_opt), 2)
    print(f"optimal-E search: {time.time() - t0:.1f}s, "
          f"E histogram: {np.bincount(E_opt)[1:]}")

    t0 = time.time()
    if args.sharded:
        from repro.distributed import make_ccm_mesh, sharded_ccm_matrix
        mesh = make_ccm_mesh((args.devices // 2, 2), ("data", "model"))
        E = int(np.median(np.asarray(E_opt)))
        rho = np.asarray(sharded_ccm_matrix(panel, panel, E=E, mesh=mesh))
        print(f"sharded CCM matrix ({args.devices} devices, fixed E={E}): "
              f"{time.time() - t0:.1f}s")
    else:
        rho = core.ccm_matrix(panel, E_opt)
        print(f"CCM matrix (grouped by optimal E): {time.time() - t0:.1f}s")

    # driver detection: evidence that unit d forces unit j is rho[j, d]
    # (cross-map the driver from the follower's manifold). The standard
    # CCM statistic is the ASYMMETRY rho[j, d] − rho[d, j]: common-drive
    # synchrony among followers is symmetric and cancels.
    drive_score = (rho - rho.T).mean(axis=0)
    ranked = np.argsort(-drive_score)
    print("units ranked by outgoing causal influence "
          f"(true drivers: {list(range(args.drivers))}):")
    for r, u in enumerate(ranked[: args.drivers + 3]):
        mark = " ← true driver" if u < args.drivers else ""
        print(f"  #{r + 1}: unit {u:3d} score {drive_score[u]:.3f}{mark}")
    top = args.drivers + 2  # common-drive confounds cost a rank or two
    hits = sum(1 for u in ranked[:top] if u < args.drivers)
    print(f"drivers recovered in top-{top}: {hits}/{args.drivers} "
          "(follower-follower links from shared forcing are a known CCM "
          "confound; the asymmetry statistic bounds, not eliminates, them)")
    return 0 if hits == args.drivers else 1


if __name__ == "__main__":
    sys.exit(main())
