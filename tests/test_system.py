"""End-to-end behaviour of the paper's system.

The full kEDM pipeline — per-series optimal-E, all-kNN with the fused
kernels (interpret mode), batched grouped lookups, fused-ρ CCM — run as
one workflow on a synthetic causal system, validating the paper's
qualitative claims end-to-end rather than per-module.
"""

import numpy as np
import jax.numpy as jnp

from repro import core
from repro.data import timeseries as ts
from repro.kernels import ops


def test_full_pipeline_kernel_path_matches_ref():
    """The whole pipeline through the Pallas kernels (interpret mode)
    reproduces the ref-path CCM skills: the portability contract."""
    x, y = ts.coupled_logistic(400, b_xy=0.0, b_yx=0.3, seed=8)
    E, tau, k = 3, 1, 4
    off = (E - 1) * tau
    xs = jnp.asarray(x)
    Y = jnp.asarray(np.stack([y, x]))

    rhos = {}
    for impl in ("ref", "interpret"):
        D = ops.pairwise_distances(xs, E=E, tau=tau, impl=impl)
        d, i = ops.topk_select(D, k=k, impl=impl)
        w = ops.make_weights(d)
        rhos[impl] = np.asarray(
            ops.lookup_rho(Y, i, w, offset=off, impl=impl))
    np.testing.assert_allclose(rhos["ref"], rhos["interpret"],
                               rtol=1e-4, atol=1e-4)
    assert rhos["ref"][1] > 0.95  # self-map sanity (library vs itself)


def test_end_to_end_causal_discovery():
    """optimal-E → grouped CCM → direction recovery, one shot."""
    x, y = ts.coupled_logistic(700, b_xy=0.0, b_yx=0.32, seed=4)
    panel = jnp.asarray(np.stack([x, y]))
    E_opt, _ = core.optimal_E_batch(panel, E_max=4)
    E_opt = np.maximum(np.asarray(E_opt), 2)
    rho = core.ccm_matrix(panel, E_opt)
    # x forces y ⇒ cross-mapping x from y's manifold (rho[1,0]) beats
    # the reverse (rho[0,1])
    assert rho[1, 0] > rho[0, 1] + 0.1, rho
    assert rho[1, 0] > 0.85


def test_tp_horizon_pipeline():
    """Tp-ahead cross-map prediction stays causal and consistent."""
    x, y = ts.coupled_logistic(500, b_xy=0.0, b_yx=0.3, seed=2)
    r0 = float(core.cross_map(jnp.asarray(y), jnp.asarray(x), E=2, Tp=0))
    r2 = float(core.cross_map(jnp.asarray(y), jnp.asarray(x), E=2, Tp=2))
    assert r0 > 0.8
    assert r2 < r0 + 0.05  # horizon can't *help*
