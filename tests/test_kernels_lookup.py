"""Pallas batched-lookup kernel (+ fused Pearson ρ) vs jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def _tables(rng, L, E, tau, k):
    x = jnp.asarray(rng.normal(size=L).astype(np.float32))
    D = ref.pairwise_distances(x, E=E, tau=tau)
    d, i = ref.topk_select(D, k=k)
    return i, ref.make_weights(d)


CASES = [
    # (N, L, E, tau, k, block)
    (1, 64, 2, 1, 3, (16, 8)),
    (8, 137, 4, 2, 5, (16, 8)),
    (23, 137, 4, 2, 5, (16, 8)),
    (17, 100, 7, 1, 8, (32, 16)),
    (5, 257, 20, 2, 21, (64, 8)),
]


@pytest.mark.parametrize("N,L,E,tau,k,block", CASES)
def test_lookup_matches_ref(rng, N, L, E, tau, k, block):
    idx, w = _tables(rng, L, E, tau, k)
    Y = jnp.asarray(rng.normal(size=(N, L)).astype(np.float32))
    off = (E - 1) * tau
    want = ref.lookup(Y, idx, w, offset=off)
    got = ops.lookup(Y, idx, w, offset=off, impl="interpret", block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("N,L,E,tau,k,block", CASES)
def test_lookup_rho_matches_ref(rng, N, L, E, tau, k, block):
    idx, w = _tables(rng, L, E, tau, k)
    Y = jnp.asarray(rng.normal(size=(N, L)).astype(np.float32))
    off = (E - 1) * tau
    want = ref.lookup_rho(Y, idx, w, offset=off)
    got = ops.lookup_rho(Y, idx, w, offset=off, impl="interpret", block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_lookup_rho_fused_equals_two_step(rng):
    """Fused path == lookup → pearson composition (the paper's §3.4 claim)."""
    idx, w = _tables(rng, 150, 5, 1, 6)
    Y = jnp.asarray(rng.normal(size=(11, 150)).astype(np.float32))
    off = 4
    yhat = ops.lookup(Y, idx, w, offset=off, impl="interpret", block=(32, 8))
    Lp = idx.shape[0]
    truth = np.asarray(Y)[:, off:off + Lp]
    want = ref.pearson_rows(jnp.asarray(yhat), jnp.asarray(truth))
    got = ops.lookup_rho(Y, idx, w, offset=off, impl="interpret", block=(32, 8))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_lookup_rho_constant_target(rng):
    """Zero-variance target → ρ defined as 0, not NaN."""
    idx, w = _tables(rng, 80, 3, 1, 4)
    Y = jnp.ones((3, 80), jnp.float32)
    got = ops.lookup_rho(Y, idx, w, offset=2, impl="interpret", block=(16, 8))
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), 0.0, atol=1e-6)


def test_lookup_perfect_self_prediction(rng):
    """Looking up the library itself with its own tables ≈ the series
    (weights concentrate on near-identical states for smooth series)."""
    t = np.linspace(0, 40 * np.pi, 800, dtype=np.float32)
    x = jnp.asarray(np.sin(t))
    E, tau, k = 3, 1, 4
    D = ref.pairwise_distances(x, E=E, tau=tau)
    d, i = ref.topk_select(D, k=k)
    w = ref.make_weights(d)
    off = (E - 1) * tau
    got = ops.lookup(x[None, :], i, w, offset=off, impl="interpret",
                     block=(64, 8))[0]
    truth = np.asarray(x)[off:off + i.shape[0]]
    rho = np.corrcoef(np.asarray(got), truth)[0, 1]
    assert rho > 0.999
