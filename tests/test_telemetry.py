"""Unified telemetry layer (ISSUE 7): span stack + sinks, the metrics
registry and its Prometheus export, artifact schema validation, the
configurable straggler threshold, resume lineage in run reports, and the
end-to-end acceptance path (journaled xmap → JSONL span log + metrics in
report.json + run inspector)."""

import json
import os
import signal

import numpy as np
import jax.numpy as jnp
import pytest

from repro import telemetry
from repro.core import ccm
from repro.data import timeseries as ts
from repro.distributed.fault import StragglerMonitor
from repro.edm import EDM, EDMConfig, PREEMPTED_EXIT, run_key
from repro.edm import inspect as edm_inspect
from repro.telemetry import schema


def _panel(n=6, steps=220, seed=3):
    panel, _ = ts.forced_network_panel(n, steps, seed=seed)
    return jnp.asarray(panel)


# ------------------------------------------------------- spans + sinks


def test_span_disabled_is_shared_noop():
    """The default path: no sinks, not enabled → the SAME no-op object
    every call (no per-call allocation), and events vanish silently.
    Doubles as the suite's sink-hygiene guard: a failure here means an
    earlier test leaked a sink (e.g. a MatrixRunner never closed)."""
    assert not telemetry.active(), \
        f"leaked sinks: {telemetry._sinks} enabled: {telemetry._enabled}"
    s1, s2 = telemetry.span("a", x=1), telemetry.span("b")
    assert s1 is s2
    with s1:
        assert telemetry.current_span_path() == ""
    telemetry.event("nobody.listening", x=1)  # must not raise


def test_span_nesting_builds_paths_and_durations():
    with telemetry.record() as rec:
        with telemetry.span("outer", a=1) as sp:
            assert telemetry.current_span_path() == "outer"
            with telemetry.span("inner"):
                assert telemetry.current_span_path() == "outer/inner"
                telemetry.event("tick", n=3)
            sp.annotate(b=2)
        assert telemetry.current_span_path() == ""
    inner, outer = rec.spans("inner")[0], rec.spans("outer")[0]
    assert inner["path"] == "outer/inner" and outer["path"] == "outer"
    assert inner["dur_s"] >= 0 and outer["dur_s"] >= inner["dur_s"]
    assert outer["attrs"] == {"a": 1, "b": 2}
    ev = rec.events_named("tick")[0]
    assert ev["path"] == "outer/inner" and ev["attrs"] == {"n": 3}
    # every record is schema-valid as emitted
    for e in rec.events:
        assert schema.validate_event(e) == []


def test_enable_activates_without_sinks():
    telemetry.enable()
    try:
        assert telemetry.active()
        assert telemetry.span("x") is not telemetry.span("x")
    finally:
        telemetry.disable()
    assert not telemetry.active()


def test_recorder_counter_deltas_ignore_prior_history():
    telemetry.counter("t_prior").inc(7)
    with telemetry.record() as rec:
        telemetry.counter("t_prior").inc(2)
        telemetry.counter("t_fresh").inc()
    assert rec.counter_delta("t_prior") == 2
    assert rec.counter_delta("t_fresh") == 1
    assert rec.counter_delta("t_never_touched") == 0


def test_jsonl_sink_writes_schema_valid_lines(tmp_path):
    path = tmp_path / "sub" / "events.jsonl"  # parent dir auto-created
    sink = telemetry.JsonlSink(str(path))
    telemetry.add_sink(sink)
    try:
        with telemetry.span("s", shape=(3, 4)):
            telemetry.event("e", arr=np.float32(1.5))  # non-JSON type
    finally:
        telemetry.remove_sink(sink)
        sink.close()
    assert schema.validate_events_file(str(path)) == []
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert [ev["name"] for ev in lines] == ["e", "s"]  # event, then span end
    assert lines[0]["attrs"]["arr"] == 1.5


# ------------------------------------------------------------- metrics


def test_metric_registry_kinds_and_type_guard():
    c = telemetry.counter("t_kinds_c")
    c.inc()
    c.inc(3)
    assert c.value == 4 and telemetry.counter("t_kinds_c") is c
    g = telemetry.gauge("t_kinds_g")
    g.set(2)
    g.set(7.5)
    assert g.value == 7.5
    h = telemetry.histogram("t_kinds_h", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 99.0):
        h.observe(v)
    assert h.counts == [1, 1, 1] and h.count == 3
    assert h.sum == pytest.approx(99.55)
    with pytest.raises(TypeError):
        telemetry.gauge("t_kinds_c")  # already a Counter


def test_render_prom_format():
    telemetry.counter("t_prom_total").inc(5)
    telemetry.gauge("t_prom_g").set(2.5)
    h = telemetry.histogram("t_prom_h", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(99.0)
    prom = telemetry.render_prom()
    assert "# TYPE t_prom_total counter\nt_prom_total 5" in prom
    assert "# TYPE t_prom_g gauge\nt_prom_g 2.5" in prom
    # histogram buckets are CUMULATIVE and end at +Inf == _count
    assert 't_prom_h_bucket{le="0.1"} 1' in prom
    assert 't_prom_h_bucket{le="1"} 2' in prom
    assert 't_prom_h_bucket{le="+Inf"} 3' in prom
    assert "t_prom_h_count 3" in prom
    snap = telemetry.metrics_snapshot()
    assert snap["t_prom_total"] == 5
    assert snap["t_prom_h"]["count"] == 3


# ----------------------------------------------------- schema validation


def test_schema_rejects_malformed_records():
    assert schema.validate_event({"type": "event", "name": "x",
                                  "ts": 1.0}) == []
    assert schema.validate_event({"type": "span", "name": "x", "ts": 1.0,
                                  "dur_s": 0.1, "path": "a/x"}) == []
    assert schema.validate_event([1, 2])  # not an object
    assert schema.validate_event({"type": "bogus", "name": "x", "ts": 0})
    assert schema.validate_event({"type": "event", "name": "", "ts": 0})
    assert schema.validate_event({"type": "span", "name": "x", "ts": 0,
                                  "dur_s": -1, "path": "x"})
    assert schema.validate_event({"type": "event", "name": "x", "ts": 0,
                                  "attrs": [1]})


def test_schema_bench_and_cli(tmp_path, capsys):
    good = {"bench": "ccm", "rows": [
        {"name": "r", "us_per_call": 12.5, "derived": "8pairs_per_s"}]}
    assert schema.validate_bench(good) == []
    assert schema.validate_bench({"bench": "", "rows": []})
    assert schema.validate_bench({"bench": "b", "rows": [
        {"name": "r", "us_per_call": 0}]})
    bench = tmp_path / "BENCH_x.json"
    bench.write_text(json.dumps(good))
    events = tmp_path / "events.jsonl"
    events.write_text(json.dumps(
        {"type": "event", "name": "e", "ts": 1.0}) + "\n")
    assert schema.main([str(bench), str(events)]) == 0
    assert "schema OK: 2 artifact(s)" in capsys.readouterr().out
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"type": "nope"}\nnot json\n')
    assert schema.main([str(bad)]) == 1
    assert schema.main([]) == 2


# ------------------------------------------------- straggler threshold


def test_straggler_monitor_synthetic_clock_and_threshold():
    """Deterministic regression: replay a timing sequence through an
    injected clock — six nominal 1s launches then a 4× outlier. The
    outlier flips the flag at threshold 3, not at threshold 8, and the
    flag publishes both the counter and the straggler.flag event."""
    t = {"now": 0.0}

    def clock():
        return t["now"]

    def replay(mon):
        for step in range(6):
            mon.start()
            t["now"] += 1.0
            assert mon.stop(step) is False
        mon.start()
        t["now"] += 4.0
        return mon.stop(6)

    with telemetry.record() as rec:
        mon = StragglerMonitor(threshold=3.0, window=10, clock=clock)
        assert replay(mon) is True
    assert rec.counter_delta("edm_stragglers_flagged") == 1
    ev = rec.events_named("straggler.flag")[0]["attrs"]
    assert ev["step"] == 6 and ev["threshold"] == 3.0
    assert ev["seconds"] == pytest.approx(4.0)
    assert mon.report()["flagged"][0]["rolling_median_s"] == 1.0

    lax = StragglerMonitor(threshold=8.0, window=10, clock=clock)
    assert replay(lax) is False
    assert lax.report()["flagged"] == []


def test_straggler_threshold_config_validation_and_keying():
    with pytest.raises(ValueError):
        EDMConfig(straggler_threshold=0.0)
    with pytest.raises(ValueError):
        StragglerMonitor(threshold=-1.0)
    # a perf-only knob: changing it must NOT change the resume key
    X = np.asarray(_panel())
    sig = ("xmap", "simplex", None, ((3, 6),))
    assert run_key(X, EDMConfig(E=3, straggler_threshold=9.0), sig) \
        == run_key(X, EDMConfig(E=3), sig)


# --------------------------------------- end-to-end acceptance (ISSUE 7)


def test_e2e_journaled_run_produces_all_telemetry_artifacts(tmp_path):
    """The acceptance path in one test: a journaled xmap emits the JSONL
    span log, folds Prometheus metrics (pairs counter + launch latency
    histogram) into report.json, counts every pair exactly once, and the
    run inspector renders the result from artifacts alone."""
    X = _panel()
    run = tmp_path / "run"
    cfg = EDMConfig(E=3, batch_libs=2, straggler_threshold=5.0)
    with telemetry.record() as rec:
        got = EDM(X, cfg).xmap(run_dir=str(run))
    assert got.shape == (6, 6)
    assert rec.counter_delta("edm_pairs_total") == 36
    assert rec.counter_delta("edm_runs_started") == 1
    assert rec.spans("session.xmap") and rec.spans("engine.drive")
    assert rec.events_named("run.start") and rec.events_named("run.complete")

    log = run / "telemetry" / "events.jsonl"
    assert log.exists()
    assert schema.validate_events_file(str(log)) == []
    names = [json.loads(line)["name"]
             for line in log.read_text().splitlines()]
    assert "run.start" in names and "run.complete" in names
    assert "engine.drive" in names  # spans land in the on-disk log too

    rep = json.loads((run / "report.json").read_text())
    assert rep["status"] == "complete"
    assert rep["rows_done"] == rep["rows_total"] == 6
    assert rep["pairs_done"] == 36 and rep["pairs_per_s"] > 0
    assert rep["tiles_committed"] == 3  # ceil(6/2)
    assert rep["stragglers"]["threshold"] == 5.0  # config threaded through
    prom = rep["metrics_prom"]
    assert "edm_pairs_total" in prom
    assert "edm_launch_latency_seconds_bucket" in prom
    assert "edm_launch_latency_seconds_count" in prom

    info = edm_inspect.inspect_run(str(run))
    assert info["status"] == "complete"
    assert info["rows_done"] == 6
    assert info["pairs_per_s"] == rep["pairs_per_s"]
    assert info["heartbeat_age_s"] is not None
    text = edm_inspect.format_summary(info)
    assert "status: complete" in text and "rows: 6/6" in text
    assert "run.complete" in text
    assert edm_inspect.main([str(run)]) == 0
    assert edm_inspect.main([str(tmp_path / "nope")]) == 2


def test_inspector_tolerates_partial_run_dir(tmp_path):
    info = edm_inspect.inspect_run(str(tmp_path))
    assert info["status"] is None and info["rows_done"] is None
    assert "no run.json" in edm_inspect.format_summary(info)


def test_resume_lineage_in_manifest_and_report(tmp_path, monkeypatch):
    """Kill → resume: the manifest accumulates one attempt record per
    process, the final report names the prior attempt's run_id, keeps
    cumulative wall time across attempts, and the telemetry log holds
    both lifecycle events."""
    X = _panel()
    cfg = EDMConfig(E=3, batch_libs=2)
    ref = EDM(X, cfg).xmap()
    run = tmp_path / "run"
    orig = ccm._group_step
    n = {"launches": 0}

    def sigterm_mid_run(*a, **k):
        n["launches"] += 1
        if n["launches"] == 2:
            os.kill(os.getpid(), signal.SIGTERM)
        return orig(*a, **k)

    monkeypatch.setattr(ccm, "_group_step", sigterm_mid_run)
    with pytest.raises(SystemExit) as exc:
        EDM(X, cfg).xmap(run_dir=str(run))
    assert exc.value.code == PREEMPTED_EXIT
    manifest = json.loads((run / "run.json").read_text())
    assert len(manifest["attempts"]) == 1
    first = manifest["attempts"][0]
    assert first["status"] == "preempted" and first["rows_resumed"] == 0
    rep1 = json.loads((run / "report.json").read_text())
    assert rep1["status"] == "preempted" and rep1["prior_run_ids"] == []

    monkeypatch.setattr(ccm, "_group_step", orig)
    got = EDM(X, cfg).xmap(run_dir=str(run))
    np.testing.assert_array_equal(ref, got)
    manifest = json.loads((run / "run.json").read_text())
    assert len(manifest["attempts"]) == 2
    assert manifest["attempts"][0] == first  # history is append-only
    second = manifest["attempts"][1]
    assert second["status"] == "complete"
    assert second["run_id"] != first["run_id"]
    assert second["rows_resumed"] == rep1["rows_done"] > 0

    rep = json.loads((run / "report.json").read_text())
    assert rep["status"] == "complete"
    assert rep["prior_run_ids"] == [first["run_id"]]
    assert rep["run_id"] == second["run_id"]
    assert rep["rows_resumed"] + rep["rows_this_attempt"] == 6
    assert rep["cumulative_elapsed_s"] >= rep["elapsed_s"]
    assert rep["cumulative_elapsed_s"] == pytest.approx(
        first["elapsed_s"] + rep["elapsed_s"], abs=1e-6)

    names = [json.loads(line)["name"] for line in
             (run / "telemetry" / "events.jsonl").read_text().splitlines()]
    assert "run.start" in names and "run.resume" in names
    # the inspector surfaces the lineage
    text = edm_inspect.format_summary(edm_inspect.inspect_run(str(run)))
    assert "attempts: 2" in text
