"""Training-loop behaviour: convergence, checkpoint-restart continuity,
preemption, microbatching equivalence, compressed-gradient training,
serving engine end-to-end."""

import dataclasses
import os
import signal

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import TrainConfig, get_config
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as tf
from repro.serving import ServeEngine
from repro.training import make_train_step, train


def _tiny():
    cfg = dataclasses.replace(
        get_config("llama3-8b", smoke=True), vocab_size=64)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                       weight_decay=0.01, seed=0)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=4, seq_len=32,
                         seed=1)
    return cfg, tcfg, pipe


def test_loss_decreases(tmp_path):
    cfg, tcfg, pipe = _tiny()
    _, hist = train(cfg, tcfg, pipe, workdir=str(tmp_path), num_steps=40,
                    ckpt_every=100, verbose=False, handle_preemption=False)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, f"no learning: {first:.3f} → {last:.3f}"


def test_checkpoint_restart_continuity(tmp_path):
    """Kill at step 20, restart, and land bitwise-equal to an unbroken run
    (pure-function data pipeline + checkpointed state)."""
    cfg, tcfg, pipe = _tiny()

    state_a, _ = train(cfg, tcfg, pipe, workdir=str(tmp_path / "a"),
                       num_steps=30, ckpt_every=100, verbose=False,
                       handle_preemption=False)

    train(cfg, tcfg, pipe, workdir=str(tmp_path / "b"), num_steps=20,
          ckpt_every=10, verbose=False, handle_preemption=False)
    state_b, _ = train(cfg, tcfg, pipe, workdir=str(tmp_path / "b"),
                       num_steps=30, ckpt_every=10, verbose=False,
                       handle_preemption=False)

    for pa, pb in zip(jax.tree.leaves(state_a["params"]),
                      jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   rtol=1e-5, atol=1e-6)


def test_preemption_checkpoint_and_clean_exit(tmp_path):
    cfg, tcfg, pipe = _tiny()

    class Boom:
        def __init__(self):
            self.n = 0

        def global_batch(self, step):
            self.n += 1
            if self.n == 5:
                os.kill(os.getpid(), signal.SIGTERM)  # simulate preemption
            return pipe.global_batch(step)

    _, hist = train(cfg, tcfg, Boom(), workdir=str(tmp_path), num_steps=50,
                    ckpt_every=100, verbose=False, handle_preemption=True)
    assert len(hist) <= 6, "loop must stop quickly after SIGTERM"
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    assert mgr.latest_step() is not None, "preemption must checkpoint"


def test_microbatch_equivalence():
    """grad-accumulated step == single-batch step (same loss, ~same params)."""
    cfg, _, pipe = _tiny()
    batch = jax.tree.map(jnp.asarray, pipe.global_batch(0))

    outs = {}
    for micro in (0, 2):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0,
                           total_steps=10, microbatch=micro, seed=0)
        init_state, step, _ = make_train_step(cfg, tcfg)
        state = init_state(jax.random.key(0))
        state, metrics = jax.jit(step)(state, batch)
        outs[micro] = (metrics["loss"], state["params"])
    np.testing.assert_allclose(float(outs[0][0]), float(outs[2][0]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[2][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_compressed_training_converges(tmp_path):
    cfg, tcfg, pipe = _tiny()
    tcfg = dataclasses.replace(tcfg, grad_compression="int8")
    _, hist = train(cfg, tcfg, pipe, workdir=str(tmp_path), num_steps=40,
                    ckpt_every=100, verbose=False, handle_preemption=False)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, f"int8-EF training broken: {first} → {last}"


def test_adamw8bit_training_converges(tmp_path):
    cfg, tcfg, pipe = _tiny()
    tcfg = dataclasses.replace(tcfg, optimizer="adamw8bit")
    _, hist = train(cfg, tcfg, pipe, workdir=str(tmp_path), num_steps=40,
                    ckpt_every=100, verbose=False, handle_preemption=False)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, f"8-bit Adam training broken: {first} → {last}"


def test_serve_engine_generates(rng):
    cfg = get_config("llama3-8b", smoke=True)
    params = tf.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, s_max=64)
    prompts = [[1, 2, 3, 4], [7, 8], [5, 5, 5, 5, 5, 5]]
    res = engine.generate(prompts, max_new=8)
    assert len(res.tokens) == 3
    for p, o in zip(prompts, res.tokens):
        assert o[: len(p)] == p
        assert len(o) == len(p) + 8
        assert all(0 <= t < cfg.vocab_size for t in o)


def test_serve_engine_greedy_matches_forward(rng):
    """Engine's first generated token == argmax of a parallel forward."""
    cfg = get_config("llama3-8b", smoke=True)
    params = tf.init_params(cfg, jax.random.key(3))
    engine = ServeEngine(cfg, params, s_max=32)
    prompt = [3, 1, 4, 1, 5, 9]
    res = engine.generate([prompt], max_new=1)
    logits, _ = tf.forward_train(
        params, cfg, {"tokens": jnp.asarray([prompt], jnp.int32)})
    want = int(jnp.argmax(logits[0, -1]))
    assert res.tokens[0][-1] == want
