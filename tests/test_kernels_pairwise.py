"""Pallas pairwise-distance kernel (fused embedding) vs jnp oracle.

Interpret mode executes the kernel body on CPU; shapes, E, tau, blocks
and both variants (VPU elementwise / MXU norm-expansion) are swept.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

CASES = [
    # (L, E, tau, block)
    (64, 1, 1, (16, 16)),
    (100, 2, 1, (32, 16)),
    (137, 4, 2, (32, 64)),
    (128, 20, 3, (64, 64)),
    (257, 7, 5, (128, 128)),
    (96, 3, 1, (8, 128)),
]


@pytest.mark.parametrize("L,E,tau,block", CASES)
@pytest.mark.parametrize("variant", ["vpu", "mxu"])
def test_pairwise_matches_ref(rng, L, E, tau, block, variant):
    x = jnp.asarray(rng.normal(size=L).astype(np.float32))
    want = ref.pairwise_distances(x, E=E, tau=tau)
    got = ops.pairwise_distances(x, E=E, tau=tau, impl="interpret",
                                 variant=variant, block=block)
    assert got.shape == want.shape == (L - (E - 1) * tau,) * 2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_pairwise_input_dtypes(rng, dtype):
    x = (rng.normal(size=80) * 10).astype(dtype)
    want = ref.pairwise_distances(jnp.asarray(x), E=3, tau=1)
    got = ops.pairwise_distances(jnp.asarray(x), E=3, tau=1,
                                 impl="interpret", block=(16, 32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_pairwise_large_offset_numerics(rng):
    """MXU norm-expansion must survive a large additive offset (centering)."""
    x = jnp.asarray((rng.normal(size=120) + 1000.0).astype(np.float32))
    want = ref.pairwise_distances(x - jnp.mean(x), E=5, tau=1)
    got = ops.pairwise_distances(x, E=5, tau=1, impl="interpret",
                                 variant="mxu", block=(32, 32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-2)


def test_pairwise_matches_materialized_embedding(rng):
    """Fused result == brute-force distances of the materialized embedding."""
    x = jnp.asarray(rng.normal(size=90).astype(np.float32))
    E, tau = 6, 2
    Z = np.asarray(ref.delay_embed(x, E, tau))
    brute = ((Z[:, None, :] - Z[None, :, :]) ** 2).sum(-1)
    got = ops.pairwise_distances(x, E=E, tau=tau, impl="interpret",
                                 block=(16, 16))
    np.testing.assert_allclose(np.asarray(got), brute, rtol=1e-4, atol=1e-4)
