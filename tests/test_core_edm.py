"""EDM behaviour: simplex projection, optimal-E recovery, S-Map."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import core
from repro.data import timeseries as ts


def test_simplex_forecasts_logistic_map():
    x = jnp.asarray(ts.logistic_map(400))
    rho = float(core.simplex_skill(x, E=2, tau=1, Tp=1))
    assert rho > 0.95, f"deterministic chaos should be 1-step predictable, ρ={rho}"


def test_simplex_skill_degrades_with_horizon():
    """Chaos: skill must decay as the forecast horizon grows."""
    x = jnp.asarray(ts.logistic_map(500))
    rhos = [float(core.simplex_skill(x, E=2, tau=1, Tp=tp)) for tp in (1, 4, 12)]
    assert rhos[0] > rhos[-1] + 0.1, f"no decay: {rhos}"


def test_optimal_E_on_lorenz():
    """Lorenz-63 needs E≈3 (2E+1 bound aside, in practice 2–5)."""
    x = jnp.asarray(ts.lorenz63(800)[0])
    best, rhos = core.optimal_E(x, E_max=8, tau=2, Tp=1)
    assert 2 <= best <= 6, f"E*={best}, ρ={np.round(np.asarray(rhos), 3)}"
    assert float(rhos[best - 1]) > 0.95


def test_optimal_E_batch_agrees_with_scalar():
    X = jnp.asarray(np.stack([ts.logistic_map(300, r=3.8),
                              ts.logistic_map(300, r=3.7, x0=0.5)]))
    E_opt, rho = core.optimal_E_batch(X, E_max=4)
    for n in range(2):
        _, rhos = core.optimal_E(X[n], E_max=4)
        np.testing.assert_allclose(np.asarray(rho[n]), np.asarray(rhos),
                                   rtol=1e-4, atol=1e-4)
        assert int(E_opt[n]) == int(jnp.argmax(rhos)) + 1


def test_knn_table_properties():
    x = jnp.asarray(ts.logistic_map(300))
    t = core.all_knn(x, E=3, tau=1)
    assert t.k == 4
    assert t.dists.shape == t.idx.shape == (298, 4)
    w = np.asarray(t.weights)
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-5)
    assert (np.diff(np.asarray(t.dists), axis=1) >= 0).all()


def test_smap_nonlinearity_detected():
    """ρ(θ) must rise for a nonlinear system (the classic S-Map test)."""
    x = jnp.asarray(ts.logistic_map(250))
    rhos = np.asarray(core.nonlinearity_test(x, E=2, thetas=(0.0, 2.0, 8.0)))
    assert rhos[-1] > rhos[0] + 0.02, f"no nonlinearity signal: {rhos}"
    assert rhos[-1] > 0.9


def test_smap_linear_system_flat_theta():
    """AR(1) noise: skill must NOT rise materially with θ."""
    rng = np.random.default_rng(7)
    n = 300
    x = np.zeros(n, np.float32)
    for t in range(1, n):
        x[t] = 0.8 * x[t - 1] + 0.1 * rng.standard_normal()
    rhos = np.asarray(core.nonlinearity_test(jnp.asarray(x), E=2,
                                             thetas=(0.0, 4.0)))
    assert rhos[1] < rhos[0] + 0.05, f"spurious nonlinearity: {rhos}"


def test_pred_rows_and_offset_helpers():
    assert core.num_embedded(100, 5, 2) == 92
    assert core.embed_offset(5, 2, Tp=3) == 11
    assert core.pred_rows(100, 5, 2, Tp=3) == 89
    with pytest.raises(ValueError):
        core.num_embedded(10, 6, 2)
