"""Library-batched all-kNN ≡ the per-series pipeline, for every B/tiling."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def _per_series_oracle(x, *, E, tau, k, exclude_self, max_idx):
    """The fused standalone per-series pipeline (one jitted program)."""

    @jax.jit
    def one(x):
        D = ref.pairwise_distances(x, E=E, tau=tau)
        return ref.topk_select(D, k=k, exclude_self=exclude_self,
                               max_idx=max_idx)

    return one(x)


@pytest.mark.parametrize("L,B,E,tau,k", [
    (137, 5, 3, 2, None),
    (96, 9, 3, 1, None),     # short series (the shape where lax.map wobbles)
    (200, 3, 1, 1, None),
    (150, 4, 4, 1, 6),       # custom-k override
])
def test_ref_batch_matches_per_series_pipeline(rng, L, B, E, tau, k):
    X = jnp.asarray(rng.normal(size=(B, L)).astype(np.float32))
    Lp = L - (E - 1) * tau
    kk = E + 1 if k is None else k
    d, i = ref.all_knn_batch(X, E=E, tau=tau, k=k)
    assert d.shape == i.shape == (B, Lp, kk)
    for b in range(B):
        want_d, want_i = _per_series_oracle(
            X[b], E=E, tau=tau, k=kk, exclude_self=True, max_idx=Lp - 1)
        np.testing.assert_array_equal(np.asarray(i[b]), np.asarray(want_i),
                                      err_msg=f"series {b}")
        # Distances: ~1 ULP, not bit-equal — the oracle is a DIFFERENT
        # XLA program (2-D accumulation) and XLA CPU may contract it
        # differently from the batched (B, Lp, Lp) stream at some
        # shapes. Bit-equality is only contracted in B (next test).
        np.testing.assert_allclose(np.asarray(d[b]), np.asarray(want_d),
                                   rtol=2e-7, atol=2e-7,
                                   err_msg=f"series {b}")


def test_ref_batch_is_bit_invariant_in_B(rng):
    """The layout contract: any batch decomposition gives identical
    tables — the per-series oracle is the B = 1 launch."""
    X = jnp.asarray(rng.normal(size=(11, 233)).astype(np.float32))
    d_all, i_all = ref.all_knn_batch(X, E=4, tau=1)
    for sl in (slice(0, 1), slice(3, 10), slice(10, 11)):
        d_s, i_s = ref.all_knn_batch(X[sl], E=4, tau=1)
        np.testing.assert_array_equal(np.asarray(d_all[sl]), np.asarray(d_s))
        np.testing.assert_array_equal(np.asarray(i_all[sl]), np.asarray(i_s))


def test_ref_batch_max_idx_and_no_self(rng):
    X = jnp.asarray(rng.normal(size=(4, 150)).astype(np.float32))
    for excl in (True, False):
        for cap in (0, 40):
            d, i = ref.all_knn_batch(X, E=3, tau=1, max_idx=cap,
                                     exclude_self=excl)
            if cap >= 4:  # slots below k valid candidates carry arbitrary
                assert int(np.asarray(i).max()) <= cap  # zero-weight idx
            for b in range(4):
                want_d, want_i = _per_series_oracle(
                    X[b], E=3, tau=1, k=4, exclude_self=excl, max_idx=cap)
                np.testing.assert_array_equal(np.asarray(i[b]),
                                              np.asarray(want_i))
                np.testing.assert_array_equal(np.asarray(d[b]),
                                              np.asarray(want_d))


def test_ref_batch_duplicate_series_tie_order(rng):
    """Exact-duplicate manifolds must produce identical tables (ties
    broken by global index, independent of batch position)."""
    X = jnp.asarray(rng.normal(size=(3, 180)).astype(np.float32))
    Xd = jnp.concatenate([X, X[:1]], axis=0)
    d, i = ref.all_knn_batch(Xd, E=3, tau=1)
    np.testing.assert_array_equal(np.asarray(d[0]), np.asarray(d[3]))
    np.testing.assert_array_equal(np.asarray(i[0]), np.asarray(i[3]))


@pytest.mark.parametrize("L,B,E,tau,k,block", [
    (137, 4, 3, 2, None, (16, 128)),   # gj > 1: streaming merge across tiles
    (200, 3, 1, 1, None, (32, 128)),
    (96, 5, 3, 1, 4, (8, 128)),
    (300, 2, 4, 1, None, (64, 128)),   # 3 column tiles, partial last tile
])
def test_interpret_kernel_matches_ref(rng, L, B, E, tau, k, block):
    X = jnp.asarray(rng.normal(size=(B, L)).astype(np.float32))
    want_d, want_i = ref.all_knn_batch(X, E=E, tau=tau, k=k)
    got_d, got_i = ops.all_knn_batch(X, E=E, tau=tau, k=k,
                                     impl="interpret", block=block)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6, atol=1e-6)


def test_interpret_kernel_b_invariance(rng):
    """Kernel-path layout contract: the per-series tiling is independent
    of B, so a batch launch equals its own B = 1 launches bit-for-bit."""
    X = jnp.asarray(rng.normal(size=(5, 120)).astype(np.float32))
    d_all, i_all = ops.all_knn_batch(X, E=3, tau=1, impl="interpret",
                                     block=(16, 128))
    for b in range(5):
        d1, i1 = ops.all_knn_batch(X[b:b + 1], E=3, tau=1,
                                   impl="interpret", block=(16, 128))
        np.testing.assert_array_equal(np.asarray(d_all[b]),
                                      np.asarray(d1[0]))
        np.testing.assert_array_equal(np.asarray(i_all[b]),
                                      np.asarray(i1[0]))


def test_interpret_kernel_caps_and_fewer_valid_than_k(rng):
    """Rows with < k valid candidates emit distinct lowest-index fill
    entries (retire-by-index in the streaming merge), matching the ref."""
    X = jnp.asarray(rng.normal(size=(3, 100)).astype(np.float32))
    for cap in (0, 1, 30):
        want_d, want_i = ref.all_knn_batch(X, E=3, tau=1, max_idx=cap)
        got_d, got_i = ops.all_knn_batch(X, E=3, tau=1, max_idx=cap,
                                         impl="interpret", block=(16, 128))
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                                   rtol=1e-6, atol=1e-6)


def test_batch_rejects_bad_rank():
    with pytest.raises(ValueError, match=r"\(B, L\)"):
        ref.all_knn_batch(jnp.zeros(32), E=2)
    with pytest.raises(ValueError, match=r"\(B, L\)"):
        from repro.kernels.knn_batch import all_knn_batch
        all_knn_batch(jnp.zeros(32), E=2)
