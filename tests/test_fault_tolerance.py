"""Fault tolerance (ISSUE 6): journaled resumable xmap runs, preemption
→ checkpoint-and-exit-17, OOM → halve-B backoff, hardened ingestion, and
the run-report plumbing (stragglers, heartbeats, invalid series)."""

import json
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ccm
from repro.data import timeseries as ts
from repro.edm import (EDM, EDMConfig, Dataset, MatrixRunner,
                       PREEMPTED_EXIT, run_key, screen_panel)
from repro.edm import runner as runner_mod


def _panel(n=6, steps=220, seed=3):
    panel, _ = ts.forced_network_panel(n, steps, seed=seed)
    return jnp.asarray(panel)


# --------------------------------------------------- drive_batched hooks


def test_drive_batched_start_and_on_block():
    """start= skips committed rows; on_block sees exactly the landed
    tiles in order, unpadded."""
    calls, blocks = [], []

    def launch(a, b, B):
        calls.append((a, b, B))
        return jnp.arange(a, a + B, dtype=jnp.float32)[:, None]

    out = ccm.drive_batched(7, 3, launch, start=3,
                            on_block=lambda a, b, blk: blocks.append(
                                (a, b, blk.copy())))
    assert calls == [(3, 6, 3), (6, 7, 3)]
    assert [(a, b) for a, b, _ in blocks] == [(3, 6), (6, 7)]
    np.testing.assert_array_equal(blocks[1][2][:, 0], [6.0])  # pad dropped
    np.testing.assert_array_equal(out[3:, 0], np.arange(3, 7))
    # nothing left to drive: no launches, None result
    assert ccm.drive_batched(4, 2, launch, start=4) is None


def test_drive_batched_monitor_counts_tiles():
    from repro.distributed.fault import StragglerMonitor
    mon = StragglerMonitor()
    ccm.drive_batched(6, 2, lambda a, b, B: jnp.zeros((B, 1)), monitor=mon)
    rep = mon.report()
    assert rep["steps"] == 3 and rep["median_s"] is not None


# ------------------------------------------------------- backoff helpers


def test_is_oom_error_markers():
    assert runner_mod.is_oom_error(RuntimeError("RESOURCE_EXHAUSTED: foo"))
    assert runner_mod.is_oom_error(Exception("Out of memory allocating"))
    assert runner_mod.is_oom_error(MemoryError())
    assert runner_mod.is_oom_error(
        RuntimeError("Execution failed: RESOURCE_EXHAUSTED: oom"))
    assert not runner_mod.is_oom_error(ValueError("shape mismatch"))
    # mentions memory mid-sentence ≠ an allocation failure: the anchored
    # match must not burn backoff retries on these
    assert not runner_mod.is_oom_error(
        ValueError("option 'out of memory handler' is unknown"))
    assert not runner_mod.is_oom_error(
        RuntimeError("watchdog saw the job run out of memory budget"))


def test_halved_batch_equalizes():
    # cap 8 over 20 remaining rows → 3 launches of ceil(20/3)=7
    assert runner_mod.halved_batch(16, 20) == 7
    assert runner_mod.halved_batch(2, 100) == 1  # floor
    assert runner_mod.halved_batch(8, 3) == 3    # cap clamps to remaining


def test_run_key_ignores_perf_knobs_only():
    """Resuming with a different batch size / snapshot cadence is legal
    (results are B-invariant); any numeric knob changes the key."""
    X = np.asarray(_panel())
    sig = ("xmap", "simplex", None, ((3, 6),))
    base = run_key(X, EDMConfig(E=3), sig)
    assert run_key(X, EDMConfig(E=3, batch_libs=2, checkpoint_every=5,
                                oom_retries=1, run_tile_rows=2), sig) == base
    assert run_key(X, EDMConfig(E=4), sig) != base
    assert run_key(X, EDMConfig(E=3, tau=2), sig) != base
    assert run_key(X * 2.0, EDMConfig(E=3), sig) != base
    assert run_key(X, EDMConfig(E=3), ("xmap", "smap", 1.0, ((3, 6),))) != base


# ------------------------------------------------- journaled local runs


def test_journaled_xmap_bit_identical_and_reported(tmp_path):
    X = _panel()
    ref = EDM(X, EDMConfig(E=3, batch_libs=2)).xmap()
    run = tmp_path / "run"
    got = EDM(X, EDMConfig(E=3, batch_libs=2)).xmap(run_dir=str(run))
    np.testing.assert_array_equal(ref, got)
    rep = json.loads((run / "report.json").read_text())
    assert rep["status"] == "complete"
    assert rep["rows_done"] == rep["rows_total"] == 6
    assert rep["stragglers"]["steps"] == 3  # ceil(6/2) launch timings
    assert len((run / "heartbeat").read_text().splitlines()) == 3
    manifest = json.loads((run / "run.json").read_text())
    assert manifest["status"] == "complete" and manifest["groups"] == [[3, 6]]


def test_completed_run_short_circuits_without_launches(tmp_path, monkeypatch):
    X = _panel()
    run = tmp_path / "run"
    ref = EDM(X, EDMConfig(E=3, batch_libs=2)).xmap(run_dir=str(run))

    def boom(*a, **k):  # any engine launch on the re-run is a failure
        raise AssertionError("completed journal must not recompute")

    monkeypatch.setattr(ccm, "_group_step", boom)
    sess = EDM(X, EDMConfig(E=3, batch_libs=2))
    np.testing.assert_array_equal(sess.xmap(run_dir=str(run)), ref)
    assert sess.stats["runs_short_circuited"] == 1


def test_stale_journal_refused(tmp_path):
    X = _panel()
    run = tmp_path / "run"
    EDM(X, EDMConfig(E=3, batch_libs=2)).xmap(run_dir=str(run))
    with pytest.raises(ValueError, match="DIFFERENT run"):
        EDM(X * 1.5, EDMConfig(E=3, batch_libs=2)).xmap(run_dir=str(run))
    with pytest.raises(ValueError, match="DIFFERENT run"):
        EDM(X, EDMConfig(E=4, batch_libs=2)).xmap(run_dir=str(run))


def test_changed_e_table_same_group_sizes_refused(tmp_path):
    """The run key hashes the FULL per-series E table: permuting E_opt
    while keeping group sizes (here {2:3, 3:3} both times) must key to
    a different run, not silently resume the stale journal."""
    X = _panel(6)
    cfg = EDMConfig(E=3, batch_libs=2)
    run = tmp_path / "run"
    EDM(X, cfg).xmap(E_opt=[2, 2, 2, 3, 3, 3], run_dir=str(run))
    with pytest.raises(ValueError, match="DIFFERENT run"):
        EDM(X, cfg).xmap(E_opt=[3, 3, 3, 2, 2, 2], run_dir=str(run))


def test_run_dir_single_writer_lock(tmp_path):
    """A second live MatrixRunner on the same run_dir fails fast; the
    lock releases on close() so a sequential resume still works."""
    d = str(tmp_path / "run")
    r1 = MatrixRunner(d, key="k", shape=(4, 4), groups_sig=[[2, 4]])
    with pytest.raises(RuntimeError, match="locked by another live run"):
        MatrixRunner(d, key="k", shape=(4, 4), groups_sig=[[2, 4]])
    r1.close()
    MatrixRunner(d, key="k", shape=(4, 4), groups_sig=[[2, 4]]).close()


def test_preempt_then_resume_recomputes_no_committed_tile(
        tmp_path, monkeypatch):
    """SIGTERM mid-run → snapshot + SystemExit(17); the rerun drives only
    the tiles the journal does not hold and is bit-identical."""
    X = _panel()
    cfg = EDMConfig(E=3, batch_libs=2)
    ref = EDM(X, cfg).xmap()
    run = tmp_path / "run"
    orig = ccm._group_step
    n = {"launches": 0}

    def sigterm_mid_run(*a, **k):
        n["launches"] += 1
        if n["launches"] == 2:  # tile 0 in flight, not yet committed
            os.kill(os.getpid(), signal.SIGTERM)
        return orig(*a, **k)

    monkeypatch.setattr(ccm, "_group_step", sigterm_mid_run)
    with pytest.raises(SystemExit) as exc:
        EDM(X, cfg).xmap(run_dir=str(run))
    assert exc.value.code == PREEMPTED_EXIT
    assert signal.getsignal(signal.SIGTERM) == signal.SIG_DFL  # restored
    rep = json.loads((run / "report.json").read_text())
    assert rep["status"] == "preempted" and 0 < rep["rows_done"] < 6

    resumed = {"launches": 0}

    def counting(*a, **k):
        resumed["launches"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(ccm, "_group_step", counting)
    got = EDM(X, cfg).xmap(run_dir=str(run))
    np.testing.assert_array_equal(ref, got)
    assert resumed["launches"] == 2  # 3 tiles total, 1 was journaled
    rep = json.loads((run / "report.json").read_text())
    assert rep["status"] == "complete" and rep["rows_resumed"] == 2


def test_oom_triggers_halve_b_retry(tmp_path, monkeypatch):
    """An injected RESOURCE_EXHAUSTED halves B (equalized) and the run
    completes bit-identically, with the decision logged in the report."""
    X = _panel()
    ref = EDM(X, EDMConfig(E=3, batch_libs=2)).xmap()
    orig = ccm._group_step
    fail = {"armed": True}

    def oom_once(*a, **k):
        if fail["armed"]:
            fail["armed"] = False
            raise RuntimeError("RESOURCE_EXHAUSTED: out of memory")
        return orig(*a, **k)

    monkeypatch.setattr(ccm, "_group_step", oom_once)
    run = tmp_path / "run"
    got = EDM(X, EDMConfig(E=3, batch_libs=6)).xmap(run_dir=str(run))
    np.testing.assert_array_equal(ref, got)
    trail = json.loads((run / "report.json").read_text())["oom_backoff"]
    assert trail[0]["action"] == "halve"
    assert trail[0]["B"] == 6 and trail[0]["to_B"] == 3


def test_oom_retries_bounded(tmp_path, monkeypatch):
    X = _panel()

    def always_oom(*a, **k):
        raise RuntimeError("RESOURCE_EXHAUSTED: out of memory")

    monkeypatch.setattr(ccm, "_group_step", always_oom)
    run = tmp_path / "run"
    with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
        EDM(X, EDMConfig(E=3, batch_libs=4, oom_retries=2)).xmap(
            run_dir=str(run))
    trail = json.loads((run / "report.json").read_text())["oom_backoff"]
    assert [t["action"] for t in trail] == ["halve", "halve", "give_up"]


def test_non_oom_errors_propagate_unretried(tmp_path, monkeypatch):
    X = _panel()
    calls = {"n": 0}

    def broken(*a, **k):
        calls["n"] += 1
        raise ValueError("not a memory problem")

    monkeypatch.setattr(ccm, "_group_step", broken)
    with pytest.raises(ValueError, match="not a memory problem"):
        EDM(X, EDMConfig(E=3, batch_libs=2)).xmap(
            run_dir=str(tmp_path / "run"))
    assert calls["n"] == 1


def test_memory_mention_unretried_but_recorded(tmp_path, monkeypatch):
    """An error that mentions memory without the anchored OOM markers
    propagates on the first launch (no halve-B retries burned) and the
    report's trail records it as unclassified."""
    X = _panel()
    calls = {"n": 0}

    def broken(*a, **k):
        calls["n"] += 1
        raise ValueError("plugin 'out of memory watcher' failed to load")

    monkeypatch.setattr(ccm, "_group_step", broken)
    run = tmp_path / "run"
    with pytest.raises(ValueError, match="failed to load"):
        EDM(X, EDMConfig(E=3, batch_libs=2, oom_retries=4)).xmap(
            run_dir=str(run))
    assert calls["n"] == 1
    trail = json.loads((run / "report.json").read_text())["oom_backoff"]
    assert [t["action"] for t in trail] == ["unclassified"]


def test_runner_refuses_finalize_with_missing_group(tmp_path):
    r = MatrixRunner(str(tmp_path / "run"), key="k", shape=(4, 4),
                     groups_sig=[[2, 4]])
    try:
        with pytest.raises(RuntimeError, match="not driven"):
            r.finalize()
    finally:
        r.close()  # detach the run's telemetry sink + release the lock


# --------------------------------------------- checkpoint restore hygiene


def test_corrupt_checkpoint_leaf_named_in_error(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    state = {"rho": np.ones((3, 3), np.float32), "done": np.zeros(3, bool)}
    mgr.save(1, state)
    step_dir = mgr._step_dir(1)
    leaf = os.path.join(step_dir, "leaf_00000.npy")
    with open(leaf, "wb") as f:
        f.write(b"\x00" * 8)  # truncated garbage
    with pytest.raises(ValueError, match="leaf 0 is unreadable"):
        mgr.restore(state, step=1)


def test_swapped_checkpoint_leaf_fails_manifest_check(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    state = {"a": np.ones((3, 3), np.float32), "b": np.zeros(3, bool)}
    mgr.save(1, state)
    leaf = os.path.join(mgr._step_dir(1), "leaf_00000.npy")
    np.save(leaf, np.ones((2, 2), np.float32))  # wrong shape vs manifest
    with pytest.raises(ValueError, match="does not match its manifest"):
        mgr.restore(state, step=1)


# --------------------------------------------------- hardened ingestion


def test_screen_panel_flags_nonfinite_and_constant():
    X = np.asarray(_panel(4)).copy()
    X[1, 7] = np.inf
    X[3, :] = 2.5
    rep = screen_panel(X)
    assert [(r["index"], r["reason"]) for r in rep] == [
        (1, "1 non-finite values"), (3, "constant series")]


def test_screen_panel_counts_and_all_inf_row():
    X = np.asarray(_panel(4)).copy()
    X[0, :] = np.inf                      # ptp is inf-inf: nonfinite wins
    X[2, 3] = np.nan
    X[2, 9] = np.nan
    rep = screen_panel(X)
    assert [(r["index"], r["reason"]) for r in rep] == [
        (0, f"{X.shape[1]} non-finite values"), (2, "2 non-finite values")]


def test_dataset_raise_names_series():
    X = np.asarray(_panel(3)).copy()
    X[2, 0] = np.nan
    with pytest.raises(ValueError, match="series c.*non-finite"):
        Dataset(X, names=["a", "b", "c"])


def test_dataset_drop_compacts_and_reports():
    X = np.asarray(_panel(4)).copy()
    X[1, :] = 0.0
    d = Dataset(X, names=list("abcd"), on_invalid="drop")
    assert d.N == 3 and d.names == ["a", "c", "d"]
    assert d.valid.all()
    assert d.invalid_report == [
        {"index": 1, "name": "b", "reason": "constant series"}]


def test_dataset_mask_keeps_shape_and_zeroes():
    X = np.asarray(_panel(4)).copy()
    X[2, 5] = -np.inf
    d = Dataset(X, on_invalid="mask")
    assert d.N == 4 and d.num_invalid == 1 and not d.is_valid(2)
    assert np.isfinite(np.asarray(d.panel)).all()


def test_masked_session_outputs_nan_flagged(tmp_path):
    """mask policy end to end: xmap rows AND columns of invalid series
    are NaN, valid entries match the clean sub-panel's values, pairwise
    calls NaN out, and the run report names the series."""
    X = np.asarray(_panel(6)).copy()
    X[1, 3] = np.nan
    X[4, :] = 1.0
    sess = EDM(X, EDMConfig(E=3, on_invalid="mask"))
    run = tmp_path / "run"
    rho = sess.xmap(run_dir=str(run))
    bad, good = [1, 4], [0, 2, 3, 5]
    assert np.isnan(rho[bad, :]).all() and np.isnan(rho[:, bad]).all()
    assert np.isfinite(rho[np.ix_(good, good)]).all()
    rep = json.loads((run / "report.json").read_text())
    assert [r["index"] for r in rep["invalid_series"]] == bad
    # valid×valid entries equal the same pairs of an all-clean session
    clean = EDM(X[good], EDMConfig(E=3)).xmap()
    np.testing.assert_array_equal(rho[np.ix_(good, good)], clean)
    # pairwise paths
    assert np.isnan(sess.ccm(0, 1))
    assert np.isfinite(sess.ccm(0, 2))
    curve = sess.ccm(4, 2, lib_sizes=(50, 100))
    assert curve.shape == (2,) and np.isnan(curve).all()
    sr = sess.surrogate_test(0, 4, num_surrogates=4)
    assert np.isnan(sr.rho) and np.isnan(sr.pvalue)
    assert np.isnan(sess.simplex(E=3)[bad]).all()
    assert np.isnan(sess.smap()[bad]).all()
    assert np.isfinite(sess.smap()[good]).all()
    E_opt, rcurve = sess.optimal_E()
    assert np.isnan(rcurve[bad]).all() and (E_opt[bad] == 1).all()


def test_clean_panel_unaffected_by_mask_policy():
    X = _panel(5)
    np.testing.assert_array_equal(
        EDM(X, EDMConfig(E=3, on_invalid="mask")).xmap(),
        EDM(X, EDMConfig(E=3)).xmap())


# ------------------------------------------- subprocess kill-and-resume


def test_subprocess_sigterm_kill_and_resume(tmp_path):
    """A real process: SIGTERM lands mid-run, the interpreter exits with
    PREEMPTED_EXIT, and a second process resumes bit-identically while
    recomputing none of the committed tiles."""
    run = str(tmp_path / "run")
    prog = textwrap.dedent("""
        import os, signal, sys
        import numpy as np, jax.numpy as jnp
        from repro.core import ccm
        from repro.data import timeseries as ts
        from repro.edm import EDM, EDMConfig
        panel, _ = ts.forced_network_panel(6, 220, seed=3)
        X = jnp.asarray(panel)
        cfg = EDMConfig(E=3, batch_libs=2)
        mode, run = sys.argv[1], sys.argv[2]
        orig = ccm._group_step
        n = {"launches": 0}
        def wrapped(*a, **k):
            n["launches"] += 1
            if mode == "kill" and n["launches"] == 2:
                os.kill(os.getpid(), signal.SIGTERM)
            return orig(*a, **k)
        ccm._group_step = wrapped
        rho = EDM(X, cfg).xmap(run_dir=run)
        np.save(os.path.join(run, f"{mode}.npy"), rho)
        print(f"LAUNCHES={n['launches']}")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    kill = subprocess.run([sys.executable, "-c", prog, "kill", run],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert kill.returncode == PREEMPTED_EXIT, kill.stderr
    with open(os.path.join(run, "report.json")) as f:
        assert json.load(f)["status"] == "preempted"
    resume = subprocess.run([sys.executable, "-c", prog, "resume", run],
                            env=env, capture_output=True, text=True,
                            timeout=300)
    assert resume.returncode == 0, resume.stderr
    assert "LAUNCHES=2" in resume.stdout  # 3 tiles total, 1 journaled
    fresh = subprocess.run(
        [sys.executable, "-c", prog, "fresh", str(tmp_path / "fresh")],
        env=env, capture_output=True, text=True, timeout=300)
    assert fresh.returncode == 0, fresh.stderr
    assert "LAUNCHES=3" in fresh.stdout
    np.testing.assert_array_equal(
        np.load(os.path.join(run, "resume.npy")),
        np.load(os.path.join(str(tmp_path / "fresh"), "fresh.npy")))
