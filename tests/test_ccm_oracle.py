"""cross_map lib-size sweeps and max_idx/exclude_self vs a numpy oracle.

The oracle re-implements the whole simplex cross-map pipeline (embed,
mask, k-NN by stable argsort, exponential weights, lookup, Pearson) in
plain numpy with no shared code, so any indexing or masking slip in the
jax path shows up as a mismatch rather than cancelling out.
"""

import numpy as np
import jax.numpy as jnp

from repro import core
from repro.data import timeseries as ts
from repro.kernels import ref


def np_embed(x, E, tau):
    Lp = len(x) - (E - 1) * tau
    return np.stack([x[k * tau:k * tau + Lp] for k in range(E)], axis=1)


def np_cross_map(lib, targets, *, E, tau=1, Tp=0, lib_size=None,
                 exclude_self=True):
    """Brute-force CCM skill of each target from lib's manifold, (N,)."""
    lib = np.asarray(lib, np.float32)
    targets = np.asarray(targets, np.float32)
    Z = np_embed(lib, E, tau)
    Lp = Z.shape[0]
    rows = Lp - max(Tp, 0)
    off = (E - 1) * tau + Tp
    k = E + 1
    D = ((Z[:, None, :] - Z[None, :, :]) ** 2).sum(-1)
    hard_max = Lp - 1 - max(Tp, 0)
    cap = hard_max if lib_size is None else min(lib_size - 1, hard_max)
    mask = np.arange(Lp)[None, :] > cap
    if exclude_self:
        mask = mask | np.eye(Lp, dtype=bool)
    Dm = np.where(mask, np.inf, D)
    idx = np.argsort(Dm, axis=1, kind="stable")[:, :k]
    d = np.sqrt(np.take_along_axis(Dm, idx, axis=1))
    w = np.exp(-d / np.maximum(d[:, :1], 1e-30))
    w = w / w.sum(axis=1, keepdims=True)
    g = targets[:, idx[:rows] + off]                    # (N, rows, k)
    yhat = (g * w[None, :rows]).sum(-1)                 # (N, rows)
    yt = targets[:, off:off + rows]
    out = []
    for n in range(targets.shape[0]):
        a = yhat[n] - yhat[n].mean()
        b = yt[n] - yt[n].mean()
        denom = np.sqrt((a * a).sum() * (b * b).sum())
        out.append((a * b).sum() / denom if denom > 0 else 0.0)
    return np.asarray(out, np.float32)


def _coupled(n):
    x, y = ts.coupled_logistic(n, b_xy=0.0, b_yx=0.32, seed=3)
    return np.asarray(x, np.float32), np.asarray(y, np.float32)


def test_cross_map_matches_numpy_oracle():
    x, y = _coupled(400)
    for E, tau, Tp in ((2, 1, 0), (3, 2, 1)):
        want = np_cross_map(y, x[None, :], E=E, tau=tau, Tp=Tp)
        got = np.asarray(core.cross_map(jnp.asarray(y), jnp.asarray(x),
                                        E=E, tau=tau, Tp=Tp))
        np.testing.assert_allclose(got, want[0], rtol=1e-3, atol=2e-3)


def test_cross_map_lib_sizes_sweep_matches_oracle():
    """The convergence sweep (CCM's causality criterion) point by point."""
    x, y = _coupled(500)
    sizes = (25, 60, 150, 300, 10_000)  # last one over-caps → hard_max
    got = np.asarray(core.cross_map(jnp.asarray(y), jnp.asarray(x), E=2,
                                    lib_sizes=sizes))
    for s, g in zip(sizes, got):
        want = np_cross_map(y, x[None, :], E=2, lib_size=s)
        np.testing.assert_allclose(g, want[0], rtol=1e-3, atol=2e-3,
                                   err_msg=f"lib_size={s}")


def test_cross_map_exclude_self_matches_oracle():
    x, y = _coupled(350)
    for excl in (True, False):
        want = np_cross_map(y, np.stack([x, y]), E=2, exclude_self=excl)
        got = np.asarray(core.cross_map(jnp.asarray(y),
                                        jnp.asarray(np.stack([x, y])),
                                        E=2, exclude_self=excl))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-3)
    # with self allowed, mapping a series onto itself is (near-)perfect
    rho_self = float(core.cross_map(jnp.asarray(y), jnp.asarray(y), E=2,
                                    exclude_self=False))
    assert rho_self > 0.999


def test_topk_max_idx_exclude_self_interaction(rng):
    """All four (max_idx, exclude_self) combinations vs stable argsort."""
    x = rng.normal(size=120).astype(np.float32)
    D = np.asarray(ref.pairwise_distances(jnp.asarray(x), E=3, tau=1))
    Lp = D.shape[0]
    for cap in (None, 0, 5, 40, Lp - 1):
        for excl in (True, False):
            mask = np.zeros((Lp, Lp), bool)
            if cap is not None:
                mask |= np.arange(Lp)[None, :] > cap
            if excl:
                mask |= np.eye(Lp, dtype=bool)
            Dm = np.where(mask, np.inf, D)
            want_i = np.argsort(Dm, axis=1, kind="stable")[:, :4]
            want_d = np.sqrt(np.take_along_axis(Dm, want_i, axis=1))
            got_d, got_i = ref.topk_select(jnp.asarray(D), k=4,
                                           exclude_self=excl, max_idx=cap)
            np.testing.assert_array_equal(np.asarray(got_i), want_i,
                                          err_msg=f"cap={cap} excl={excl}")
            np.testing.assert_allclose(np.asarray(got_d), want_d,
                                       rtol=1e-5, atol=1e-5)


def test_multi_e_max_idx_matches_capped_oracle(rng):
    """The engine's per-level caps reproduce capped per-E argsort tables."""
    x = rng.normal(size=90).astype(np.float32)
    cap = 30
    d, i = ref.all_knn_multi_e(jnp.asarray(x), E_max=3, tau=1, max_idx=cap)
    for E in (1, 2, 3):
        Lp = 90 - (E - 1)
        Z = np_embed(x, E, 1)
        D = ((Z[:, None, :] - Z[None, :, :]) ** 2).sum(-1)
        Dm = np.where((np.arange(Lp)[None, :] > cap) | np.eye(Lp, dtype=bool),
                      np.inf, D)
        want_i = np.argsort(Dm, axis=1, kind="stable")[:, :E + 1]
        np.testing.assert_array_equal(np.asarray(i[E - 1, :Lp, :E + 1]),
                                      want_i)
