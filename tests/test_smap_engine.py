"""Batched S-Map engine ≡ the per-query weighted-lstsq oracle.

Covers the Gram kernel (interpret vs ref), the normal-equations engine vs
an explicit float64 numpy lstsq oracle across E/τ/Tp/θ grids, the seed
parity of the rewritten public API, the d̄=0 degenerate-series guard, the
S-Map cross-mapping workload, Jacobian extraction, and the sharded
θ-sweep/matrix wiring.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import core
from repro.data import timeseries as ts
from repro.distributed import (
    make_ccm_mesh,
    sharded_smap_matrix,
    sharded_smap_theta,
)
from repro.kernels import ops, ref
from repro.kernels.smap_gram import smap_gram as smap_gram_kernel


def _numpy_smap(x, Y, *, E, tau, Tp, theta, exclude_self=True):
    """Explicit per-query weighted lstsq in float64 — the brute-force oracle.

    Returns (pred (N, rows), truth (N, rows), coef (N, rows, E+1)).
    """
    x = np.asarray(x, np.float64)
    Y = np.asarray(Y, np.float64)
    L = x.shape[-1]
    Lp = L - (E - 1) * tau
    rows = Lp - max(Tp, 0)
    off = (E - 1) * tau + Tp
    Z = np.stack([x[k * tau:k * tau + Lp] for k in range(E)], axis=1)[:rows]
    A = np.concatenate([np.ones((rows, 1)), Z], axis=1)
    d = np.sqrt(((Z[:, None, :] - Z[None, :, :]) ** 2).sum(-1))
    yv = Y[:, off:off + rows]
    N = Y.shape[0]
    pred = np.zeros((N, rows))
    coef = np.zeros((N, rows, E + 1))
    for j in range(rows):
        dbar = d[j].mean()
        w = np.exp(-theta * d[j] / max(dbar, 1e-30))
        if exclude_self:
            w[j] = 0.0
        sw = np.sqrt(w)[:, None]
        for n in range(N):
            b, *_ = np.linalg.lstsq(A * sw, yv[n] * sw[:, 0], rcond=None)
            pred[n, j] = A[j] @ b
            coef[n, j] = b
    return pred, yv, coef


def _rho(pred, truth):
    return np.asarray(ref.pearson_rows(jnp.asarray(pred[None]),
                                       jnp.asarray(truth[None])))[0]


@pytest.mark.parametrize("E,tau,Tp", [
    (1, 1, 1), (2, 1, 0), (3, 2, 1), (2, 2, 3), (4, 1, 2),
])
@pytest.mark.parametrize("theta", [0.0, 0.5, 4.0])
def test_engine_matches_numpy_lstsq_oracle(rng, E, tau, Tp, theta):
    """Acceptance: engine ρ agrees with the per-query lstsq oracle ≤1e-4."""
    x = np.asarray(ts.logistic_map(130)) + 0.01 * rng.normal(size=130).astype(
        np.float32)
    want_p, truth, _ = _numpy_smap(x, x[None], E=E, tau=tau, Tp=Tp,
                                   theta=theta)
    got_p, got_t = core.smap_predict(jnp.asarray(x), E=E, tau=tau, Tp=Tp,
                                     theta=theta, impl="ref")
    np.testing.assert_allclose(np.asarray(got_t), truth[0], rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_p), want_p[0], rtol=1e-2,
                               atol=1e-3)
    assert abs(_rho(np.asarray(got_p), truth[0])
               - _rho(want_p[0], truth[0])) <= 1e-4


@pytest.mark.parametrize("L,E,tau,Tp,excl,block", [
    (137, 3, 2, 1, True, (16, 128)),   # gj = 1, partial row tiles
    (300, 2, 1, 1, True, (16, 128)),   # gj > 1: streaming column merge
    (300, 1, 1, 0, False, (8, 256)),   # E=1, Tp=0, self included
    (413, 5, 1, 3, True, (64, 128)),   # partial tiles at both axes
])
def test_gram_kernel_interpret_matches_ref(rng, L, E, tau, Tp, excl, block):
    x = jnp.asarray(rng.normal(size=L).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(2, L)).astype(np.float32))
    thetas = (0.0, 0.7, 3.0)
    want_G, want_M = ref.smap_gram(x, Y, E=E, tau=tau, Tp=Tp, thetas=thetas,
                                   exclude_self=excl)
    got_G, got_M = smap_gram_kernel(x, Y, E=E, tau=tau, Tp=Tp, thetas=thetas,
                                    exclude_self=excl, block=block,
                                    interpret=True)
    scale_G = float(np.abs(np.asarray(want_G)).max())
    scale_M = float(np.abs(np.asarray(want_M)).max())
    np.testing.assert_allclose(np.asarray(got_G), np.asarray(want_G),
                               rtol=1e-5, atol=1e-5 * max(scale_G, 1.0))
    np.testing.assert_allclose(np.asarray(got_M), np.asarray(want_M),
                               rtol=1e-5, atol=1e-5 * max(scale_M, 1.0))


def test_gram_dispatch_interpret_matches_ref():
    x = jnp.asarray(ts.logistic_map(200))
    want = ops.smap_gram(x, x[None], E=2, thetas=(0.0, 2.0), impl="ref")
    got = ops.smap_gram(x, x[None], E=2, thetas=(0.0, 2.0),
                        impl="interpret", block=(32, 128))
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4,
                                   atol=1e-4)


@pytest.mark.parametrize("theta", [0.0, 2.0, 8.0])
def test_smap_predict_matches_seed(theta):
    """Engine path ≡ the seed per-query lstsq path (ρ within 1e-4)."""
    x = jnp.asarray(ts.logistic_map(250))
    p_new, t_new = core.smap_predict(x, E=2, theta=theta, impl="ref")
    p_old, t_old = core.smap_predict_seed(x, E=2, theta=theta)
    np.testing.assert_allclose(np.asarray(t_new), np.asarray(t_old),
                               rtol=1e-6, atol=1e-6)
    assert abs(_rho(np.asarray(p_new), np.asarray(t_new))
               - _rho(np.asarray(p_old), np.asarray(t_old))) <= 1e-4


def test_nonlinearity_test_is_one_engine_call_and_matches_seed():
    """ρ(θ) from the fused sweep ≡ stacking per-θ seed skills."""
    x = jnp.asarray(ts.logistic_map(220))
    thetas = (0.0, 0.5, 2.0, 8.0)
    got = np.asarray(core.nonlinearity_test(x, E=2, thetas=thetas,
                                            impl="ref"))
    for t, theta in enumerate(thetas):
        pred, truth = core.smap_predict_seed(x, E=2, theta=theta)
        want = _rho(np.asarray(pred), np.asarray(truth))
        np.testing.assert_allclose(got[t], want, rtol=1e-4, atol=1e-4)


def test_smap_predict_batch_agrees_per_series():
    X = jnp.asarray(np.stack([ts.logistic_map(180, r=3.8),
                              ts.logistic_map(180, r=3.7, x0=0.5)]))
    thetas = (0.0, 1.0, 4.0)
    preds, truth = core.smap_predict_batch(X, E=2, thetas=thetas, impl="ref")
    rho = np.asarray(core.smap_theta_sweep(X, E=2, thetas=thetas, impl="ref"))
    assert preds.shape == (2, 3, 178) and truth.shape == (2, 178)
    assert rho.shape == (2, 3)
    for s in range(2):
        want = np.asarray(core.nonlinearity_test(X[s], E=2, thetas=thetas,
                                                 impl="ref"))
        np.testing.assert_allclose(rho[s], want, rtol=1e-5, atol=1e-5)


def test_constant_series_dbar_guard():
    """Regression (ISSUE 2 satellite): d̄ = 0 for a constant series must not
    produce NaN weights/predictions — mirrors the PR 1 make_weights all-inf
    fix. The ridge solve degrades to shrinkage toward the constant."""
    xc = jnp.full((80,), 0.7, jnp.float32)
    for theta in (0.0, 4.0):
        pred, truth = core.smap_predict(xc, E=2, theta=theta, impl="ref")
        assert np.isfinite(np.asarray(pred)).all(), f"NaN pred at θ={theta}"
        np.testing.assert_allclose(np.asarray(pred), 0.7, atol=1e-3)
    rho = np.asarray(core.smap_theta_sweep(xc[None], E=2,
                                           thetas=(0.0, 2.0), impl="ref"))
    assert np.isfinite(rho).all()  # zero-variance truth → ρ = 0, not NaN


def test_smap_cross_map_matches_numpy_oracle():
    xs, ys = ts.coupled_logistic(160, b_xy=0.0, b_yx=0.3, seed=7)
    lib, tgt = np.asarray(ys), np.asarray(xs)
    for theta in (0.0, 2.0):
        want_p, truth, _ = _numpy_smap(lib, tgt[None], E=2, tau=1, Tp=0,
                                       theta=theta)
        got = float(core.smap_cross_map(jnp.asarray(lib), jnp.asarray(tgt),
                                        E=2, theta=theta, impl="ref"))
        want = _rho(want_p[0], truth[0])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_smap_cross_map_theta_grid_shape_and_direction():
    xs, ys = ts.coupled_logistic(500, b_xy=0.0, b_yx=0.32, seed=3)
    x, y = jnp.asarray(xs), jnp.asarray(ys)
    thetas = (0.0, 1.0, 4.0)
    rho_grid = np.asarray(core.smap_cross_map(y, jnp.stack([x, y]), E=2,
                                              thetas=thetas, impl="ref"))
    assert rho_grid.shape == (3, 2)
    # X forces Y: cross-mapping X from Y's manifold beats the converse.
    rho_x_from_y = float(core.smap_cross_map(y, x, E=2, theta=2.0))
    rho_y_from_x = float(core.smap_cross_map(x, y, E=2, theta=2.0))
    assert rho_x_from_y > rho_y_from_x + 0.1, (
        f"asymmetry missing: {rho_x_from_y} vs {rho_y_from_x}")


def test_smap_matrix_group_consistency():
    panel, _ = ts.forced_network_panel(4, 260, seed=2)
    X = jnp.asarray(panel)
    E_opt = np.array([2, 3, 2, 3], np.int32)
    rho = core.smap_matrix(X, E_opt, theta=1.0)
    assert rho.shape == (4, 4)
    for l in range(4):
        for t in range(4):
            want = float(core.smap_cross_map(X[l], X[t], E=int(E_opt[t]),
                                             theta=1.0))
            np.testing.assert_allclose(rho[l, t], want, rtol=1e-4, atol=1e-4)


def test_smap_jacobian_tracks_logistic_derivative():
    """Deyle–Sugihara: at large θ the S-Map coefficients approximate the
    true state-dependent Jacobian — for the logistic map, f'(x) = r − 2rx."""
    r = 3.8
    x = jnp.asarray(ts.logistic_map(400, r=r))
    J = np.asarray(core.smap_jacobian(x, E=1, theta=8.0, impl="ref"))
    assert J.shape == (399, 1)
    truth = r - 2 * r * np.asarray(x)[:399]
    corr = np.corrcoef(J[:, 0], truth)[0, 1]
    assert corr > 0.95, f"Jacobian does not track f'(x): corr={corr}"


def test_smap_fit_coef_matches_oracle_coefficients(rng):
    x = np.asarray(ts.logistic_map(140)) + 0.01 * rng.normal(
        size=140).astype(np.float32)
    _, _, want_c = _numpy_smap(x, x[None], E=2, tau=1, Tp=1, theta=2.0)
    _, coef = core.smap_fit(jnp.asarray(x), jnp.asarray(x)[None], E=2,
                            thetas=(2.0,), impl="ref")
    assert coef.shape == (1, 1, 138, 3)
    np.testing.assert_allclose(np.asarray(coef[0, 0]), want_c[0], rtol=5e-2,
                               atol=5e-3)


def test_sharded_smap_theta_matches_local_single_device():
    panel, _ = ts.forced_network_panel(4, 220, seed=13)
    X = jnp.asarray(panel)
    mesh = make_ccm_mesh((1,), ("data",))
    thetas = (0.0, 1.0, 4.0)
    rho_s = np.asarray(sharded_smap_theta(X, E=2, thetas=thetas, mesh=mesh,
                                          impl="ref"))
    rho_l = np.asarray(core.smap_theta_sweep(X, E=2, thetas=thetas,
                                             impl="ref"))
    assert rho_s.shape == (4, 3)
    np.testing.assert_allclose(rho_s, rho_l, rtol=1e-5, atol=1e-5)


def test_sharded_smap_matrix_matches_local_single_device():
    panel, _ = ts.forced_network_panel(4, 220, seed=9)
    X = jnp.asarray(panel)
    mesh = make_ccm_mesh((1, 1), ("data", "model"))
    rho_s = np.asarray(sharded_smap_matrix(X, X, E=2, theta=1.0, mesh=mesh,
                                           impl="ref"))
    rho_l = core.smap_matrix(X, 2, theta=1.0, impl="ref")
    np.testing.assert_allclose(rho_s, rho_l, rtol=1e-4, atol=1e-4)
