"""Incremental master append ≡ cold rebuild, bit for bit.

The serving contract (ISSUE 8): ``ops.master_append`` grows a cached
multi-E kNN master by Δt points in O(Lp·(k+Δt)) per level and the result
must be indistinguishable — every distance bit, every index, every tie,
every garbage slot — from throwing the table away and rebuilding with
``ops.all_knn_multi_e`` on the full series. Anything weaker would make a
warm serving session's answers depend on its append history.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.edm.dataset import Dataset, screen_panel, series_stats
from repro.edm.plan import panel_master_append
from repro.edm.session import EDM
from repro.kernels import ops, ref


def _series(rng, L, kind):
    x = rng.normal(size=L).astype(np.float32)
    if kind == "tie":  # heavy value collisions → exercises tie ordering
        x = np.round(x * 2) / 2
    return jnp.asarray(x)


def _cold_and_grown(x, *, L_old, E_max, tau, k, impl="ref"):
    d0, i0 = ref.all_knn_multi_e(x[:L_old], E_max=E_max, tau=tau, k=k)
    grown = ops.master_append(x, d0, i0, tau=tau, impl=impl)
    cold = ref.all_knn_multi_e(x, E_max=E_max, tau=tau, k=k)
    return grown, cold


def _assert_bit_equal(grown, cold, msg=""):
    np.testing.assert_array_equal(np.asarray(grown[0]), np.asarray(cold[0]),
                                  err_msg=f"distances {msg}")
    np.testing.assert_array_equal(np.asarray(grown[1]), np.asarray(cold[1]),
                                  err_msg=f"indices {msg}")


@pytest.mark.parametrize("L_new,E_max,tau,dt", [
    (100, 3, 1, 1),
    (100, 3, 1, 17),
    (154, 4, 2, 7),     # Lp not a multiple of anything convenient
    (211, 6, 1, 64),    # deep levels, big tick
    (40, 3, 2, 7),      # thin levels after the slice
    (400, 1, 1, 32),    # E_max=1: no delay structure at all
])
@pytest.mark.parametrize("kind", ["rand", "tie"])
def test_append_bit_identical_to_cold_rebuild(rng, L_new, E_max, tau, dt,
                                              kind):
    x = _series(rng, L_new, kind)
    L_old = L_new - dt
    Lp1 = L_old - (E_max - 1) * tau
    k = min(Lp1 + 3, 20, L_old - 1)
    grown, cold = _cold_and_grown(x, L_old=L_old, E_max=E_max, tau=tau, k=k)
    _assert_bit_equal(grown, cold, f"(L={L_new}, E={E_max}, tau={tau}, "
                                   f"dt={dt}, {kind})")


@pytest.mark.parametrize("L_new,E_max,tau,dt,k", [
    (30, 4, 2, 2, 25),   # k_m exceeds deep levels' candidate count:
    (24, 6, 1, 3, 20),   # garbage (inf) slots present before AND after
    (20, 3, 2, 4, 16),   # the append, pattern must match cold exactly
])
def test_append_garbage_slots_match_cold(rng, L_new, E_max, tau, dt, k):
    x = _series(rng, L_new, "rand")
    grown, cold = _cold_and_grown(x, L_old=L_new - dt, E_max=E_max,
                                  tau=tau, k=k)
    assert not bool(np.isfinite(np.asarray(cold[0])).all()), \
        "regime check: this grid is meant to produce garbage slots"
    _assert_bit_equal(grown, cold, "(garbage regime)")


@pytest.mark.parametrize("impl", ["interpret"])
@pytest.mark.parametrize("L_new,E_max,tau,dt", [
    (100, 3, 1, 7),
    (154, 4, 2, 17),
    (30, 4, 2, 2),       # garbage regime via the kernel path too
])
def test_kernel_path_matches_cold(rng, impl, L_new, E_max, tau, dt):
    """The Pallas selection kernel inherits the same bit contract."""
    x = _series(rng, L_new, "tie")
    L_old = L_new - dt
    Lp1 = L_old - (E_max - 1) * tau
    k = 25 if L_new == 30 else min(Lp1 + 3, 20, L_old - 1)
    grown, cold = _cold_and_grown(x, L_old=L_old, E_max=E_max, tau=tau,
                                  k=k, impl=impl)
    _assert_bit_equal(grown, cold, f"(kernel, L={L_new})")


def test_multi_tick_append_equals_one_cold_build(rng):
    """Append history must not leak into the table: many small ticks
    land bit-identically on the single cold build of the final series."""
    L = 163
    x = _series(rng, L, "rand")
    d, i = ref.all_knn_multi_e(x[:100], E_max=3, tau=1, k=8)
    for stop in (101, 108, 131, 163):
        d, i = ops.master_append(x[:stop], d, i, tau=1)
    cold = ref.all_knn_multi_e(x, E_max=3, tau=1, k=8)
    _assert_bit_equal((d, i), cold, "(4 ticks)")


def test_panel_append_matches_panel_master(rng):
    X = jnp.asarray(rng.normal(size=(6, 120)).astype(np.float32))
    from repro.edm.plan import panel_master
    dM, iM = panel_master(X[:, :100], E_max=4, tau=1, k=7, impl="ref")
    grown = panel_master_append(X, dM, iM, tau=1, impl="ref")
    cold = panel_master(X, E_max=4, tau=1, k=7, impl="ref")
    _assert_bit_equal(grown, cold, "(panel)")


def test_append_args_validated(rng):
    x = _series(rng, 50, "rand")
    d, i = ref.all_knn_multi_e(x, E_max=3, tau=1, k=5)
    with pytest.raises(ValueError):  # dt < 1: nothing appended
        ops.master_append(x, d, i, tau=1)
    with pytest.raises(ValueError):  # shrunk series
        ops.master_append(x[:40], d, i, tau=1)
    with pytest.raises(ValueError):  # dists/idx shape mismatch
        ops.master_append(jnp.concatenate([x, x[:4]]), d, i[:, :-1], tau=1)


# ---------------------------------------------------------------- sessions


def test_session_append_master_bit_matches_cold_session(rng):
    full = rng.normal(size=(5, 130)).astype(np.float32)
    warm = EDM(full[:, :100], E_max=4, cache=True)
    warm.optimal_E()                       # builds + caches the master
    warm.append(full[:, 100:])
    cold = EDM(full, E_max=4, cache=True)
    cold._master(warm._cache["master"][3])
    _assert_bit_equal(warm._cache["master"][:2], cold._cache["master"][:2],
                      "(session master)")
    # ...and every consumer downstream of the master agrees too.
    np.testing.assert_array_equal(warm.optimal_E()[1], cold.optimal_E()[1])
    np.testing.assert_array_equal(np.asarray(warm.ccm(0, 2)),
                                  np.asarray(cold.ccm(0, 2)))
    assert warm.stats["knn_master_appends"] == 1
    assert warm.stats["knn_master_builds"] == 1


def test_session_append_without_master_stays_lazy(rng):
    sess = EDM(rng.normal(size=(4, 90)).astype(np.float32), E_max=3,
               cache=True)
    sess.append(rng.normal(size=(4, 5)).astype(np.float32))
    assert "master" not in sess._cache
    assert sess.stats.get("knn_master_appends", 0) == 0
    assert sess.data.L == 95


# ------------------------------------------------------- delta screening


def test_screen_panel_delta_mode_matches_full_screen(rng):
    full = rng.normal(size=(6, 80)).astype(np.float32)
    full[1, 70] = np.nan            # fault arrives in the delta
    full[3, :] = 2.5                # constant throughout
    prior = series_stats(full[:, :64])
    delta_recs = screen_panel(full[:, 64:], prior=prior)
    full_recs = screen_panel(full)
    assert ([r["index"] for r in delta_recs]
            == [r["index"] for r in full_recs] == [1, 3])
    assert "appended delta" in delta_recs[0]["reason"]
    assert delta_recs[1]["reason"] == "constant series"


def test_dataset_append_raise_names_series_and_mutates_nothing(rng):
    panel = rng.normal(size=(3, 60)).astype(np.float32)
    ds = Dataset(panel, names=["a", "b", "c"])
    bad = rng.normal(size=(3, 4)).astype(np.float32)
    bad[1, 2] = np.inf
    with pytest.raises(ValueError, match="series b"):
        ds.append(bad)
    assert ds.L == 60 and ds.valid.all() and not ds.invalid_report


def test_dataset_append_mask_and_drop_policies(rng):
    panel = rng.normal(size=(4, 60)).astype(np.float32)
    bad = rng.normal(size=(4, 4)).astype(np.float32)
    bad[2, 0] = np.nan
    dm = Dataset(panel, on_invalid="mask")
    recs = dm.append(bad)
    assert [r["index"] for r in recs] == [2]
    assert list(dm.valid) == [True, True, False, True]
    assert bool(np.isfinite(np.asarray(dm.panel)).all())

    dd = Dataset(panel, on_invalid="drop", names=list("wxyz"))
    recs = dd.append(bad)
    assert recs[0]["index"] == 2 and recs[0]["name"] == "y"
    assert dd.N == 3 and dd.names == ["w", "x", "z"] and dd.valid.all()


def test_dataset_append_constant_series_can_become_valid(rng):
    panel = rng.normal(size=(2, 50)).astype(np.float32)
    panel[1, :] = 7.0
    ds = Dataset(panel, on_invalid="mask")
    assert not ds.is_valid(1)
    delta = rng.normal(size=(2, 6)).astype(np.float32)
    assert ds.append(delta) == []          # nothing NEW became invalid
    assert ds.is_valid(1)                  # variation arrived: now usable


def test_session_append_drop_compacts_master_rows(rng):
    full = rng.normal(size=(5, 110)).astype(np.float32)
    bad = full[:, 100:].copy()
    bad[2, 3] = np.nan
    sess = EDM(Dataset(full[:, :100], on_invalid="drop"), E_max=3,
               cache=True)
    sess._master(3)
    sess.append(bad)
    keep = [0, 1, 3, 4]
    ref_full = full[keep].copy()
    ref_full[:, 100:] = np.asarray(bad)[keep]
    cold = EDM(ref_full, E_max=3, cache=True)
    cold._master(3)
    _assert_bit_equal(sess._cache["master"][:2], cold._cache["master"][:2],
                      "(drop compaction)")
