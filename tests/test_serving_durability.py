"""Durable serving: WAL + crash recovery (PR 10 tentpole, part 1).

The contract under test: with ``EDMServer(state_dir=...)``, every
registration and every *accepted* append is durable before its future
resolves, and ``EDMServer.recover(state_dir)`` rebuilds every panel
**bit-identically** at its pre-crash library version — including after
kill -9 mid-append-stream, a torn WAL tail, compaction, master
eviction, and masked-invalid panels. Oracles are cold sessions /
uninterrupted servers on the same data; equality is bitwise
(``np.float32`` compare), never approximate.
"""

import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro import telemetry
from repro.data import timeseries as ts
from repro.edm import EDM, EDMConfig
from repro.serving import (EDMServer, FaultInjector, PanelQuarantined,
                           WalError)

CFG = dict(E_max=3, cache=True)
E_REQ = 3
PAIRS = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]


@pytest.fixture(scope="module")
def panel():
    x, _ = ts.forced_network_panel(5, 240, seed=11)
    return np.asarray(x, np.float32)


@pytest.fixture(scope="module")
def deltas():
    rng = np.random.default_rng(23)
    return [rng.standard_normal((5, 4)).astype(np.float32)
            for _ in range(6)]


def _drain_all(srv):
    while srv.scheduler.drain_once():
        pass


def _grown(panel, deltas, k):
    return (panel if k == 0
            else np.concatenate([panel, *deltas[:k]], axis=1))


def _served_ccm(srv, name, pairs):
    futs = srv.submit_many(
        "ccm", name, [{"lib": l, "target": t, "E": E_REQ}
                      for l, t in pairs])
    _drain_all(srv)
    return [np.float32(f.result()) for f in futs]


def _oracle_ccm(grown, pairs):
    sess = EDM(grown, EDMConfig(**CFG))
    return [np.float32(v) for v in sess.ccm_batch(pairs, E=E_REQ)]


# ------------------------------------------------- basic WAL round trip


def test_recover_bit_identical_after_appends(tmp_path, panel, deltas):
    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False) as srv:
        srv.register_panel("p", panel, **CFG)
        _served_ccm(srv, "p", PAIRS)  # warm master: appends then merge
        for d in deltas[:3]:
            f = srv.submit("append", "p", delta=d)
            _drain_all(srv)
            assert f.result()["version"] >= 1

    rec = EDMServer.recover(sd, autostart=False)
    try:
        info = rec.recovery_report["p"]
        assert info["version"] == 3 and info["torn_tail_bytes"] == 0
        entry = rec.registry.get("p")
        assert entry.version == 3
        got = _served_ccm(rec, "p", PAIRS)
        want = _oracle_ccm(_grown(panel, deltas, 3), PAIRS)
        assert got == want  # bitwise: float32 equality
    finally:
        rec.close()


def test_recovered_panel_keeps_appending_bit_identically(
        tmp_path, panel, deltas):
    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False) as srv:
        srv.register_panel("p", panel, **CFG)
        srv.submit("append", "p", delta=deltas[0])
        _drain_all(srv)

    rec = EDMServer.recover(sd, autostart=False)
    try:
        f = rec.submit("append", "p", delta=deltas[1])
        _drain_all(rec)
        assert f.result()["version"] == 2
        got = _served_ccm(rec, "p", PAIRS)
        want = _oracle_ccm(_grown(panel, deltas, 2), PAIRS)
        assert got == want
    finally:
        rec.close()


def test_compaction_bounds_replay_and_gcs_segments(
        tmp_path, panel, deltas):
    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False,
                   compact_every=2) as srv:
        srv.register_panel("p", panel, **CFG)
        for d in deltas[:5]:
            srv.submit("append", "p", delta=d)
            _drain_all(srv)
        pdir = srv.registry.get("p").wal.pdir
        names = sorted(os.listdir(pdir))
    # compactions at v2 and v4 ran; older snapshots/WALs are GC'd.
    assert "snap-0000000004" in names
    assert "wal-0000000004.log" in names
    assert not any(n.startswith(("snap-0000000002", "wal-0000000000",
                                 "wal-0000000002")) for n in names)

    rec = EDMServer.recover(sd, autostart=False)
    try:
        info = rec.recovery_report["p"]
        assert info["snapshot"] == 4 and info["replayed"] == 1
        assert info["version"] == 5
        got = _served_ccm(rec, "p", PAIRS)
        assert got == _oracle_ccm(_grown(panel, deltas, 5), PAIRS)
    finally:
        rec.close()


# -------------------------------------------------- recovery edge cases


def test_truncated_wal_tail_recovers_to_last_record_and_warns(
        tmp_path, panel, deltas):
    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False,
                   compact_every=100) as srv:
        srv.register_panel("p", panel, **CFG)
        for d in deltas[:3]:
            srv.submit("append", "p", delta=d)
            _drain_all(srv)
        pdir = srv.registry.get("p").wal.pdir

    wal = Path(pdir) / "wal-0000000000.log"
    blob = wal.read_bytes()
    wal.write_bytes(blob[:-7])  # tear the final record mid-payload

    with pytest.warns(UserWarning, match="torn tail"):
        rec = EDMServer.recover(sd, autostart=False)
    try:
        info = rec.recovery_report["p"]
        assert info["version"] == 2 and info["torn_tail_bytes"] > 0
        got = _served_ccm(rec, "p", PAIRS)
        assert got == _oracle_ccm(_grown(panel, deltas, 2), PAIRS)
        # The post-recovery rotation truncated the torn tail for good:
        # a second recovery is clean.
        rec.close()
        rec2 = EDMServer.recover(sd, autostart=False)
        assert rec2.recovery_report["p"]["version"] == 2
        assert rec2.recovery_report["p"]["torn_tail_bytes"] == 0
        rec2.close()
    finally:
        rec.close()


def test_fingerprint_mismatch_is_refused(tmp_path, panel):
    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False) as srv:
        srv.register_panel("p", panel, **CFG)
        pdir = srv.registry.get("p").wal.pdir
    tampered = np.array(np.load(os.path.join(pdir, "base.npy")))
    tampered[0, 0] += 1.0
    np.save(os.path.join(pdir, "base.npy"), tampered)
    with pytest.raises(WalError, match="fingerprint"):
        EDMServer.recover(sd, autostart=False)


def test_recover_evicted_master_panel(tmp_path, panel, deltas):
    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False) as srv:
        srv.register_panel("p", panel, **CFG)
        _served_ccm(srv, "p", PAIRS)      # builds the master
        srv.submit("append", "p", delta=deltas[0])
        _drain_all(srv)
        assert srv.evict_panel("p") > 0   # cold on disk AND in memory

    rec = EDMServer.recover(sd, autostart=False)
    try:
        assert rec.recovery_report["p"]["version"] == 1
        got = _served_ccm(rec, "p", PAIRS)
        assert got == _oracle_ccm(_grown(panel, deltas, 1), PAIRS)
    finally:
        rec.close()


def test_subscription_reregistered_post_restart(tmp_path, panel, deltas):
    sd = str(tmp_path / "state")
    watch = PAIRS[:3]
    with EDMServer(state_dir=sd, autostart=False) as srv:
        srv.register_panel("p", panel, **CFG)
        f = srv.submit("subscribe", "p",
                       pairs=[list(p) for p in watch], E=E_REQ)
        _drain_all(srv)
        f.result()
        srv.submit("append", "p", delta=deltas[0])
        _drain_all(srv)

    # Subscriptions are NOT durable state: recovery starts with none,
    # and a re-registered watch list linearizes with the new stream.
    rec = EDMServer.recover(sd, autostart=False)
    try:
        assert rec.subscriptions.count() == 0
        f = rec.submit("subscribe", "p",
                       pairs=[list(p) for p in watch], E=E_REQ)
        _drain_all(rec)
        sub = f.result()
        assert sub["version"] == 1
        assert [np.float32(v) for v in sub["rho"]] == _oracle_ccm(
            _grown(panel, deltas, 1), watch)
        rec.submit("append", "p", delta=deltas[1])
        _drain_all(rec)
        ticks = rec.subscription(sub["id"]).poll(timeout=1.0)
        assert ticks and ticks[-1]["version"] == 2
        assert [np.float32(v) for v in ticks[-1]["rho"]] == _oracle_ccm(
            _grown(panel, deltas, 2), watch)
    finally:
        rec.close()


def test_mask_policy_panel_recovers_bit_identically(tmp_path):
    rng = np.random.default_rng(3)
    dirty = rng.standard_normal((4, 120)).astype(np.float32)
    dirty[1, 10] = np.nan                       # masked at registration
    d0 = rng.standard_normal((4, 5)).astype(np.float32)
    d1 = rng.standard_normal((4, 5)).astype(np.float32)
    d1[2, 3] = np.inf                           # masked at append time

    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False, compact_every=1) as srv, \
            EDMServer(autostart=False) as live:
        for s in (srv, live):
            s.register_panel("p", dirty, on_invalid="mask", **CFG)
            for d in (d0, d1):
                s.submit("append", "p", delta=d)
                _drain_all(s)
        live_ds = live.registry.get("p").sess.data

        rec = EDMServer.recover(sd, autostart=False)
        try:
            ds = rec.registry.get("p").sess.data
            assert np.asarray(ds.panel).tobytes() == \
                np.asarray(live_ds.panel).tobytes()
            assert np.array_equal(ds.valid, live_ds.valid)
            for k in ("cnt", "lo", "hi"):
                assert np.array_equal(ds._stats[k], live_ds._stats[k])
            assert ds.invalid_report == live_ds.invalid_report
        finally:
            rec.close()


def test_reregister_into_existing_state_dir_is_refused(tmp_path, panel):
    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False) as srv:
        srv.register_panel("p", panel, **CFG)
    with EDMServer(state_dir=sd, autostart=False) as srv2:
        with pytest.raises(ValueError, match="recover"):
            srv2.register_panel("p", panel, **CFG)
        # the failed durable publish rolled the registry claim back
        with pytest.raises(KeyError):
            srv2.registry.get("p")
        srv2.register_panel("other", panel, **CFG)  # new names still fine


def test_config_mesh_refused_for_durable_registration(tmp_path, panel):
    import types
    mesh = types.SimpleNamespace(axis_names=("data", "model"))
    sd = str(tmp_path / "state")
    with EDMServer(state_dir=sd, autostart=False) as srv:
        with pytest.raises(ValueError, match="mesh"):
            srv.register_panel("p", panel,
                               config=EDMConfig(mesh=mesh, **CFG))
        with pytest.raises(KeyError):
            srv.registry.get("p")


# ------------------------------------------- WAL failure == quarantine


def test_wal_write_failure_quarantines_panel(tmp_path, panel, deltas):
    fi = FaultInjector(seed=0, rates={"wal_write": 1.0})
    sd = str(tmp_path / "state")
    with telemetry.record() as rec:
        with EDMServer(state_dir=sd, autostart=False, faults=fi) as srv:
            srv.register_panel("p", panel, **CFG)
            f = srv.submit("append", "p", delta=deltas[0])
            _drain_all(srv)
            with pytest.raises(Exception, match="injected WAL"):
                f.result(timeout=5)
            # memory is ahead of the log: the panel fails fast now
            with pytest.raises(PanelQuarantined):
                srv.submit("ccm", "p", lib=0, target=1, E=E_REQ)
            assert "p" in srv.scheduler.quarantined_panels()
    assert rec.counter_delta("serve_quarantined") == 1

    # recovery serves the last DURABLE version (0), bit-identically
    rec2 = EDMServer.recover(sd, autostart=False)
    try:
        assert rec2.recovery_report["p"]["version"] == 0
        got = _served_ccm(rec2, "p", PAIRS)
        assert got == _oracle_ccm(panel, PAIRS)
    finally:
        rec2.close()


# ------------------------------------------------ kill -9 (the big one)

_CHILD = r"""
import os, sys, time
import numpy as np
from repro.serving import EDMServer

state_dir, n_appends = sys.argv[1], int(sys.argv[2])
panel = np.load(os.path.join(state_dir, "panel.npy"))
delta = np.load(os.path.join(state_dir, "delta.npy"))
srv = EDMServer(state_dir=state_dir, workers=1)
srv.register_panel("kp", panel, E_max=3, cache=True)
srv.call("ccm", "kp", lib=0, target=1, E=3)   # warm master: appends merge
print("READY", flush=True)
for k in range(n_appends):
    r = srv.call("append", "kp", delta=delta)
    print(f"ACK {r['version']}", flush=True)
print("DONE", flush=True)
time.sleep(120)
"""


@pytest.mark.slow
def test_kill9_mid_append_stream_recovers_bit_identically(
        tmp_path, panel, deltas):
    """kill -9 between append ticks; recovery must restore the panel at
    its last durable version with answers bit-identical to an
    uninterrupted session at that version (the acceptance assert)."""
    sd = str(tmp_path / "state")
    os.makedirs(sd)
    delta = deltas[0]
    n_appends = 6
    np.save(os.path.join(sd, "panel.npy"), panel)
    np.save(os.path.join(sd, "delta.npy"), delta)

    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen([sys.executable, "-c", _CHILD, sd,
                             str(n_appends)],
                            stdout=subprocess.PIPE, text=True, env=env)
    acked = 0
    try:
        deadline = time.monotonic() + 180
        for line in proc.stdout:
            if line.startswith("ACK"):
                acked = int(line.split()[1])
                if acked >= 2:
                    break  # kill -9 mid-stream, between ticks
            if time.monotonic() > deadline:
                raise TimeoutError("child never reached 2 acks")
        assert acked >= 2, "child exited before acking"
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)

    rec = EDMServer.recover(sd, autostart=False)
    try:
        v = rec.recovery_report["kp"]["version"]
        # every ACKed append is durable; later un-ACKed ticks may or may
        # not have hit the log before the kill
        assert acked <= v <= n_appends
        assert rec.registry.get("kp").version == v
        grown = np.concatenate([panel] + [delta] * v, axis=1)
        assert rec.registry.get("kp").sess.data.L == grown.shape[1]
        got = _served_ccm(rec, "kp", PAIRS)
        assert got == _oracle_ccm(grown, PAIRS)
    finally:
        rec.close()
