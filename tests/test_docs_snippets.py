"""Execute the fenced ``python`` snippets in README.md and docs/*.md.

The docs are part of tier-1: every ```python block is executed top to
bottom in one namespace per file (so a later block may use names an
earlier one defined), against a small synthetic panel pre-seeded under
the documented convention names (``panel``, ``panel_a``, ``panel_b``).
Blocks containing a literal ``...`` are illustrative fragments and are
skipped. An API rename or signature change that would silently rot the
docs fails here instead.
"""

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = [ROOT / "README.md", ROOT / "docs" / "API.md",
        ROOT / "docs" / "ARCHITECTURE.md"]

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _blocks(path: pathlib.Path) -> list[str]:
    return [m.group(1) for m in _FENCE.finditer(path.read_text())]


@pytest.mark.parametrize("path", DOCS, ids=lambda p: p.name)
def test_doc_snippets_execute(path):
    assert path.exists(), f"documented file {path} is missing"
    blocks = _blocks(path)
    assert blocks, f"{path.name} has no python snippets"
    from repro.data import timeseries as ts
    panel, _ = ts.forced_network_panel(6, 600, seed=7)
    ns = {"panel": panel, "panel_a": panel[:3], "panel_b": panel[3:]}
    ran = 0
    for i, code in enumerate(blocks):
        if "..." in code:
            continue  # illustrative fragment by convention
        exec(compile(code, f"{path.name}[block {i}]", "exec"), ns)
        ran += 1
    assert ran, f"{path.name}: every python snippet was skipped"
