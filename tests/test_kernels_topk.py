"""Pallas k-pass top-k kernel vs jnp oracle and numpy full sort."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def _dist(rng, Lp):
    x = jnp.asarray(rng.normal(size=Lp + 4).astype(np.float32))
    return ref.pairwise_distances(x, E=5, tau=1)


@pytest.mark.parametrize("Lp", [16, 33, 100, 131])
@pytest.mark.parametrize("k", [1, 2, 6, 21])
@pytest.mark.parametrize("block_rows", [4, 8, 16])
def test_topk_matches_ref(rng, Lp, k, block_rows):
    if k >= Lp:
        pytest.skip("k must be < Lp with self-exclusion")
    D = _dist(rng, Lp)
    want_d, want_i = ref.topk_select(D, k=k)
    got_d, got_i = ops.topk_select(D, k=k, impl="interpret",
                                   block_rows=block_rows)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6, atol=1e-6)


def test_topk_vs_numpy_sort(rng):
    D = np.asarray(_dist(rng, 77))
    k = 8
    got_d, got_i = ops.topk_select(jnp.asarray(D), k=k, impl="interpret")
    Dm = D + np.where(np.eye(77, dtype=bool), np.inf, 0.0)
    want = np.sqrt(np.sort(Dm, axis=1)[:, :k])
    np.testing.assert_allclose(np.asarray(got_d), want, rtol=1e-5, atol=1e-6)
    # indices actually point at those distances
    rows = np.arange(77)[:, None]
    np.testing.assert_allclose(
        np.sqrt(Dm[rows, np.asarray(got_i)]), want, rtol=1e-5, atol=1e-6
    )


def test_topk_exclude_self_and_sorted(rng):
    D = _dist(rng, 60)
    d, i = ops.topk_select(D, k=5, impl="interpret")
    i = np.asarray(i)
    assert (i != np.arange(60)[:, None]).all(), "self must be excluded"
    d = np.asarray(d)
    assert (np.diff(d, axis=1) >= 0).all(), "ascending order"


def test_topk_include_self(rng):
    D = _dist(rng, 40)
    d, i = ops.topk_select(D, k=3, exclude_self=False, impl="interpret")
    assert (np.asarray(i)[:, 0] == np.arange(40)).all()
    np.testing.assert_allclose(np.asarray(d)[:, 0], 0.0, atol=1e-6)


@pytest.mark.parametrize("max_idx", [5, 20, 39])
def test_topk_max_idx_dynamic(rng, max_idx):
    """Library-prefix restriction (convergence sweeps) without re-lowering."""
    D = _dist(rng, 40)
    want_d, want_i = ref.topk_select(D, k=4, max_idx=max_idx)
    got_d, got_i = ops.topk_select(D, k=4, max_idx=max_idx, impl="interpret")
    assert int(np.asarray(got_i).max()) <= max_idx
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6, atol=1e-6)


def test_topk_select_chunked_path_matches_plain(rng):
    """ref.topk_select now routes through the two-stage chunk-max prefilter
    (ISSUE 2 satellite); on an Lp large enough to activate it (Lp > 4·W,
    k < n_chunks) it must stay bit-identical to full-row lax.top_k —
    values, indices, and tie order."""
    Lp = 333  # 11 chunks of W=32, padded last chunk
    x = jnp.asarray(rng.normal(size=Lp + 4).astype(np.float32))
    D = ref.pairwise_distances(x, E=5, tau=1)
    for k, max_idx in ((4, None), (1, None), (8, 100)):
        got_d, got_i = ref.topk_select(D, k=k, max_idx=max_idx)
        Dm = jnp.where(jnp.eye(Lp, dtype=bool), jnp.inf, D)
        if max_idx is not None:
            Dm = jnp.where(jnp.arange(Lp)[None, :] > max_idx, jnp.inf, Dm)
        nd, ik = jax.lax.top_k(-Dm, k)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ik))
        np.testing.assert_array_equal(np.asarray(got_d),
                                      np.sqrt(np.maximum(-np.asarray(nd), 0)))


def test_topk_select_chunked_path_tie_stability():
    """Mass ties across chunk boundaries: first (lowest) index must win,
    exactly as the seed's full-row stable top_k."""
    Lp = 256  # 8 chunks, all-equal rows force cross-chunk ties everywhere
    D = jnp.ones((Lp, Lp), jnp.float32)
    got_d, got_i = ref.topk_select(D, k=5, exclude_self=True)
    want_i = np.tile(np.arange(5), (Lp, 1))
    want_i[:5] = [[j for j in range(6) if j != r][:5] for r in range(5)]
    np.testing.assert_array_equal(np.asarray(got_i), want_i)
    np.testing.assert_allclose(np.asarray(got_d), 1.0)


def test_topk_ties_are_stable(rng):
    """Duplicate distances: first index wins, matching the oracle."""
    Lp = 32
    D = np.ones((Lp, Lp), np.float32)  # all distances equal
    np.fill_diagonal(D, 0.0)
    got_d, got_i = ops.topk_select(jnp.asarray(D), k=3, impl="interpret")
    want_d, want_i = ref.topk_select(jnp.asarray(D), k=3)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


# ------------------------- multi-cap streaming variant (topk_select_sizes)


@pytest.mark.parametrize("caps", [(0,), (3, 17, 60, 99), (50, 2000)])
@pytest.mark.parametrize("block", [(8, 32), (4, 128), (16, 512)])
def test_topk_sizes_kernel_matches_ref(rng, caps, block):
    """Column-tiled streaming kernel ≡ the jnp oracle for any tiling:
    distances, indices, and the inf/PAD_IDX invalid-slot contract."""
    D = _dist(rng, 104)
    want_d, want_i = ref.topk_select_sizes(D, k=6, max_idxs=caps)
    got_d, got_i = ops.topk_select_sizes(D, k=6, max_idxs=caps,
                                         impl="interpret", block=block)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("exclude_self", [True, False])
def test_topk_sizes_kernel_exclude_self(rng, exclude_self):
    D = _dist(rng, 70)
    caps = (10, 42, 69)
    want_d, want_i = ref.topk_select_sizes(D, k=4, max_idxs=caps,
                                           exclude_self=exclude_self)
    got_d, got_i = ops.topk_select_sizes(D, k=4, max_idxs=caps,
                                         exclude_self=exclude_self,
                                         impl="interpret", block=(8, 32))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6, atol=1e-6)


def test_topk_sizes_kernel_tie_stability():
    """Mass ties spanning column blocks AND cap boundaries: min global
    index must win at every cap, as in the stable full-row sort."""
    Lp = 96
    D = jnp.ones((Lp, Lp), jnp.float32)
    caps = (7, 40, 95)
    want_d, want_i = ref.topk_select_sizes(D, k=5, max_idxs=caps)
    got_d, got_i = ops.topk_select_sizes(D, k=5, max_idxs=caps,
                                         impl="interpret", block=(8, 16))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))


def test_topk_sizes_single_cap_equals_topk_select(rng):
    """S=1 degenerates to the plain kernel's semantics on valid slots."""
    D = _dist(rng, 64)
    got_d, got_i = ops.topk_select_sizes(D, k=4, max_idxs=(50,),
                                         impl="interpret", block=(8, 32))
    wd, wi = ref.topk_select(D, k=4, max_idx=50)
    fin = np.isfinite(np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(got_i[0])[fin],
                                  np.asarray(wi)[fin])
    np.testing.assert_allclose(np.asarray(got_d[0]), np.asarray(wd),
                               rtol=1e-6, atol=1e-6)
