"""Chaos suite for the serving stack (PR 10 tentpole, part 2).

Seeded fault-injection scenarios drive randomized kill → recover →
append cycles through a live ``EDMServer`` and check the two contracts
the overload/failure design promises:

* **Liveness** — every submitted request resolves within bound: a
  result, ``Overloaded``, ``DeadlineExceeded``, ``PanelQuarantined``,
  an injected fault, or a named worker-death error. Never a hung
  future.
* **Linearizability** — every *successful* CCM answer is bit-identical
  to a singleton oracle at some consistent library version: exactly the
  number of successful appends submitted before it (per-panel FIFO +
  version barrier). Every successful append's version is its 1-based
  rank among successful appends. After ``close`` → ``recover``, the
  panel is at version == #successful appends and serves oracle bits.

The oracle trick: every append in a scenario carries the IDENTICAL
delta, so library state after k commits depends only on k — one cold
session per commit count answers for every interleaving the thread
pool can produce (asserts stay schedule-independent even though the
fault draws land on different requests per run).
"""

import bisect
import threading
import time
from concurrent.futures import TimeoutError as _FutureTimeout

import numpy as np
import pytest

from repro import telemetry
from repro.data import timeseries as ts
from repro.edm import EDM, EDMConfig
from repro.serving import (DeadlineExceeded, Draining, EDMServer,
                           FaultInjector, Overloaded, PanelQuarantined,
                           WalError)
from repro.serving.faultinject import (POINTS, InjectedFault,
                                       InjectedWalError,
                                       InjectedWorkerDeath)

N, L0, DL = 4, 120, 3
MAX_APPENDS = 8
WATCH = [(0, 1), (1, 2), (2, 3), (3, 0)]
ES = (2, 3)

_PANEL = None
_DELTA = None
_ORACLE: dict[int, dict] = {}


def _panel():
    global _PANEL, _DELTA
    if _PANEL is None:
        x, _ = ts.forced_network_panel(N, L0, seed=5)
        _PANEL = np.asarray(x, np.float32)
        _DELTA = np.random.default_rng(7).standard_normal(
            (N, DL)).astype(np.float32)
    return _PANEL, _DELTA


def oracle(k: int) -> dict:
    """Singleton answers at commit count ``k`` (cold session)."""
    if k not in _ORACLE:
        panel, delta = _panel()
        grown = (panel if k == 0
                 else np.concatenate([panel] + [delta] * k, axis=1))
        sess = EDM(grown, EDMConfig(E_max=3, cache=True))
        _ORACLE[k] = {E: [np.float32(v)
                          for v in sess.ccm_batch(WATCH, E=E)]
                      for E in ES}
    return _ORACLE[k]


# --------------------------------------------------- injector unit tests


def test_fault_injector_is_seed_deterministic():
    rates = {p: 0.5 for p in POINTS}
    a = FaultInjector(seed=3, rates=rates)
    b = FaultInjector(seed=3, rates=rates)
    c = FaultInjector(seed=4, rates=rates)
    seq = {fi: {p: [fi.fire(p) for _ in range(50)] for p in POINTS}
           for fi in (a, b, c)}
    assert seq[a] == seq[b]              # same seed → same draws
    assert seq[a] != seq[c]              # different seed → different
    # streams are independent per point: firing one point does not
    # perturb another's sequence
    d = FaultInjector(seed=3, rates=rates)
    only_wal = [d.fire("wal_write") for _ in range(50)]
    assert only_wal == seq[a]["wal_write"]


def test_fault_injector_max_fires_and_counters():
    fi = FaultInjector(seed=0, rates={"launch_error": 1.0}, max_fires=2)
    hits = [fi.fire("launch_error") for _ in range(10)]
    assert sum(hits) == 2 and hits[:2] == [True, True]
    assert fi.calls["launch_error"] == 10
    assert fi.fired["launch_error"] == 2
    with pytest.raises(InjectedFault, match="RESOURCE_EXHAUSTED"):
        FaultInjector(rates={"launch_oom": 1.0}).check("launch_oom")
    with pytest.raises(InjectedWalError, match="injected WAL"):
        FaultInjector(rates={"wal_write": 1.0}).check("wal_write")
    with pytest.raises(ValueError, match="unknown fault points"):
        FaultInjector(rates={"nope": 1.0})


# -------------------------------------------------------- chaos scenarios


def _allowed(exc: BaseException) -> bool:
    if isinstance(exc, (Overloaded, DeadlineExceeded, PanelQuarantined,
                        Draining, InjectedFault, OSError, WalError)):
        return True
    return (isinstance(exc, RuntimeError)
            and str(exc).startswith(("serve worker died",
                                     "scheduler closed")))


RATES = {"worker_death": 0.08, "launch_error": 0.08,
         "launch_oom": 0.05, "slow_launch": 0.10, "wal_write": 0.03}


@pytest.mark.parametrize("seed", range(20))
def test_chaos_scenario_liveness_and_linearizability(seed, tmp_path):
    panel, delta = _panel()
    rng = np.random.default_rng((20260808, seed))
    sd = str(tmp_path / "state")
    fi = FaultInjector(seed=seed, rates=RATES, slow_s=0.005)
    srv = EDMServer(state_dir=sd, compact_every=4, workers=2,
                    supervise=True, max_queue_depth=64,
                    quarantine_after=3, faults=fi,
                    revive_backoff_s=(0.01, 0.1))
    srv.scheduler.supervise_interval = 0.02
    submitted = []      # (kind, fut, ticket, j, E)
    n_appends = 0
    try:
        srv.register_panel("cp", panel, E_max=3, cache=True)
        for _ in range(28):
            do_append = n_appends < MAX_APPENDS and rng.random() < 0.3
            try:
                if do_append:
                    n_appends += 1
                    f = srv.submit("append", "cp", delta=delta)
                    submitted.append(("append", f, f.ticket, None, None))
                else:
                    j = int(rng.integers(len(WATCH)))
                    E = int(rng.choice(ES))
                    kw = {}
                    if rng.random() < 0.1:
                        kw["deadline_s"] = 0.0   # guaranteed to expire
                    f = srv.submit("ccm", "cp", lib=WATCH[j][0],
                                   target=WATCH[j][1], E=E, **kw)
                    submitted.append(("ccm", f, f.ticket, j, E))
            except Exception as exc:  # refused at admission
                assert _allowed(exc), f"submit raised {exc!r}"

        # ---- liveness: EVERY accepted future resolves within bound
        outcomes = []
        for kind, fut, ticket, j, E in submitted:
            try:
                res = fut.result(timeout=120)
            except _FutureTimeout:
                pytest.fail(f"hung future: ticket {ticket} ({kind})")
            except Exception as exc:
                assert _allowed(exc), \
                    f"ticket {ticket} ({kind}) failed with {exc!r}"
                outcomes.append((kind, ticket, j, E, None))
            else:
                outcomes.append((kind, ticket, j, E, res))

        # ---- linearizability against the commit-count oracle
        ok_appends = sorted(t for k, t, _, _, r in outcomes
                            if k == "append" and r is not None)
        for rank, t in enumerate(ok_appends):
            _, _, _, _, res = next(o for o in outcomes if o[1] == t)
            assert res["version"] == rank + 1
        for kind, ticket, j, E, res in outcomes:
            if kind != "ccm" or res is None:
                continue
            k = bisect.bisect_left(ok_appends, ticket)
            assert np.float32(res) == oracle(k)[E][j], \
                f"ticket {ticket}: served bits diverge from oracle[{k}]"
    finally:
        srv.close()

    # ---- crash recovery: durable state == the successful appends
    n_committed = len(ok_appends)
    rec = EDMServer.recover(sd, autostart=False)
    try:
        assert rec.recovery_report["cp"]["version"] == n_committed
        futs = rec.submit_many(
            "ccm", "cp", [{"lib": l, "target": t, "E": 3}
                          for l, t in WATCH])
        while rec.scheduler.drain_once():
            pass
        got = [np.float32(f.result()) for f in futs]
        assert got == oracle(n_committed)[3]
    finally:
        rec.close()


# ------------------------------------------------- supervisor + drain


def test_supervisor_revives_dead_worker_and_service_resumes():
    panel, _ = _panel()
    fi = FaultInjector(seed=1, rates={"worker_death": 1.0}, max_fires=1)
    with telemetry.record() as rec:
        srv = EDMServer(workers=1, supervise=True, faults=fi,
                        revive_backoff_s=(0.01, 0.05))
        srv.scheduler.supervise_interval = 0.01
        try:
            srv.register_panel("sp", panel, E_max=3, cache=True)
            f = srv.submit("ccm", "sp", lib=0, target=1, E=3)
            with pytest.raises(RuntimeError, match="serve worker died"):
                f.result(timeout=30)
            deadline = time.monotonic() + 10
            while not srv.health()["ok"]:
                assert time.monotonic() < deadline, "supervisor never " \
                    "revived the worker"
                time.sleep(0.01)
            # exactly one injected death; the revived worker serves
            got = srv.call("ccm", "sp", lib=0, target=1, E=3, timeout=30)
            assert np.float32(got) == oracle(0)[3][0]
            assert fi.fired["worker_death"] == 1
        finally:
            srv.close()
    assert rec.counter_delta("serve_worker_revives") >= 1
    assert rec.counter_delta("serve_worker_deaths") == 1


def test_drain_stops_admission_and_empties_queues():
    panel, delta = _panel()
    srv = EDMServer(autostart=False, workers=1)
    try:
        srv.register_panel("dp", panel, E_max=3, cache=True)
        futs = [srv.submit("append", "dp", delta=delta)
                for _ in range(3)]
        done = {}
        t = threading.Thread(
            target=lambda: done.setdefault("ok", srv.drain(timeout=30)))
        t.start()
        deadline = time.monotonic() + 5
        while not srv.scheduler._draining:
            assert time.monotonic() < deadline
            time.sleep(0.005)
        with pytest.raises(Draining):
            srv.submit("ccm", "dp", lib=0, target=1, E=3)
        assert srv.health()["ok"] is False      # draining reads not-ok
        while srv.scheduler.drain_once():       # queued work still runs
            pass
        t.join(timeout=30)
        assert done.get("ok") is True
        assert [f.result()["version"] for f in futs] == [1, 2, 3]
    finally:
        srv.close()


def test_quarantine_after_repeated_launch_failures():
    panel, _ = _panel()
    fi = FaultInjector(seed=0, rates={"worker_death": 1.0}, max_fires=3)
    with telemetry.record() as rec:
        srv = EDMServer(workers=1, supervise=True, quarantine_after=3,
                        faults=fi, revive_backoff_s=(0.01, 0.05))
        srv.scheduler.supervise_interval = 0.01
        try:
            srv.register_panel("qp", panel, E_max=3, cache=True)
            failures = 0
            deadline = time.monotonic() + 30
            while "qp" not in srv.scheduler.quarantined_panels():
                assert time.monotonic() < deadline, \
                    "panel never quarantined"
                try:
                    srv.call("ccm", "qp", lib=0, target=1, E=3,
                             timeout=30)
                except (RuntimeError, PanelQuarantined):
                    failures += 1
                time.sleep(0.02)
            assert failures >= 3
            with pytest.raises(PanelQuarantined):
                srv.submit("ccm", "qp", lib=0, target=1, E=3)
            # operator reset: injector is exhausted, service resumes
            assert srv.clear_quarantine("qp") is True
            got = srv.call("ccm", "qp", lib=0, target=1, E=3, timeout=30)
            assert np.float32(got) == oracle(0)[3][0]
        finally:
            srv.close()
    assert rec.counter_delta("serve_quarantined") == 1


def test_injected_worker_death_is_base_exception():
    # the point rides the real worker-death path, which a plain
    # ``except Exception`` must NOT catch
    assert issubclass(InjectedWorkerDeath, BaseException)
    assert not issubclass(InjectedWorkerDeath, Exception)
