"""Batched CCM convergence engine: the multi-cap streaming top-k oracle,
``ccm_convergence`` vs the seed per-size loop, master-derived capped
tables vs legacy ``topk_select`` (bit-identical incl. tie order), the
lib_sizes validation fix, and the sharded convergence engine."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import core
from repro.core.ccm import normalize_lib_sizes
from repro.data import timeseries as ts
from repro.edm.plan import _derive_idx, _gathered_dists, master_slack_covers
from repro.kernels import ops, ref


def _dist(rng, Lp, E=4):
    x = jnp.asarray(rng.normal(size=Lp + E - 1).astype(np.float32))
    return ref.pairwise_distances(x, E=E, tau=1)


# -------------------------------------------- multi-cap top-k oracle


@pytest.mark.parametrize("caps", [(0,), (2, 9, 40, 99), (7, 7, 120),
                                  (50, 103, 2000)])
@pytest.mark.parametrize("exclude_self", [True, False])
def test_topk_select_sizes_matches_per_cap_topk(rng, caps, exclude_self):
    """Level s ≡ topk_select(max_idx=caps[s]) on every valid slot;
    invalid slots are inf / PAD_IDX (the per-cap calls emit arbitrary
    masked-column indices there — both are weight-zero downstream)."""
    D = _dist(rng, 104)
    dS, iS = ref.topk_select_sizes(D, k=6, max_idxs=caps,
                                   exclude_self=exclude_self)
    for s, m in enumerate(caps):
        wd, wi = ref.topk_select(D, k=6, exclude_self=exclude_self,
                                 max_idx=m)
        wd, wi = np.asarray(wd), np.asarray(wi)
        ok = np.isfinite(wd)
        np.testing.assert_array_equal(np.asarray(dS[s]),
                                      np.where(ok, wd, np.inf))
        np.testing.assert_array_equal(np.asarray(iS[s])[ok], wi[ok])
        assert (np.asarray(iS[s])[~ok] == ref.PAD_IDX).all()


def test_topk_select_sizes_tie_order_vs_numpy(rng):
    """Quantized distances force mass ties: the streamed merge must keep
    lax.top_k's (value, index) stable order at every cap."""
    x = np.round(rng.normal(size=90), 1).astype(np.float32)  # many ties
    D = ref.pairwise_distances(jnp.asarray(x), E=3, tau=1)
    Lp = D.shape[0]
    caps = (4, 30, 61, 87)
    dS, iS = ref.topk_select_sizes(D, k=5, max_idxs=caps)
    Dn = np.asarray(D)
    for s, m in enumerate(caps):
        mask = (np.arange(Lp)[None, :] > m) | np.eye(Lp, dtype=bool)
        Dm = np.where(mask, np.inf, Dn)
        want_i = np.argsort(Dm, axis=1, kind="stable")[:, :5]
        ok = np.isfinite(np.take_along_axis(Dm, want_i, axis=1))
        np.testing.assert_array_equal(np.asarray(iS[s])[ok], want_i[ok])


def test_topk_select_sizes_validation(rng):
    D = _dist(rng, 40)
    with pytest.raises(ValueError, match="ascending"):
        ref.topk_select_sizes(D, k=3, max_idxs=(10, 5))
    with pytest.raises(ValueError, match=">= 0"):
        ref.topk_select_sizes(D, k=3, max_idxs=(-1, 5))
    with pytest.raises(ValueError, match="empty"):
        ref.topk_select_sizes(D, k=3, max_idxs=())


# --------------------------------------- convergence engine parity


def test_ccm_convergence_bit_identical_to_seed_loop():
    """The one-pass engine ≡ the seed per-size re-scan loop, bitwise,
    across an (E, tau, Tp) × sizes grid."""
    x, y = ts.coupled_logistic(400, b_xy=0.0, b_yx=0.32, seed=3)
    lib, tgt = jnp.asarray(y), jnp.asarray(np.stack([x, y]))
    sizes = (10, 60, 150, 399)
    for E, tau, Tp in ((1, 1, 0), (2, 1, 0), (3, 2, 1), (4, 1, 2)):
        got = np.asarray(core.ccm_convergence(
            lib, tgt, E=E, tau=tau, Tp=Tp, lib_sizes=sizes))
        want = np.asarray(core.cross_map_sizes_seed(
            lib, tgt, E=E, tau=tau, Tp=Tp, lib_sizes=sizes))
        np.testing.assert_array_equal(got, want, err_msg=f"E={E} tau={tau}")


def test_cross_map_lib_sizes_delegates_bit_identically():
    x, y = ts.coupled_logistic(500, b_xy=0.0, b_yx=0.32, seed=3)
    sizes = (25, 60, 150, 300)
    got = np.asarray(core.cross_map(jnp.asarray(y), jnp.asarray(x), E=2,
                                    lib_sizes=sizes))
    want = np.asarray(core.cross_map_sizes_seed(
        jnp.asarray(y), jnp.asarray(x)[None, :], E=2, lib_sizes=sizes))[:, 0]
    np.testing.assert_array_equal(got, want)


# ------------------------------------------ lib_sizes validation fix


def test_lib_sizes_unsorted_duplicate_oversized_warn_and_match():
    """Regression: cross_map used to silently recompute duplicates and
    silently clamp oversized sizes. Now it warns once and still returns
    the legacy values in the caller's order/shape."""
    x, y = ts.coupled_logistic(350, b_xy=0.0, b_yx=0.32, seed=3)
    lib, tgt = jnp.asarray(y), jnp.asarray(x)
    sizes = (200, 50, 50, 10_000)
    with pytest.warns(UserWarning, match="unsorted"):
        got = np.asarray(core.cross_map(lib, tgt, E=2, lib_sizes=sizes))
    assert got.shape == (4,)
    want = np.asarray(core.cross_map_sizes_seed(
        lib, tgt[None, :], E=2, lib_sizes=sizes))[:, 0]
    np.testing.assert_array_equal(got, want)
    assert got[1] == got[2]  # duplicates share one computation
    with pytest.warns(UserWarning, match="duplicates"):
        core.cross_map(lib, tgt, E=2, lib_sizes=(50, 50))
    with pytest.warns(UserWarning, match="exceed"):
        core.cross_map(lib, tgt, E=2, lib_sizes=(50, 9_999))


def test_lib_sizes_invalid_raise():
    x, y = ts.coupled_logistic(200, seed=1)
    with pytest.raises(ValueError, match=">= 1"):
        core.cross_map(jnp.asarray(y), jnp.asarray(x), E=2,
                       lib_sizes=(0, 50))
    with pytest.raises(ValueError, match="empty"):
        core.cross_map(jnp.asarray(y), jnp.asarray(x), E=2, lib_sizes=())


def test_normalize_lib_sizes_mapping():
    caps, inv = normalize_lib_sizes([300, 50, 50, 120], Lp=200, Tp=1)
    assert caps == (49, 119, 198)
    np.testing.assert_array_equal(inv, [2, 0, 0, 1])


# ------------------------- master-derived capped tables (satellite)


@pytest.mark.parametrize("series", ["random", "periodic"])
def test_master_derived_capped_tables_bit_identical_to_topk(rng, series):
    """The k_master-slack rule end to end: capped neighbor tables derived
    from the uncapped multi-E master match the legacy per-size
    ``topk_select`` bit-identically — indices AND distances — across an
    (E, tau, Tp) × cap grid. The periodic series tiles one pattern so
    distinct library points are *exactly* duplicated: every neighbor
    list then contains exact distance ties, and the derived tables must
    reproduce lax.top_k's first-index tie order. (Ties must be exact in
    the accumulator — values that merely collide after rounding can
    differ by 1 ULP between the multi-E and per-E accumulation streams,
    the documented reuse caveat in edm/plan.py.) The derivation runs
    under jit, exactly as the plan-layer drivers do — eager dispatch
    fuses the distance recomputation differently and is NOT bit-exact."""
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("E", "tau", "k", "cap"))
    def derive(x, iE, *, E, tau, k, cap):
        ik, ok = _derive_idx(iE, k=k, max_idx=cap)
        return _gathered_dists(x, ik, ok, E=E, tau=tau), ik

    L = 120
    if series == "periodic":
        x = np.tile(rng.normal(size=10).astype(np.float32), L // 10)
    else:
        x = rng.normal(size=L).astype(np.float32)
    xj = jnp.asarray(x)
    for E, tau, Tp in ((2, 1, 0), (3, 1, 1), (2, 2, 0)):
        Lp = core.num_embedded(L, E, tau)
        k = E + 1
        k_master = k + 36  # slack: caps down to Lp − 1 − 36 derivable
        _, iM = ops.all_knn_multi_e(xj, E_max=E, tau=tau, k=k_master,
                                    exclude_self=True, impl="ref")
        iE = iM[E - 1, :Lp]
        for cap in (Lp - 2 - Tp, Lp - 10, Lp - 36):
            cap = min(cap, Lp - 1 - Tp)
            assert master_slack_covers((cap,), Lp=Lp, k=k,
                                       k_master=k_master)
            d, ik = derive(xj, iE, E=E, tau=tau, k=k, cap=cap)
            D = ref.pairwise_distances(xj, E=E, tau=tau)
            wd, wi = ref.topk_select(D, k=k, max_idx=cap)
            wd, wi = np.asarray(wd), np.asarray(wi)
            fin = np.isfinite(wd)
            np.testing.assert_array_equal(
                np.asarray(ik)[fin], wi[fin],
                err_msg=f"E={E} tau={tau} cap={cap}")
            # Recomputed distances agree to 1 ULP at table level (XLA
            # fuses this standalone subgraph slightly differently than
            # the full driver); the driver-level ρ below is bit-exact.
            np.testing.assert_allclose(
                np.asarray(d), np.where(fin, wd, np.inf),
                rtol=3e-7, atol=0,
                err_msg=f"E={E} tau={tau} cap={cap}")
            assert (np.asarray(ik)[~fin] == -1).all()


def test_master_derived_rho_bit_identical_to_seed_loop(rng):
    """End to end through the production driver
    (``ccm_convergence_from_master``): master-derived convergence
    curves are bit-identical to the legacy per-size ``topk_select``
    sweep across an (E, tau, Tp) × size grid."""
    from repro.edm.plan import ccm_convergence_from_master
    L = 260
    x = rng.normal(size=L).astype(np.float32)
    Y = rng.normal(size=(3, L)).astype(np.float32)
    xj, Yj = jnp.asarray(x), jnp.asarray(Y)
    for E, tau, Tp in ((1, 1, 0), (2, 1, 0), (3, 1, 1), (2, 2, 0),
                       (4, 1, 2)):
        Lp = core.num_embedded(L, E, tau)
        k = E + 1
        k_master = k + 50
        _, iM = ops.all_knn_multi_e(xj, E_max=E, tau=tau, k=k_master,
                                    exclude_self=True, impl="ref")
        caps = tuple(sorted({min(Lp - 1 - Tp, c)
                             for c in (Lp - 45, Lp - 20, Lp - 2 - Tp)}))
        assert master_slack_covers(caps, Lp=Lp, k=k, k_master=k_master)
        got = np.asarray(ccm_convergence_from_master(
            xj, iM[E - 1], Yj, E=E, tau=tau, Tp=Tp, caps=caps, k=k,
            impl="ref"))
        want = np.asarray(core.cross_map_sizes_seed(
            xj, Yj, E=E, tau=tau, Tp=Tp,
            lib_sizes=tuple(c + 1 for c in caps)))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"E={E} tau={tau} Tp={Tp}")


def test_master_slack_rule_boundary():
    """One column short of the rule must be rejected, exactly at it OK."""
    Lp, k = 100, 4
    assert master_slack_covers((90,), Lp=Lp, k=k, k_master=k + 9)
    assert not master_slack_covers((90,), Lp=Lp, k=k, k_master=k + 8)
    assert not master_slack_covers((10, 90), Lp=Lp, k=k, k_master=k + 9)


# ------------------------------------------------ sharded convergence


def test_sharded_ccm_convergence_single_device():
    from repro.distributed import make_ccm_mesh, sharded_ccm_convergence
    panel, _ = ts.forced_network_panel(4, 220, seed=9)
    X = jnp.asarray(panel)
    sizes = (40, 120, 210)
    mesh = make_ccm_mesh((1, 1), ("data", "model"))
    got = np.asarray(sharded_ccm_convergence(
        X, X, E=2, lib_sizes=sizes, mesh=mesh, impl="ref"))
    assert got.shape == (3, 4, 4)
    for lib in range(4):
        want = np.asarray(core.ccm_convergence(
            X[lib], X, E=2, lib_sizes=sizes, impl="ref"))
        np.testing.assert_allclose(got[:, lib, :], want, rtol=1e-5,
                                   atol=1e-6)
    E_opt = np.array([2, 3, 2, 4], np.int32)
    got2 = sharded_ccm_convergence(X, X, E_opt=E_opt, lib_sizes=sizes,
                                   mesh=mesh, impl="ref")
    for t in range(4):
        for lib in range(4):
            want = np.asarray(core.ccm_convergence(
                X[lib], X[t][None, :], E=int(E_opt[t]), lib_sizes=sizes,
                impl="ref"))[:, 0]
            np.testing.assert_allclose(got2[:, lib, t], want, rtol=1e-5,
                                       atol=1e-6)
    with pytest.raises(ValueError, match="exactly one"):
        sharded_ccm_convergence(X, X, lib_sizes=sizes, mesh=mesh)
    with pytest.raises(ValueError, match="exactly one"):
        sharded_ccm_convergence(X, X, E=2, E_opt=E_opt, lib_sizes=sizes,
                                mesh=mesh)
