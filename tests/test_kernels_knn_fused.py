"""Fused pairwise+top-k kernel (beyond-paper) ≡ two-kernel composition."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("L,E,tau,k,br", [
    (137, 4, 2, 5, 8),
    (200, 1, 1, 2, 16),
    (96, 20, 1, 21, 8),
    (257, 7, 3, 8, 32),
])
def test_fused_knn_matches_two_kernel(rng, L, E, tau, k, br):
    x = jnp.asarray(rng.normal(size=L).astype(np.float32))
    D = ref.pairwise_distances(x, E=E, tau=tau)
    want_d, want_i = ref.topk_select(D, k=k)
    got_d, got_i = ops.all_knn(x, E=E, tau=tau, k=k, impl="interpret",
                               fused=True)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-5, atol=1e-5)


def test_fused_knn_max_idx(rng):
    x = jnp.asarray(rng.normal(size=150).astype(np.float32))
    D = ref.pairwise_distances(x, E=3, tau=1)
    want_d, want_i = ref.topk_select(D, k=4, max_idx=40)
    got_d, got_i = ops.all_knn(x, E=3, tau=1, k=4, impl="interpret",
                               fused=True, max_idx=40)
    assert int(np.asarray(got_i).max()) <= 40
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_fused_knn_hbm_traffic_model():
    """The point of the fusion: result bytes ≪ distance-matrix bytes."""
    L, E, k = 10_000, 20, 21
    Lp = L - (E - 1)
    baseline = 2 * 4 * Lp * Lp          # D write + D read
    fused = 8 * Lp * k + 2 * 4 * L * E  # results + series reads
    assert baseline / fused > 200, baseline / fused
