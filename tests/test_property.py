"""Hypothesis property tests on the system's invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core.smap_engine import smap_theta_sweep
from repro.core.stats import CoMoments
from repro.data import timeseries as ts
from repro.kernels import ops, ref

_settings = dict(max_examples=25, deadline=None)

# The S-Map sweeps below run a full engine program per example; keep the
# example count small and derandomized (stable examples across CI runs).
_smap_settings = dict(max_examples=6, deadline=None, derandomize=True)


def series(min_len=24, max_len=96):
    return hnp.arrays(
        np.float32, st.integers(min_len, max_len),
        elements=st.floats(-100, 100, width=32, allow_nan=False),
    )


@given(x=series(), E=st.integers(1, 6), tau=st.integers(1, 3))
@settings(**_settings)
def test_distance_matrix_invariants(x, E, tau):
    if len(x) - (E - 1) * tau < 4:
        return
    D = np.asarray(ref.pairwise_distances(jnp.asarray(x), E=E, tau=tau))
    assert (D >= -1e-5).all(), "squared distances are non-negative"
    np.testing.assert_allclose(D, D.T, rtol=1e-4, atol=1e-3)  # symmetry
    assert np.abs(np.diag(D)).max() <= 1e-3  # zero diagonal


@given(x=series(), E=st.integers(1, 5), shift=st.floats(-50, 50, width=32))
@settings(**_settings)
def test_distance_shift_invariance(x, E, shift):
    """Delay-embedding distances are invariant to additive shifts."""
    if len(x) - (E - 1) < 4:
        return
    a = ref.pairwise_distances(jnp.asarray(x), E=E, tau=1)
    b = ref.pairwise_distances(jnp.asarray(x + np.float32(shift)), E=E, tau=1)
    scale = max(1.0, float(np.abs(np.asarray(a)).max()))
    np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                               atol=1e-3)


@given(x=series(min_len=32), k=st.integers(1, 8))
@settings(**_settings)
def test_topk_is_partial_sort(x, k):
    x = x + np.linspace(0, 1e-3, len(x), dtype=np.float32)  # break mass ties
    D = ref.pairwise_distances(jnp.asarray(x), E=2, tau=1)
    Lp = D.shape[0]
    if k >= Lp:
        return
    d, i = ref.topk_select(D, k=k)
    d, i = np.asarray(d), np.asarray(i)
    Dm = np.asarray(D) + np.where(np.eye(Lp, dtype=bool), np.inf, 0)
    full = np.sqrt(np.sort(Dm, axis=1))
    np.testing.assert_allclose(d, full[:, :k], rtol=1e-4, atol=1e-5)
    assert (i >= 0).all() and (i < Lp).all()


@given(
    d=hnp.arrays(np.float32, (7, 5),
                 elements=st.floats(0, 1000, width=32, allow_nan=False))
)
@settings(**_settings)
def test_weights_are_simplex(d):
    d = np.sort(d, axis=1)
    w = np.asarray(ref.make_weights(jnp.asarray(d)))
    assert (w >= 0).all()
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-5)
    # nearest neighbor never gets less weight than the farthest
    assert (w[:, 0] >= w[:, -1] - 1e-6).all()


@given(
    a=hnp.arrays(np.float32, 50, elements=st.floats(-10, 10, width=32,
                                                    allow_nan=False)),
    scale=st.floats(0.125, 100, width=32),
    shift=st.floats(-100, 100, width=32),
)
@settings(**_settings)
def test_pearson_affine_invariance(a, scale, shift):
    if np.std(a) < 1e-3:
        return
    b = np.float32(scale) * a + np.float32(shift)
    rho = float(ref.pearson_rows(jnp.asarray(a[None]), jnp.asarray(b[None]))[0])
    assert abs(rho - 1.0) < 1e-3


@given(
    ab=hnp.arrays(np.float32, (2, 60),
                  elements=st.floats(-50, 50, width=32, allow_nan=False)),
    split=st.integers(5, 55),
)
@settings(**_settings)
def test_comoments_merge_equals_batch(ab, split):
    """Schubert–Gertz merge of two chunks == stats of the concatenation."""
    # ρ is ill-conditioned at (near-)zero variance; the merge identity is
    # exact there only in exact arithmetic. Compare away from degeneracy.
    if min(np.std(ab[0][:split]), np.std(ab[0][split:]),
           np.std(ab[1][:split]), np.std(ab[1][split:])) < 1e-1:
        return
    a, b = jnp.asarray(ab[0]), jnp.asarray(ab[1])
    whole = CoMoments.from_batch(a, b)
    left = CoMoments.from_batch(a[:split], b[:split])
    right = CoMoments.from_batch(a[split:], b[split:])
    merged = left.merge(right)
    np.testing.assert_allclose(float(merged.m2_a), float(whole.m2_a),
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(float(merged.c_ab), float(whole.c_ab),
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(float(merged.pearson), float(whole.pearson),
                               rtol=1e-3, atol=1e-3)


@given(x0=st.floats(0.15, 0.85), n=st.integers(250, 380))
@settings(**_smap_settings)
def test_smap_rho_rises_with_theta_on_logistic_map(x0, n):
    """Nonlinear (state-dependent) dynamics: S-Map skill must rise with the
    locality parameter θ, for any chaotic-logistic initial condition."""
    x = jnp.asarray(ts.logistic_map(int(n), x0=float(x0)))
    rho = np.asarray(smap_theta_sweep(x[None], E=2, thetas=(0.0, 2.0, 8.0),
                                      impl="ref"))[0]
    assert rho[-1] > rho[0] + 0.02, f"no nonlinearity signal: {rho}"
    assert rho[-1] > 0.9


@given(phi=st.floats(0.25, 0.9), seed=st.integers(0, 2**16))
@settings(**_smap_settings)
def test_smap_rho_flat_on_ar1(phi, seed):
    """Linear stochastic dynamics: localizing the fit can only lose data —
    ρ(θ) must NOT rise materially for AR(1) noise, for any (φ, seed)."""
    rng = np.random.default_rng(seed)
    n = 300
    x = np.zeros(n, np.float32)
    for t in range(1, n):
        x[t] = np.float32(phi) * x[t - 1] + 0.1 * rng.standard_normal()
    rho = np.asarray(smap_theta_sweep(jnp.asarray(x)[None], E=2,
                                      thetas=(0.0, 4.0), impl="ref"))[0]
    assert rho[1] < rho[0] + 0.05, f"spurious nonlinearity: {rho}"


@given(x=series(min_len=40, max_len=80))
@settings(**_settings)
def test_lookup_convex_combination_bounds(x):
    """Simplex predictions are convex combinations → bounded by the data."""
    xs = jnp.asarray(x)
    E, tau, k = 3, 1, 4
    Lp = len(x) - (E - 1) * tau
    if Lp <= k + 1:
        return
    D = ref.pairwise_distances(xs, E=E, tau=tau)
    d, i = ref.topk_select(D, k=k)
    w = ref.make_weights(d)
    yhat = np.asarray(ref.lookup(xs[None], i, w, offset=(E - 1) * tau))
    lo, hi = float(x.min()), float(x.max())
    span = max(hi - lo, 1e-3)
    assert yhat.min() >= lo - 1e-3 * span - 1e-4
    assert yhat.max() <= hi + 1e-3 * span + 1e-4


# ----------------------------------------------- ingestion mask policy


def _corrupt(panel, bad_idx, kind):
    """Inject one invalid series (non-finite or constant) at bad_idx."""
    panel = panel.copy()
    if kind == "nan":
        panel[bad_idx, ::7] = np.nan
    elif kind == "inf":
        panel[bad_idx, 3] = np.inf
    else:
        panel[bad_idx, :] = panel[bad_idx, 0]
    return panel


@given(bad=st.integers(0, 4), kind=st.sampled_from(["nan", "inf", "const"]),
       seed=st.integers(0, 2**10))
@settings(**_smap_settings)
def test_mask_policy_nan_closure(bad, kind, seed):
    """For ANY single corrupt series, on_invalid="mask" yields exactly:
    NaN on every output touching it, and bit-identical values elsewhere
    to the clean sub-panel session (drop) — mask never leaks a corrupt
    series into a valid pair's result."""
    from repro.edm import EDM, EDMConfig
    panel, _ = ts.forced_network_panel(5, 160, seed=seed)
    X = _corrupt(np.asarray(panel), bad, kind)
    sess = EDM(X, EDMConfig(E=2, on_invalid="mask"))
    rho = sess.xmap()
    good = [i for i in range(5) if i != bad]
    assert np.isnan(rho[bad, :]).all() and np.isnan(rho[:, bad]).all()
    dropped = EDM(X, EDMConfig(E=2, on_invalid="drop"))
    assert dropped.data.N == 4
    np.testing.assert_array_equal(rho[np.ix_(good, good)], dropped.xmap())
    # pairwise closure: NaN iff the pair touches the corrupt series
    g = good[0]
    assert np.isnan(sess.ccm(g, bad)) and np.isnan(sess.ccm(bad, g))
    assert np.isfinite(sess.ccm(good[0], good[1]))
    sr = sess.surrogate_test(bad, g, num_surrogates=3)
    assert np.isnan(sr.rho) and np.isnan(sr.pvalue)


@given(kind=st.sampled_from(["nan", "inf", "const"]),
       seed=st.integers(0, 2**10))
@settings(**_smap_settings)
def test_raise_policy_always_names_offender(kind, seed):
    from repro.edm import Dataset
    panel, _ = ts.forced_network_panel(4, 120, seed=seed)
    X = _corrupt(np.asarray(panel), 2, kind)
    with pytest.raises(ValueError, match="series 2"):
        Dataset(X)
