"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, shape and finiteness checks; decode-step checks where applicable.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import ARCHS, SKIP_CELLS, get_config

B, S = 2, 32


def _batch(cfg, rng):
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = models.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng)

    logits, aux = jax.jit(
        lambda p, b: models.forward_train(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN in logits"

    def loss(p):
        return models.loss_fn(p, cfg, batch)[0]

    l0, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(l0)), f"{arch}: NaN loss"
    gnorm = float(
        jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                     for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm {gnorm}"
    # loss is roughly log(V) at init (sanity against exploding init)
    assert float(l0) < 3 * np.log(cfg.vocab_size) + 5


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if "decode_32k" not in SKIP_CELLS.get(a, set())]
)
def test_decode_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = models.init_params(cfg, jax.random.key(0))
    s_max = 16
    cache = models.init_cache(cfg, B, s_max)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)).astype(np.int32))
    logits, new_cache = jax.jit(
        lambda p, t, c: models.decode_step(p, cfg, t, c, jnp.int32(3))
    )(params, tok, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN decode logits"
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
    for a, b in zip(jax.tree.leaves(new_cache), jax.tree.leaves(cache)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-lite-16b",
                                  "jamba-v0.1-52b", "xlstm-125m"])
def test_decode_matches_forward(arch, rng):
    """Greedy decode over a short prompt == argmax of the parallel forward
    (causal consistency of cache plumbing across all layer kinds)."""
    cfg = get_config(arch, smoke=True)
    if cfg.embed_inputs:
        pytest.skip("token decode only")
    if cfg.moe is not None:
        # Dropless for this test: capacity drops are a train-path batch
        # effect absent in single-token decode (GShard semantics).
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = models.init_params(cfg, jax.random.key(1))
    T = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T)).astype(np.int32))
    full_logits, _ = models.forward_train(params, cfg, {"tokens": toks})

    cache = models.init_cache(cfg, 1, T)
    step = jax.jit(lambda p, t, c, pos: models.decode_step(p, cfg, t, c, pos))
    for t in range(T):
        logits, cache = step(params, toks[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[0, 0]), np.asarray(full_logits[0, t]),
            rtol=2e-2, atol=2e-2,
        )


def test_prefill_cache_matches_decode_attn(rng):
    cfg = get_config("llama3-8b", smoke=True)
    params = models.init_params(cfg, jax.random.key(2))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32))
    logits, caches = models.prefill(params, cfg, {"tokens": toks}, s_max=12)
    assert logits.shape == (1, 1, cfg.vocab_size)
    k = jax.tree.leaves(caches)[0]
    assert k.shape[2] == 12  # padded seq axis (units, B, s_max, ...)


def test_param_counts_reasonable():
    cfg = get_config("llama3-8b")
    n = cfg.param_count()
    assert 7.5e9 < n < 9e9, f"llama3-8b param count {n/1e9:.2f}B"
    cfg4 = get_config("llama4-maverick-400b-a17b")
    total = cfg4.param_count()
    active = cfg4.active_param_count()
    assert 3.5e11 < total < 4.6e11, f"maverick total {total/1e9:.0f}B"
    assert 1.2e10 < active < 2.2e10, f"maverick active {active/1e9:.1f}B"


def test_abstract_params_no_alloc():
    cfg = get_config("nemotron-4-15b")  # full config — must not allocate
    tree = models.abstract_params(cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(tree))
    assert 1.4e10 < n < 1.8e10, f"nemotron param count {n/1e9:.1f}B"
