"""Serving phase 2: worker pool, LRU eviction, subscriptions (ISSUE 9).

The concurrency & parity battery. The single-worker PR-8 design passes
most of these trivially (everything serializes); the pooled design must
earn them:

* **Per-panel linearization under randomized interleavings** — ≥3
  panels, ≥8 client threads mixing submit/submit_many/append/evict.
  Within a panel, requests execute in ticket (submit) order, so a CCM
  answer must bit-match the singleton ``ccm_batch`` oracle at exactly
  version = #appends on that panel with a smaller ticket. Derandomized
  hypothesis drives the schedules.
* **Eviction parity** — evict → rebuild and evict → re-append bit-match
  a never-evicted session across E/τ/Δt grids including duplicate-tie
  panels; the LRU honors the byte budget under interleaved multi-panel
  load.
* **Worker liveness** — a dead drain worker turns ``/healthz`` degraded
  (it used to answer healthy) and ``revive_workers`` restores service.
* **Error paths** — an op raising mid-batch fails only the affected
  futures; a failed append neither wedges the panel queue nor leaks the
  version barrier.
* **Subscriptions** — every append tick pushes re-scored ρ that
  bit-matches a never-evicted direct session at that version.
"""

import threading

import numpy as np
import pytest

from repro import telemetry
from repro.edm.session import EDM
from repro.serving import EDMServer, serve_http  # noqa: F401 (HTTP below)


def _panel(n, length, seed, tie=False):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, length)).astype(np.float32)
    if tie:  # heavy value collisions → exercises master tie ordering
        x = np.round(x * 2) / 2
    return x


def _drain_all(srv):
    sizes = []
    while True:
        n = srv.scheduler.drain_once()
        if not n:
            return sizes
        sizes.append(n)


# ---------------------------------------------------- pool structure


def test_round_robin_across_panels_keeps_per_panel_fifo():
    """Ready-list rotation: a busy panel's remainder goes behind other
    panels, but never reorders within the panel."""
    pa, pb = _panel(4, 200, 0), _panel(4, 200, 1)
    with telemetry.record() as rec, EDMServer(autostart=False) as srv:
        srv.register_panel("a", pa, E_max=3, cache=True)
        srv.register_panel("b", pb, E_max=3, cache=True)
        srv.submit("ccm", "a", lib=0, target=1, E=3)
        srv.submit("ccm", "a", lib=1, target=2, E=2)   # incompatible tail
        srv.submit("ccm", "b", lib=0, target=1, E=3)
        assert _drain_all(srv) == [1, 1, 1]
    batches = rec.spans("serve.batch")
    assert [b["attrs"]["panel"] for b in batches] == ["a", "b", "a"]


def test_distinct_panels_drain_concurrently_under_pool():
    """Two panels, two workers: a slow op on panel a must not block
    panel b's requests (the PR-8 single drain serialized them)."""
    pa, pb = _panel(4, 200, 2), _panel(4, 200, 3)
    gate = threading.Event()
    with EDMServer(autostart=False, workers=2) as srv:
        srv.register_panel("a", pa, E_max=3, cache=True)
        srv.register_panel("b", pb, E_max=3, cache=True)
        sched = srv.scheduler
        orig = sched._exec_one

        def slow(entry, r):
            if r.params.get("block"):
                assert gate.wait(30), "panel b never unblocked panel a"
            return orig(entry, r)

        sched._exec_one = slow
        sched.start()
        fa = srv.submit("simplex", "a", E=3, block=True)
        fb = srv.submit("simplex", "b", E=3)
        # b completes while a is still parked on the gate — impossible
        # with one drain worker.
        np.asarray(fb.result(timeout=30))
        assert not fa.done()
        gate.set()
        np.asarray(fa.result(timeout=30))


# -------------------------------------------- randomized linearization


try:  # optional dep: fall back to fixed seeds (≡ derandomize=True)
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

N_SER, L0 = 5, 140
DELTAS = 2          # appends available per panel
DT = 8              # columns per append tick
E_REQ = 3
PANELS = ("pan0", "pan1", "pan2")
PAIRS = [(0, 2), (1, 3), (0, 4), (2, 1), (3, 0), (4, 2)]


@pytest.fixture(scope="module")
def stress_world():
    """Panels, their append deltas, and singleton-ccm_batch oracles at
    every library version (the quiesced pre/post oracles)."""
    data = {p: _panel(N_SER, L0 + DELTAS * DT, seed=10 + i)
            for i, p in enumerate(PANELS)}
    oracles = {}
    for p, full in data.items():
        per_version = []
        for v in range(DELTAS + 1):
            sess = EDM(full[:, : L0 + v * DT], E_max=4, cache=True)
            sess.optimal_E()
            per_version.append({pair: sess.ccm_batch([pair], E=E_REQ)[0]
                                for pair in PAIRS})
        oracles[p] = per_version
    deltas = {p: [full[:, L0 + v * DT: L0 + (v + 1) * DT]
                  for v in range(DELTAS)] for p, full in data.items()}
    return data, deltas, oracles


def _hyp_or_seeds(fn):
    """Drive by derandomized hypothesis when available, else the same
    deterministic schedule space via fixed-seed parametrize."""
    if _HAVE_HYPOTHESIS:
        return settings(max_examples=3, deadline=None, derandomize=True)(
            given(seed=st.integers(0, 2**16 - 1))(fn))
    return pytest.mark.parametrize("seed", [7, 1234, 40961])(fn)


@_hyp_or_seeds
def test_randomized_interleavings_linearize_per_panel(stress_world, seed):
    """8 client threads × random submit/submit_many/append/evict across
    3 panels: every served CCM answer must bit-match the singleton
    oracle at version = #appends on its panel with a smaller ticket
    (per-panel FIFO + version barrier = the full linearization)."""
    data, deltas, oracles = stress_world
    rng = np.random.default_rng(seed)
    with EDMServer(workers=3) as srv:
        for p in PANELS:
            srv.register_panel(p, data[p][:, :L0], E_max=4, cache=True)
            srv.call("optimal_E", p)
        remaining = {p: list(deltas[p]) for p in PANELS}
        alloc_lock = threading.Lock()
        ccm_log: list = []    # (panel, future)  — fut.ticket carries order
        app_log: list = []    # (panel, future)
        log_lock = threading.Lock()
        errs: list = []

        def worker(tid):
            try:
                trng = np.random.default_rng(seed * 1000 + tid)
                for _ in range(5):
                    p = PANELS[trng.integers(len(PANELS))]
                    roll = trng.random()
                    if roll < 0.15:
                        with alloc_lock:
                            delta = (remaining[p].pop(0)
                                     if remaining[p] else None)
                        if delta is not None:
                            f = srv.submit("append", p, delta=delta)
                            with log_lock:
                                app_log.append((p, f))
                            continue
                        roll = 0.5  # fall through to a query
                    if roll < 0.25:
                        srv.evict_panel(p)  # memory event, never answers
                    elif roll < 0.6:
                        pair = PAIRS[trng.integers(len(PAIRS))]
                        f = srv.submit("ccm", p, lib=pair[0],
                                       target=pair[1], E=E_REQ)
                        with log_lock:
                            ccm_log.append((p, pair, f))
                    else:
                        burst = [dict(lib=l, target=t, E=E_REQ)
                                 for l, t in PAIRS[:3]]
                        futs = srv.submit_many("ccm", p, burst)
                        with log_lock:
                            ccm_log.extend(
                                (p, pr, f)
                                for pr, f in zip(PAIRS[:3], futs))
            except Exception as exc:  # noqa: BLE001
                errs.append(exc)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
        assert not errs
        # Quiesce: every future resolved before we read tickets/answers.
        for _, f in app_log:
            f.result(timeout=60)
        append_tickets = {p: sorted(f.ticket for q, f in app_log
                                    if q == p) for p in PANELS}
        for p, pair, f in ccm_log:
            rho = np.asarray(f.result(timeout=60))
            v = sum(t < f.ticket for t in append_tickets[p])
            np.testing.assert_array_equal(
                rho, oracles[p][v][pair],
                err_msg=f"{p} ticket {f.ticket} pair {pair}: answer is "
                        f"not the version-{v} singleton oracle")
        # Appends themselves linearize: versions 1..n in ticket order.
        for p in PANELS:
            got = [f.result(timeout=60)["version"]
                   for q, f in sorted(app_log, key=lambda it: it[1].ticket)
                   if q == p]
            assert got == list(range(1, len(got) + 1)), (p, got)
        # Post-quiesce, every panel answers at its final version exactly.
        for p in PANELS:
            v = len(append_tickets[p])
            for pair in PAIRS[:2]:
                np.testing.assert_array_equal(
                    np.asarray(srv.call("ccm", p, lib=pair[0],
                                        target=pair[1], E=E_REQ)),
                    oracles[p][v][pair])


# ------------------------------------------------------ eviction parity


@pytest.mark.parametrize("E,tau,dt", [(3, 1, 4), (4, 2, 7), (2, 1, 1)])
@pytest.mark.parametrize("tie", [False, True])
def test_evict_rebuild_and_reappend_bit_match_never_evicted(E, tau, dt, tie):
    full = _panel(5, 220 + dt, seed=100 * E + 10 * tau + dt, tie=tie)
    old, delta = full[:, :220], full[:, 220:]
    pairs = PAIRS[:4]
    never = EDM(old, E_max=4, tau=tau, cache=True)
    never.optimal_E()
    pre = {p: never.ccm_batch([p], E=E)[0] for p in pairs}
    never.append(delta)  # master grown incrementally, never dropped
    post = {p: never.ccm_batch([p], E=E)[0] for p in pairs}

    with EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, E_max=4, tau=tau, cache=True)
        srv.submit("optimal_E", "p")
        _drain_all(srv)
        entry = srv.registry.get("p")
        assert entry.master_nbytes() > 0
        # evict → rebuild: cold queries bit-match the warm session
        assert srv.evict_panel("p") > 0
        assert entry.master_nbytes() == 0
        futs = [srv.submit("ccm", "p", lib=l, target=t, E=E)
                for l, t in pairs]
        _drain_all(srv)
        for p, f in zip(pairs, futs):
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=30)), pre[p],
                err_msg=f"evict->rebuild pair {p} (E={E} tau={tau})")
        # evict → re-append: the appended-after-eviction panel still
        # bit-matches the never-evicted incremental session
        assert srv.evict_panel("p") > 0
        fa = srv.submit("append", "p", delta=delta)
        futs = [srv.submit("ccm", "p", lib=l, target=t, E=E)
                for l, t in pairs]
        _drain_all(srv)
        assert fa.result(timeout=30)["L"] == full.shape[1]
        for p, f in zip(pairs, futs):
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=30)), post[p],
                err_msg=f"evict->reappend pair {p} (E={E} tau={tau} "
                        f"dt={dt} tie={tie})")


def test_lru_honors_byte_budget_under_interleaved_load():
    """3 panels, budget ≈ 1.5 masters: totals stay within budget (the
    MRU master is exempt by design), evictions hit the COLDEST panel,
    and every answer stays bit-identical."""
    panels = {f"p{i}": _panel(6, 260, seed=40 + i) for i in range(3)}
    oracle = {}
    for name, data in panels.items():
        s = EDM(data, E_max=4, cache=True)
        s.optimal_E()
        oracle[name] = s.ccm_batch(PAIRS[:3], E=3)
    with telemetry.record() as rec, EDMServer(autostart=False) as srv:
        for name, data in panels.items():
            srv.register_panel(name, data, E_max=4, cache=True)
            srv.submit("optimal_E", name)
        _drain_all(srv)
        one = srv.registry.get("p0").master_nbytes()
        assert one > 0
        assert srv.registry.master_bytes_total() == 3 * one
        srv.registry.set_budget(int(1.5 * one))
        rounds = ["p0", "p1", "p2", "p0", "p2", "p1", "p0"]
        for name in rounds:
            futs = [srv.submit("ccm", name, lib=l, target=t, E=3)
                    for l, t in PAIRS[:3]]
            _drain_all(srv)
            got = np.asarray([f.result(timeout=30) for f in futs])
            np.testing.assert_array_equal(
                got, oracle[name], err_msg=f"post-eviction answers {name}")
            # ≤ budget once eviction can help (MRU exemption: a single
            # master fits the 1.5× budget, so totals must comply).
            assert srv.registry.master_bytes_total() <= int(1.5 * one), \
                f"budget violated after {name}"
    assert rec.counter_delta("serve_evictions") >= 3
    infos = {i["name"]: i for i in srv.registry.infos()}
    assert sum(i["evictions"] for i in infos.values()) >= 3


# ----------------------------------------------------- worker liveness


def test_healthz_degrades_on_dead_worker_and_recovers():
    """A dead drain worker must flip /healthz to degraded (it used to
    stay green) and revive_workers() must restore service."""
    with EDMServer(workers=2) as srv:
        srv.register_panel("p", _panel(4, 200, 7), E_max=3, cache=True)
        srv.call("optimal_E", "p")
        assert srv.health()["ok"]
        sched = srv.scheduler
        orig = sched._exec_one

        def boom(entry, r):
            if r.params.get("poison"):
                raise SystemExit("injected worker death")
            return orig(entry, r)

        sched._exec_one = boom
        f = srv.submit("simplex", "p", E=3, poison=True)
        with pytest.raises(RuntimeError, match="worker died"):
            f.result(timeout=30)
        for _ in range(100):  # the dying thread's epilogue races us
            h = srv.health()
            if not h["ok"]:
                break
            threading.Event().wait(0.05)
        assert not h["ok"], "healthz stayed green with a dead worker"
        assert sum(not w["alive"] for w in h["workers"]) == 1
        assert srv.health()["queues"] == {"p": 0}
        # Recovery: respawn, then the pool serves again (poison cleared).
        sched._exec_one = orig
        assert sched.revive_workers() == 1
        assert srv.health()["ok"]
        np.asarray(srv.call("simplex", "p", E=3))


def test_healthz_http_reports_503_when_degraded():
    with EDMServer(workers=1) as srv:
        srv.register_panel("p", _panel(4, 200, 8), E_max=3)
        httpd = serve_http(srv)
        port = httpd.server_address[1]
        import json
        import urllib.error
        import urllib.request

        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/healthz", timeout=30) as r:
            body = json.loads(r.read())
            assert r.status == 200 and body["ok"]
            assert body["workers"][0]["alive"]
            assert body["queues"] == {}
        sched = srv.scheduler
        sched._exec_one = lambda entry, r: (_ for _ in ()).throw(
            SystemExit("die"))
        try:
            srv.submit("simplex", "p", E=3).exception(timeout=30)
            deadline = 100
            while deadline:
                try:
                    with urllib.request.urlopen(
                            f"http://127.0.0.1:{port}/healthz",
                            timeout=30) as r:
                        pass
                except urllib.error.HTTPError as e:
                    assert e.code == 503
                    degraded = json.loads(e.read())
                    assert not degraded["ok"]
                    break
                deadline -= 1
                threading.Event().wait(0.05)
            assert deadline, "healthz never degraded over HTTP"
        finally:
            httpd.shutdown()


# --------------------------------------------------------- error paths


def test_mid_batch_failure_hits_only_affected_futures():
    """In a loop-executed (dedup) batch, one request raising must fail
    that future alone — its batch peers still get results, and the
    panel queue keeps draining."""
    with EDMServer(autostart=False) as srv:
        srv.register_panel("p", _panel(4, 200, 9), E_max=3, cache=True)
        sched = srv.scheduler
        orig = sched._exec_one
        doomed = set()

        def picky(entry, r):
            if r.ticket in doomed:
                raise RuntimeError(f"injected failure #{r.ticket}")
            return orig(entry, r)

        sched._exec_one = picky
        futs = [srv.submit("simplex", "p", E=3) for _ in range(3)]
        doomed.add(futs[1].ticket)
        assert sched.drain_once() == 3  # one dedup batch of 3
        ok0 = np.asarray(futs[0].result(timeout=30))
        with pytest.raises(RuntimeError, match="injected failure"):
            futs[1].result(timeout=30)
        np.testing.assert_array_equal(
            np.asarray(futs[2].result(timeout=30)), ok0)
        # queue not wedged: a follow-up request drains normally
        f = srv.submit("optimal_E", "p")
        assert sched.drain_once() == 1
        f.result(timeout=30)


def test_shared_launch_failure_fails_batch_but_not_queue():
    """A coalesced CCM batch shares ONE launch: if it raises, all its
    futures fail together — but later batches still execute."""
    with EDMServer(autostart=False) as srv:
        srv.register_panel("p", _panel(5, 200, 11), E_max=3, cache=True)
        srv.submit("optimal_E", "p")
        _drain_all(srv)
        sess = srv.registry.get("p").sess
        orig = sess.ccm_batch
        calls = {"n": 0}

        def flaky(pairs, *, E):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient engine failure")
            return orig(pairs, E=E)

        sess.ccm_batch = flaky
        futs = [srv.submit("ccm", "p", lib=l, target=t, E=3)
                for l, t in PAIRS[:3]]
        assert srv.scheduler.drain_once() == 3
        for f in futs:
            with pytest.raises(RuntimeError, match="transient"):
                f.result(timeout=30)
        retry = [srv.submit("ccm", "p", lib=l, target=t, E=3)
                 for l, t in PAIRS[:3]]
        assert srv.scheduler.drain_once() == 3
        got = [np.asarray(f.result(timeout=30)) for f in retry]
        del sess.ccm_batch  # restore the bound method
        want = sess.ccm_batch([(l, t) for l, t in PAIRS[:3]], E=3)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_failed_append_neither_wedges_queue_nor_leaks_barrier():
    """A rejected append (NaN delta) fails only its own future; the
    requests queued BEHIND its barrier still execute and answer at the
    UN-appended version, and a later valid append works normally."""
    full = _panel(5, 160, seed=12)
    old, bad, good = full[:, :140], full[:, 140:150].copy(), full[:, 140:150]
    bad[1, 3] = np.nan
    d_old = EDM(old, E_max=3, cache=True)
    d_old.optimal_E()
    d_new = EDM(np.concatenate([old, good], axis=1), E_max=3, cache=True)
    d_new.optimal_E()
    with EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, names=[f"s{i}" for i in range(5)],
                           E_max=3, cache=True)
        srv.submit("optimal_E", "p")
        _drain_all(srv)
        fa = srv.submit("append", "p", delta=bad)
        behind = [srv.submit("ccm", "p", lib=l, target=t, E=2)
                  for l, t in PAIRS[:3]]
        sizes = _drain_all(srv)
        assert sizes == [1, 3]  # failed append solo, queries still batch
        with pytest.raises(ValueError, match="series s1"):
            fa.result(timeout=30)
        entry = srv.registry.get("p")
        assert entry.version == 0 and entry.queued_version == 1
        for p, f in zip(PAIRS[:3], behind):
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=30)),
                d_old.ccm_batch([p], E=2)[0],
                err_msg=f"behind-failed-append pair {p}")
        # barrier not leaked: a valid append still versions cleanly
        fa2 = srv.submit("append", "p", delta=good)
        after = [srv.submit("ccm", "p", lib=l, target=t, E=2)
                 for l, t in PAIRS[:3]]
        _drain_all(srv)
        assert fa2.result(timeout=30)["version"] == 1
        assert entry.queued_version == 2
        for p, f in zip(PAIRS[:3], after):
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=30)),
                d_new.ccm_batch([p], E=2)[0],
                err_msg=f"post-valid-append pair {p}")


# -------------------------------------------------------- subscriptions


def test_subscription_ticks_bit_match_direct_sessions():
    full = _panel(5, 156, seed=13)
    old = full[:, :140]
    ticks = [full[:, 140:148], full[:, 148:156]]
    watch = PAIRS[:3]
    sessions = []
    for v in range(3):
        s = EDM(full[:, : 140 + v * 8], E_max=3, cache=True)
        s.optimal_E()
        sessions.append(s)
    with EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, E_max=3, cache=True)
        srv.submit("optimal_E", "p")
        fs = srv.submit("subscribe", "p", pairs=watch, E=2)
        _drain_all(srv)
        info = fs.result(timeout=30)
        sub = srv.subscription(info["id"])
        np.testing.assert_array_equal(
            np.asarray(info["rho"]), sessions[0].ccm_batch(watch, E=2))
        base = sub.poll()
        assert len(base) == 1 and base[0]["version"] == 0
        assert base[0]["d_rho"] is None
        for v, delta in enumerate(ticks, start=1):
            srv.submit("append", "p", delta=delta)
            _drain_all(srv)
            got = sub.poll()
            assert len(got) == 1
            t = got[0]
            assert t["version"] == v and t["L"] == 140 + v * 8
            np.testing.assert_array_equal(
                t["rho"], sessions[v].ccm_batch(watch, E=2),
                err_msg=f"tick {v} not bit-identical to direct session")
            np.testing.assert_array_equal(
                t["d_rho"],
                sessions[v].ccm_batch(watch, E=2)
                - sessions[v - 1].ccm_batch(watch, E=2))
        assert sub.poll(timeout=0.01) == []
        srv.unsubscribe(info["id"])
        with pytest.raises(KeyError):
            srv.subscription(info["id"])


def test_subscription_survives_eviction_bitwise():
    """Evicting the panel between ticks must not change a single pushed
    bit — the append path re-grows from the rebuilt master."""
    full = _panel(5, 152, seed=14)
    old, d1, d2 = full[:, :140], full[:, 140:146], full[:, 146:152]
    watch = PAIRS[:2]
    g1 = EDM(full[:, :146], E_max=3, cache=True)
    g1.optimal_E()
    g2 = EDM(full, E_max=3, cache=True)
    g2.optimal_E()
    with EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, E_max=3, cache=True)
        fs = srv.submit("subscribe", "p", pairs=watch, E=2)
        srv.submit("append", "p", delta=d1)
        _drain_all(srv)
        sub = srv.subscription(fs.result(timeout=30)["id"])
        srv.evict_panel("p")
        srv.submit("append", "p", delta=d2)
        _drain_all(srv)
        got = sub.poll()
        assert [t["version"] for t in got] == [0, 1, 2]
        np.testing.assert_array_equal(got[1]["rho"],
                                      g1.ccm_batch(watch, E=2))
        np.testing.assert_array_equal(got[2]["rho"],
                                      g2.ccm_batch(watch, E=2))


def test_subscription_http_roundtrip_long_poll():
    import json
    import urllib.request
    full = _panel(4, 148, seed=15)
    old, delta = full[:, :140], full[:, 140:]
    grown = EDM(full, E_max=3, cache=True)
    grown.optimal_E()
    with EDMServer() as srv:
        httpd = serve_http(srv)
        port = httpd.server_address[1]

        def post(path, body):
            req = urllib.request.Request(
                f"http://127.0.0.1:{port}{path}",
                json.dumps(body).encode(),
                {"Content-Type": "application/json"})
            with urllib.request.urlopen(req, timeout=60) as r:
                return json.loads(r.read())

        try:
            post("/v1/register", {"panel": "p", "data": old.tolist(),
                                  "E_max": 3, "cache": True})
            sid = post("/v1/subscribe",
                       {"panel": "p", "pairs": [[0, 2], [1, 3]],
                        "E": 2})["result"]["id"]
            post("/v1/append", {"panel": "p", "delta": delta.tolist()})
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/v1/subscriptions/{sid}"
                    f"?timeout=10", timeout=60) as r:
                ticks = json.loads(r.read())["ticks"]
            assert [t["version"] for t in ticks] == [0, 1]
            want = grown.ccm_batch([(0, 2), (1, 3)], E=2)
            got = np.asarray([np.nan if v is None else v
                              for v in ticks[1]["rho"]], np.float32)
            np.testing.assert_array_equal(got, want)
            assert post("/v1/unsubscribe", {"id": sid})["result"]
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/v1/subscriptions/{sid}"
                    "?timeout=0") as r:
                raise AssertionError("poll of closed sub should 404")
        except urllib.error.HTTPError as e:
            assert e.code == 404
        finally:
            httpd.shutdown()
