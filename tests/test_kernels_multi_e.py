"""Incremental multi-E all-kNN engine ≡ the per-E two-kernel pipeline."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import core
from repro.data import timeseries as ts
from repro.kernels import ops, ref


def _check_against_per_E(x, d, i, *, E_max, tau, ks, max_idx=None,
                         exclude_self=True):
    """Every level of the stacked tables equals its per-E oracle; padding
    outside each level's (Lp_E, k_E) block is inf / -1."""
    L = x.shape[-1]
    k_max = max(ks)
    assert d.shape == i.shape == (E_max, L, k_max)
    for E in range(1, E_max + 1):
        Lp = L - (E - 1) * tau
        kE = ks[E - 1]
        mx = None if max_idx is None else min(max_idx, Lp - 1)
        D = ref.pairwise_distances(x, E=E, tau=tau)
        want_d, want_i = ref.topk_select(D, k=kE, exclude_self=exclude_self,
                                         max_idx=mx)
        np.testing.assert_array_equal(np.asarray(i[E - 1, :Lp, :kE]),
                                      np.asarray(want_i), err_msg=f"E={E}")
        np.testing.assert_allclose(np.asarray(d[E - 1, :Lp, :kE]),
                                   np.asarray(want_d), rtol=1e-5, atol=1e-5,
                                   err_msg=f"E={E}")
        assert np.all(np.isinf(np.asarray(d[E - 1, Lp:, :])))
        assert np.all(np.asarray(i[E - 1, Lp:, :]) == ref.PAD_IDX)
        assert np.all(np.isinf(np.asarray(d[E - 1, :, kE:])))
        assert np.all(np.asarray(i[E - 1, :, kE:]) == ref.PAD_IDX)


@pytest.mark.parametrize("L,E_max,tau,k", [
    (137, 5, 2, None),
    (200, 1, 1, None),
    (96, 8, 1, 4),      # uniform-k override
    (193, 6, 3, None),  # partial tiles at every level
])
def test_ref_multi_e_matches_per_E(rng, L, E_max, tau, k):
    x = jnp.asarray(rng.normal(size=L).astype(np.float32))
    d, i = ref.all_knn_multi_e(x, E_max=E_max, tau=tau, k=k)
    _check_against_per_E(x, d, i, E_max=E_max, tau=tau,
                         ks=ref.multi_e_ks(E_max, k))


@pytest.mark.parametrize("L,E_max,tau,k,block", [
    (137, 5, 2, None, (16, 128)),   # gj > 1: streaming merge across tiles
    (200, 1, 1, None, (32, 128)),
    (96, 8, 1, 4, (8, 128)),
    (300, 4, 1, None, (64, 128)),   # 3 column tiles, partial last tile
])
def test_interpret_kernel_matches_ref(rng, L, E_max, tau, k, block):
    x = jnp.asarray(rng.normal(size=L).astype(np.float32))
    want_d, want_i = ref.all_knn_multi_e(x, E_max=E_max, tau=tau, k=k)
    got_d, got_i = ops.all_knn_multi_e(x, E_max=E_max, tau=tau, k=k,
                                       impl="interpret", block=block)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6, atol=1e-6)


def test_interpret_kernel_max_idx_and_no_self(rng):
    x = jnp.asarray(rng.normal(size=150).astype(np.float32))
    for excl in (True, False):
        want_d, want_i = ref.all_knn_multi_e(x, E_max=4, tau=1, max_idx=40,
                                             exclude_self=excl)
        got_d, got_i = ops.all_knn_multi_e(x, E_max=4, tau=1, max_idx=40,
                                           exclude_self=excl,
                                           impl="interpret", block=(16, 128))
        assert int(np.asarray(got_i).max()) <= 40
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                                   rtol=1e-6, atol=1e-6)


def test_interpret_kernel_fewer_valid_candidates_than_k(rng):
    """Regression: rows with < k valid candidates must emit distinct
    (lowest-index) fill entries, not the same index repeated — removal in
    the streaming merge has to be by index, since inf entries can't be
    retired by setting them to inf again."""
    x = jnp.asarray(rng.normal(size=100).astype(np.float32))
    for cap in (0, 1):
        want_d, want_i = ref.all_knn_multi_e(x, E_max=3, tau=1, max_idx=cap)
        got_d, got_i = ops.all_knn_multi_e(x, E_max=3, tau=1, max_idx=cap,
                                           impl="interpret", block=(16, 128))
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                                   rtol=1e-6, atol=1e-6)


def test_interpret_kernel_column_tiled_large_L(rng):
    """Acceptance: Lp beyond one VMEM block — L ≥ 8192 forces the streaming
    k-best merge across 4 column tiles (and 8 row blocks) in interpret."""
    L = 8192
    x = jnp.asarray(rng.normal(size=L).astype(np.float32))
    want_d, want_i = ref.all_knn_multi_e(x, E_max=2, tau=1)
    got_d, got_i = ops.all_knn_multi_e(x, E_max=2, tau=1, impl="interpret",
                                       block=(1024, 2048))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6, atol=1e-6)


def test_multi_e_sorted_ascending(rng):
    x = jnp.asarray(rng.normal(size=180).astype(np.float32))
    d, _ = ref.all_knn_multi_e(x, E_max=6, tau=1)
    for E in range(1, 7):
        Lp = 180 - (E - 1)
        dE = np.asarray(d[E - 1, :Lp, :E + 1])
        assert (np.diff(dE, axis=1) >= 0).all(), f"E={E} not sorted"


def test_rho_curve_matches_seed_sweep_every_E(rng):
    """Acceptance: ρ(E) from the one-pass engine ≡ the seed per-E sweep for
    every E in 1..E_max, f32 tolerance."""
    x = jnp.asarray(ts.logistic_map(400))
    for tau, Tp in ((1, 1), (2, 3)):
        want = np.asarray(core.optimal_E_sweep_seed(x, E_max=10, tau=tau,
                                                    Tp=Tp, impl="ref"))
        got = np.asarray(core.rho_curve(x, E_max=10, tau=tau, Tp=Tp,
                                        impl="ref"))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rho_curve_interpret_matches_ref():
    x = jnp.asarray(ts.logistic_map(300))
    want = np.asarray(core.rho_curve(x, E_max=6, impl="ref"))
    got = np.asarray(core.rho_curve(x, E_max=6, impl="interpret"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_make_weights_all_inf_row_is_zero_not_nan():
    """Regression: an all-inf distance row (aggressive max_idx cap leaves no
    valid neighbor) must yield zero weights, not NaN ρ downstream."""
    d = jnp.asarray(np.array([[np.inf, np.inf, np.inf],
                              [0.5, 1.0, np.inf],
                              [0.0, 0.0, 1.0]], np.float32))
    w = np.asarray(ref.make_weights(d))
    assert np.isfinite(w).all(), f"NaN/inf weights: {w}"
    np.testing.assert_allclose(w[0], 0.0)
    np.testing.assert_allclose(w[1:].sum(axis=1), 1.0, rtol=1e-5)
    # duplicate-neighbor guard still intact (cppEDM semantics)
    assert w[2, 0] == w[2, 1] > w[2, 2]


def test_make_weights_zero_row_via_engine_cap():
    """End-to-end: a max_idx cap of -1 (no candidates at all) flows through
    make_weights without NaN."""
    x = jnp.asarray(np.linspace(0, 1, 50, dtype=np.float32))
    d, i = ref.all_knn_multi_e(x, E_max=2, tau=1, max_idx=-1)
    w = np.asarray(ref.make_weights(d[0, :49, :2]))
    assert np.isfinite(w).all()
    np.testing.assert_allclose(w, 0.0)
