"""Library-batched CCM matrix engine: batch-axis bit-parity, ragged
batches, launch counting, the auto B memory-budget rule, and the session
routing of ISSUE 5."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import core, telemetry
from repro.core import ccm
from repro.data import timeseries as ts
from repro.edm import EDM, EDMConfig
from repro.edm import plan as edm_plan


def _panel(n=7, steps=240, seed=5):
    panel, _ = ts.forced_network_panel(n, steps, seed=seed)
    return jnp.asarray(panel)


# --------------------------------------------------- batch-axis parity


def test_batched_bit_invariant_in_B_including_ragged():
    """The layout contract: results never depend on the batch size —
    B = 1 (the per-series oracle), a ragged split (Nl % B != 0), and a
    one-launch run are bit-identical."""
    X = _panel(7)
    runs = [core.ccm_group_batched(X, X, E=3, impl="ref", batch_libs=B)
            for B in (1, 2, 3, 7)]  # 7 % 2 and 7 % 3 != 0: ragged finals
    for got in runs[1:]:
        np.testing.assert_array_equal(runs[0], got)


def test_batched_matches_legacy_ccm_group():
    """Index/tie order is exact vs the legacy per-series ``lax.map``
    path by construction; ρ is bit-equal on these shapes (the ~1 ULP
    lax.map drift documented in kernels/ref.py shows up only at some
    shapes, e.g. Lp = 94 — see the bench's allclose guard there)."""
    X = _panel(6)
    for E, tau, Tp in ((2, 1, 0), (3, 2, 1), (5, 1, 2)):
        got = core.ccm_group_batched(X, X, E=E, tau=tau, Tp=Tp, impl="ref",
                                     batch_libs=4)
        want = np.asarray(core.ccm_group(X, X, E=E, tau=tau, Tp=Tp,
                                         impl="ref"))
        np.testing.assert_array_equal(got, want, err_msg=f"E={E}")


def test_batched_duplicate_manifold_tie_order():
    """Exact-duplicate library series get identical matrix rows — ties
    are broken by global neighbor index, not by batch position."""
    X = _panel(5)
    Xd = jnp.concatenate([X, X[:1]], axis=0)  # series 5 duplicates 0
    rho = core.ccm_group_batched(Xd, Xd, E=3, impl="ref", batch_libs=4)
    np.testing.assert_array_equal(rho[0], rho[5])


def test_batched_empty_library_axis():
    """Review follow-up: zero libraries → empty matrix, like ccm_group."""
    X = _panel(4)
    rho = core.ccm_group_batched(X[:0], X, E=2, impl="ref")
    assert rho.shape == (0, 4)
    sess = EDM(X, EDMConfig(E_max=4))
    sess.optimal_E()
    iM = sess._cache["master"][1]
    rho_m = edm_plan.ccm_group_from_master_batched(
        X[:0], iM[:0, 1], X, E=2, tau=1, Tp=0, k=3, impl="ref")
    assert rho_m.shape == (0, 4)


def test_batched_single_target_and_custom_k():
    X = _panel(4)
    rho = core.ccm_group_batched(X, X[0], E=2, impl="ref", batch_libs=3)
    assert rho.shape == (4, 1)
    rho_k = core.ccm_group_batched(X, X, E=2, k=5, impl="ref", batch_libs=2)
    np.testing.assert_array_equal(
        rho_k, core.ccm_group_batched(X, X, E=2, k=5, impl="ref",
                                      batch_libs=4))


def test_master_batched_bit_invariant_and_matches_per_series():
    """The cached-master twin obeys the same layout contract and equals
    the legacy per-series derivation."""
    X = _panel(6)
    sess = EDM(X, EDMConfig(E_max=4))
    sess.optimal_E()
    dM, iM, k_m, lv = sess._cache["master"]
    E = 3
    runs = [edm_plan.ccm_group_from_master_batched(
        X, iM[:, E - 1], X, E=E, tau=1, Tp=0, k=E + 1, impl="ref",
        batch_libs=B) for B in (1, 4, 6)]
    for got in runs[1:]:
        np.testing.assert_array_equal(runs[0], got)
    legacy = np.asarray(edm_plan.ccm_group_from_master(
        X, iM[:, E - 1], X, E=E, tau=1, Tp=0, k=E + 1, impl="ref"))
    np.testing.assert_array_equal(runs[0], legacy)


# ------------------------------------------------------ launch counting


def test_engine_launch_count_ceil_nl_over_b():
    """ceil(Nl/B) engine launches, exactly — the padded ragged final
    batch rides in the last launch, never a retrace or an extra step.
    Counted via the ``edm_group_launches`` telemetry counter the launch
    closure increments at runtime (no cache clear needed — launches are
    per call, not per trace)."""
    X = _panel(7)
    launches = telemetry.counter("edm_group_launches")
    base = launches.value
    core.ccm_group_batched(X, X, E=3, impl="ref", batch_libs=3)
    assert launches.value - base == 3  # ceil(7/3)
    base = launches.value
    core.ccm_group_batched(X, X, E=3, impl="ref", batch_libs=7)
    assert launches.value - base == 1
    base = launches.value
    core.ccm_group_batched(X, X, E=3, impl="ref", batch_libs=100)  # clamped
    assert launches.value - base == 1


def test_session_xmap_launch_count():
    """The session's xmap drives each E-group with ceil(N/B) launches of
    the right engine: master-derived when the cached master covers the
    group, direct otherwise. Asserted via Recorder counter deltas on the
    two launch counters."""
    X = _panel(6)
    with telemetry.record() as rec:
        EDM(X, EDMConfig(E=3, batch_libs=2)).xmap()  # fixed E: one group
    assert rec.counter_delta("edm_group_launches") == 3  # ceil(6/2)
    assert rec.counter_delta("edm_master_launches") == 0  # no master built

    sess2 = EDM(X, EDMConfig(E_max=4, batch_libs=2))
    sess2.optimal_E()  # builds the master the xmap then derives from
    groups = len(set(sess2.optimal_E()[0].tolist()))
    with telemetry.record() as rec2:
        sess2.xmap()
    assert rec2.counter_delta("edm_group_launches") == 0
    assert rec2.counter_delta("edm_master_launches") == 3 * groups


def test_repeat_xmap_amortizes_via_master_on_second_call():
    """Review follow-up: a one-shot matrix skips the master build, but a
    REPEATING xmap workload on a caching session must recover the
    amortization — the second call builds the master once, later calls
    derive from it, and every call agrees bit-for-bit."""
    X = _panel(5)
    sess = EDM(X, EDMConfig(E=3))
    p0 = sess.plan("xmap")
    assert "direct engine" in p0.detail and p0.builds == ()
    first = sess.xmap()
    assert "master" not in sess._cache
    assert sess.stats["xmap_direct_runs"] == 1
    p1 = sess.plan("xmap")
    assert "cached kNN master" in p1.detail and p1.builds == ("master",)
    second = sess.xmap()
    assert sess.stats["knn_master_builds"] == 1
    third = sess.xmap()
    assert sess.stats["knn_master_builds"] == 1  # built once, reused
    np.testing.assert_array_equal(first, second)
    np.testing.assert_array_equal(first, third)


# ------------------------------------------------------- auto B sizing


def test_auto_batch_libs_budget_rule():
    # B·Lp²·4 bytes under the budget, clamped to [1, Nl]
    assert core.auto_batch_libs(1024, 500, budget_mb=64) == 16
    assert core.auto_batch_libs(4096, 64, budget_mb=64) == 1  # budget < Lp²
    assert core.auto_batch_libs(64, 100, budget_mb=64) == 100  # whole panel
    assert core.auto_batch_libs(1024, 8, budget_mb=1 << 20) == 8  # Nl clamp
    # launches are equalized under the cap: a 949-cap against Nl=1024
    # must not schedule a full launch plus a 75→949 padded one
    per_mb = 4 * 94 * 94 / 2**20
    B = core.auto_batch_libs(94, 1024, budget_mb=949 * per_mb)
    assert B == 512  # two even launches, both under the cap
    B_default = core.auto_batch_libs(1024, 500)  # backend-aware default
    assert B_default == core.auto_batch_libs(
        1024, 500, budget_mb=ccm._default_budget_mb())


def test_config_batch_knobs_validated():
    with pytest.raises(ValueError, match="batch_libs"):
        EDMConfig(batch_libs=0)
    with pytest.raises(ValueError, match="batch_budget_mb"):
        EDMConfig(batch_budget_mb=0)
    X = _panel(4)
    a = EDM(X, EDMConfig(E=2, batch_libs=3)).xmap()
    b = EDM(X, EDMConfig(E=2, batch_budget_mb=0.5)).xmap()  # tiny budget
    np.testing.assert_array_equal(a, b)  # knobs never change results


# ------------------------------------------------------ session parity


def test_session_xmap_equals_batched_composition_per_E_group():
    X = _panel(6)
    sess = EDM(X, EDMConfig(E_max=5))
    E_opt, _ = sess.optimal_E()
    got = sess.xmap()
    want = np.zeros((6, 6), np.float32)
    for E in sorted(set(E_opt.tolist())):
        m = np.nonzero(E_opt == E)[0]
        want[:, m] = core.ccm_group_batched(X, X[m], E=int(E), impl="ref")
    np.testing.assert_array_equal(got, want)


def test_sharded_local_block_batching_matches_unbatched():
    """The per-shard batched inner engine gives the same matrix for any
    B (1×1 mesh exercises the real shard_map path in-process)."""
    from repro.distributed import make_ccm_mesh, sharded_ccm_matrix
    X = _panel(5, 220)
    mesh = make_ccm_mesh((1, 1), ("data", "model"))
    runs = [np.asarray(sharded_ccm_matrix(X, X, E=2, mesh=mesh, impl="ref",
                                          batch_libs=B))
            for B in (1, 2, 5)]
    for got in runs[1:]:
        np.testing.assert_array_equal(runs[0], got)
    E_opt = np.array([2, 3, 2, 4, 3], np.int32)
    got_e = sharded_ccm_matrix(X, X, E_opt=E_opt, mesh=mesh, impl="ref",
                               batch_libs=2)
    np.testing.assert_allclose(got_e, core.ccm_matrix(X, E_opt),
                               rtol=1e-5, atol=1e-6)


def test_egroup_layout_device_side_matches_host_reference():
    """The device-built permutation equals the old host-side layout:
    groups ascending by E, members in index order, each padded to a
    multiple of S by repeating its last member, interleaved per shard."""
    from repro.distributed.sharded_ccm import _egroup_layout, pad_members
    E_opt = np.array([3, 2, 5, 2, 2, 3, 5, 5, 5], np.int32)
    for S in (1, 2, 4):
        perm, keep, segs = _egroup_layout(jnp.asarray(E_opt), S)
        # host reference (the pre-PR-5 implementation)
        seg_perm, seg_keep, ref_segs = [], [], []
        for E in sorted(set(E_opt.tolist())):
            members = np.nonzero(E_opt == E)[0]
            padded = pad_members(members, S)
            kp = np.arange(len(padded)) < len(members)
            w = len(padded) // S
            ref_segs.append((int(E), w))
            seg_perm.append(padded.reshape(S, w))
            seg_keep.append(kp.reshape(S, w))
        np.testing.assert_array_equal(
            np.asarray(perm), np.concatenate(seg_perm, axis=1).reshape(-1))
        np.testing.assert_array_equal(
            keep, np.concatenate(seg_keep, axis=1).reshape(-1))
        assert segs == tuple(ref_segs)
