"""CCM behaviour: causal direction, convergence, pairwise matrix, sharding."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.data import timeseries as ts
from repro.distributed import (
    make_ccm_mesh,
    pad_to_multiple,
    sharded_ccm_matrix,
    sharded_optimal_E,
)


def _coupled(n=600):
    # X forces Y strongly; Y does not force X.
    return ts.coupled_logistic(n, b_xy=0.0, b_yx=0.32, seed=3)


def test_ccm_detects_direction():
    x, y = _coupled()
    E = 2
    # "X causes Y" evidence: cross-map X from Y's manifold.
    rho_x_from_y = float(core.cross_map(jnp.asarray(y), jnp.asarray(x), E=E))
    rho_y_from_x = float(core.cross_map(jnp.asarray(x), jnp.asarray(y), E=E))
    assert rho_x_from_y > 0.85, f"forcing not detected: {rho_x_from_y}"
    assert rho_x_from_y > rho_y_from_x + 0.15, (
        f"asymmetry missing: {rho_x_from_y} vs {rho_y_from_x}"
    )


def test_ccm_convergence_with_library_size():
    """The 'convergent' in CCM: skill rises with library size for a true
    causal link (Sugihara 2012)."""
    x, y = _coupled(900)
    sizes = (60, 200, 880)
    curve = np.asarray(
        core.cross_map(jnp.asarray(y), jnp.asarray(x), E=2, lib_sizes=sizes)
    )
    assert curve[-1] > curve[0] + 0.1, f"no convergence: {curve}"
    assert (np.diff(curve) > -0.05).all(), f"non-monotone beyond tol: {curve}"


def test_ccm_matrix_recovers_star_topology():
    panel, adj = ts.forced_network_panel(8, 500, n_drivers=1, coupling=0.3,
                                         seed=5)
    E_opt = np.full(8, 2, np.int32)
    rho = core.ccm_matrix(jnp.asarray(panel), E_opt)
    # driver-forces-follower links: cross-map driver from follower manifolds
    # => rho[follower, driver] high vs reverse.
    forced = [rho[j, 0] for j in range(1, 8)]
    reverse = [rho[0, j] for j in range(1, 8)]
    assert np.mean(forced) > np.mean(reverse) + 0.1, (
        f"forced={np.round(forced, 2)} reverse={np.round(reverse, 2)}"
    )


def test_ccm_matrix_grouped_by_E_matches_cross_map():
    panel, _ = ts.forced_network_panel(4, 300, seed=2)
    X = jnp.asarray(panel)
    E_opt = np.array([2, 3, 2, 3], np.int32)
    rho = core.ccm_matrix(X, E_opt)
    for l in range(4):
        for t in range(4):
            want = float(core.cross_map(X[l], X[t], E=int(E_opt[t])))
            np.testing.assert_allclose(rho[l, t], want, rtol=1e-4, atol=1e-4)


def test_sharded_ccm_matches_local_single_device():
    panel, _ = ts.forced_network_panel(6, 300, seed=9)
    X = jnp.asarray(panel)
    E = 2
    mesh = make_ccm_mesh((1, 1), ("data", "model"))
    rho_sharded = np.asarray(
        sharded_ccm_matrix(X, X, E=E, mesh=mesh, impl="ref")
    )
    rho_local = core.ccm_matrix(X, np.full(6, E, np.int32))
    np.testing.assert_allclose(rho_sharded, rho_local, rtol=1e-4, atol=1e-4)


def test_sharded_optimal_E_matches_local():
    """In-shard multi-E tables ≡ the local optimal_E_batch driver."""
    panel, _ = ts.forced_network_panel(4, 220, seed=13)
    X = jnp.asarray(panel)
    mesh = make_ccm_mesh((1,), ("data",))
    E_s, rho_s = sharded_optimal_E(X, E_max=5, mesh=mesh, axes=("data",),
                                   impl="ref")
    E_l, rho_l = core.optimal_E_batch(X, E_max=5, impl="ref")
    np.testing.assert_array_equal(np.asarray(E_s), np.asarray(E_l))
    np.testing.assert_allclose(np.asarray(rho_s), np.asarray(rho_l),
                               rtol=1e-5, atol=1e-5)


def test_pad_to_multiple():
    x = jnp.ones((5, 3))
    assert pad_to_multiple(x, 4, axis=0).shape == (8, 3)
    assert pad_to_multiple(x, 5, axis=0).shape == (5, 3)


def test_sharded_ccm_multidevice_subprocess():
    """Run the sharded engine on 8 emulated host devices in a subprocess
    (keeps this process at 1 device) and check against the local driver."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro import core
        from repro.data import timeseries as ts
        from repro.distributed import (
            make_ccm_mesh, sharded_ccm_matrix, sharded_optimal_E)
        panel, _ = ts.forced_network_panel(8, 240, seed=11)
        X = jnp.asarray(panel)
        mesh = make_ccm_mesh((4, 2), ("data", "model"))
        rho_s = np.asarray(sharded_ccm_matrix(X, X, E=2, mesh=mesh, impl="ref"))
        rho_l = core.ccm_matrix(X, np.full(8, 2, np.int32))
        np.testing.assert_allclose(rho_s, rho_l, rtol=1e-3, atol=1e-3)
        mesh1 = make_ccm_mesh((8,), ("data",))
        E_s, rho_es = sharded_optimal_E(X, E_max=4, mesh=mesh1,
                                        axes=("data",), impl="ref")
        E_l, rho_el = core.optimal_E_batch(X, E_max=4, impl="ref")
        np.testing.assert_array_equal(np.asarray(E_s), np.asarray(E_l))
        np.testing.assert_allclose(np.asarray(rho_es), np.asarray(rho_el),
                                   rtol=1e-3, atol=1e-3)
        print("SHARDED_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout
