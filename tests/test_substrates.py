"""Substrate tests: optimizer (fp32 + 8-bit), schedules, checkpointing
(atomic/retention/resume/elastic), data determinism, compression."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.distributed.compression import (
    allreduce_compressed,
    ef_compress_tree,
    init_error_buf,
)
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    warmup_cosine,
)


# ---------------------------------------------------------------- optim


def _rosenbrock_ish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2) + 5 * jnp.sum((y - x**2) ** 2)


@pytest.mark.parametrize("bits8", [False, True])
def test_adamw_optimizes(bits8):
    params = {"x": jnp.full((8,), -1.0), "y": jnp.full((8,), 2.0)}
    state = adamw_init(params, bits8=bits8)
    loss0 = float(_rosenbrock_ish(params))

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(_rosenbrock_ish)(params)
        params, state = adamw_update(
            grads, state, params, lr=3e-2, weight_decay=0.0, bits8=bits8)
        return params, state, loss

    for _ in range(300):
        params, state, loss = step(params, state)
    assert float(loss) < 0.05 * loss0, f"bits8={bits8}: loss {float(loss)}"


def test_adamw8bit_tracks_fp32():
    """8-bit moments must land within a few % of the fp32 trajectory.
    Shape chosen to be codec-eligible (last dim % 256 == 0, ≥64k)."""
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(size=(128, 512)).astype(np.float32))
    tgt = jnp.asarray(rng.normal(size=(128, 512)).astype(np.float32))

    def run(bits8):
        params = {"w": w0}
        state = adamw_init(params, bits8=bits8)
        for _ in range(50):
            grads = {"w": 2 * (params["w"] - tgt)}
            params, state = adamw_update(
                grads, state, params, lr=1e-2, weight_decay=0.0, bits8=bits8)
        return np.asarray(params["w"])

    a, b = run(False), run(True)
    # quantization noise accumulates as a bounded random walk; what matters
    # is trajectory-level agreement (divergence would be O(10+), see the
    # linear-codemap failure mode documented in adamw.py)
    assert np.abs(a - b).max() < 0.1, np.abs(a - b).max()


def test_adamw8bit_state_is_int8():
    params = {"w": jnp.zeros((64, 1024)), "b": jnp.zeros((100,))}
    state = adamw_init(params, bits8=True)
    assert state["m"]["w"]["q"].dtype == jnp.int8
    assert state["m"]["w"]["q"].shape == (64, 1024)  # sharding-preserving
    bytes_8 = state["m"]["w"]["q"].size + 4 * state["m"]["w"]["scale"].size
    assert bytes_8 < 0.3 * 64 * 1024 * 4, "8-bit state must be ≲ 1/4 of fp32"
    # small / non-blocking leaves keep fp32 moments
    assert state["m"]["b"].dtype == jnp.float32


def test_warmup_cosine_shape():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                              total_steps=100)) for s in range(101)]
    assert lr[0] == 0.0 and abs(lr[10] - 1.0) < 1e-6
    assert lr[50] < lr[10] and lr[100] <= lr[50]
    assert abs(lr[100] - 0.1) < 1e-6  # final_frac


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


# ------------------------------------------------------------ checkpoint


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(4, 6)).astype(np.float32)),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                   "c": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _tree(0)
    mgr.save(10, state)
    restored = mgr.restore(jax.tree.map(lambda x: x, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4
    restored = mgr.restore(_tree(0))
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree(4)["a"]))


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree(1))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(0))
    with pytest.raises(ValueError):
        mgr.restore({"only": jnp.zeros((2,))})


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto an explicit sharding (elastic mesh change path)."""
    mgr = CheckpointManager(str(tmp_path))
    state = _tree(3)
    mgr.save(1, state)
    mesh = compat.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    shardings = jax.tree.map(lambda _: sh, state)
    restored = mgr.restore(state, shardings=shardings)
    assert restored["a"].sharding == sh
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))


# ------------------------------------------------------------------ data


def test_pipeline_deterministic_and_sharded():
    pipe = TokenPipeline(vocab_size=97, batch=8, seq_len=16, seed=3)
    a = pipe.global_batch(5)["tokens"]
    b = pipe.global_batch(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = pipe.global_batch(6)["tokens"]
    assert (a != c).any()
    # rank slices tile the global batch exactly
    parts = [pipe.batch_slice(5, rank=r, world=4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), a)
    assert a.min() >= 0 and a.max() < 97


# ---------------------------------------------------------- compression


@pytest.mark.parametrize("kind", ["bf16", "int8"])
def test_ef_compression_error_feedback(kind):
    """Error feedback: the *accumulated* delivered signal converges to the
    accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(512,)).astype(np.float32)) * 1e-3
    grads = {"w": g_true}
    ebuf = init_error_buf(grads)
    delivered = jnp.zeros_like(g_true)
    for _ in range(30):
        wire, ebuf = ef_compress_tree(grads, ebuf, kind)
        delivered = delivered + wire["w"]
    total_err = np.abs(np.asarray(delivered - 30 * g_true)).max()
    # without EF, int8 bias would accumulate linearly; with EF it's ≤ 1 quantum
    assert total_err < 2e-4, total_err


def test_allreduce_compressed_single_device():
    mesh = compat.make_mesh((1,), ("data",))
    g = jnp.asarray(np.random.default_rng(1).normal(size=(256,))
                    .astype(np.float32))
    out = compat.shard_map(
        lambda x: allreduce_compressed(x, "data", "int8"),
        mesh=mesh, in_specs=jax.sharding.PartitionSpec(None),
        out_specs=jax.sharding.PartitionSpec(None), check_vma=False)(g)
    # int8 quantum for N(0,1) data: absmax/127 ≈ 0.024 → half-quantum atol
    np.testing.assert_allclose(np.asarray(out), np.asarray(g),
                               rtol=2e-2, atol=1.5e-2)
