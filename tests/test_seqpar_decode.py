"""Sequence-parallel KV decode (§Perf D-2) ≡ plain decode, multi-device."""

import os
import subprocess
import sys
import textwrap


def test_seqpar_decode_matches_plain_multidevice():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import transformer as tf
        from repro.models import meshctx

        cfg = get_config("llama3-8b", smoke=True)
        params = tf.init_params(cfg, jax.random.key(0))
        rng = np.random.default_rng(0)
        B, S = 4, 16
        toks = [jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)),
                            jnp.int32) for _ in range(6)]

        from repro.launch.mesh import make_test_mesh

        def run(seqpar):
            mesh = make_test_mesh((2, 4), ("data", "model"))
            with meshctx.use_mesh(mesh if seqpar else None):
                meshctx.set_seqpar_decode(seqpar)
                cache = tf.init_cache(cfg, B, S)
                outs = []
                step = jax.jit(lambda p, t, c, pos: tf.decode_step(
                    p, cfg, t, c, pos))
                for t, tok in enumerate(toks):
                    logits, cache = step(params, tok, cache, jnp.int32(t))
                    outs.append(np.asarray(logits))
                meshctx.set_seqpar_decode(False)
                return np.stack(outs)

        plain = run(False)
        seqpar = run(True)
        np.testing.assert_allclose(seqpar, plain, rtol=2e-4, atol=2e-4)
        print("SEQPAR_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SEQPAR_OK" in out.stdout
