"""EDMConfig / Dataset validation and the ops impl-dispatch satellite."""

import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.edm import EDM, EDMConfig, Dataset
from repro.kernels import ops


# ------------------------------------------------------------ EDMConfig


@pytest.mark.parametrize("bad", [
    dict(E=0), dict(E=-3),
    dict(E_max=0),
    dict(tau=0), dict(tau=-1),
    dict(Tp=-1), dict(Tp_cross=-2),
    dict(theta=-0.5),
    dict(thetas=()), dict(thetas=(0.0, -1.0, 2.0)),
    dict(k=0),
    dict(ridge=-1e-3),
    dict(impl="bogus"),
])
def test_config_rejects_invalid(bad):
    with pytest.raises(ValueError):
        EDMConfig(**bad)


def test_config_defaults_valid_and_frozen():
    c = EDMConfig()
    assert c.thetas[0] == 0.0 and all(t >= 0 for t in c.thetas)
    with pytest.raises(Exception):
        c.E = 3
    c2 = c.replace(E=4, tau=2)
    assert (c2.E, c2.tau) == (4, 2) and c.E is None


def test_config_derived_fields():
    c = EDMConfig(E=3, k=7, Tp=2, Tp_cross=0)
    assert c.k_for(3) == 7
    assert EDMConfig().k_for(3) == 4  # simplex default E + 1
    assert c.slack == 2
    assert EDMConfig().slack == 1
    # E > E_max widens the sweep bound instead of failing
    assert EDMConfig(E=25, E_max=20).E_max == 25


def _stub_mesh(**shape):
    return types.SimpleNamespace(shape=dict(shape),
                                 axis_names=tuple(shape))


def test_config_mesh_axis_names_checked():
    with pytest.raises(ValueError, match="missing"):
        EDMConfig(mesh=_stub_mesh(data=2), tgt_axes=("model",))
    EDMConfig(mesh=_stub_mesh(data=2, model=2))  # ok


def test_panel_validation_k_exceeds_pred_rows():
    x = np.random.default_rng(0).standard_normal((2, 40)).astype(np.float32)
    rows = 40 - (3 - 1) * 1 - 1  # pred_rows(L=40, E=3, tau=1, Tp=1)
    EDM(x, EDMConfig(E=3, k=rows))  # boundary ok
    with pytest.raises(ValueError, match="prediction rows"):
        EDM(x, EDMConfig(E=3, k=rows + 1))


def test_panel_validation_series_too_short():
    # random, not zeros: constant series trip the on_invalid="raise"
    # ingestion screen before the length check this test targets
    x = np.random.default_rng(0).standard_normal((2, 10)).astype(np.float32)
    with pytest.raises(ValueError, match="too short"):
        EDM(x, EDMConfig(E_max=15))


def test_panel_validation_mesh_divisibility():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((6, 64)).astype(np.float32)
    mesh = _stub_mesh(data=4, model=2)
    with pytest.raises(ValueError, match="do not divide"):
        EDM(x, EDMConfig(E=2, mesh=mesh, pad=False))
    EDM(x, EDMConfig(E=2, mesh=mesh, pad=True))  # auto-pad accepts
    EDM(rng.standard_normal((8, 64)).astype(np.float32),
        EDMConfig(E=2, mesh=mesh, pad=False))  # divisible accepts


# -------------------------------------------------------------- Dataset


def test_dataset_promotes_and_validates():
    rng = np.random.default_rng(0)
    d = Dataset(rng.standard_normal(32).astype(np.float32))
    assert (d.N, d.L) == (1, 32)
    with pytest.raises(ValueError):
        Dataset(np.zeros((2, 3, 4), np.float32))
    with pytest.raises(ValueError):
        Dataset(rng.standard_normal((2, 32)).astype(np.float32),
                names=["only-one"])


def test_dataset_names_and_embedding_cache():
    d = Dataset(np.random.default_rng(1).standard_normal((3, 40)),
                names=["a", "b", "c"])
    assert d.index_of("b") == 1
    assert d.series("c").shape == (40,)
    Z = d.embedding(E=3, tau=2)
    assert Z.shape == (3, 40 - 2 * 2, 3)
    assert d.embedding(E=3, tau=2) is Z  # cached object, not recomputed
    np.testing.assert_allclose(np.asarray(Z[0, :, 1]),
                               np.asarray(d.panel[0, 2:38]))


# ------------------------------------------------- ops impl dispatch


def test_resolve_impl_errors_on_unknown():
    with pytest.raises(ValueError, match="unknown impl"):
        ops.resolve_impl("cuda")
    with pytest.raises(ValueError, match="unknown impl"):
        ops.pairwise_distances(jnp.zeros(16), E=2, impl="bogus")


def test_use_impl_scoped_override():
    base = ops.resolve_impl("auto")
    with ops.use_impl("interpret"):
        assert ops.resolve_impl("auto") == "interpret"
        with ops.use_impl("ref"):
            assert ops.resolve_impl("auto") == "ref"
        assert ops.resolve_impl("auto") == "interpret"
        # explicit names still win over the override
        assert ops.resolve_impl("ref") == "ref"
    assert ops.resolve_impl("auto") == base
    with pytest.raises(ValueError):
        with ops.use_impl("nope"):
            pass  # pragma: no cover
    assert ops.resolve_impl("auto") == base  # stack not corrupted
