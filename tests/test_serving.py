"""EDM server: scheduler coalescing, append barriers, HTTP front end.

The serving contracts (ISSUE 8):

* FIFO across signatures — a batch never executes before an earlier
  incompatible request.
* Compatible CCM requests coalesce into ONE launch whose per-request
  answers are bit-identical to direct ``EDM`` session calls (telemetry
  counter-delta assertions, PR-7 style — no monkeypatching).
* An append is a version barrier: requests behind it see the grown
  library, requests ahead of it the old one, and every answer is
  bit-identical to the quiesced ordering.
* The submit API is thread-safe under concurrent clients.
"""

import json
import threading
import urllib.request

import numpy as np
import pytest

from repro import telemetry
from repro.edm.session import EDM
from repro.serving import EDMServer, serve_http


@pytest.fixture(scope="module")
def panel():
    from repro.data.timeseries import forced_network_panel
    p, _ = forced_network_panel(6, 300, seed=9)
    return np.asarray(p)


PAIRS = [(0, 2), (1, 3), (0, 4), (2, 5), (1, 2), (3, 0)]


def _direct(panel):
    sess = EDM(panel, E_max=4, cache=True)
    sess.optimal_E()
    return sess


# ------------------------------------------------------------ coalescing


def test_compatible_ccm_requests_coalesce_into_one_launch(panel):
    old = panel[:, :280]
    with telemetry.record() as rec, EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, E_max=4, cache=True)
        srv.submit("optimal_E", "p")
        srv.scheduler.drain_once()
        futs = [srv.submit("ccm", "p", lib=l, target=t, E=3)
                for l, t in PAIRS]
        assert srv.scheduler.drain_once() == len(PAIRS)  # one batch
        got = [f.result(timeout=5) for f in futs]
    # ONE coalesced launch, n−1 launches saved — counter-delta style.
    assert rec.counter_delta("serve_ccm_group_launches") == 1
    assert rec.counter_delta("serve_batches") == 2  # optimal_E + ccm batch
    assert rec.counter_delta("serve_launches_saved") == len(PAIRS) - 1
    assert rec.counter_delta("serve_requests") == len(PAIRS) + 1
    direct = _direct(old)
    for (l, t), rho in zip(PAIRS, got):
        # bit-identical to the direct session call (singleton ccm_batch
        # is the quiesced oracle — batch composition must not matter)...
        np.testing.assert_array_equal(
            np.asarray(rho), direct.ccm_batch([(l, t)], E=3)[0],
            err_msg=f"pair ({l},{t}) not bit-identical to direct call")
        # ...and numerically the classic single-pair engine's answer.
        np.testing.assert_allclose(
            np.asarray(rho), np.asarray(direct.ccm(l, t, E=3)),
            rtol=1e-6, atol=1e-6)


def test_fifo_across_mixed_signatures(panel):
    """A later-arriving compatible request must not leapfrog an earlier
    incompatible one: batches run in head-of-queue arrival order."""
    old = panel[:, :280]
    with telemetry.record() as rec, EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, E_max=4, cache=True)
        srv.submit("ccm", "p", lib=0, target=2, E=3)
        srv.submit("ccm", "p", lib=1, target=3, E=2)   # different E
        srv.submit("simplex", "p", E=3)
        srv.submit("ccm", "p", lib=0, target=4, E=3)   # compatible w/ head
        sizes = []
        while True:
            n = srv.scheduler.drain_once()
            if not n:
                break
            sizes.append(n)
    # E=3 head coalesces with the 4th request; E=2 and simplex stay solo
    # and execute in arrival order between them.
    assert sizes == [2, 1, 1]
    batches = [e for e in rec.spans("serve.batch")]
    assert [b["attrs"]["op"] for b in batches] == ["ccm", "ccm", "simplex"]
    assert [b["attrs"]["size"] for b in batches] == [2, 1, 1]


def test_duplicate_panel_ops_dedup_to_one_execution(panel):
    old = panel[:, :280]
    with telemetry.record() as rec, EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, E_max=4, cache=True)
        futs = [srv.submit("optimal_E", "p") for _ in range(4)]
        assert srv.scheduler.drain_once() == 4
        results = [f.result(timeout=5) for f in futs]
    assert rec.counter_delta("serve_batches") == 1
    assert rec.counter_delta("edm_knn_master_builds") == 1  # ONE compute
    for E_opt, rho in results[1:]:
        np.testing.assert_array_equal(E_opt, results[0][0])
        np.testing.assert_array_equal(rho, results[0][1])


def test_ccm_batch_is_batch_invariant(panel):
    """The serving bit contract: a pair's ρ is independent of which
    other pairs share its launch (singleton == any batch)."""
    sess = _direct(panel[:, :280])
    full = sess.ccm_batch(PAIRS, E=3)
    for j, pair in enumerate(PAIRS):
        np.testing.assert_array_equal(
            sess.ccm_batch([pair], E=3)[0], full[j],
            err_msg=f"pair {pair} depends on batch composition")
    np.testing.assert_array_equal(
        sess.ccm_batch(PAIRS[2:5], E=3), full[2:5])
    # and numerically equivalent to the classic engine
    for j, (l, t) in enumerate(PAIRS):
        np.testing.assert_allclose(full[j], np.asarray(sess.ccm(l, t, E=3)),
                                   rtol=1e-6, atol=1e-6)


# --------------------------------------------------------- append barrier


def test_append_sequences_against_inflight_compatible_batch(panel):
    """Requests queued before/after an append resolve against the
    pre-/post-append library — bit-identical to the quiesced order."""
    old, delta = panel[:, :280], panel[:, 280:]
    with telemetry.record() as rec, EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, E_max=4, cache=True)
        srv.submit("optimal_E", "p")
        srv.scheduler.drain_once()
        pre = [srv.submit("ccm", "p", lib=l, target=t, E=3)
               for l, t in PAIRS[:3]]
        fa = srv.submit("append", "p", delta=delta)
        post = [srv.submit("ccm", "p", lib=l, target=t, E=3)
                for l, t in PAIRS[:3]]
        sizes = []
        while True:
            n = srv.scheduler.drain_once()
            if not n:
                break
            sizes.append(n)
        # pre-batch coalesced, append solo (barrier), post-batch coalesced
        assert sizes == [3, 1, 3]
        assert fa.result(timeout=5)["L"] == panel.shape[1]
        assert rec.counter_delta("serve_appends") == 1
        assert rec.counter_delta("edm_knn_master_appends") == 1  # no rebuild
        d_old = _direct(old)
        d_new = _direct(panel)
        for (l, t), f in zip(PAIRS[:3], pre):
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=5)),
                d_old.ccm_batch([(l, t)], E=3)[0],
                err_msg=f"pre-append pair ({l},{t})")
        for (l, t), f in zip(PAIRS[:3], post):
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=5)),
                d_new.ccm_batch([(l, t)], E=3)[0],
                err_msg=f"post-append pair ({l},{t})")


def test_append_rejects_nan_delta_and_names_series(panel):
    old, delta = panel[:, :280], panel[:, 280:].copy()
    delta[2, 1] = np.nan
    with EDMServer(autostart=False) as srv:
        srv.register_panel("p", old, names=[f"s{i}" for i in range(6)],
                           E_max=4)
        fut = srv.submit("append", "p", delta=delta)
        srv.scheduler.drain_once()
        with pytest.raises(ValueError, match="series s2"):
            fut.result(timeout=5)
        # server state untouched: panel length unchanged, next op fine
        assert srv.registry.get("p").sess.data.L == 280


# --------------------------------------------------------- threaded mode


def test_concurrent_clients_threaded_worker(panel):
    old = panel[:, :280]
    direct = _direct(old)
    want = {(l, t): direct.ccm_batch([(l, t)], E=3)[0] for l, t in PAIRS}
    with EDMServer() as srv:  # live worker thread
        srv.register_panel("p", old, E_max=4, cache=True)
        srv.call("optimal_E", "p")
        results: dict = {}
        errs: list = []

        def client(pair):
            try:
                results[pair] = np.asarray(
                    srv.call("ccm", "p", lib=pair[0], target=pair[1], E=3))
            except Exception as exc:  # noqa: BLE001
                errs.append(exc)

        threads = [threading.Thread(target=client, args=(p,))
                   for p in PAIRS * 3]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errs
    for pair, rho in results.items():
        np.testing.assert_array_equal(rho, want[pair],
                                      err_msg=f"pair {pair}")


def test_append_during_inflight_traffic_is_linearized(panel):
    """Concurrent clients + one append tick: every answer matches the
    pre- or post-append direct value, and anything submitted after the
    append resolves matches post-append exactly."""
    old, delta = panel[:, :280], panel[:, 280:]
    d_old = _direct(old)
    d_new = _direct(panel)
    pre = {p: d_old.ccm_batch([p], E=3)[0] for p in PAIRS}
    post = {p: d_new.ccm_batch([p], E=3)[0] for p in PAIRS}
    with EDMServer() as srv:
        srv.register_panel("p", old, E_max=4, cache=True)
        srv.call("optimal_E", "p")
        answers: list = []
        errs: list = []

        def client(pair):
            try:
                answers.append(
                    (pair, np.asarray(srv.call("ccm", "p", lib=pair[0],
                                               target=pair[1], E=3))))
            except Exception as exc:  # noqa: BLE001
                errs.append(exc)

        threads = [threading.Thread(target=client, args=(p,))
                   for p in PAIRS * 2]
        for t in threads[:6]:
            t.start()
        fa = srv.submit("append", "p", delta=delta)
        for t in threads[6:]:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errs and fa.result(timeout=60)["L"] == panel.shape[1]
        for pair, rho in answers:
            assert (np.array_equal(rho, pre[pair])
                    or np.array_equal(rho, post[pair])), \
                f"pair {pair}: answer matches neither library version"
        # quiesced: everything from here on is post-append, exactly
        for pair in PAIRS:
            np.testing.assert_array_equal(
                np.asarray(srv.call("ccm", "p", lib=pair[0],
                                    target=pair[1], E=3)), post[pair])


# ---------------------------------------------------------------- errors


def test_unknown_panel_and_op_rejected(panel):
    with EDMServer(autostart=False) as srv:
        with pytest.raises(KeyError, match="ghost"):
            srv.submit("ccm", "ghost", lib=0, target=1)
        srv.register_panel("p", panel[:, :280])
        with pytest.raises(ValueError, match="unknown op"):
            srv.submit("smap_all_the_things", "p")
        with pytest.raises(ValueError, match="already registered"):
            srv.register_panel("p", panel[:, :280])


# ------------------------------------------------------------------ HTTP


def test_http_front_end_roundtrip(panel):
    old, delta = panel[:, :280], panel[:, 280:]
    with EDMServer() as srv:
        httpd = serve_http(srv)
        port = httpd.server_address[1]

        def post(path, body, code=200):
            req = urllib.request.Request(
                f"http://127.0.0.1:{port}{path}",
                json.dumps(body).encode(),
                {"Content-Type": "application/json"})
            try:
                with urllib.request.urlopen(req, timeout=120) as r:
                    assert r.status == code
                    return json.loads(r.read())
            except urllib.error.HTTPError as e:
                assert e.code == code
                return json.loads(e.read())

        info = post("/v1/register",
                    {"panel": "p", "data": old.tolist(), "E_max": 4})
        assert info["result"]["L"] == 280
        rho = post("/v1/ccm",
                   {"panel": "p", "lib": 0, "target": 2, "E": 3})["result"]
        direct = _direct(old)
        assert rho == pytest.approx(float(direct.ccm(0, 2, E=3)))
        grown = post("/v1/append",
                     {"panel": "p", "delta": delta.tolist()})["result"]
        assert grown["L"] == panel.shape[1] and grown["version"] == 1
        assert post("/v1/ccm", {"panel": "ghost", "lib": 0, "target": 1},
                    code=400)["error"]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=30) as r:
            prom = r.read().decode()
        assert "serve_requests" in prom and "serve_queue_depth" in prom
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/panels", timeout=30) as r:
            panels = json.loads(r.read())["panels"]
        assert panels[0]["name"] == "p" and panels[0]["version"] == 1
        httpd.shutdown()


def test_http_malformed_bodies_all_get_400(panel):
    """Every malformed-body shape gets a named 400, never a 500."""
    with EDMServer(autostart=False) as srv:
        srv.register_panel("p", panel[:, :280], E_max=4, cache=True)
        httpd = serve_http(srv)
        port = httpd.server_address[1]

        def post_raw(path, payload):
            req = urllib.request.Request(
                f"http://127.0.0.1:{port}{path}", payload,
                {"Content-Type": "application/json"})
            try:
                with urllib.request.urlopen(req, timeout=30) as r:
                    return r.status, json.loads(r.read())
            except urllib.error.HTTPError as e:
                return e.code, json.loads(e.read())

        def post(path, body):
            return post_raw(path, json.dumps(body).encode())

        cases = [
            (post("/v1/ccm", {"lib": 0, "target": 1}), "missing 'panel'"),
            (post("/v1/register", {"panel": "q"}), "missing 'data'"),
            (post("/v1/append", {"panel": "p"}), "missing 'delta'"),
            (post("/v1/unsubscribe", {}), "missing 'id'"),
            (post("/v1/ccm", [1, 2, 3]), "JSON object"),
        ]
        for (code, body), needle in cases:
            assert code == 400, f"expected 400 for {needle!r}, got {code}"
            assert needle in body["error"]
        # undecodable JSON is a 400 too (ValueError path), not a 500
        code, body = post_raw("/v1/ccm", b"{not json")
        assert code == 400 and body["error"]
        # and op-level validation errors surface as 400 with the message
        code, body = post("/v1/ccm", {"panel": "ghost", "lib": 0,
                                      "target": 1})
        assert code == 400 and "ghost" in body["error"]
        httpd.shutdown()


def test_subscription_poll_survives_spurious_wakeup(panel):
    """A notify_all with no tick queued must NOT end the long-poll
    early: poll re-waits on the remaining deadline (regression for the
    spurious-wakeup early return)."""
    import time as _time

    from repro.serving import Subscription
    sub = Subscription("s-spur", "p", [(0, 1)], {3: [0]})

    def spurious():
        for _ in range(3):
            _time.sleep(0.05)
            with sub._cv:
                sub._cv.notify_all()     # deliberate: no tick, no close

    t = threading.Thread(target=spurious)
    t0 = _time.monotonic()
    t.start()
    got = sub.poll(timeout=0.5)
    elapsed = _time.monotonic() - t0
    t.join()
    assert got == []                     # nothing was ever queued
    assert elapsed >= 0.45, \
        f"poll returned after {elapsed:.3f}s — spurious wakeup ended it"
    # ...while a REAL tick still ends the wait early
    def push_soon():
        _time.sleep(0.05)
        sub.push(1, 300, np.zeros(1, np.float32))

    t = threading.Thread(target=push_soon)
    t0 = _time.monotonic()
    t.start()
    got = sub.poll(timeout=5.0)
    elapsed = _time.monotonic() - t0
    t.join()
    assert len(got) == 1 and elapsed < 4.0
    # close() also ends the wait promptly with []
    def close_soon():
        _time.sleep(0.05)
        sub.close()

    t = threading.Thread(target=close_soon)
    t.start()
    assert sub.poll(timeout=5.0) == []
    t.join()


def test_http_client_disconnect_is_counted_not_crashed(panel):
    """A client that RSTs mid-long-poll is counted; the server keeps
    answering on other connections."""
    import socket
    import time as _time
    with telemetry.record() as rec, EDMServer() as srv:
        srv.register_panel("p", panel[:, :280], E_max=4, cache=True)
        sub = srv.subscribe("p", [(0, 2)], E=3)
        srv.subscription(sub["id"]).poll(timeout=5)   # eat baseline tick
        httpd = serve_http(srv)
        port = httpd.server_address[1]
        s = socket.create_connection(("127.0.0.1", port), timeout=10)
        s.sendall((f"GET /v1/subscriptions/{sub['id']}?timeout=1 "
                   f"HTTP/1.1\r\nHost: x\r\n\r\n").encode())
        _time.sleep(0.5)   # let the handler read the request + block
        # RST the connection while the handler is still in poll()
        s.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                     __import__("struct").pack("ii", 1, 0))
        s.close()
        deadline = _time.monotonic() + 10
        while rec.counter_delta("serve_client_disconnects") < 1:
            assert _time.monotonic() < deadline, \
                "disconnect never counted"
            _time.sleep(0.05)
        # the server still serves post-disconnect
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/panels", timeout=30) as r:
            assert json.loads(r.read())["panels"][0]["name"] == "p"
        httpd.shutdown()


def _post_expect(port, path, body, timeout=60):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}", json.dumps(body).encode(),
        {"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, dict(r.headers), json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), json.loads(e.read())


def test_http_429_overloaded_with_retry_after(panel):
    with EDMServer(autostart=False, max_queue_depth=1) as srv:
        srv.register_panel("p", panel[:, :280], E_max=4, cache=True)
        fill = srv.submit("ccm", "p", lib=0, target=2, E=3)
        httpd = serve_http(srv)
        port = httpd.server_address[1]
        code, headers, body = _post_expect(
            port, "/v1/ccm", {"panel": "p", "lib": 1, "target": 3,
                              "E": 3})
        assert code == 429
        assert int(headers["Retry-After"]) >= 1
        assert body["retry_after_s"] > 0
        assert "max_queue_depth" in body["error"]
        while srv.scheduler.drain_once():
            pass
        fill.result(timeout=5)
        # capacity is back: go live and the same request succeeds
        srv.scheduler.start()
        code, _, body = _post_expect(
            port, "/v1/ccm", {"panel": "p", "lib": 1, "target": 3,
                              "E": 3})
        assert code == 200
        httpd.shutdown()


def test_http_504_deadline_and_503_wedged_and_draining(panel):
    # 504: a live server claims the request after its 0-second deadline
    with telemetry.record() as rec, EDMServer() as srv:
        srv.register_panel("p", panel[:, :280], E_max=4, cache=True)
        httpd = serve_http(srv)
        port = httpd.server_address[1]
        code, _, body = _post_expect(
            port, "/v1/ccm", {"panel": "p", "lib": 0, "target": 2,
                              "E": 3, "deadline_s": 0.0})
        assert code == 504 and "deadline" in body["error"]
        httpd.shutdown()
    assert rec.counter_delta("serve_deadline_exceeded") == 1

    # 503: nothing drains an autostart=False server — the HTTP thread's
    # bounded wait fires instead of wedging the connection forever
    with telemetry.record() as rec, EDMServer(autostart=False) as srv:
        srv.register_panel("p", panel[:, :280], E_max=4, cache=True)
        httpd = serve_http(srv, request_timeout_s=0.3)
        port = httpd.server_address[1]
        code, _, body = _post_expect(
            port, "/v1/ccm", {"panel": "p", "lib": 0, "target": 2,
                              "E": 3})
        assert code == 503 and "timed out" in body["error"]

        # 503 while draining: admission is closed, healthz degrades
        while srv.scheduler.drain_once():   # retire the wedged request
            pass
        assert srv.drain(timeout=10) is True
        code, _, body = _post_expect(
            port, "/v1/ccm", {"panel": "p", "lib": 1, "target": 3,
                              "E": 3})
        assert code == 503 and "draining" in body["error"]
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/healthz", timeout=30) as r:
                code = r.status
        except urllib.error.HTTPError as e:
            code = e.code
        assert code == 503
        httpd.shutdown()
    assert rec.counter_delta("serve_request_timeouts") == 1
