"""EDM session facade: parity with the legacy free functions, cached-kNN
reuse (kernel-invocation counting), plan introspection, sharded routing,
and the batched submit_panel entry point."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core, telemetry
from repro.data import timeseries as ts
from repro.edm import EDM, EDMConfig
from repro.kernels import ops


def _panel(n=6, steps=240, seed=5):
    panel, _ = ts.forced_network_panel(n, steps, seed=seed)
    return jnp.asarray(panel)


# ------------------------------------------------------ facade parity


def test_optimal_e_bit_identical_to_legacy():
    X = _panel()
    sess = EDM(X, EDMConfig(E_max=5))
    E_opt, rho = sess.optimal_E()
    E_l, rho_l = core.optimal_E_batch(X, E_max=5)
    np.testing.assert_array_equal(E_opt, np.asarray(E_l))
    np.testing.assert_array_equal(rho, np.asarray(rho_l))


def test_xmap_bit_identical_to_legacy_group_composition():
    X = _panel()
    sess = EDM(X, EDMConfig(E_max=5))
    E_opt, _ = sess.optimal_E()
    got = sess.xmap()
    want = np.zeros((X.shape[0],) * 2, np.float32)
    for E in sorted(set(E_opt.tolist())):
        m = np.nonzero(E_opt == E)[0]
        want[:, m] = np.asarray(
            core.ccm_group(X, X[m], E=int(E), tau=1, Tp=0))
    np.testing.assert_array_equal(got, want)


def test_xmap_smap_bit_identical_to_legacy():
    X = _panel(4, 220)
    sess = EDM(X, EDMConfig(E=2, theta=1.5))
    got = sess.xmap(method="smap")
    want = np.zeros((4, 4), np.float32)
    members = np.arange(4)
    want[:, members] = np.asarray(
        core.smap_group(X, X, E=2, tau=1, Tp=0, theta=1.5, impl="ref"))
    np.testing.assert_array_equal(got, want)


def test_simplex_fixed_e_bit_identical():
    X = _panel(4)
    sess = EDM(X, EDMConfig(E_max=5))
    got = sess.simplex(E=3)
    want = np.asarray([core.simplex_skill(x, E=3) for x in X])
    np.testing.assert_array_equal(got, want)


def test_simplex_per_series_reads_cached_sweep():
    X = _panel(4)
    sess = EDM(X, EDMConfig(E_max=5))
    E_opt, rho = sess.optimal_E()
    skill = sess.simplex()
    np.testing.assert_array_equal(
        skill, rho[np.arange(4), E_opt - 1])


def test_smap_sweep_bit_identical_and_grouped():
    X = _panel(5)
    thetas = (0.0, 0.5, 2.0)
    sess = EDM(X, EDMConfig(E_max=4, thetas=thetas))
    # fixed E: one engine launch
    np.testing.assert_array_equal(
        sess.smap(E=2),
        np.asarray(core.smap_theta_sweep(X, E=2, thetas=thetas, impl="ref")))
    # per-series E: grouped by the cached optimal E
    E_opt, _ = sess.optimal_E()
    got = sess.smap()
    want = np.zeros((5, len(thetas)), np.float32)
    for E in sorted(set(E_opt.tolist())):
        m = np.nonzero(E_opt == E)[0]
        want[m] = np.asarray(core.smap_theta_sweep(
            X[m], E=int(E), thetas=thetas, impl="ref"))
    np.testing.assert_array_equal(got, want)


def test_ccm_convergence_matches_cross_map():
    X = _panel(3)
    sess = EDM(X, EDMConfig(E=2))
    sizes = (60, 120, 230)
    got = sess.ccm(0, 1, lib_sizes=sizes)
    want = np.asarray(core.cross_map(X[0], X[1], E=2, Tp=0,
                                     lib_sizes=sizes))
    np.testing.assert_array_equal(got, want)
    # E defaults to the *target's* optimal E when not fixed
    sess2 = EDM(X, EDMConfig(E_max=4))
    E_opt, _ = sess2.optimal_E()
    np.testing.assert_array_equal(
        sess2.ccm(0, 2),
        np.asarray(core.cross_map(X[0], X[2], E=int(E_opt[2]), Tp=0)))


def test_facade_parity_on_random_panels():
    """Property-style: facade == legacy bit-for-bit on random panels."""
    rng = np.random.default_rng(42)
    for trial in range(4):
        n = int(rng.integers(3, 7))
        L = int(rng.integers(150, 300))
        tau = int(rng.integers(1, 3))
        E_max = int(rng.integers(3, 7))
        X = jnp.asarray(rng.standard_normal((n, L)).astype(np.float32))
        sess = EDM(X, EDMConfig(E_max=E_max, tau=tau))
        E_opt, rho = sess.optimal_E()
        E_l, rho_l = core.optimal_E_batch(X, E_max=E_max, tau=tau)
        np.testing.assert_array_equal(E_opt, np.asarray(E_l))
        np.testing.assert_array_equal(rho, np.asarray(rho_l))
        got = sess.xmap()
        want = np.zeros((n, n), np.float32)
        for E in sorted(set(E_opt.tolist())):
            m = np.nonzero(E_opt == E)[0]
            want[:, m] = np.asarray(
                core.ccm_group(X, X[m], E=int(E), tau=tau, Tp=0))
        np.testing.assert_array_equal(got, want)


def test_legacy_matrix_wrappers_delegate():
    X = _panel(5)
    E_opt, _ = core.optimal_E_batch(X, E_max=4)
    E_opt = np.asarray(E_opt)
    sess = EDM(X, EDMConfig(E_max=4))
    np.testing.assert_array_equal(core.ccm_matrix(X, E_opt),
                                  sess.xmap(E_opt=E_opt))
    # E_opt=None now auto-computes through the session cache
    auto = core.ccm_matrix(X)
    want = EDM(X, EDMConfig()).xmap()
    np.testing.assert_array_equal(auto, want)
    np.testing.assert_array_equal(
        core.smap_matrix(X, 2, theta=1.0, impl="ref"),
        EDM(X, EDMConfig(E=2, theta=1.0, impl="ref")).xmap(method="smap"))


# ------------------------------------------------- cached-kNN reuse


def test_knn_engine_runs_exactly_once_per_panel():
    """Regression for the facade's core promise: optimal_E → simplex →
    xmap on one panel trace the multi-E kNN engine exactly once, and the
    per-E pairwise pipeline never runs at all. Counted via the telemetry
    dispatch counters (trace-time increments, hence the cache clear);
    test_ops_counter_matches_monkeypatch_shim guards that these counters
    track real dispatches."""
    X = _panel()
    jax.clear_caches()  # ops counters count trace-time dispatches
    with telemetry.record() as rec:
        sess = EDM(X, EDMConfig(E_max=5))
        sess.optimal_E()
        sess.simplex(E=2)
        sess.simplex()
        sess.xmap()
        sess.optimal_E()
    assert rec.counter_delta("edm_ops_all_knn_multi_e_calls") == 1
    assert rec.counter_delta("edm_ops_pairwise_distances_calls") == 0
    assert rec.counter_delta("edm_knn_master_builds") == 1
    assert rec.counter_delta("edm_knn_master_hits") >= 2
    assert sess.stats["knn_master_builds"] == 1
    assert sess.stats["knn_master_hits"] >= 2
    assert sess.stats["rho_hits"] >= 2


def test_cache_disabled_falls_back_to_legacy_paths():
    """cache=False must recompute neighbors (direct batched engine), not
    read a master — and still agree with the cached session."""
    X = _panel(4)
    jax.clear_caches()
    with telemetry.record() as rec:
        sess = EDM(X, EDMConfig(E_max=4, cache=False))
        E_opt, rho = sess.optimal_E()
        got = sess.xmap()
    # direct engine recomputes distances, never builds a master
    assert rec.counter_delta("edm_ops_all_knn_batch_calls") >= 1
    assert rec.counter_delta("edm_knn_master_builds") == 0
    E_l, rho_l = core.optimal_E_batch(X, E_max=4)
    np.testing.assert_array_equal(E_opt, np.asarray(E_l))
    np.testing.assert_array_equal(got, EDM(X, EDMConfig(E_max=4)).xmap())


def test_requests_above_e_max_rebuild_master_not_clamp():
    """Regression: jnp gathers clamp out-of-range indices, so reading a
    level-7 table from a level-4 master would silently return level-4
    results. The session must rebuild the master at the deeper level."""
    X = _panel(3)
    sess = EDM(X, EDMConfig(E_max=4))
    sess.optimal_E()
    got = sess.simplex(E=7)
    want = np.asarray([core.simplex_skill(x, E=7) for x in X])
    np.testing.assert_array_equal(got, want)
    assert sess.stats["knn_master_builds"] == 2  # level 4, then level 7
    E_hi = np.array([6, 2, 6], np.int32)
    got_m = sess.xmap(E_opt=E_hi)
    want_m = np.zeros((3, 3), np.float32)
    for E in (2, 6):
        m = np.nonzero(E_hi == E)[0]
        want_m[:, m] = np.asarray(core.ccm_group(X, X[m], E=E, Tp=0))
    np.testing.assert_array_equal(got_m, want_m)


def test_fixed_e_session_on_short_panel():
    """Regression: a fixed-E session must size its kNN master to the E it
    uses, not the default E_max=20 sweep (which would crash on panels
    this short and waste ~E_max/E work on longer ones)."""
    rng = np.random.default_rng(8)
    X = jnp.asarray(rng.standard_normal((2, 21)).astype(np.float32))
    sess = EDM(X, EDMConfig(E=2))
    got = sess.simplex()
    want = np.asarray([core.simplex_skill(x, E=2) for x in X])
    np.testing.assert_array_equal(got, want)
    assert sess._cache["master"][3] == 2  # built at level E, not E_max


def test_flush_xmap_reuses_batch_session_state():
    """Regression: flush()'s xmap branch slices the batch session's
    E_opt and kNN master into the per-panel sessions instead of
    re-running the multi-E engine per queued panel."""
    X = _panel(6)
    jax.clear_caches()
    with telemetry.record() as rec:
        sess = EDM(X, EDMConfig(E_max=4))
        t1 = sess.submit_panel(X[:3], tasks=("optimal_E", "xmap"))
        t2 = sess.submit_panel(X[3:], tasks=("optimal_E", "xmap"))
        res = sess.flush()
    # one batch master, panels get slices
    assert rec.counter_delta("edm_ops_all_knn_multi_e_calls") == 1
    assert rec.counter_delta("edm_panels_flushed") == 2
    assert [s["name"] for s in rec.spans("session.flush")] \
        == ["session.flush"]
    for ticket, sl in ((t1, slice(0, 3)), (t2, slice(3, 6))):
        np.testing.assert_array_equal(
            res[ticket].xmap, EDM(X[sl], EDMConfig(E_max=4)).xmap())


# ----------------------------------------- ccm convergence + surrogates


def test_ccm_lib_sizes_runs_knn_engine_once_per_panel():
    """Acceptance regression for ISSUE 4: a convergence sweep never
    re-runs kNN per size. With the master's slack covering every cap the
    sweep derives tables from the ONE master pass (no pairwise, no
    top-k at all); smaller caps fall back to exactly one pairwise +
    one multi-cap streaming top-k, regardless of |sizes|. Staged deltas
    read the ops dispatch counters directly."""
    X = _panel()
    names = {"multi_e": "edm_ops_all_knn_multi_e_calls",
             "pairwise": "edm_ops_pairwise_distances_calls",
             "topk": "edm_ops_topk_select_calls",
             "topk_sizes": "edm_ops_topk_select_sizes_calls"}

    def snap():
        return {k: telemetry.counter(n).value for k, n in names.items()}

    def delta(base):
        now = snap()
        return {k: now[k] - base[k] for k in names}

    jax.clear_caches()
    base = snap()
    sess = EDM(X, EDMConfig(E_max=4, extra_slack=60))
    sess.optimal_E()
    assert delta(base)["multi_e"] == 1
    # slack covers caps down to Lp-1-60: master-derived, zero kNN work
    sess.ccm(0, 1, lib_sizes=(190, 210, 239))
    assert delta(base) == {"multi_e": 1, "pairwise": 0, "topk": 0,
                           "topk_sizes": 0}
    # deep caps: ONE engine pass for all 8 sizes, never per-size
    sess.ccm(0, 1, lib_sizes=(20, 40, 60, 80, 100, 140, 180, 200))
    assert delta(base) == {"multi_e": 1, "pairwise": 1, "topk": 0,
                           "topk_sizes": 1}
    assert sess.stats["knn_master_builds"] == 1


def test_ops_counter_matches_monkeypatch_shim(monkeypatch):
    """The one shim test kept on purpose: the telemetry dispatch counter
    and a counting monkeypatch shim must see the SAME calls. If a kernel
    path ever stops routing through ``ops`` (so the counter undercounts)
    or the counter double-fires, this trips before the counter-based
    regressions above start lying."""
    X = _panel(4)
    calls = {"n": 0}
    real = ops.all_knn_multi_e

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(ops, "all_knn_multi_e", counting)
    jax.clear_caches()
    with telemetry.record() as rec:
        EDM(X, EDMConfig(E_max=4)).optimal_E()
    assert calls["n"] >= 1
    assert rec.counter_delta("edm_ops_all_knn_multi_e_calls") == calls["n"]


def test_ccm_lib_sizes_bit_identical_to_legacy_loop():
    X = _panel()
    sizes = (30, 100, 180, 235)
    for cfg in (EDMConfig(E=3), EDMConfig(E=3, extra_slack=220)):
        sess = EDM(X, cfg)
        if cfg.extra_slack:
            sess.simplex()  # builds the master the sweep derives from
        got = sess.ccm(0, 1, lib_sizes=sizes)
        want = np.asarray(core.cross_map_sizes_seed(
            X[0], X[1][None, :], E=3, Tp=0, lib_sizes=sizes))[:, 0]
        np.testing.assert_array_equal(got, want)


def test_surrogate_test_detects_causality_and_null():
    from repro.data import timeseries as ts2
    x, y = ts2.coupled_logistic(500, b_xy=0.0, b_yx=0.32, seed=3)
    rng = np.random.default_rng(0)
    noise = rng.standard_normal(500).astype(np.float32)
    sess = EDM(np.stack([x, y, noise]), EDMConfig(E=2))
    # X forces Y: cross-map X from Y's manifold → significant
    r = sess.surrogate_test(1, 0, num_surrogates=60, seed=1)
    assert r.pvalue < 0.05 and bool(r.significant)
    assert r.surrogate_rho.shape == (60,)
    assert r.rho > 0.8 and float(np.max(r.surrogate_rho)) < r.rho
    # independent noise → insignificant
    r2 = sess.surrogate_test(0, 2, num_surrogates=60, seed=1)
    assert r2.pvalue > 0.05
    # the actual score is exactly the plain ccm() skill
    np.testing.assert_array_equal(np.float32(r.rho), sess.ccm(1, 0))
    # deterministic under a fixed seed
    r3 = sess.surrogate_test(1, 0, num_surrogates=60, seed=1)
    np.testing.assert_array_equal(r.surrogate_rho, r3.surrogate_rho)


def test_surrogate_test_convergence_and_seasonal():
    from repro.data import timeseries as ts2
    x, y = ts2.coupled_logistic(400, b_xy=0.0, b_yx=0.32, seed=3)
    sess = EDM(np.stack([x, y]), EDMConfig(E=2))
    r = sess.surrogate_test(1, 0, num_surrogates=19,
                            lib_sizes=(50, 150, 380), seed=2)
    assert r.rho.shape == (3,) and r.surrogate_rho.shape == (3, 19)
    assert r.pvalue.shape == (3,)
    assert (np.diff(r.rho) > -0.05).all()  # convergence of the real curve
    rs = sess.surrogate_test(1, 0, num_surrogates=10, method="seasonal",
                             period=12, seed=2)
    assert 0.0 < rs.pvalue <= 1.0
    with pytest.raises(ValueError, match="period"):
        sess.surrogate_test(1, 0, num_surrogates=5, method="seasonal")
    with pytest.raises(ValueError, match="unknown method"):
        sess.surrogate_test(1, 0, num_surrogates=5, method="bootstrap")


def test_seasonal_surrogates_preserve_phase_profile():
    from repro.edm import make_surrogates
    L, period = 120, 12
    y = np.sin(2 * np.pi * np.arange(L) / period).astype(np.float32)
    y += 0.01 * np.arange(L, dtype=np.float32)  # distinct values per slot
    surr = make_surrogates(y, 8, method="seasonal", period=period, seed=0)
    for m in range(8):
        assert not np.array_equal(surr[m], y)
        for p in range(period):
            np.testing.assert_array_equal(
                np.sort(surr[m, p::period]), np.sort(y[p::period]))
    shuf = make_surrogates(y, 4, method="shuffle", seed=0)
    np.testing.assert_array_equal(np.sort(shuf, axis=1),
                                  np.sort(np.tile(y, (4, 1)), axis=1))


# ------------------------------------------------------------- plans


def test_plan_introspection():
    X = _panel(4)
    sess = EDM(X, EDMConfig(E_max=4))
    p = sess.plan("optimal_E")
    assert (p.placement, p.impl) == ("local", ops.resolve_impl("auto"))
    assert "master" in p.builds and "rho" in p.builds
    sess.optimal_E()
    p2 = sess.plan("xmap")
    assert p2.reuse == ("master", "rho") and p2.builds == ()
    assert "cached" in p2.detail
    with pytest.raises(ValueError, match="unknown task"):
        sess.plan("teleport")
    with pytest.raises(ValueError, match="unknown xmap method"):
        sess.xmap(method="granger")


def test_plan_sharded_placement():
    import types
    mesh = types.SimpleNamespace(shape={"data": 2, "model": 2},
                                 axis_names=("data", "model"))
    sess = EDM(_panel(4), EDMConfig(E_max=4, mesh=mesh))
    assert sess.plan("optimal_E").placement == "sharded"
    assert sess.plan("xmap").placement == "sharded"
    assert "zero collectives" in sess.plan("xmap").detail


# ------------------------------------------------------ submit_panel


def test_submit_panel_batches_and_matches_per_panel():
    X = _panel(6)
    sess = EDM(X, EDMConfig(E_max=4))
    t1 = sess.submit_panel(X[:2], tasks=("optimal_E", "smap"))
    t2 = sess.submit_panel(X[2:], tasks=("optimal_E", "smap"))
    t3 = sess.submit_panel(X[0], tasks=("optimal_E",))  # 1-D promoted
    res = sess.flush()
    assert sess.stats["panels_flushed"] == 3 and sess._queue == []
    for ticket, sl in ((t1, slice(0, 2)), (t2, slice(2, 6))):
        E_l, rho_l = core.optimal_E_batch(X[sl], E_max=4)
        np.testing.assert_array_equal(res[ticket].E_opt, np.asarray(E_l))
        np.testing.assert_array_equal(res[ticket].rho, np.asarray(rho_l))
        assert res[ticket].smap.shape == (sl.stop - sl.start,
                                          len(sess.config.thetas))
    assert res[t3].E_opt.shape == (1,)
    with pytest.raises(ValueError, match="unknown task"):
        sess.submit_panel(X, tasks=("fly",))
    assert sess.flush() == {}  # queue drained


# ---------------------------------------------------- sharded routing


def test_sharded_session_multidevice_subprocess():
    """mesh= config routes optimal_E/xmap/smap through the zero-collective
    sharded engines on 8 emulated devices; results match local sessions
    (per-shard pairwise route vs cached-master route → allclose)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax.numpy as jnp
        from repro.data import timeseries as ts
        from repro.edm import EDM, EDMConfig
        from repro.distributed import (
            make_ccm_mesh, sharded_ccm_matrix, sharded_smap_matrix)
        panel, _ = ts.forced_network_panel(7, 240, seed=11)  # 7: needs pad
        X = jnp.asarray(panel)
        mesh = make_ccm_mesh((4, 2), ("data", "model"))
        local = EDM(X, EDMConfig(E_max=4))
        E_opt, rho = local.optimal_E()
        sess = EDM(X, EDMConfig(E_max=4, mesh=mesh))
        E_s, rho_s = sess.optimal_E()
        np.testing.assert_array_equal(E_s, E_opt)
        np.testing.assert_allclose(rho_s, rho, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(sess.xmap(), local.xmap(),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(sess.xmap(method="smap"),
                                   local.xmap(method="smap"),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(sess.smap(), local.smap(),
                                   rtol=1e-3, atol=1e-3)
        # direct E_opt-mode engines agree with the session routing
        np.testing.assert_allclose(
            sharded_ccm_matrix(X, X, E_opt=E_opt, mesh=mesh, impl="ref"),
            sess.xmap(), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            sharded_smap_matrix(X, X, E_opt=E_opt, mesh=mesh, impl="ref"),
            sess.xmap(method="smap"), rtol=1e-5, atol=1e-5)
        print("EDM_SHARDED_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "EDM_SHARDED_OK" in out.stdout


def test_sharded_egroup_matrix_single_device():
    """E_opt-mode sharded engines on a 1×1 mesh equal the local matrices
    (covers the E-group layout/permutation round trip in-process)."""
    from repro.distributed import (
        make_ccm_mesh, sharded_ccm_matrix, sharded_smap_matrix)
    X = _panel(5, 220)
    E_opt = np.array([2, 3, 2, 4, 3], np.int32)
    mesh = make_ccm_mesh((1, 1), ("data", "model"))
    got = sharded_ccm_matrix(X, X, E_opt=E_opt, mesh=mesh, impl="ref")
    want = core.ccm_matrix(X, E_opt)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    got_s = sharded_smap_matrix(X, X, E_opt=E_opt, mesh=mesh, impl="ref")
    want_s = core.smap_matrix(X, E_opt, impl="ref")
    np.testing.assert_allclose(got_s, want_s, rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="exactly one"):
        sharded_ccm_matrix(X, X, E=2, E_opt=E_opt, mesh=mesh)
    with pytest.raises(ValueError, match="exactly one"):
        sharded_smap_matrix(X, X, mesh=mesh)
