"""Pallas TPU kernel: FUSED all-kNN — pairwise distances + top-k in one.

Beyond-paper optimization (EXPERIMENTS.md §Perf, EDM cell). kEDM follows
the exhaustive two-kernel design: materialize the (Lp, Lp) distance
matrix in global memory (Alg. 1), then partially sort each row (Alg. 2).
Its own roofline analysis (paper Figs. 6–7) shows exactly that matrix
write+read is the dominant memory term.

On TPU the two phases fuse: each grid cell computes a (br, Lp) row-block
of distances directly into VMEM — embedding fused as in pairwise_dist.py
— and immediately runs the k-pass argmin-extract on it. The distance
matrix never touches HBM: traffic drops from 2·4·Lp² bytes (write+read)
to 8·Lp·k bytes of results plus the series reads — ~470× less at the
paper's L=10⁴, k=21 scale, removing the dominant roofline term of both
kEDM kernels at once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import strict_sq


def _kernel(mx_ref, xc_ref, xr_ref, dk_ref, ik_ref, *, E, tau, k, br, Lp,
            exclude_self):
    i0 = pl.program_id(0) * br
    # ---- Alg. 1 (fused embedding) on a (br, Lp) row block, in VMEM
    acc = jnp.zeros((br, Lp), jnp.float32)
    for kk in range(E):  # E ≤ 20: unrolled
        xi = xc_ref[pl.dslice(i0 + kk * tau, br), :]  # (br, 1)
        xj = xr_ref[:, pl.dslice(kk * tau, Lp)]  # (1, Lp)
        d = xi - xj
        acc = acc + strict_sq(d)
    # ---- Alg. 2 masking + k-pass extraction, still in VMEM
    cols = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
    max_idx = mx_ref[0, 0]
    invalid = cols > max_idx
    if exclude_self:
        rows = i0 + jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
        invalid = invalid | (cols == rows)
    acc = jnp.where(invalid, jnp.inf, acc)
    dists, idxs = [], []
    for _ in range(k):
        m = jnp.min(acc, axis=1, keepdims=True)
        cand = jnp.where(acc == m, cols, 2**30)
        idx = jnp.min(cand, axis=1, keepdims=True)
        dists.append(m)
        idxs.append(idx)
        acc = jnp.where(cols == idx, jnp.inf, acc)
    dk_ref[...] = jnp.sqrt(jnp.maximum(jnp.concatenate(dists, axis=1), 0.0))
    ik_ref[...] = jnp.concatenate(idxs, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("E", "tau", "k", "exclude_self", "block_rows",
                     "interpret"))
def all_knn_fused(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused all-kNN over one series → (dists (Lp,k), idx (Lp,k))."""
    k = E + 1 if k is None else k
    L = x.shape[-1]
    Lp = L - (E - 1) * tau
    if Lp <= 0:
        raise ValueError(f"series too short: L={L}, E={E}, tau={tau}")
    br = max(8, min(block_rows, Lp))
    gi = pl.cdiv(Lp, br)
    need = gi * br + (E - 1) * tau  # no dynamic-slice clamping (row axis)
    x32 = x.astype(jnp.float32)
    x32 = x32 - jnp.mean(x32)
    xpad = jnp.pad(x32, (0, max(need, L) - L))
    mx = jnp.full((1, 1), Lp - 1 if max_idx is None else max_idx, jnp.int32)
    dk, ik = pl.pallas_call(
        functools.partial(_kernel, E=E, tau=tau, k=k, br=br, Lp=Lp,
                          exclude_self=exclude_self),
        grid=(gi,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((xpad.shape[0], 1), lambda i: (0, 0)),  # column
            pl.BlockSpec((1, xpad.shape[0]), lambda i: (0, 0)),  # row
        ],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Lp, k), jnp.float32),
            jax.ShapeDtypeStruct((Lp, k), jnp.int32),
        ],
        interpret=interpret,
    )(mx, xpad[:, None], xpad[None, :])
    return dk, ik
