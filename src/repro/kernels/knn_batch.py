"""Pallas TPU kernel: library-batched all-kNN with streaming k-best merge.

The CCM matrix engine primitive (ISSUE 5). kEDM's all-pairs CCM drives
one all-kNN pass per library series, N times; this kernel adds a
*leading series-grid axis* to ``knn_multi_e.py``'s streaming k-best
tiling so ONE launch emits the neighbor tables of B library series at a
fixed E: the grid is (series, row blocks, column blocks) with the column
axis minor/sequential, each cell accumulates its series' (br, bc)
fused-embedding distance block in VMEM (E unrolled lag terms, the
(Lp, E) embedding never materialized) and merges it into the running
per-row k-best that lives in the revisited output block.

The batch axis is embarrassingly independent — series b's tiling,
accumulation order, and min-global-index tie-breaking are *identical*
for every B, so a B-series launch is bit-identical to B separate B = 1
launches (the layout contract the ref oracle also guarantees). Merge
semantics match ``knn_multi_e.py`` exactly (squared running bests,
retire-by-index so rows with < k valid candidates emit distinct fill
entries, sqrt once after the last column step); see its docstring for
the tie-order proof.

VMEM per cell is O(L + br·bc + br·k): two layouts of the one series
being processed (column/row copies, as in ``knn_multi_e.py``), the
distance block, and the running k-best — per-cell footprint does not
grow with B, which is what lets B scale to the host-side memory budget
(``core.ccm.auto_batch_libs``) instead of a VMEM ceiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import num_embedded, strict_sq

_BIG_I = 2**30  # python int: jnp constants must not be captured by kernels


def _kernel(xc_ref, xr_ref, dk_ref, ik_ref, *, E, tau, k, mx, br, bc, gj,
            exclude_self):
    i0 = pl.program_id(1) * br
    j = pl.program_id(2)
    j0 = j * bc

    @pl.when(j == 0)
    def _init():  # running k-best state lives in the revisited out block
        dk_ref[...] = jnp.full((1, br, k), jnp.inf, jnp.float32)
        ik_ref[...] = jnp.full((1, br, k), _BIG_I, jnp.int32)

    rows = i0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
    cols = j0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    acc = jnp.zeros((br, bc), jnp.float32)
    for e in range(E):  # E ≤ ~20: unrolled, as in knn_multi_e.py
        xi = xc_ref[pl.dslice(i0 + e * tau, br), :]  # (br, 1) sublanes
        xj = xr_ref[:, pl.dslice(j0 + e * tau, bc)]  # (1, bc) lanes
        d = xi - xj
        acc = acc + strict_sq(d)
    invalid = cols > mx  # static cap, pre-clamped to Lp − 1
    if exclude_self:
        invalid = invalid | (cols == rows)
    cand_d = jnp.concatenate(
        [jnp.where(invalid, jnp.inf, acc), dk_ref[0]], axis=1)
    cand_i = jnp.concatenate([cols, ik_ref[0]], axis=1)
    best_d, best_i = [], []
    for _ in range(k):
        m = jnp.min(cand_d, axis=1, keepdims=True)
        sel = jnp.where(cand_d == m, cand_i, _BIG_I)
        bi = jnp.min(sel, axis=1, keepdims=True)  # stable ties: min index
        best_d.append(m)
        best_i.append(bi)
        # Retire the winner by index (clearing BOTH arrays) — inf-distance
        # entries can't be retired via distance alone; see knn_multi_e.py.
        removed = cand_i == bi
        cand_d = jnp.where(removed, jnp.inf, cand_d)
        cand_i = jnp.where(removed, _BIG_I, cand_i)
    dk_ref[0] = jnp.concatenate(best_d, axis=1)
    ik_ref[0] = jnp.concatenate(best_i, axis=1)

    @pl.when(j == gj - 1)
    def _finalize():  # squared → Euclidean, once all columns are merged
        dk_ref[...] = jnp.sqrt(jnp.maximum(dk_ref[...], 0.0))


@functools.partial(
    jax.jit,
    static_argnames=("E", "tau", "k", "mx", "exclude_self", "block",
                     "interpret"))
def _call(X, *, E, tau, k, mx, exclude_self, block, interpret):
    B, L = X.shape
    Lp = num_embedded(L, E, tau)
    br = max(8, min(block[0], Lp))
    bc = max(128, min(block[1], Lp))
    gi = pl.cdiv(Lp, br)
    gj = pl.cdiv(Lp, bc)
    # Pad so no in-kernel dynamic slice ever clamps (row/col + lag reach).
    need = max(gi * br, gj * bc) + (E - 1) * tau
    Xp = jnp.pad(X.astype(jnp.float32), ((0, 0), (0, need - L)))
    return pl.pallas_call(
        functools.partial(_kernel, E=E, tau=tau, k=k, mx=mx, br=br, bc=bc,
                          gj=gj, exclude_self=exclude_self),
        grid=(B, gi, gj),
        in_specs=[
            pl.BlockSpec((need, 1), lambda b, i, j: (0, b)),  # column copy
            pl.BlockSpec((1, need), lambda b, i, j: (b, 0)),  # row copy
        ],
        out_specs=[
            pl.BlockSpec((1, br, k), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, br, k), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Lp, k), jnp.float32),
            jax.ShapeDtypeStruct((B, Lp, k), jnp.int32),
        ],
        interpret=interpret,
    )(Xp.T, Xp)


def all_knn_batch(
    X: jax.Array,
    *,
    E: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
    block: tuple[int, int] = (128, 1024),
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Neighbor tables for B series in one launch → (dists, idx), (B, Lp, k).

    Slice b equals the per-series two-kernel pipeline on ``X[b]`` (same
    ``lax.top_k`` tie order), for any B and any (br, bc) tiling.
    """
    X = jnp.asarray(X)
    if X.ndim != 2:
        raise ValueError(f"X must be (B, L), got shape {X.shape}")
    L = X.shape[-1]
    Lp = num_embedded(L, E, tau)  # raises on too-short series
    k = E + 1 if k is None else int(k)
    mx = Lp - 1 if max_idx is None else min(int(max_idx), Lp - 1)
    return _call(X, E=E, tau=tau, k=k, mx=mx, exclude_self=exclude_self,
                 block=block, interpret=interpret)
