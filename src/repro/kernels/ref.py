"""Pure-jnp reference oracles for the EDM kernels.

These are the ground truth the Pallas kernels are validated against
(``tests/test_kernels_*``) and the path that multi-pod dry-runs lower
(the container's CPU backend cannot compile Mosaic/TPU kernels).

Index conventions (0-based, matching DESIGN.md §2):
  - delay embedding of a series ``x`` of length L with dimension E and lag tau:
        z_i[k] = x[i + k*tau],   k in [0, E),  i in [0, Lp),
    where ``Lp = L - (E-1)*tau`` is the number of embedded points.
  - embedded point i corresponds to *time* index ``t = i + (E-1)*tau``
    (its most recent component).
  - a lookup with horizon Tp reads target values at
    ``I[j, k] + (E-1)*tau + Tp`` — callers pass that combined ``offset``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_INF = jnp.float32(jnp.inf)


def strict_sq(d: jax.Array) -> jax.Array:
    """The rounded square fl(d·d), pinned to strict IEEE at every shape.

    Every distance chain in the repo accumulates ``acc ± d·d``. Left
    bare, XLA CPU's backend (LLVM) may contract the multiply and the
    accumulate into one FMA — one rounding instead of two — and whether
    it does depends on how the surrounding program fused and vectorized,
    i.e. on *buffer shapes*. That makes accumulator bits a function of
    program shape, which breaks every bit-parity contract in the repo:
    ``master_append``'s gathered/slab recomputes vs the cold (L, L)
    build, derived-table recomputes vs engine outputs, and multi-E vs
    per-E cross-checks. (``lax.optimization_barrier`` does NOT help: the
    contraction happens below HLO, inside a fused loop body — measured.)

    The guard select breaks the mul→add edge the contraction pattern
    needs: ``d·d > −1`` is always true for real data, but neither XLA's
    simplifier nor LLVM can prove it (without ``nnan``, ``d·d`` may be
    NaN and the select must keep the 0.0 arm), so the select survives to
    codegen and the product is materialized with its own rounding —
    strict two-rounding semantics at any shape, matching a scalar numpy
    ``fl(acc ± fl(d·d))`` chain exactly. NaN products select 0.0; inputs
    are screened finite, so that arm is dead in practice.
    """
    d2 = d * d
    return jnp.where(d2 > -1.0, d2, jnp.zeros_like(d2))


def num_embedded(L: int, E: int, tau: int) -> int:
    """Number of valid delay-embedding vectors."""
    n = L - (E - 1) * tau
    if n <= 0:
        raise ValueError(f"series too short: L={L}, E={E}, tau={tau}")
    return n


def delay_embed(x: jax.Array, E: int, tau: int) -> jax.Array:
    """Materialized time-delay embedding, shape (Lp, E).

    Only used by tests and the S-Map solver; the distance kernels fuse
    this step (the paper's core optimization).
    """
    L = x.shape[-1]
    Lp = num_embedded(L, E, tau)
    cols = [jax.lax.dynamic_slice_in_dim(x, k * tau, Lp, axis=-1) for k in range(E)]
    return jnp.stack(cols, axis=-1)


@functools.partial(jax.jit, static_argnames=("E", "tau"))
def pairwise_distances(x: jax.Array, *, E: int, tau: int) -> jax.Array:
    """Squared-Euclidean pairwise distance matrix of the delay embedding.

    Fused formulation (no (Lp, E) matrix is materialized): accumulates
    ``(x[i+k*tau] - x[j+k*tau])**2`` over k. Returns (Lp, Lp) float32.
    """
    x = x.astype(jnp.float32)
    Lp = num_embedded(x.shape[-1], E, tau)
    acc = jnp.zeros((Lp, Lp), jnp.float32)
    for k in range(E):
        xk = jax.lax.dynamic_slice_in_dim(x, k * tau, Lp, axis=-1)
        d = xk[:, None] - xk[None, :]
        acc = acc + strict_sq(d)
    return acc


@functools.partial(jax.jit, static_argnames=("k", "exclude_self"))
def topk_select(
    D: jax.Array,
    *,
    k: int,
    exclude_self: bool = True,
    max_idx: jax.Array | int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Partial sort: k smallest entries per row of a squared-distance matrix.

    Returns (dists, idx): ``dists`` are *Euclidean* (sqrt applied — the
    "normalize" step of the paper's Algorithm 2), sorted ascending, shape
    (Lp, k); ``idx`` int32 embedded indices.

    ``exclude_self`` masks the diagonal (CCM/simplex leave-one-out).
    ``max_idx`` (inclusive) restricts neighbor candidates — used for
    Tp-horizon validity and library-size convergence sweeps.
    """
    Lp = D.shape[0]
    cols = jnp.arange(Lp, dtype=jnp.int32)
    mask = jnp.zeros((Lp, Lp), bool)
    if exclude_self:
        mask = mask | jnp.eye(Lp, dtype=bool)
    if max_idx is not None:
        mask = mask | (cols[None, :] > jnp.asarray(max_idx, jnp.int32))
    Dm = jnp.where(mask, _INF, D)
    # Two-stage chunk-max top-k (exact incl. ties — see _chunked_topk):
    # ~W/k× fewer elements through XLA-CPU's sequential TopK scan than the
    # plain full-row jax.lax.top_k the seed used.
    neg_d, idx = _chunked_topk(-Dm, k)
    return jnp.sqrt(jnp.maximum(-neg_d, 0.0)), idx.astype(jnp.int32)


def check_sizes_caps(max_idxs) -> tuple[int, ...]:
    """Validate a multi-cap tuple (non-empty, >= 0, ascending) → ints.

    The one contract both ``topk_select_sizes`` implementations (this
    oracle and the Pallas kernel) enforce; ``ops`` dispatches to them
    unchecked.
    """
    caps = tuple(int(m) for m in max_idxs)
    if not caps:
        raise ValueError("max_idxs must not be empty")
    if any(m < 0 for m in caps):
        raise ValueError(f"max_idxs must be >= 0, got {caps}")
    if any(b < a for a, b in zip(caps, caps[1:])):
        raise ValueError(f"max_idxs must be ascending, got {caps}")
    return caps


@functools.partial(jax.jit, static_argnames=("k", "max_idxs", "exclude_self"))
def topk_select_sizes(
    D: jax.Array,
    *,
    k: int,
    max_idxs: tuple[int, ...],
    exclude_self: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """k smallest per row under EVERY prefix cap in one pass → (S, Lp, k).

    The multi-cap primitive behind CCM convergence sweeps: ``max_idxs``
    is an ascending tuple of inclusive column caps (one per library
    size), and level s of the output equals ``topk_select(D, k=k,
    max_idx=max_idxs[s])`` — same Euclidean distances, same
    ``lax.top_k`` (value, index) tie order for every valid slot. Slots
    with no valid candidate under a cap are dist=inf / idx=``PAD_IDX``
    (the per-cap calls emit arbitrary masked-column indices there;
    both carry zero simplex weight, so downstream ρ is bit-identical).

    One pass instead of S: columns are consumed in ascending segments
    between consecutive caps, each segment's k-best merged into a
    running table. The merge concatenates the running k-best (all
    indices below the segment) before the segment's candidates, so
    ``lax.top_k``'s positional tie-breaking remains global
    (value, index) order — the invariant that makes the running table
    reusable across caps.
    """
    Lp = D.shape[0]
    caps = check_sizes_caps(max_idxs)
    neg = -D.astype(jnp.float32)
    rows = jnp.arange(Lp, dtype=jnp.int32)[:, None]
    run_nd = jnp.full((Lp, k), -_INF, jnp.float32)
    run_i = jnp.full((Lp, k), PAD_IDX, jnp.int32)
    outs_d, outs_i, prev = [], [], 0
    for m in caps:
        hi = min(m + 1, Lp)
        if hi > prev:
            w = hi - prev
            seg = jax.lax.slice_in_dim(neg, prev, hi, axis=1)
            seg_cols = prev + jnp.arange(w, dtype=jnp.int32)[None, :]
            if exclude_self:
                seg = jnp.where(seg_cols == rows, -_INF, seg)
            if w > k:
                snd, pos = _chunked_topk(seg, k)
                si = pos + prev
            else:
                snd, si = seg, jnp.broadcast_to(seg_cols, (Lp, w))
            cand_nd = jnp.concatenate([run_nd, snd], axis=1)
            cand_i = jnp.concatenate([run_i, si], axis=1)
            run_nd, pos = jax.lax.top_k(cand_nd, k)
            run_i = jnp.take_along_axis(cand_i, pos, axis=1)
            prev = hi
        ok = run_nd > -_INF
        outs_d.append(jnp.where(ok, jnp.sqrt(jnp.maximum(-run_nd, 0.0)),
                                _INF))
        outs_i.append(jnp.where(ok, run_i, jnp.int32(PAD_IDX)))
    return jnp.stack(outs_d), jnp.stack(outs_i)


def make_weights(dists: jax.Array, eps: float = 1e-30) -> jax.Array:
    """Simplex weights from sorted neighbor distances, paper step (3).

    w_i = exp(-d_i / d_min) normalized to sum 1; d_min is the nearest
    distance, guarded so exact-duplicate neighbors dominate (cppEDM
    semantics).

    Rows with *no* valid neighbor (all-inf distances, e.g. from an
    aggressive ``max_idx`` cap) get all-zero weights instead of NaN:
    inf/inf ratios are forced to inf (→ zero weight) and the normalizer
    is clamped away from zero.
    """
    d_min = jnp.maximum(dists[..., :1], eps)
    ratio = jnp.where(jnp.isfinite(d_min), dists / d_min, jnp.inf)
    w = jnp.exp(-ratio)
    s = jnp.sum(w, axis=-1, keepdims=True)
    return jnp.where(s > 0, w / jnp.maximum(s, eps), 0.0)


@functools.partial(jax.jit, static_argnames=("offset",))
def lookup(
    Y: jax.Array, idx: jax.Array, w: jax.Array, *, offset: int = 0
) -> jax.Array:
    """Batched simplex lookup, paper Algorithm 3.

    Y:   (N, L) target series sharing the library's neighbor tables.
    idx: (Lp, k) int32 embedded neighbor indices.
    w:   (Lp, k) normalized weights.
    Returns (N, Lp): Yhat[n, j] = sum_k w[j, k] * Y[n, idx[j, k] + offset].
    """
    g = jnp.take(Y, idx + offset, axis=-1)  # (N, Lp, k)
    return jnp.einsum("njk,jk->nj", g, w.astype(Y.dtype))


@functools.partial(jax.jit, static_argnames=("offset",))
def lookup_rho(
    Y: jax.Array, idx: jax.Array, w: jax.Array, *, offset: int = 0
) -> jax.Array:
    """Fused lookup + Pearson ρ (paper §3.4 "on-the-fly" path).

    Compares Yhat[n, j] against the aligned truth Y[n, j + offset] and
    returns ρ per target, shape (N,). Never materializes Yhat in HBM on
    the kernel path; this oracle just composes the two refs.
    """
    yhat = lookup(Y, idx, w, offset=offset)
    Lp = idx.shape[0]
    yt = jax.lax.dynamic_slice_in_dim(Y, offset, Lp, axis=-1)
    return pearson_rows(yhat, yt)


# --------------------------------------------------------------------------
# Library-batched all-kNN (the CCM matrix engine primitive).
#
# One launch computes the neighbor tables of B library series at one E —
# the batch axis is embarrassingly independent, so this is a *layout*
# contract, not a numerics change: the result is bit-invariant in B (any
# batch decomposition of this program gives identical tables — the
# contract journaled resume and OOM backoff re-tiling rely on). What is
# NOT contracted is bit-equality against *other programs* computing the
# same tables: XLA CPU contracts the distance accumulation differently
# at some shapes (~1 ULP) both inside ``lax.map`` bodies (e.g. Lp = 94,
# the legacy ``core.ccm.ccm_group`` route) and in the standalone 2-D
# per-series pipeline (e.g. L = 150, E = 4) — selection indices still
# agree (ties at 1 ULP don't arise in practice), distances wobble in
# the last bit. One more entry in the XLA-CPU contraction pathology
# file alongside the TopK slowdown in ROADMAP.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("E", "tau", "k", "exclude_self",
                                             "max_idx"))
def _all_knn_batch(X, *, E, tau, k, exclude_self, max_idx):
    B, L = X.shape
    Lp = num_embedded(L, E, tau)
    Xf = X.astype(jnp.float32)
    acc = jnp.zeros((B, Lp, Lp), jnp.float32)
    for lag in range(E):  # same accumulation order as pairwise_distances
        xk = jax.lax.dynamic_slice_in_dim(Xf, lag * tau, Lp, axis=-1)
        d = xk[:, :, None] - xk[:, None, :]
        acc = acc + strict_sq(d)
    cols = jnp.arange(Lp, dtype=jnp.int32)
    mask = jnp.zeros((Lp, Lp), bool)
    if exclude_self:
        mask = mask | jnp.eye(Lp, dtype=bool)
    if max_idx is not None:
        mask = mask | (cols[None, :] > max_idx)
    # One batched top-k over the whole (B, Lp, Lp) stack: selection is
    # row-independent and rounding-free, so batching it is exact — and it
    # hoists the TopK out of any lax.map body (where XLA CPU degenerates).
    # No (B·Lp, Lp) reshape: it would cut the mask/negate fusion into the
    # chunk-max prefilter and re-materialize the stack (2× at Lp=4094).
    neg_d, idx = _chunked_topk(-jnp.where(mask[None], _INF, acc), k)
    return (jnp.sqrt(jnp.maximum(-neg_d, 0.0)),
            idx.astype(jnp.int32))


def all_knn_batch(
    X: jax.Array,
    *,
    E: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
) -> tuple[jax.Array, jax.Array]:
    """All-kNN tables for B library series in ONE launch → (B, Lp, k).

    ``X`` is a (B, L) stack of series; slice b of the output matches the
    fused per-series pipeline (``pairwise_distances`` + ``topk_select``)
    on ``X[b]`` — indices exactly, with ``lax.top_k``'s (value, index)
    tie order; distances to ~1 ULP (the per-series pipeline is a
    different XLA program, see the section comment). Results are
    **bit-invariant in B**: any batch decomposition of this program
    yields identical tables — that is the resume/backoff contract.
    """
    X = jnp.asarray(X)
    if X.ndim != 2:
        raise ValueError(f"X must be (B, L), got shape {X.shape}")
    num_embedded(X.shape[-1], E, tau)  # raises on too-short series
    k = E + 1 if k is None else int(k)
    max_idx = None if max_idx is None else int(max_idx)
    return _all_knn_batch(X, E=E, tau=tau, k=k, exclude_self=exclude_self,
                          max_idx=max_idx)


# --------------------------------------------------------------------------
# Incremental multi-E all-kNN (the one-pass optimal-E sweep engine).
#
# D_E = D_{E-1} + the rank-1 lag term (x[i+(E-1)τ] − x[j+(E-1)τ])², so the
# full stack of per-E neighbor tables costs one O(E_max·Lp²) accumulation
# instead of the O(ΣE·Lp²) of re-running the pairwise kernel per E.
# Outputs are padded to the E=1 shape: (E_max, Lp_1, k_max) with Lp_1 = L,
# k_max = max-per-E k; padding is dist=inf / idx=PAD_IDX.
# --------------------------------------------------------------------------

PAD_IDX = -1  # idx padding outside the valid (Lp_E, k_E) block per level

_CHUNK_W = 32  # column-chunk width of the two-stage top-k; power of two


def _chunked_topk(neg: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Exact top-k (largest) per row via a chunk-max prefilter.

    Two-stage selection: (1) reduce each row to per-chunk maxima and pick
    the k best chunks, (2) run the real top_k over only those chunks'
    k·W candidates — ~W/k× fewer elements through the (single-threaded,
    ~2ns/elem) XLA-CPU TopK scan. The chunk maxima are computed with a
    pairwise elementwise max tree, NOT ``jnp.max(axis=-1)``: the XLA CPU
    reduce emitter goes scalar on this shape when its input is an
    in-graph accumulator (~15× slower than the tree; measured).

    EXACT, ties included: if a chunk holding a true top-k element v were
    not selected, each of the k selected chunks contributes a maximum
    outranking v (greater value, or equal value in an earlier chunk —
    stage-1 top_k is stable), giving v ≥ k predecessors — contradiction.
    Sorting the selected chunk ids keeps candidates in global column
    order, so stage-2 tie-breaking equals full-row stability; the ragged
    last chunk's out-of-range candidate slots are masked to -inf (same
    semantics as padding the row, without the full-matrix pad copy that
    used to dominate the cost on materialized inputs — ~70ms of the
    ~200ms total at Lp=4096).

    ``neg`` may carry leading batch dims ((…, Lc)); every stage is
    row-independent, so the result per row is identical to the 2-D call
    — the batched kNN engine passes its (B, Lp, Lp) stack directly
    instead of reshaping to (B·Lp, Lp), which would cut the fusion of
    the mask/negate producers into stage 1 and re-materialize the whole
    stack (measured 2× end-to-end at Lp=4094).
    """
    Lc = neg.shape[-1]
    lead = neg.shape[:-1]
    C = -(-Lc // _CHUNK_W)
    if k >= C or Lc <= 4 * _CHUNK_W:  # prefilter can't shrink the scan
        nd, ik = jax.lax.top_k(neg, k)
        return nd, ik.astype(jnp.int32)
    C0 = Lc // _CHUNK_W
    body = neg[..., :C0 * _CHUNK_W].reshape(*lead, C0, _CHUNK_W)
    m, w = body, _CHUNK_W
    while w > 1:  # vectorized pairwise max tree → (…, C0) chunk maxima
        m = jnp.maximum(m[..., :w // 2], m[..., w // 2:w])
        w //= 2
    m = m[..., 0]
    if C0 != C:  # ragged last chunk: tiny (…, Lc−C0·W) reduce
        m = jnp.concatenate(
            [m, jnp.max(neg[..., C0 * _CHUNK_W:], axis=-1, keepdims=True)],
            axis=-1)
    _, cid = jax.lax.top_k(m, k)
    cid = jnp.sort(cid, axis=-1)  # global column order → stable ties
    gidx = (cid[..., :, None] * _CHUNK_W
            + jnp.arange(_CHUNK_W, dtype=cid.dtype)
            ).reshape(*lead, k * _CHUNK_W)
    cand = jnp.take_along_axis(neg, jnp.minimum(gidx, Lc - 1), axis=-1)
    cand = jnp.where(gidx < Lc, cand, -_INF)
    nd, pos = jax.lax.top_k(cand, k)
    ik = jnp.take_along_axis(gidx, pos, axis=-1)
    return nd, ik.astype(jnp.int32)


def multi_e_ks(E_max: int, k: int | None) -> tuple[int, ...]:
    """Per-level neighbor counts: k_E = E+1 (simplex default) or uniform k."""
    if E_max < 1:
        raise ValueError(f"E_max must be >= 1, got {E_max}")
    if k is None:
        return tuple(e + 2 for e in range(E_max))  # E = e+1 → k = E+1
    return (int(k),) * E_max


def multi_e_max_idx(L: int, E_max: int, tau: int, max_idx) -> tuple[int, ...]:
    """Per-level candidate caps, clamped to the level's last valid index.

    ``max_idx`` may be None (no user cap), a python int, or a static
    (E_max,) sequence of ints (e.g. ``Lp_E − 1 − Tp`` for optimal-E's
    horizon-validity constraint). Static on purpose: the caps bake into
    the accumulation stream as constants (see ``_all_knn_multi_e``), and
    every caller derives them from already-static (L, E_max, tau, Tp).
    """
    base = [L - e * tau - 1 for e in range(E_max)]
    if max_idx is None:
        return tuple(base)
    mx = np.broadcast_to(np.asarray(max_idx, np.int64), (E_max,))
    return tuple(int(min(m, b)) for m, b in zip(mx, base))


def pad_multi_e_tables(
    dists: jax.Array, idx: jax.Array, *, E_max: int, tau: int,
    ks: tuple[int, ...],
) -> tuple[jax.Array, jax.Array]:
    """Force dist=inf / idx=PAD_IDX outside each level's (Lp_E, k_E) block."""
    L = dists.shape[1]
    lev = jnp.arange(E_max, dtype=jnp.int32)[:, None, None]
    rows = jnp.arange(L, dtype=jnp.int32)[None, :, None]
    kcol = jnp.arange(dists.shape[2], dtype=jnp.int32)[None, None, :]
    ks_a = jnp.asarray(ks, jnp.int32)[:, None, None]
    valid = (rows < L - lev * tau) & (kcol < ks_a)
    return (jnp.where(valid, dists, _INF),
            jnp.where(valid, idx, jnp.int32(PAD_IDX)))


@functools.partial(jax.jit, static_argnames=("E_max", "tau", "ks", "mxs",
                                             "exclude_self"))
def _all_knn_multi_e(x, *, E_max, tau, ks, mxs, exclude_self):
    # Invalidity is monotone when the caps are non-increasing (always true
    # for the defaults and for optimal-E's Lp_E−1−Tp caps): a column masked
    # at level e stays masked at every later level. Then masking FUSES into
    # the accumulation stream — the accumulator holds *negated* distances
    # with invalid entries stuck at −inf (−inf − d² = −inf), and the level
    # extraction runs directly on it: one read-modify-write of the matrix
    # per level, no separate masked copy. (Negating the accumulator
    # instead of the top_k input is bit-exact: f32 rounding commutes with
    # negation.)
    L = x.shape[-1]
    k_max = max(ks)
    xpad = jnp.pad(x.astype(jnp.float32), (0, (E_max - 1) * tau))
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    sticky = all(b <= a for a, b in zip(mxs, mxs[1:]))
    acc = jnp.zeros((L, L), jnp.float32)
    outs_d, outs_i = [], []
    for e in range(E_max):  # level e ↔ embedding dim E = e+1
        xk = jax.lax.dynamic_slice_in_dim(xpad, e * tau, L, axis=-1)
        d = xk[:, None] - xk[None, :]
        d2 = strict_sq(d)  # shape-independent bits — the append contract
        invalid = cols > mxs[e]
        if exclude_self and (e == 0 or not sticky):
            invalid = invalid | (cols == rows)
        if sticky:
            acc = jnp.where(invalid, -_INF, acc - d2)
            neg = acc
        else:  # non-monotone caps: mask a per-level copy instead
            acc = acc - d2
            neg = jnp.where(invalid, -_INF, acc)
        # Rows ≥ Lp_E are garbage (x-padding) but cheap — the extraction
        # scans them and the final pad mask discards them; this avoids a
        # strided slice copy per level.
        nd, ik = _chunked_topk(neg, ks[e])
        pad = k_max - ks[e]
        outs_d.append(jnp.pad(jnp.sqrt(jnp.maximum(-nd, 0.0)),
                              ((0, 0), (0, pad)), constant_values=jnp.inf))
        outs_i.append(jnp.pad(ik, ((0, 0), (0, pad)),
                              constant_values=PAD_IDX))
    return jnp.stack(outs_d), jnp.stack(outs_i)


def all_knn_multi_e(
    x: jax.Array,
    *,
    E_max: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
) -> tuple[jax.Array, jax.Array]:
    """Neighbor tables for *every* E in 1..E_max in one incremental pass.

    Returns (dists, idx), both (E_max, L, k_max): slice ``[E-1, :Lp_E, :k_E]``
    for the table at dimension E — identical to running ``pairwise_distances``
    + ``topk_select`` at that E. Padding is dist=inf / idx=PAD_IDX.
    """
    L = x.shape[-1]
    num_embedded(L, E_max, tau)  # raises on too-short series
    ks = multi_e_ks(E_max, k)
    mxs = multi_e_max_idx(L, E_max, tau, max_idx)
    d, i = _all_knn_multi_e(x, E_max=E_max, tau=tau, ks=ks, mxs=mxs,
                            exclude_self=exclude_self)
    return pad_multi_e_tables(d, i, E_max=E_max, tau=tau, ks=ks)


# --------------------------------------------------------------------------
# S-Map weighted normal equations (the batched S-Map engine substrate).
#
# For query row j and locality θ, S-Map fits ŷ = [1, z_j]·b with
# b = argmin Σ_i w_i (y_i − [1, z_i]·b)²,  w_i = exp(−θ d_ij / d̄_j).
# Instead of one lstsq per (j, θ) on √w-scaled copies of the design matrix
# (the seed path), the engine accumulates the (E+1, E+1) weighted Gram
# matrix G = AᵀWA and moment vector m = AᵀWy for EVERY (j, θ, target) at
# once and batch-solves the ridge-regularized normal equations downstream
# (core/smap_engine.py has the conditioning discussion).
# --------------------------------------------------------------------------

_DBAR_TINY = 1e-30  # d̄ below this ⇒ degenerate (constant) row: use ratio 0


def smap_ratio(x: jax.Array, *, E: int, tau: int, rows: int) -> jax.Array:
    """(rows, rows) S-Map distance ratios d_ij / d̄_j over the library.

    d̄_j is the mean Euclidean distance from query j to ALL library points
    (self included — its zero distance is part of the mean, matching
    cppEDM). Degenerate rows (d̄ ≈ 0, e.g. a constant series) would make
    the exp(−θ·d/d̄) weights NaN/inf; they get ratio 0 (⇒ weight 1), the
    only consistent limit since d̄ = 0 forces every d_ij = 0 too.
    """
    d = jnp.sqrt(jnp.maximum(
        pairwise_distances(x, E=E, tau=tau)[:rows, :rows], 0.0))
    dbar = jnp.mean(d, axis=1, keepdims=True)
    return d / jnp.where(dbar > _DBAR_TINY, dbar, 1.0)


@functools.partial(
    jax.jit, static_argnames=("E", "tau", "Tp", "thetas", "exclude_self"))
def smap_gram(
    x: jax.Array,
    Y: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas: tuple[float, ...],
    exclude_self: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Weighted Gram/moment accumulation for every (query row, θ, target).

    x: (L,) library series; Y: (N, L) target panel (self-prediction is
    Y = x[None]). With rows = Lp − max(Tp, 0) library points (those whose
    Tp-ahead truth exists) and A = [1 | delay_embed(x)[:rows]] of shape
    (rows, E+1):

      G[j, t]    = Aᵀ W_{j,θ_t} A            (rows, T, E+1, E+1)
      M[j, t, n] = Aᵀ W_{j,θ_t} y_n          (rows, T, N,   E+1)

    where W_{j,θ} = diag(exp(−θ d_ij / d̄_j)) with the self weight zeroed
    when ``exclude_self`` (leave-one-out) and y_n[i] = Y[n, i + off],
    off = (E−1)τ + Tp. Each θ is one (rows, rows) @ (rows, (E+1)²) matmul
    — no per-query solve loop, no (T, rows, rows) weight tensor. Tp ≥ 0.
    """
    x = x.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    L = x.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = Lp - max(Tp, 0)
    off = (E - 1) * tau + Tp
    E1 = E + 1
    A = jnp.concatenate(
        [jnp.ones((rows, 1), jnp.float32), delay_embed(x, E, tau)[:rows]],
        axis=1)
    ratio = smap_ratio(x, E=E, tau=tau, rows=rows)
    yv = jax.lax.dynamic_slice_in_dim(Y, off, rows, axis=-1)  # (N, rows)
    N = yv.shape[0]
    AA = (A[:, :, None] * A[:, None, :]).reshape(rows, E1 * E1)
    yA = (yv.T[:, :, None] * A[:, None, :]).reshape(rows, N * E1)
    self_mask = jnp.eye(rows, dtype=bool)
    Gs, Ms = [], []
    for t in thetas:  # |θ| ≤ ~16: unrolled, two matmuls per θ
        W = jnp.exp(jnp.float32(-t) * ratio)
        if exclude_self:
            W = jnp.where(self_mask, 0.0, W)
        Gs.append((W @ AA).reshape(rows, E1, E1))
        Ms.append((W @ yA).reshape(rows, N, E1))
    return jnp.stack(Gs, axis=1), jnp.stack(Ms, axis=1)


def pearson_rows(a: jax.Array, b: jax.Array) -> jax.Array:
    """Row-wise Pearson correlation, two-pass (numerically stable)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    am = a - jnp.mean(a, axis=-1, keepdims=True)
    bm = b - jnp.mean(b, axis=-1, keepdims=True)
    cov = jnp.sum(am * bm, axis=-1)
    va = jnp.sum(am * am, axis=-1)
    vb = jnp.sum(bm * bm, axis=-1)
    denom = jnp.sqrt(va * vb)
    return jnp.where(denom > 0, cov / jnp.maximum(denom, 1e-30), 0.0)

# --------------------------------------------------------------------------
# Incremental master append (the serving-path stream-in/merge primitive).
#
# A session's multi-E master is the top-k_m table of ``all_knn_multi_e``
# over the library axis. When the monitored series grows by dt points the
# level-e library grows by exactly dt columns (Lp_e = L − e·τ), and the
# table can be updated without the O(Lp²) rebuild:
#
#   - OLD rows (i < Lp_old_e): their coordinates are unchanged, so any
#     old column surviving into the new top-k_m must already sit in the
#     stored top-k_m. Merge the stored k_m candidates against only the
#     dt new columns — O(Lp·(k_m+dt)) per level.
#   - NEW rows (Lp_old_e ≤ i < Lp_new_e): no stored state; one full
#     (dt, L_new) scan per level.
#
# Bit-parity with a cold rebuild is the contract (tests/test_master_
# append.py property-tests it over Δt/E/τ grids, ties included). Three
# rules make it hold:
#
#   1. Every distance chain is STRICT two-rounding IEEE — ``strict_sq``
#      in ``_all_knn_multi_e`` and in the recompute chains below. Strict
#      per-element chains are deterministic regardless of buffer shape
#      or vectorization, so the (Lp, k) gathered recompute of a stored
#      candidate, the (dt, L) slab, and the cold (L, L) accumulator all
#      produce the same bits. (Left bare, XLA CPU FMA-contracts
#      acc − d·d at some shapes and the three programs disagree by
#      1 ULP — measured; see ``strict_sq``.)
#   2. The merge orders candidates in the *pre-sqrt* negated-squared
#      domain (sqrt is many-to-one after f32 rounding — merging on sqrt
#      values can invert 1-ULP ties), with candidates laid out
#      [stored slots ascending, new columns ascending]: stored indices
#      are < Lp_old_e ≤ new indices and ``lax.top_k`` is positionally
#      stable, so equal-value ties resolve in global column order —
#      exactly the cold extraction's tie rule.
#   3. Stored garbage slots (dist=inf from k_m > Lp_old_e − 1) carry the
#      OLD deterministic pattern [i, Lp_old_e, …]; those indices collide
#      with now-valid columns. They enter the merge as −inf candidates
#      and every surviving garbage slot is re-normalized afterwards to
#      the cold pattern [i, Lp_new_e, …] — which, because garbage
#      survives only when the finite count f equals Lp_new_e − 1, is
#      exactly ``idx = i`` at slot f and ``idx = slot`` beyond it.
#
# The merge itself is then pure selection over carried bits, so the
# Pallas variant (kernels/knn_append.py) shares these guarantees.
# --------------------------------------------------------------------------


def append_new_row_slab(x, *, dt, E_max, tau):
    """Negated-squared distances of the dt newest rows vs all columns.

    Returns (E_max, dt, L_new) UNMASKED accumulator levels: entry
    [e, r, j] equals the cold accumulator value at
    (row Lp_old_e + r, col j) wherever the cold entry is valid (strict
    chains are shape-independent). Row r of level e also supplies the
    dt new COLUMNS of every old row by symmetry: negation and squaring
    are exact and the per-lag chain order is identical, so
    acc(i, j) == acc(j, i) bitwise. Shared by the ref and Pallas paths.
    """
    L_new = x.shape[-1]
    xpad = jnp.pad(x.astype(jnp.float32), (0, (E_max - 1) * tau))
    xls = [jax.lax.dynamic_slice_in_dim(xpad, l * tau, L_new, axis=-1)
           for l in range(E_max)]
    outs = []
    for e in range(E_max):
        Lp_old = L_new - dt - e * tau
        Lp_new = L_new - e * tau
        acc = jnp.zeros((dt, L_new), jnp.float32)
        for l in range(e + 1):
            xl = xls[l]
            df = xl[Lp_old:Lp_new, None] - xl[None, :]
            acc = acc - strict_sq(df)
        outs.append(acc)
    return jnp.stack(outs)


def normalize_garbage(nd, ik, rows):
    """Rewrite non-finite slots to the cold build's garbage pattern.

    ``nd`` (rows, k) negated-squared merge output, ``ik`` its indices,
    ``rows`` (rows,) the row ids. Garbage survives the merge only when
    the finite count equals the row's full valid-neighbor count, so the
    cold pattern is self at the first garbage slot, then the slot id.
    """
    finite = nd > -_INF
    nfin = jnp.sum(finite.astype(jnp.int32), axis=1)[:, None]
    slot = jnp.arange(nd.shape[1], dtype=jnp.int32)[None, :]
    garb = jnp.where(slot == nfin, rows[:, None], slot)
    return jnp.where(finite, ik, garb)


@functools.partial(jax.jit, static_argnames=("dt", "E_max", "tau"))
def _master_append(x, dM, iM, *, dt, E_max, tau):
    L_new = x.shape[-1]
    L_old = L_new - dt
    k_m = dM.shape[-1]
    xpad = jnp.pad(x.astype(jnp.float32), (0, (E_max - 1) * tau))
    xls = [jax.lax.dynamic_slice_in_dim(xpad, l * tau, L_new, axis=-1)
           for l in range(E_max)]
    slab = append_new_row_slab(x, dt=dt, E_max=E_max, tau=tau)
    outs_d, outs_i = [], []
    for e in range(E_max):  # level e ↔ embedding dim E = e+1
        Lp_old = L_old - e * tau
        Lp_new = L_new - e * tau
        rows_o = jnp.arange(Lp_old, dtype=jnp.int32)
        new_cols = Lp_old + jnp.arange(dt, dtype=jnp.int32)
        # -- old rows: recompute stored candidates (strict chain) --------
        i_o = iM[e, :Lp_old]
        ok = jnp.isfinite(dM[e, :Lp_old])
        jj = jnp.maximum(i_o, 0)  # clamp garbage/PAD for a safe gather
        acc_s = jnp.zeros((Lp_old, k_m), jnp.float32)
        for l in range(e + 1):
            xl = xls[l]
            ds = xl[:Lp_old, None] - xl[jj]
            acc_s = acc_s - strict_sq(ds)
        # dt new columns of every old row — slab transpose, by symmetry
        nd_new = slab[e, :, :Lp_old].T
        cand_nd = jnp.concatenate([jnp.where(ok, acc_s, -_INF), nd_new],
                                  axis=1)
        cand_i = jnp.concatenate(
            [i_o, jnp.broadcast_to(new_cols, (Lp_old, dt))], axis=1)
        nd_o, pos = jax.lax.top_k(cand_nd, k_m)
        ik_o = normalize_garbage(
            nd_o, jnp.take_along_axis(cand_i, pos, axis=1), rows_o)
        # -- new rows: full slab rows, masked like the cold accumulator --
        rows_n = Lp_old + jnp.arange(dt, dtype=jnp.int32)
        colsL = jnp.arange(L_new, dtype=jnp.int32)[None, :]
        inval = (colsL > Lp_new - 1) | (colsL == rows_n[:, None])
        nd_n, ik_n = _chunked_topk(jnp.where(inval, -_INF, slab[e]), k_m)
        # -- assemble the level ------------------------------------------
        nd = jnp.concatenate([nd_o, nd_n], axis=0)
        ik = jnp.concatenate([ik_o, ik_n], axis=0)
        d_lvl = jnp.sqrt(jnp.maximum(-nd, 0.0))
        outs_d.append(jnp.pad(d_lvl, ((0, L_new - Lp_new), (0, 0)),
                              constant_values=jnp.inf))
        outs_i.append(jnp.pad(ik, ((0, L_new - Lp_new), (0, 0)),
                              constant_values=PAD_IDX))
    return jnp.stack(outs_d), jnp.stack(outs_i)


def check_append_args(x, dists, idx, tau: int) -> int:
    """Validate master_append inputs; returns dt (the appended width)."""
    E_max, L_old, _ = dists.shape
    L_new = int(x.shape[-1])
    dt = L_new - L_old
    if dt < 1:
        raise ValueError(f"append needs at least one new point, got dt={dt}")
    if idx.shape != dists.shape:
        raise ValueError(
            f"dists/idx shape mismatch: {dists.shape} vs {idx.shape}")
    num_embedded(L_old, E_max, tau)  # stored master must already be valid
    return dt


def master_append(
    x: jax.Array,
    dists: jax.Array,
    idx: jax.Array,
    *,
    tau: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Grow a multi-E master table to cover ``dt`` appended points.

    ``x`` is the FULL appended series (length L_new); ``dists``/``idx``
    are the stored ``all_knn_multi_e`` tables of its length-L_old
    prefix, both (E_max, L_old, k_m) with uniform k (``panel_master``
    masters). Returns the (E_max, L_new, k_m) tables, bit-identical to
    ``all_knn_multi_e(x, E_max=E_max, tau=tau, k=k_m)`` at
    O(Lp·(k_m+dt)) per level instead of O(Lp²).
    """
    dt = check_append_args(x, dists, idx, tau)
    E_max = dists.shape[0]
    return _master_append(x, dists, idx, dt=dt, E_max=E_max, tau=tau)
