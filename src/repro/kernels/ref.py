"""Pure-jnp reference oracles for the EDM kernels.

These are the ground truth the Pallas kernels are validated against
(``tests/test_kernels_*``) and the path that multi-pod dry-runs lower
(the container's CPU backend cannot compile Mosaic/TPU kernels).

Index conventions (0-based, matching DESIGN.md §2):
  - delay embedding of a series ``x`` of length L with dimension E and lag tau:
        z_i[k] = x[i + k*tau],   k in [0, E),  i in [0, Lp),
    where ``Lp = L - (E-1)*tau`` is the number of embedded points.
  - embedded point i corresponds to *time* index ``t = i + (E-1)*tau``
    (its most recent component).
  - a lookup with horizon Tp reads target values at
    ``I[j, k] + (E-1)*tau + Tp`` — callers pass that combined ``offset``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_INF = jnp.float32(jnp.inf)


def num_embedded(L: int, E: int, tau: int) -> int:
    """Number of valid delay-embedding vectors."""
    n = L - (E - 1) * tau
    if n <= 0:
        raise ValueError(f"series too short: L={L}, E={E}, tau={tau}")
    return n


def delay_embed(x: jax.Array, E: int, tau: int) -> jax.Array:
    """Materialized time-delay embedding, shape (Lp, E).

    Only used by tests and the S-Map solver; the distance kernels fuse
    this step (the paper's core optimization).
    """
    L = x.shape[-1]
    Lp = num_embedded(L, E, tau)
    cols = [jax.lax.dynamic_slice_in_dim(x, k * tau, Lp, axis=-1) for k in range(E)]
    return jnp.stack(cols, axis=-1)


@functools.partial(jax.jit, static_argnames=("E", "tau"))
def pairwise_distances(x: jax.Array, *, E: int, tau: int) -> jax.Array:
    """Squared-Euclidean pairwise distance matrix of the delay embedding.

    Fused formulation (no (Lp, E) matrix is materialized): accumulates
    ``(x[i+k*tau] - x[j+k*tau])**2`` over k. Returns (Lp, Lp) float32.
    """
    x = x.astype(jnp.float32)
    Lp = num_embedded(x.shape[-1], E, tau)
    acc = jnp.zeros((Lp, Lp), jnp.float32)
    for k in range(E):
        xk = jax.lax.dynamic_slice_in_dim(x, k * tau, Lp, axis=-1)
        d = xk[:, None] - xk[None, :]
        acc = acc + d * d
    return acc


@functools.partial(jax.jit, static_argnames=("k", "exclude_self"))
def topk_select(
    D: jax.Array,
    *,
    k: int,
    exclude_self: bool = True,
    max_idx: jax.Array | int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Partial sort: k smallest entries per row of a squared-distance matrix.

    Returns (dists, idx): ``dists`` are *Euclidean* (sqrt applied — the
    "normalize" step of the paper's Algorithm 2), sorted ascending, shape
    (Lp, k); ``idx`` int32 embedded indices.

    ``exclude_self`` masks the diagonal (CCM/simplex leave-one-out).
    ``max_idx`` (inclusive) restricts neighbor candidates — used for
    Tp-horizon validity and library-size convergence sweeps.
    """
    Lp = D.shape[0]
    cols = jnp.arange(Lp, dtype=jnp.int32)
    mask = jnp.zeros((Lp, Lp), bool)
    if exclude_self:
        mask = mask | jnp.eye(Lp, dtype=bool)
    if max_idx is not None:
        mask = mask | (cols[None, :] > jnp.asarray(max_idx, jnp.int32))
    Dm = jnp.where(mask, _INF, D)
    neg_d, idx = jax.lax.top_k(-Dm, k)
    return jnp.sqrt(jnp.maximum(-neg_d, 0.0)), idx.astype(jnp.int32)


def make_weights(dists: jax.Array, eps: float = 1e-30) -> jax.Array:
    """Simplex weights from sorted neighbor distances, paper step (3).

    w_i = exp(-d_i / d_min) normalized to sum 1; d_min is the nearest
    distance, guarded so exact-duplicate neighbors dominate (cppEDM
    semantics).
    """
    d_min = jnp.maximum(dists[..., :1], eps)
    w = jnp.exp(-dists / d_min)
    return w / jnp.sum(w, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("offset",))
def lookup(
    Y: jax.Array, idx: jax.Array, w: jax.Array, *, offset: int = 0
) -> jax.Array:
    """Batched simplex lookup, paper Algorithm 3.

    Y:   (N, L) target series sharing the library's neighbor tables.
    idx: (Lp, k) int32 embedded neighbor indices.
    w:   (Lp, k) normalized weights.
    Returns (N, Lp): Yhat[n, j] = sum_k w[j, k] * Y[n, idx[j, k] + offset].
    """
    g = jnp.take(Y, idx + offset, axis=-1)  # (N, Lp, k)
    return jnp.einsum("njk,jk->nj", g, w.astype(Y.dtype))


@functools.partial(jax.jit, static_argnames=("offset",))
def lookup_rho(
    Y: jax.Array, idx: jax.Array, w: jax.Array, *, offset: int = 0
) -> jax.Array:
    """Fused lookup + Pearson ρ (paper §3.4 "on-the-fly" path).

    Compares Yhat[n, j] against the aligned truth Y[n, j + offset] and
    returns ρ per target, shape (N,). Never materializes Yhat in HBM on
    the kernel path; this oracle just composes the two refs.
    """
    yhat = lookup(Y, idx, w, offset=offset)
    Lp = idx.shape[0]
    yt = jax.lax.dynamic_slice_in_dim(Y, offset, Lp, axis=-1)
    return pearson_rows(yhat, yt)


def pearson_rows(a: jax.Array, b: jax.Array) -> jax.Array:
    """Row-wise Pearson correlation, two-pass (numerically stable)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    am = a - jnp.mean(a, axis=-1, keepdims=True)
    bm = b - jnp.mean(b, axis=-1, keepdims=True)
    cov = jnp.sum(am * bm, axis=-1)
    va = jnp.sum(am * am, axis=-1)
    vb = jnp.sum(bm * bm, axis=-1)
    denom = jnp.sqrt(va * vb)
    return jnp.where(denom > 0, cov / jnp.maximum(denom, 1e-30), 0.0)
