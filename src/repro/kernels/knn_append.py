"""Pallas kernel: k-best merge for incremental master append.

The serving-path companion to ``ref.master_append``: same O(Lp·(k+dt))
per-level stream-in/merge (see the append section of kernels/ref.py for
the contract and the strict-chain/tie-order/garbage rules), with the
per-row k-best selection lowered to a Pallas kernel instead of
``lax.top_k``.

The split of labor is deliberate: candidate *values* are produced by the
same strict-``jnp`` chains the reference uses (``ref.strict_sq`` keeps
them bit-identical to the cold build at any shape), and the kernel is
PURE SELECTION — no float arithmetic, only compares and gathers — so the
Pallas path inherits the reference's bit-parity guarantee for free. The
selection rule is ``knn_batch.py``'s retire-by-index min-merge
((value asc, index asc), distinct fill entries for < k-candidate rows),
which equals ``lax.top_k`` over the positionally-ordered candidate
layout (stored slots are already in global (value, index) order and
their indices all precede the appended columns').

One layout subtlety this kernel owns: a stored GARBAGE slot (dist=inf
from k_m exceeding a level's candidate count) carries the old build's
deterministic index pattern ``[i, Lp_old_e, …]`` — indices that collide
with now-valid appended columns. Retire-by-index would then retire a
real candidate along with the garbage slot, so garbage indices are
remapped to distinct ``_BIG_I + slot`` sentinels before the merge; every
surviving non-finite slot is re-normalized to the cold pattern
afterwards (``ref.normalize_garbage``, shared with the reference path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref
from repro.kernels.ref import PAD_IDX, _INF

_BIG_I = 2**30  # python int: jnp constants must not be captured by kernels


def _select_kernel(cd_ref, ci_ref, dk_ref, ik_ref, *, k, br):
    """Per-row k smallest (value asc, index asc) of a candidate block.

    Inputs are positive squared distances (inf = masked or garbage) with
    per-row-unique indices (sentinels ≥ _BIG_I for garbage). Pure
    selection — the output value bits are copies of input bits.
    """
    i0 = pl.program_id(0) * br
    cand_d = cd_ref[pl.dslice(i0, br), :]
    cand_i = ci_ref[pl.dslice(i0, br), :]
    best_d, best_i = [], []
    for _ in range(k):
        m = jnp.min(cand_d, axis=1, keepdims=True)
        sel = jnp.where(cand_d == m, cand_i, _BIG_I + 2**20)
        bi = jnp.min(sel, axis=1, keepdims=True)  # stable ties: min index
        best_d.append(m)
        best_i.append(bi)
        removed = cand_i == bi
        cand_d = jnp.where(removed, jnp.inf, cand_d)
        cand_i = jnp.where(removed, _BIG_I + 2**20, cand_i)
    dk_ref[...] = jnp.concatenate(best_d, axis=1)
    ik_ref[...] = jnp.concatenate(best_i, axis=1)


def _select(cand_d, cand_i, *, k, block, interpret):
    """k-best rows of (R, C) candidates via the selection kernel."""
    R, C = cand_d.shape
    br = max(8, min(block, R))
    g = pl.cdiv(R, br)
    pad = g * br - R
    # Padding rows are all-inf/sentinel: selected then discarded.
    cand_d = jnp.pad(cand_d, ((0, pad), (0, 0)), constant_values=jnp.inf)
    cand_i = jnp.pad(cand_i, ((0, pad), (0, 0)), constant_values=_BIG_I)
    dk, ik = pl.pallas_call(
        functools.partial(_select_kernel, k=k, br=br),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((g * br, C), lambda i: (0, 0)),
            pl.BlockSpec((g * br, C), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g * br, k), jnp.float32),
            jax.ShapeDtypeStruct((g * br, k), jnp.int32),
        ],
        interpret=interpret,
    )(cand_d, cand_i)
    return dk[:R], ik[:R]


@functools.partial(jax.jit, static_argnames=("dt", "E_max", "tau", "block",
                                             "interpret"))
def _master_append(x, dM, iM, *, dt, E_max, tau, block, interpret):
    L_new = x.shape[-1]
    L_old = L_new - dt
    k_m = dM.shape[-1]
    xpad = jnp.pad(x.astype(jnp.float32), (0, (E_max - 1) * tau))
    xls = [jax.lax.dynamic_slice_in_dim(xpad, l * tau, L_new, axis=-1)
           for l in range(E_max)]
    slab = _ref.append_new_row_slab(x, dt=dt, E_max=E_max, tau=tau)
    outs_d, outs_i = [], []
    for e in range(E_max):  # level e ↔ embedding dim E = e+1
        Lp_old = L_old - e * tau
        Lp_new = L_new - e * tau
        rows_o = jnp.arange(Lp_old, dtype=jnp.int32)
        new_cols = Lp_old + jnp.arange(dt, dtype=jnp.int32)
        slot = jnp.arange(k_m, dtype=jnp.int32)[None, :]
        # -- old rows: strict-chain recompute of stored candidates -------
        i_o = iM[e, :Lp_old]
        ok = jnp.isfinite(dM[e, :Lp_old])
        jj = jnp.maximum(i_o, 0)
        acc_s = jnp.zeros((Lp_old, k_m), jnp.float32)
        for l in range(e + 1):
            xl = xls[l]
            ds = xl[:Lp_old, None] - xl[jj]
            acc_s = acc_s - _ref.strict_sq(ds)
        nd_new = slab[e, :, :Lp_old].T
        cand_d = jnp.concatenate(
            [jnp.where(ok, -acc_s, jnp.inf), -nd_new], axis=1)
        cand_i = jnp.concatenate(
            [jnp.where(ok, i_o, _BIG_I + slot),
             jnp.broadcast_to(new_cols, (Lp_old, dt))], axis=1)
        dk_o, ik_sel = _select(cand_d, cand_i, k=k_m, block=block,
                               interpret=interpret)
        ik_o = _ref.normalize_garbage(-dk_o, ik_sel, rows_o)
        # -- new rows: full slab rows, masked like the cold accumulator --
        rows_n = Lp_old + jnp.arange(dt, dtype=jnp.int32)
        colsL = jnp.arange(L_new, dtype=jnp.int32)[None, :]
        inval = (colsL > Lp_new - 1) | (colsL == rows_n[:, None])
        dk_n, ik_seln = _select(
            jnp.where(inval, jnp.inf, -slab[e]),
            jnp.broadcast_to(colsL, (dt, L_new)),
            k=k_m, block=block, interpret=interpret)
        ik_n = _ref.normalize_garbage(-dk_n, ik_seln, rows_n)
        # -- assemble the level ------------------------------------------
        dk = jnp.concatenate([dk_o, dk_n], axis=0)
        ik = jnp.concatenate([ik_o, ik_n], axis=0)
        d_lvl = jnp.sqrt(jnp.maximum(dk, 0.0))
        outs_d.append(jnp.pad(d_lvl, ((0, L_new - Lp_new), (0, 0)),
                              constant_values=jnp.inf))
        outs_i.append(jnp.pad(ik, ((0, L_new - Lp_new), (0, 0)),
                              constant_values=PAD_IDX))
    return jnp.stack(outs_d), jnp.stack(outs_i)


def master_append(
    x: jax.Array,
    dists: jax.Array,
    idx: jax.Array,
    *,
    tau: int = 1,
    block: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Kernel-path ``ref.master_append`` — bit-identical, same contract."""
    dt = _ref.check_append_args(x, dists, idx, tau)
    E_max = dists.shape[0]
    return _master_append(x, dists, idx, dt=dt, E_max=E_max, tau=tau,
                          block=block, interpret=interpret)
