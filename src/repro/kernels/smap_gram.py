"""Pallas TPU kernel: column-tiled S-Map weighted-Gram accumulation.

S-Map (the paper's other core EDM method, validated against cppEDM) fits,
for every query row j and locality θ, a locally weighted linear model over
ALL library points — there is no k-nearest truncation to exploit, so the
seed paid one ``lstsq`` per (j, θ) over a materialized (Lp, Lp) distance
matrix. This kernel replaces that with the normal-equations accumulation

    G[j, θ]    = Aᵀ W_{j,θ} A    (E+1, E+1)
    M[j, θ, n] = Aᵀ W_{j,θ} y_n  (E+1,)

streamed over library (column) tiles in the same design language as
``knn_multi_e.py``: the raw series lives in VMEM (the delay embedding and
the distances are fused in-kernel, never touching HBM), the grid is
(row blocks, phase, column blocks) with the column axis minor/sequential,
and the output blocks double as running accumulators revisited across all
column steps. VMEM per cell is O(L + br·bc + T·(E+1)²·br + T·N·(E+1)·br)
— no (rows, rows) weight or distance matrix ever exists anywhere.

The S-Map weight w_ij = exp(−θ d_ij / d̄_j) needs the full-row mean d̄_j
*before* any weight can be formed, which a single streaming pass cannot
provide. The middle grid axis is a two-phase sweep over the same column
tiles: phase 0 recomputes each (br, bc) distance block and accumulates the
row sums (→ d̄, an output block revisited across tiles), phase 1 recomputes
the block again (O(E·br·bc), cheaper than round-tripping it through HBM;
measured against a VMEM d-row cache and kept — see the ``cache_phase1``
note on ``smap_gram``)
and accumulates, per θ, the E+1 rank-(E+1) MXU matmuls (w ⊙ aᵖ) @ A_tile
into the Gram/moment outputs. Degenerate rows (d̄ ≈ 0, constant series)
take ratio 0 ⇒ weight 1 — see ``ref.smap_ratio``.

Per-level semantics match ``ref.smap_gram`` exactly (library = the first
``rows`` embedded points, self distance included in d̄, self weight zeroed
under leave-one-out); the two agree to f32 accumulation-order noise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import _DBAR_TINY, num_embedded


def _kernel(xc_ref, xr_ref, y_ref, ds_ref, g_ref, m_ref, *scratch, E, tau,
            off, rows, thetas, br, bc, exclude_self):
    i0 = pl.program_id(0) * br
    p = pl.program_id(1)  # 0: accumulate row sums (d̄) · 1: accumulate G, M
    j = pl.program_id(2)
    j0 = j * bc
    E1 = E + 1
    N = y_ref.shape[0]
    dc_ref = scratch[0] if scratch else None  # cache_phase1 distance rows

    T = len(thetas)

    @pl.when((p == 0) & (j == 0))
    def _init():  # running accumulators live in the revisited out blocks
        ds_ref[...] = jnp.zeros((br, 1), jnp.float32)
        g_ref[...] = jnp.zeros((T, E1, br, E1), jnp.float32)
        m_ref[...] = jnp.zeros((T, N, br, E1), jnp.float32)

    rows_i = i0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
    cols_i = j0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    valid = cols_i < rows  # library = embedded points with Tp-ahead truth

    def compute_d():  # fused-embedding distance block, O(E·br·bc) VPU work
        acc = jnp.zeros((br, bc), jnp.float32)
        for e in range(E):  # E ≤ ~20: unrolled, as in pairwise_dist.py
            xi = xc_ref[pl.dslice(i0 + e * tau, br), :]  # (br, 1) sublanes
            xj = xr_ref[:, pl.dslice(j0 + e * tau, bc)]  # (1, bc) lanes
            d = xi - xj
            acc = acc + d * d
        return jnp.sqrt(jnp.maximum(acc, 0.0))

    def rowsum(d):  # d̄ numerator; self's zero distance is included
        ds_ref[...] += jnp.sum(jnp.where(valid, d, 0.0), axis=1,
                               keepdims=True)

    def _gram_accumulate(d):
        dbar = ds_ref[...] * (1.0 / rows)  # (br, 1)
        ratio = d / jnp.where(dbar > _DBAR_TINY, dbar, 1.0)
        invalid = ~valid
        if exclude_self:
            invalid = invalid | (cols_i == rows_i)  # leave-one-out
        # Design-matrix tile in both layouts, straight from the series
        # caches (no in-kernel transposes): A_i = [1, x_i, …, x_{i+(E−1)τ}].
        at = jnp.concatenate(
            [jnp.ones((bc, 1), jnp.float32)]
            + [xc_ref[pl.dslice(j0 + e * tau, bc), :] for e in range(E)],
            axis=1)  # (bc, E1)
        arows = [jnp.ones((1, bc), jnp.float32)] + [
            xr_ref[:, pl.dslice(j0 + e * tau, bc)] for e in range(E)]
        for t, theta in enumerate(thetas):  # |θ| ≤ ~16: unrolled
            w = jnp.where(invalid, 0.0,
                          jnp.exp(jnp.float32(-theta) * ratio))  # (br, bc)
            for q in range(E1):  # Gᵀ row q: ((w ⊙ aᵠ) @ A_tile) on the MXU
                g_ref[t, q] += jax.lax.dot_general(
                    w * arows[q], at, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
            for n in range(N):
                yn = y_ref[pl.dslice(n, 1), pl.dslice(j0 + off, bc)]
                m_ref[t, n] += jax.lax.dot_general(
                    w * yn, at, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)

    if dc_ref is None:  # default: recompute the block in phase 1

        @pl.when(p == 0)
        def _rowsum():
            rowsum(compute_d())

        @pl.when(p == 1)
        def _gram():
            _gram_accumulate(compute_d())
    else:  # cache_phase1: phase 0 spills the d rows to VMEM scratch

        @pl.when(p == 0)
        def _rowsum_cache():
            d = compute_d()
            rowsum(d)
            dc_ref[:, pl.dslice(j0, bc)] = d

        @pl.when(p == 1)
        def _gram_cache():
            _gram_accumulate(dc_ref[:, pl.dslice(j0, bc)])


@functools.partial(
    jax.jit,
    static_argnames=("E", "tau", "Tp", "thetas", "exclude_self", "block",
                     "interpret", "cache_phase1"))
def _call(x, Y, *, E, tau, Tp, thetas, exclude_self, block, interpret,
          cache_phase1=False):
    L = x.shape[-1]
    rows = num_embedded(L, E, tau) - max(Tp, 0)
    off = (E - 1) * tau + Tp
    E1 = E + 1
    T = len(thetas)
    N = Y.shape[0]
    br = max(8, min(block[0], rows))
    bc = max(128, min(block[1], rows))
    gi = pl.cdiv(rows, br)
    gj = pl.cdiv(rows, bc)
    # Pad so no in-kernel dynamic slice ever clamps (row/col + lag/Tp reach).
    need = max(gi * br, gj * bc) + (E - 1) * tau + max(Tp, 0)
    xpad = jnp.pad(x.astype(jnp.float32), (0, need - L))
    ypad = jnp.pad(Y.astype(jnp.float32), ((0, 0), (0, need - L)))
    _, G, M = pl.pallas_call(
        functools.partial(_kernel, E=E, tau=tau, off=off, rows=rows,
                          thetas=thetas, br=br, bc=bc,
                          exclude_self=exclude_self),
        scratch_shapes=(
            [pltpu.VMEM((br, gj * bc), jnp.float32)] if cache_phase1
            else []),
        grid=(gi, 2, gj),
        in_specs=[
            pl.BlockSpec((need, 1), lambda i, p, j: (0, 0)),  # column copy
            pl.BlockSpec((1, need), lambda i, p, j: (0, 0)),  # row copy
            pl.BlockSpec((N, need), lambda i, p, j: (0, 0)),  # target panel
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i, p, j: (i, 0)),
            pl.BlockSpec((T, E1, br, E1), lambda i, p, j: (0, 0, i, 0)),
            pl.BlockSpec((T, N, br, E1), lambda i, p, j: (0, 0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gi * br, 1), jnp.float32),     # Σ_i d_ij
            jax.ShapeDtypeStruct((T, E1, gi * br, E1), jnp.float32),
            jax.ShapeDtypeStruct((T, N, gi * br, E1), jnp.float32),
        ],
        interpret=interpret,
    )(xpad[:, None], xpad[None, :], ypad)
    # Kernel layout keeps (br, E1) matmul tiles contiguous; callers want
    # query-major (rows, T, …) for the batched Cholesky solve.
    G = jnp.transpose(G, (2, 0, 1, 3))[:rows]  # (rows, T, E1, E1)
    M = jnp.transpose(M, (2, 0, 1, 3))[:rows]  # (rows, T, N, E1)
    return G, M


def smap_gram(
    x: jax.Array,
    Y: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas: tuple[float, ...],
    exclude_self: bool = True,
    block: tuple[int, int] = (128, 1024),
    interpret: bool = False,
    cache_phase1: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Streaming weighted Gram/moments → (G (rows,T,E+1,E+1), M (rows,T,N,E+1)).

    Semantics identical to ``ref.smap_gram`` (see its docstring); Y is the
    (N, L) target panel (Y = x[None] for self-prediction).

    ``cache_phase1`` resolves the ROADMAP S-Map follow-on (a): instead of
    recomputing each O(E·br·bc) distance block in the phase-1 sweep,
    phase 0 spills its √acc rows to a (br, rows) f32 VMEM scratch that
    phase 1 reads back (bit-equal outputs — same arithmetic either way).
    Measured (Pallas interpreter at L=512, E=6, |θ|=4, N=2,
    block=(64, 256); the container has no TPU, so this measures executed
    ops, not MXU/VPU overlap): recompute 9.8 ms, cache 11.8 ms — the
    cache LOSES even before hardware effects, and on a real TPU the
    recompute is VPU work that overlaps the phase-1 MXU matmuls while
    the scratch costs 4·br·rows bytes of VMEM, capping the library near
    rows ≈ 16k at br=128 before the scratch alone eats half of VMEM.
    Default therefore stays ``False`` (recompute); the knob exists for
    TPU profiling to revisit.
    """
    L = x.shape[-1]
    num_embedded(L, E, tau)  # raises on too-short series
    if Y.shape[-1] != L:
        raise ValueError("library/target series length mismatch")
    return _call(x, Y, E=E, tau=tau, Tp=Tp,
                 thetas=tuple(float(t) for t in thetas),
                 exclude_self=exclude_self, block=block, interpret=interpret,
                 cache_phase1=cache_phase1)
