"""Pallas TPU kernel: fused time-delay embedding + pairwise distances.

The paper's Algorithm 1 (kEDM §3.3.1): compute the (Lp, Lp) squared-distance
matrix of the E-dimensional delay embedding *without materializing the
embedding*, reading only the raw 1-D series. On Kokkos the series is cached
in team scratch; here the (small) series lives in VMEM for every grid cell
and each cell computes one (bi, bj) output tile.

Two variants (DESIGN.md §2):

* ``vpu``  — the faithful port: unrolled k-loop of rank-1 differences,
  elementwise FMA on the VPU. Arithmetic intensity grows with E exactly as
  the paper reports.
* ``mxu``  — beyond-paper: the cross term is computed as a skinny matmul
  ``Z_i @ Z_jᵀ`` with E zero-padded to 128 so it runs on the MXU; the
  embedding tiles are still built in-kernel from contiguous VMEM slices
  (the fusion is preserved). ops.py centers the series first so the
  ‖z_i‖² + ‖z_j‖² − 2⟨z_i,z_j⟩ expansion is numerically safe.

Layout trick: the series is passed twice, as a (Lpad, 1) column and a
(1, Lpad) row, so the i-axis slices land on sublanes and the j-axis slices
on lanes with no in-kernel transposes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import strict_sq

MXU_K = 128  # MXU contraction width the embedding dim is padded to.


def _kernel_vpu(xc_ref, xr_ref, o_ref, *, E: int, tau: int, bi: int, bj: int):
    i0 = pl.program_id(0) * bi
    j0 = pl.program_id(1) * bj
    acc = jnp.zeros((bi, bj), jnp.float32)
    for k in range(E):  # E <= 20: unrolled, as in the paper's inner loop
        xi = xc_ref[pl.dslice(i0 + k * tau, bi), :]  # (bi, 1) sublanes
        xj = xr_ref[:, pl.dslice(j0 + k * tau, bj)]  # (1, bj) lanes
        d = xi - xj
        acc = acc + strict_sq(d)
    o_ref[...] = acc


def _kernel_mxu(xc_ref, xr_ref, o_ref, *, E: int, tau: int, bi: int, bj: int):
    i0 = pl.program_id(0) * bi
    j0 = pl.program_id(1) * bj
    # Build embedding tiles in-kernel (fusion preserved), padded to MXU width.
    zi = jnp.concatenate(
        [xc_ref[pl.dslice(i0 + k * tau, bi), :] for k in range(E)]
        + [jnp.zeros((bi, MXU_K - E), jnp.float32)],
        axis=1,
    )  # (bi, 128)
    zjT = jnp.concatenate(
        [xr_ref[:, pl.dslice(j0 + k * tau, bj)] for k in range(E)]
        + [jnp.zeros((MXU_K - E, bj), jnp.float32)],
        axis=0,
    )  # (128, bj)
    cross = jax.lax.dot_general(
        zi, zjT, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bi, bj) on the MXU
    ni = jnp.sum(zi * zi, axis=1, keepdims=True)  # (bi, 1)
    nj = jnp.sum(zjT * zjT, axis=0, keepdims=True)  # (1, bj)
    o_ref[...] = jnp.maximum(ni + nj - 2.0 * cross, 0.0)


@functools.partial(
    jax.jit, static_argnames=("E", "tau", "block", "variant", "interpret")
)
def pairwise_distances(
    x: jax.Array,
    *,
    E: int,
    tau: int,
    block: tuple[int, int] = (256, 256),
    variant: str = "vpu",
    interpret: bool = False,
) -> jax.Array:
    """Fused-embedding squared pairwise distances via Pallas. (Lp, Lp) f32."""
    L = x.shape[-1]
    Lp = L - (E - 1) * tau
    if Lp <= 0:
        raise ValueError(f"series too short: L={L}, E={E}, tau={tau}")
    bi, bj = (min(block[0], Lp), min(block[1], Lp))
    # Sublane/lane alignment: distances are cheap to over-tile; clamp to >=8.
    bi = max(8, bi)
    bj = max(8, bj)
    gi = pl.cdiv(Lp, bi)
    gj = pl.cdiv(Lp, bj)
    # Pad so no in-kernel dynamic slice ever clamps (DESIGN.md §2): the last
    # tile reads up to (tiles*b - b) + (E-1)tau + b.
    need = max(gi * bi, gj * bj) + (E - 1) * tau
    x32 = x.astype(jnp.float32)
    # Centering makes the MXU norm-expansion numerically safe and is free
    # for distances; apply to both variants for bit-compat between them.
    x32 = x32 - jnp.mean(x32)
    xpad = jnp.pad(x32, (0, need - L))
    kern = _kernel_mxu if variant == "mxu" else _kernel_vpu
    return pl.pallas_call(
        functools.partial(kern, E=E, tau=tau, bi=bi, bj=bj),
        grid=(gi, gj),
        in_specs=[
            pl.BlockSpec((need, 1), lambda i, j: (0, 0)),  # column copy
            pl.BlockSpec((1, need), lambda i, j: (0, 0)),  # row copy
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Lp, Lp), jnp.float32),
        interpret=interpret,
    )(xpad[:, None], xpad[None, :])
