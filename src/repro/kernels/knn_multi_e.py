"""Pallas TPU kernel: incremental multi-E all-kNN with streaming k-best merge.

Beyond-paper optimization. kEDM's ``edim`` (optimal embedding dimension,
§3.4) re-runs the full pairwise+top-k pipeline once per E, paying
O(ΣE·Lp²) = O(E_max²·Lp²/2) FLOPs and E_max round trips of the distance
matrix through global memory. But the squared delay-embedding distance
obeys a first-order recurrence in E:

    D_E[i, j] = D_{E-1}[i, j] + (x[i+(E-1)τ] − x[j+(E-1)τ])²,

so one accumulation sweep of the E_max lag terms visits every D_E on the
way to D_{E_max}. This kernel exploits that: each grid cell holds a
(br, bc) block of the distance matrix in VMEM, adds the lag terms one E
at a time, and *at every level E* extracts that block's top-k before
adding the next term — emitting the complete stack of per-E neighbor
tables (E_max, Lp_1, k_max) in a single O(E_max·Lp²) pass with the
distance matrix never touching HBM.

Streaming k-best merge (the column-tiling that removes ``knn_fused.py``'s
one-VMEM-row-block ceiling on Lp): the grid is (row blocks, column
blocks) with the column axis minor, i.e. sequential on TPU. The output
block for a row block is revisited across all column steps and doubles as
the running k-best state: at level E the cell concatenates its masked
(br, bc) distance block (with global column indices) against the running
(br, k_max) best-so-far (with their indices) and runs k_E passes of
(min, first-argmin-by-*global*-index, mask) over the combined candidates.
Min-global-index tie-breaking makes the streaming result bit-identical to
a stable full-row partial sort (``jax.lax.top_k`` on the masked row), for
any column tiling. After the last column step the squared running bests
are rooted (sqrt) in place.

VMEM per cell is O(L + br·bc + E_max·br·k_max): the raw series is
cached in VMEM (kEDM keeps it in team scratch the same way), the
distance block is a fixed (br, bc) tile, and the quadratic (br, Lp)
row block of ``knn_fused.py`` is gone — Lp is bounded by the linear
series cache, not by a full-width distance row in VMEM.

Per-level semantics match ``ref.all_knn_multi_e``: level e (E = e+1) has
Lp_E = L − e·τ valid rows/cols, k_E neighbors (E+1 by default), a static
per-level candidate cap ``mxs[e]`` (pre-clamped to Lp_E − 1), and optional
self-exclusion. Output padding outside each level's (Lp_E, k_E) block is
dist=inf / idx=PAD_IDX, applied by the host-side wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import (
    multi_e_ks,
    multi_e_max_idx,
    num_embedded,
    pad_multi_e_tables,
    strict_sq,
)

_BIG_I = 2**30  # python int: jnp constants must not be captured by kernels


def _kernel(xc_ref, xr_ref, dk_ref, ik_ref, *, E_max, tau, ks, mxs,
            br, bc, gj, exclude_self):
    i0 = pl.program_id(0) * br
    j = pl.program_id(1)
    j0 = j * bc
    k_max = max(ks)

    @pl.when(j == 0)
    def _init():  # running k-best state lives in the revisited out block
        dk_ref[...] = jnp.full((E_max, br, k_max), jnp.inf, jnp.float32)
        ik_ref[...] = jnp.full((E_max, br, k_max), _BIG_I, jnp.int32)

    rows = i0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
    cols = j0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    acc = jnp.zeros((br, bc), jnp.float32)
    for e in range(E_max):  # E_max ≤ ~20: unrolled, as in pairwise_dist.py
        xi = xc_ref[pl.dslice(i0 + e * tau, br), :]  # (br, 1) sublanes
        xj = xr_ref[:, pl.dslice(j0 + e * tau, bc)]  # (1, bc) lanes
        d = xi - xj
        acc = acc + strict_sq(d)
        # ---- level-E extraction: merge this block into the running k-best
        invalid = cols > mxs[e]  # static cap, pre-clamped to Lp_E − 1
        if exclude_self:
            invalid = invalid | (cols == rows)
        cand_d = jnp.concatenate(
            [jnp.where(invalid, jnp.inf, acc), dk_ref[e]], axis=1)
        cand_i = jnp.concatenate([cols, ik_ref[e]], axis=1)
        best_d, best_i = [], []
        for _ in range(ks[e]):
            m = jnp.min(cand_d, axis=1, keepdims=True)
            sel = jnp.where(cand_d == m, cand_i, _BIG_I)
            bi = jnp.min(sel, axis=1, keepdims=True)  # stable ties: min index
            best_d.append(m)
            best_i.append(bi)
            # Retire the winner by index, clearing BOTH arrays: inf-distance
            # entries can't be retired via distance alone (they're already
            # inf), and an un-cleared index would win every later inf-tie —
            # re-emitting the same index on rows with < k valid candidates.
            # Global indices are unique across the tile ∪ running set, so
            # exactly the selected entry is removed (bi == _BIG_I only
            # retires interchangeable init padding).
            removed = cand_i == bi
            cand_d = jnp.where(removed, jnp.inf, cand_d)
            cand_i = jnp.where(removed, _BIG_I, cand_i)
        pad = k_max - ks[e]
        if pad:
            best_d.append(jnp.full((br, pad), jnp.inf, jnp.float32))
            best_i.append(jnp.full((br, pad), _BIG_I, jnp.int32))
        dk_ref[e] = jnp.concatenate(best_d, axis=1)
        ik_ref[e] = jnp.concatenate(best_i, axis=1)

    @pl.when(j == gj - 1)
    def _finalize():  # squared → Euclidean, once all columns are merged
        dk_ref[...] = jnp.sqrt(jnp.maximum(dk_ref[...], 0.0))


@functools.partial(
    jax.jit,
    static_argnames=("E_max", "tau", "ks", "mxs", "exclude_self", "block",
                     "interpret"))
def _call(x, *, E_max, tau, ks, mxs, exclude_self, block, interpret):
    L = x.shape[-1]
    k_max = max(ks)
    br = max(8, min(block[0], L))
    bc = max(128, min(block[1], L))
    gi = pl.cdiv(L, br)
    gj = pl.cdiv(L, bc)
    # Pad so no in-kernel dynamic slice ever clamps (row/col + lag reach).
    need = max(gi * br, gj * bc) + (E_max - 1) * tau
    xpad = jnp.pad(x.astype(jnp.float32), (0, need - L))
    dk, ik = pl.pallas_call(
        functools.partial(_kernel, E_max=E_max, tau=tau, ks=ks, mxs=mxs,
                          br=br, bc=bc, gj=gj, exclude_self=exclude_self),
        grid=(gi, gj),
        in_specs=[
            pl.BlockSpec((need, 1), lambda i, j: (0, 0)),  # column copy
            pl.BlockSpec((1, need), lambda i, j: (0, 0)),  # row copy
        ],
        out_specs=[
            pl.BlockSpec((E_max, br, k_max), lambda i, j: (0, i, 0)),
            pl.BlockSpec((E_max, br, k_max), lambda i, j: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E_max, L, k_max), jnp.float32),
            jax.ShapeDtypeStruct((E_max, L, k_max), jnp.int32),
        ],
        interpret=interpret,
    )(xpad[:, None], xpad[None, :])
    return pad_multi_e_tables(dk, ik, E_max=E_max, tau=tau, ks=ks)


def all_knn_multi_e(
    x: jax.Array,
    *,
    E_max: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
    block: tuple[int, int] = (128, 1024),
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One-pass neighbor tables for every E in 1..E_max → (dists, idx).

    Both outputs are (E_max, Lp_1, k_max); ``[E-1, :Lp_E, :k_E]`` is the
    table at dimension E, identical to the per-E two-kernel pipeline.
    """
    L = x.shape[-1]
    num_embedded(L, E_max, tau)  # raises on too-short series
    ks = multi_e_ks(E_max, k)
    mxs = multi_e_max_idx(L, E_max, tau, max_idx)
    return _call(x, E_max=E_max, tau=tau, ks=ks, mxs=mxs,
                 exclude_self=exclude_self, block=block, interpret=interpret)
