"""Single-source dispatch layer for the EDM kernels.

This is the repo's analog of kEDM's "single codebase, many backends"
portability story: every caller goes through these entry points, and the
implementation is chosen per platform —

  * ``pallas``    — Mosaic/TPU kernels (the performance path),
  * ``interpret`` — the same kernels executed by the Pallas interpreter
                    (CPU correctness validation; what CI runs here),
  * ``ref``       — pure-jnp oracles (also what multi-pod dry-runs lower,
                    since Mosaic cannot target the CPU backend).

``impl="auto"`` resolves to the innermost ``use_impl`` override if one is
active (the plan layer in ``repro.edm`` sets it per plan), else to
``pallas`` on TPU and ``ref`` elsewhere. Unknown impl names are an error
everywhere — they used to fall through to the kernel path and fail with
an obscure Mosaic error much later.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.kernels import lookup as _lookup_k
from repro.kernels import pairwise_dist as _pairwise_k
from repro.kernels import ref as _ref
from repro.kernels import topk as _topk_k

make_weights = _ref.make_weights
pearson_rows = _ref.pearson_rows
num_embedded = _ref.num_embedded
delay_embed = _ref.delay_embed

#: Every implementation name the dispatch layer accepts.
IMPLS = ("auto", "pallas", "interpret", "ref")

_impl_stack: list[str] = []  # innermost use_impl override wins


@functools.cache
def _platform_default() -> str:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:  # pragma: no cover - no backend at all
        platform = "cpu"
    return "pallas" if platform == "tpu" else "ref"


def default_impl() -> str:
    """Current default implementation: ``use_impl`` override, else platform."""
    if _impl_stack and _impl_stack[-1] != "auto":
        return _impl_stack[-1]
    return _platform_default()


@contextlib.contextmanager
def use_impl(name: str):
    """Scoped module-level default: ``with ops.use_impl("interpret"): ...``.

    Inside the block every ``impl="auto"`` call resolves to ``name``
    (``"auto"`` restores the platform default). This is how the plan layer
    (``repro.edm``) pins one backend for a whole plan instead of threading
    ``impl=`` through every call site.

    Caveat: resolution happens at *trace* time, and jitted callables key
    their cache on the static string ``"auto"``, not on what it resolved
    to — a program traced under one override is happily reused under
    another. Code that flips impls mid-session (the plan layer, tests)
    must pass the concrete name from ``resolve_impl`` into jitted
    functions rather than rely on ``"auto"`` inside the block.
    """
    if name not in IMPLS:
        raise ValueError(f"unknown impl {name!r}; expected one of {IMPLS}")
    _impl_stack.append(name)
    try:
        yield
    finally:
        _impl_stack.pop()


def resolve_impl(impl: str = "auto") -> str:
    """Concrete implementation name for ``impl`` (errors on unknown names)."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    return default_impl() if impl == "auto" else impl


_resolve = resolve_impl


def _tel(op: str, impl: str, **attrs) -> None:
    """Per-dispatch telemetry: an ``edm_ops_<op>_calls`` counter bump
    plus (when a sink is live) an ``ops.<op>`` event with static
    shape/impl attrs.

    Counters, not timed spans, on purpose: these dispatchers run at
    *trace* time inside jitted programs, where ``block_until_ready``
    cannot fence a tracer — a wall-time span here would measure trace
    overhead once and nothing on cached calls. Timed spans live at the
    driver level (``core.ccm.drive_batched``), where tile landings are
    real device syncs. A dispatch count therefore means "this op was
    traced", which is exactly the invocation-count contract the session
    cache tests assert (they clear jit caches first).
    """
    telemetry.counter(f"edm_ops_{op}_calls").inc()
    if telemetry.active():
        telemetry.event(f"ops.{op}", impl=impl, **attrs)


def pairwise_distances(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    impl: str = "auto",
    variant: str = "vpu",
    block: tuple[int, int] = (256, 256),
) -> jax.Array:
    """(Lp, Lp) squared distances of the delay embedding (fused, Alg. 1)."""
    impl = _resolve(impl)
    _tel("pairwise_distances", impl, E=E, tau=tau, L=int(x.shape[-1]))
    if impl == "ref":
        return _ref.pairwise_distances(x, E=E, tau=tau)
    return _pairwise_k.pairwise_distances(
        x, E=E, tau=tau, block=block, variant=variant,
        interpret=(impl == "interpret"),
    )


def topk_select(
    D: jax.Array,
    *,
    k: int,
    exclude_self: bool = True,
    max_idx=None,
    impl: str = "auto",
    block_rows: int = 8,
) -> tuple[jax.Array, jax.Array]:
    """k nearest per row → (Euclidean dists, int32 idx), ascending (Alg. 2)."""
    impl = _resolve(impl)
    _tel("topk_select", impl, k=k, Lp=int(D.shape[-1]))
    if impl == "ref":
        return _ref.topk_select(D, k=k, exclude_self=exclude_self,
                                max_idx=max_idx)
    return _topk_k.topk_select(
        D, k=k, exclude_self=exclude_self, max_idx=max_idx,
        block_rows=block_rows, interpret=(impl == "interpret"),
    )


def topk_select_sizes(
    D: jax.Array,
    *,
    k: int,
    max_idxs: tuple[int, ...],
    exclude_self: bool = True,
    impl: str = "auto",
    block: tuple[int, int] = (8, 512),
) -> tuple[jax.Array, jax.Array]:
    """k nearest per row under EVERY prefix cap in one pass → (S, Lp, k).

    ``max_idxs`` is an ascending tuple of inclusive candidate caps (one
    per library size); level s equals ``topk_select(D, k=k,
    max_idx=max_idxs[s])`` on every valid slot, with dist=inf /
    idx=``ref.PAD_IDX`` where a cap leaves fewer than k candidates. The
    CCM convergence-sweep primitive: one streaming pass instead of S
    full re-scans of the distance matrix (see kernels/topk.py).
    """
    impl = _resolve(impl)
    _tel("topk_select_sizes", impl, k=k, sizes=len(max_idxs),
         Lp=int(D.shape[-1]))
    if impl == "ref":
        return _ref.topk_select_sizes(
            D, k=k, max_idxs=tuple(int(m) for m in max_idxs),
            exclude_self=exclude_self)
    return _topk_k.topk_select_sizes(
        D, k=k, max_idxs=tuple(int(m) for m in max_idxs),
        exclude_self=exclude_self, block=block,
        interpret=(impl == "interpret"))


def all_knn(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
    impl: str = "auto",
    variant: str = "vpu",
    fused: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """All-kNN search over one library series (paper §3.3).

    Returns (dists (Lp, k), idx (Lp, k)); k defaults to E+1 (simplex).
    ``fused=True`` uses the single-kernel pairwise+top-k (beyond-paper:
    the distance matrix never reaches HBM; see kernels/knn_fused.py) —
    identical results, ~470× less kernel HBM traffic at paper scale.
    """
    k = E + 1 if k is None else k
    impl_r = _resolve(impl)
    _tel("all_knn", impl_r, E=E, k=k, fused=fused, L=int(x.shape[-1]))
    if fused and impl_r != "ref":
        from repro.kernels.knn_fused import all_knn_fused
        return all_knn_fused(
            x, E=E, tau=tau, k=k, exclude_self=exclude_self,
            max_idx=max_idx, interpret=(impl_r == "interpret"))
    D = pairwise_distances(x, E=E, tau=tau, impl=impl, variant=variant)
    return topk_select(D, k=k, exclude_self=exclude_self, max_idx=max_idx,
                       impl=impl)


def all_knn_batch(
    X: jax.Array,
    *,
    E: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
    impl: str = "auto",
    block: tuple[int, int] = (128, 1024),
) -> tuple[jax.Array, jax.Array]:
    """All-kNN tables for B library series in ONE launch → (B, Lp, k).

    The CCM matrix engine primitive: batches the kNN axis so an E-group
    of the all-pairs matrix costs ceil(N/B) launches instead of N
    sequential ``lax.map`` steps. Slice b equals the fused per-series
    pipeline on ``X[b]`` with ``lax.top_k``'s tie order, and results are
    bit-invariant in B (the per-series oracle is the B = 1 launch); see
    kernels/knn_batch.py and ``ref.all_knn_batch``.
    """
    impl = _resolve(impl)
    _tel("all_knn_batch", impl, E=E, B=int(X.shape[0]),
         L=int(X.shape[-1]))
    if impl == "ref":
        return _ref.all_knn_batch(
            X, E=E, tau=tau, k=k, exclude_self=exclude_self, max_idx=max_idx)
    from repro.kernels.knn_batch import all_knn_batch as _batch_k
    return _batch_k(
        X, E=E, tau=tau, k=k, exclude_self=exclude_self, max_idx=max_idx,
        block=block, interpret=(impl == "interpret"))


def all_knn_multi_e(
    x: jax.Array,
    *,
    E_max: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
    impl: str = "auto",
    block: tuple[int, int] = (128, 1024),
):
    """Incremental all-kNN for every E in 1..E_max in ONE O(E_max·Lp²) pass.

    Returns (dists, idx), both (E_max, Lp_1, k_max) padded with inf/-1;
    ``[E-1, :Lp_E, :k_E]`` equals the per-E ``pairwise_distances`` +
    ``topk_select`` result. This is the optimal-E sweep engine: the seed
    per-E pipeline costs O(ΣE·Lp²); the recurrence D_E = D_{E-1} + one
    rank-1 lag term collapses it (see kernels/knn_multi_e.py).
    """
    impl = _resolve(impl)
    _tel("all_knn_multi_e", impl, E_max=E_max, L=int(x.shape[-1]))
    if impl == "ref":
        return _ref.all_knn_multi_e(
            x, E_max=E_max, tau=tau, k=k, exclude_self=exclude_self,
            max_idx=max_idx)
    from repro.kernels.knn_multi_e import all_knn_multi_e as _multi_e
    return _multi_e(
        x, E_max=E_max, tau=tau, k=k, exclude_self=exclude_self,
        max_idx=max_idx, block=block, interpret=(impl == "interpret"))


def master_append(
    x: jax.Array,
    dists: jax.Array,
    idx: jax.Array,
    *,
    tau: int = 1,
    impl: str = "auto",
    block: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Stream dt appended points into a multi-E master — O(Lp·(k+dt))/level.

    ``x`` is the FULL grown series; ``dists``/``idx`` are the stored
    ``all_knn_multi_e`` tables of its prefix (uniform k — masters).
    Returns the grown (E_max, L_new, k_m) tables, bit-identical to a
    cold rebuild on ``x`` (see the append section in kernels/ref.py for
    the strict-chain rules that make that hold). The impl knob selects
    the merge-stage engine — ref's ``top_k`` and the Pallas k-best merge
    (kernels/knn_append.py) are bit-identical selection over the same
    candidate bits.
    """
    impl = _resolve(impl)
    _tel("master_append", impl, E_max=int(dists.shape[0]),
         L=int(x.shape[-1]), dt=int(x.shape[-1]) - int(dists.shape[1]))
    if impl == "ref":
        return _ref.master_append(x, dists, idx, tau=tau)
    from repro.kernels.knn_append import master_append as _append_k
    return _append_k(x, dists, idx, tau=tau, block=block,
                     interpret=(impl == "interpret"))


def smap_gram(
    x: jax.Array,
    Y: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas: tuple[float, ...],
    exclude_self: bool = True,
    impl: str = "auto",
    block: tuple[int, int] = (128, 1024),
) -> tuple[jax.Array, jax.Array]:
    """S-Map weighted normal-equations accumulation for every (row, θ, target).

    Returns (G (rows, T, E+1, E+1), M (rows, T, N, E+1)) — the AᵀWA Gram
    matrices and AᵀWy moments the batched S-Map engine solves downstream
    (core/smap_engine.py). The kernel path streams library column tiles
    and never materializes any (rows, rows) object (kernels/smap_gram.py);
    the ref path holds one (rows, rows) weight matrix at a time (never the
    (T, rows, rows) stack).
    """
    impl = _resolve(impl)
    thetas = tuple(float(t) for t in thetas)
    _tel("smap_gram", impl, E=E, thetas=len(thetas), L=int(x.shape[-1]))
    if impl == "ref":
        return _ref.smap_gram(x, Y, E=E, tau=tau, Tp=Tp, thetas=thetas,
                              exclude_self=exclude_self)
    from repro.kernels.smap_gram import smap_gram as _smap_gram_k
    return _smap_gram_k(
        x, Y, E=E, tau=tau, Tp=Tp, thetas=thetas, exclude_self=exclude_self,
        block=block, interpret=(impl == "interpret"))


def lookup(
    Y: jax.Array,
    idx: jax.Array,
    w: jax.Array,
    *,
    offset: int = 0,
    impl: str = "auto",
    block: tuple[int, int] = (128, 128),
) -> jax.Array:
    """Batched simplex lookup → (N, Lp) predictions (Alg. 3)."""
    impl = _resolve(impl)
    _tel("lookup", impl, N=int(Y.shape[0]))
    if impl == "ref":
        return _ref.lookup(Y, idx, w, offset=offset)
    return _lookup_k.lookup(Y, idx, w, offset=offset, block=block,
                            interpret=(impl == "interpret"))


def lookup_rho(
    Y: jax.Array,
    idx: jax.Array,
    w: jax.Array,
    *,
    offset: int = 0,
    impl: str = "auto",
    block: tuple[int, int] = (128, 128),
) -> jax.Array:
    """Fused lookup + Pearson ρ per target → (N,) (paper §3.4 fused path)."""
    impl = _resolve(impl)
    _tel("lookup_rho", impl, N=int(Y.shape[0]))
    if impl == "ref":
        return _ref.lookup_rho(Y, idx, w, offset=offset)
    return _lookup_k.lookup_rho(Y, idx, w, offset=offset, block=block,
                                interpret=(impl == "interpret"))
