"""Pallas TPU kernel: batched simplex lookup (+ fused Pearson ρ).

The paper's Algorithm 3 (kEDM §3.4): predictions for N target series that
share one library's neighbor tables,

    yhat[n, j] = sum_k W[j, k] * Y[n, I[j, k] + offset].

Kokkos caches the target series in team scratch and unrolls the k-loop;
the TPU adaptation (DESIGN.md §2) puts **targets on the 128-lane axis**:
the target block is held in VMEM transposed, (L, bn), so each neighbor
gather ``Y_T[I[j,k]+offset, :]`` is a single sublane dynamic-slice of a
(1, bn) vector — the lane-major analog of kEDM's coalesced reads. The
k-loop (k ≤ 32) is unrolled; the j-loop is a fori with direct stores.

``lookup_rho`` is the paper's "on-the-fly correlation" path: predicted
values never reach HBM; per-target covariance statistics are accumulated
across j-tiles in a revisited output block using the numerically stable
pairwise-merge scheme of Schubert & Gertz (the paper's ref. [15]).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_tile(yT_ref, i_ref, w_ref, j0, *, k, bj, bn, offset):
    """Compute one (bj, bn) tile of predictions into a VMEM value."""

    hi = yT_ref.shape[0] - 1

    def body(j, acc):
        row = jnp.zeros((1, bn), jnp.float32)
        for kk in range(k):  # unrolled: k is small and static
            # clamp: padded rows of ragged j-tiles hold undefined indices
            idx = jnp.clip(i_ref[j, kk] + offset, 0, hi)
            row = row + w_ref[j, kk] * yT_ref[pl.dslice(idx, 1), :]
        return jax.lax.dynamic_update_slice(acc, row, (j, 0))

    return jax.lax.fori_loop(0, bj, body, jnp.zeros((bj, bn), jnp.float32))


def _kernel_lookup(yT_ref, i_ref, w_ref, o_ref, *, k, bj, bn, offset):
    o_ref[...] = _gather_tile(yT_ref, i_ref, w_ref, None, k=k, bj=bj, bn=bn,
                              offset=offset)


@functools.partial(
    jax.jit, static_argnames=("offset", "block", "interpret")
)
def lookup(
    Y: jax.Array,
    idx: jax.Array,
    w: jax.Array,
    *,
    offset: int = 0,
    block: tuple[int, int] = (128, 128),
    interpret: bool = False,
) -> jax.Array:
    """Batched lookup via Pallas. Returns (N, Lp) float32."""
    N, L = Y.shape
    Lp, k = idx.shape
    bj, bn = (max(8, min(block[0], Lp)), max(8, min(block[1], N)))
    gj, gn = pl.cdiv(Lp, bj), pl.cdiv(N, bn)
    # Pad the time axis so idx+offset slices never clamp, incl. the padded
    # rows of ragged j-tiles (their idx payload is undefined → clamp-safe 0).
    Lpad = L + 1
    yT = jnp.pad(Y.astype(jnp.float32).T, ((0, Lpad - L), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel_lookup, k=k, bj=bj, bn=bn, offset=offset),
        grid=(gn, gj),
        in_specs=[
            pl.BlockSpec((Lpad, bn), lambda n, j: (0, n)),
            pl.BlockSpec((bj, k), lambda n, j: (j, 0)),
            pl.BlockSpec((bj, k), lambda n, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bj, bn), lambda n, j: (j, n)),
        out_shape=jax.ShapeDtypeStruct((Lp, N), jnp.float32),
        interpret=interpret,
    )(yT, _sanitize_idx(idx, L - 1 - offset), w.astype(jnp.float32))
    return out.T


def _sanitize_idx(idx: jax.Array, hi: int) -> jax.Array:
    """Clamp indices into [0, hi]; padded tile rows may hold garbage."""
    return jnp.clip(idx.astype(jnp.int32), 0, max(hi, 0))


# ---------------------------------------------------------------- fused rho


def _kernel_rho(yT_ref, i_ref, w_ref, s_ref, *, k, bj, bn, offset, Lp):
    j = pl.program_id(1)
    j0 = j * bj

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    yhat = _gather_tile(yT_ref, i_ref, w_ref, j0, k=k, bj=bj, bn=bn,
                        offset=offset)
    ytrue = yT_ref[pl.dslice(j0 + offset, bj), :]  # contiguous truth rows
    # Mask ragged-edge rows with selects, not multiplies: the interpreter
    # (and Mosaic) pad ragged input blocks with undefined values, which may
    # be NaN — and NaN * 0 == NaN would poison the reduction.
    valid_b = j0 + jax.lax.broadcasted_iota(jnp.int32, (bj, 1), 0) < Lp
    valid = valid_b.astype(jnp.float32)
    yhat = jnp.where(valid_b, yhat, 0.0)
    ytrue = jnp.where(valid_b, ytrue, 0.0)

    # Tile-local two-pass stats (masked), then Schubert–Gertz pairwise merge
    # with the running stats held in the revisited output block.
    nt = jnp.sum(valid)  # scalar
    nt_safe = jnp.maximum(nt, 1.0)
    ma_t = jnp.sum(yhat, axis=0, keepdims=True) / nt_safe  # (1, bn)
    mb_t = jnp.sum(ytrue, axis=0, keepdims=True) / nt_safe
    da = (yhat - ma_t) * valid
    db = (ytrue - mb_t) * valid
    M2a_t = jnp.sum(da * da, axis=0, keepdims=True)
    M2b_t = jnp.sum(db * db, axis=0, keepdims=True)
    C_t = jnp.sum(da * db, axis=0, keepdims=True)

    n0 = s_ref[0:1, :]
    ma0, mb0 = s_ref[1:2, :], s_ref[2:3, :]
    M2a0, M2b0, C0 = s_ref[3:4, :], s_ref[4:5, :], s_ref[5:6, :]
    n1 = n0 + nt
    n1_safe = jnp.maximum(n1, 1.0)
    dA = ma_t - ma0
    dB = mb_t - mb0
    f = n0 * nt / n1_safe
    s_ref[0:1, :] = n1
    s_ref[1:2, :] = ma0 + dA * nt / n1_safe
    s_ref[2:3, :] = mb0 + dB * nt / n1_safe
    s_ref[3:4, :] = M2a0 + M2a_t + dA * dA * f
    s_ref[4:5, :] = M2b0 + M2b_t + dB * dB * f
    s_ref[5:6, :] = C0 + C_t + dA * dB * f


@functools.partial(
    jax.jit, static_argnames=("offset", "block", "interpret")
)
def lookup_rho(
    Y: jax.Array,
    idx: jax.Array,
    w: jax.Array,
    *,
    offset: int = 0,
    block: tuple[int, int] = (128, 128),
    interpret: bool = False,
) -> jax.Array:
    """Fused lookup + Pearson ρ per target. Returns (N,) float32.

    The (N, Lp) prediction matrix never leaves VMEM (paper §3.4).
    """
    N, L = Y.shape
    Lp, k = idx.shape
    bj, bn = (max(8, min(block[0], Lp)), max(8, min(block[1], N)))
    gj, gn = pl.cdiv(Lp, bj), pl.cdiv(N, bn)
    Lpad = L + bj + 1  # truth-row slice of the last ragged tile must not clamp
    yT = jnp.pad(Y.astype(jnp.float32).T, ((0, Lpad - L), (0, 0)))
    stats = pl.pallas_call(
        functools.partial(_kernel_rho, k=k, bj=bj, bn=bn, offset=offset, Lp=Lp),
        grid=(gn, gj),  # j innermost: stats block revisited across j
        in_specs=[
            pl.BlockSpec((Lpad, bn), lambda n, j: (0, n)),
            pl.BlockSpec((bj, k), lambda n, j: (j, 0)),
            pl.BlockSpec((bj, k), lambda n, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((8, bn), lambda n, j: (0, n)),
        out_shape=jax.ShapeDtypeStruct((8, N), jnp.float32),
        interpret=interpret,
    )(yT, _sanitize_idx(idx, L - 1 - offset), w.astype(jnp.float32))
    M2a, M2b, C = stats[3], stats[4], stats[5]
    denom = jnp.sqrt(M2a * M2b)
    return jnp.where(denom > 0, C / jnp.maximum(denom, 1e-30), 0.0)
