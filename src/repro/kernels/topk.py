"""Pallas TPU kernels: small-k partial sort of a distance matrix.

The paper's Algorithm 2 (kEDM §3.3.2) uses per-thread priority queues in
GPU shared memory, merged by a team leader — and reports the queues' scratch
footprint degrading occupancy as E (hence k = E+1) grows.

Priority queues are branch-hostile on the TPU VPU, so the TPU-idiomatic
equivalent (DESIGN.md §2) is **k-pass vectorized extraction**: each grid
cell holds a (br, Lp) row block in VMEM and performs k passes of
(min, first-argmin, mask) — every pass is a full-width lane reduction, no
data-dependent control flow. k ≤ 32 in EDM (k = E+1, E ≤ 20), so the
k·Lp read traffic stays within a small constant of the queue approach
while vectorizing perfectly.

Emits Euclidean distances (sqrt — the "normalize D_k" step of Alg. 2) and
int32 indices, both sorted ascending. Self-exclusion (leave-one-out) and a
dynamic ``max_idx`` candidate cap (library-size sweeps, Tp validity) are
fused into the masking pass.

``topk_select_sizes`` is the multi-cap variant behind CCM convergence
sweeps: ONE column-tiled pass over the distance matrix emits the k-best
table under every prefix library cap, instead of S full-matrix re-scans.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import PAD_IDX, check_sizes_caps

_BIG_I = 2**30  # python int: jnp constants must not be captured by kernels


def _kernel(mx_ref, d_ref, dk_ref, ik_ref, *, k: int, br: int, Lp: int,
            exclude_self: bool):
    i0 = pl.program_id(0) * br
    d = d_ref[...]  # (br, Lcols)
    cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    max_idx = mx_ref[0, 0]
    invalid = (cols >= Lp) | (cols > max_idx)
    if exclude_self:
        rows = i0 + jax.lax.broadcasted_iota(jnp.int32, d.shape, 0)
        invalid = invalid | (cols == rows)
    d = jnp.where(invalid, jnp.inf, d)
    dists, idxs = [], []
    for _ in range(k):
        m = jnp.min(d, axis=1, keepdims=True)  # (br, 1)
        cand = jnp.where(d == m, cols, _BIG_I)
        idx = jnp.min(cand, axis=1, keepdims=True)  # first argmin: stable ties
        dists.append(m)
        idxs.append(idx)
        d = jnp.where(cols == idx, jnp.inf, d)
    dk_ref[...] = jnp.sqrt(jnp.maximum(jnp.concatenate(dists, axis=1), 0.0))
    ik_ref[...] = jnp.concatenate(idxs, axis=1)


@functools.partial(
    jax.jit, static_argnames=("k", "exclude_self", "block_rows", "interpret")
)
def topk_select(
    D: jax.Array,
    *,
    k: int,
    exclude_self: bool = True,
    max_idx: jax.Array | int | None = None,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """k smallest per row of a squared-distance matrix → (dists, idx).

    dists: (Lp, k) f32 Euclidean, ascending. idx: (Lp, k) int32.
    ``max_idx`` is dynamic (no re-lowering across library-size sweeps).
    """
    Lp = D.shape[0]
    br = max(1, min(block_rows, Lp))
    mx = jnp.full((1, 1), Lp - 1 if max_idx is None else max_idx, jnp.int32)
    dk, ik = pl.pallas_call(
        functools.partial(_kernel, k=k, br=br, Lp=Lp, exclude_self=exclude_self),
        grid=(pl.cdiv(Lp, br),),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # dynamic candidate cap
            pl.BlockSpec((br, Lp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Lp, k), jnp.float32),
            jax.ShapeDtypeStruct((Lp, k), jnp.int32),
        ],
        interpret=interpret,
    )(mx, D)
    return dk, ik


def _merge_kbest(cand_d, cand_i, k):
    """k passes of (min, min-global-index-on-ties, retire-by-index).

    Identical discipline to ``knn_multi_e``'s streaming merge: selecting
    the minimum *global index* among distance ties makes the streamed
    result bit-identical to a stable full-row partial sort for any
    column tiling, and retiring the winner by index (clearing both
    arrays) keeps rows with < k valid candidates from re-emitting one.
    """
    best_d, best_i = [], []
    for _ in range(k):
        m = jnp.min(cand_d, axis=1, keepdims=True)
        sel = jnp.where(cand_d == m, cand_i, _BIG_I)
        bi = jnp.min(sel, axis=1, keepdims=True)
        best_d.append(m)
        best_i.append(bi)
        removed = cand_i == bi
        cand_d = jnp.where(removed, jnp.inf, cand_d)
        cand_i = jnp.where(removed, _BIG_I, cand_i)
    return jnp.concatenate(best_d, axis=1), jnp.concatenate(best_i, axis=1)


def _sizes_kernel(d_ref, dk_ref, ik_ref, run_d, run_i, *, k, caps, br, bc,
                  Lp, exclude_self):
    i0 = pl.program_id(0) * br
    j = pl.program_id(1)
    j0 = j * bc

    @pl.when(j == 0)
    def _init():  # running k-best scratch + snapshot outputs
        run_d[...] = jnp.full((br, k), jnp.inf, jnp.float32)
        run_i[...] = jnp.full((br, k), _BIG_I, jnp.int32)
        dk_ref[...] = jnp.full((len(caps), br, k), jnp.inf, jnp.float32)
        ik_ref[...] = jnp.full((len(caps), br, k), _BIG_I, jnp.int32)

    d = d_ref[...]  # (br, bc)
    rows = i0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
    cols = j0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    invalid = cols >= Lp
    if exclude_self:
        invalid = invalid | (cols == rows)
    # Snapshots BEFORE the main merge: level s's table is the running
    # k-best over columns [0, caps[s]], so it merges the pre-block state
    # with only this block's columns ≤ caps[s]. Caps are static — each
    # level's snapshot column block is known at trace time, so each cap
    # costs one extra merge at exactly one column step.
    for s, m in enumerate(caps):
        sb = min(m, Lp - 1) // bc  # the column block holding cap s

        @pl.when(j == sb)
        def _snapshot(s=s, m=m):
            snap = jnp.where(invalid | (cols > m), jnp.inf, d)
            cand_d = jnp.concatenate([snap, run_d[...]], axis=1)
            cand_i = jnp.concatenate([cols, run_i[...]], axis=1)
            bd, bi = _merge_kbest(cand_d, cand_i, k)
            dk_ref[s] = jnp.sqrt(jnp.maximum(bd, 0.0))
            ik_ref[s] = bi
    # Main stream: fold the full block (masked to the global cap) into
    # the running k-best reused by every later snapshot.
    cand_d = jnp.concatenate(
        [jnp.where(invalid | (cols > caps[-1]), jnp.inf, d), run_d[...]],
        axis=1)
    cand_i = jnp.concatenate([cols, run_i[...]], axis=1)
    run_d[...], run_i[...] = _merge_kbest(cand_d, cand_i, k)


@functools.partial(
    jax.jit,
    static_argnames=("k", "max_idxs", "exclude_self", "block", "interpret"))
def topk_select_sizes(
    D: jax.Array,
    *,
    k: int,
    max_idxs: tuple[int, ...],
    exclude_self: bool = True,
    block: tuple[int, int] = (8, 512),
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """k smallest per row under every prefix cap in one pass → (S, Lp, k).

    Column-tiled streaming variant of ``ref.topk_select_sizes`` (same
    semantics: ascending inclusive caps, dist=inf / idx=PAD_IDX in slots
    with no valid candidate). The grid is (row blocks, column blocks)
    with the column axis minor (sequential on TPU); the running k-best
    lives in VMEM scratch and is reused incrementally across caps — one
    merge per column block plus one snapshot merge per cap, never a
    re-scan of earlier columns. Columns past the largest cap are not
    even loaded (the column grid stops at it).
    """
    Lp = D.shape[0]
    caps = check_sizes_caps(max_idxs)
    S = len(caps)
    br = max(1, min(block[0], Lp))
    bc = max(k, min(block[1], Lp))
    gi = pl.cdiv(Lp, br)
    gj = pl.cdiv(min(Lp, caps[-1] + 1), bc)
    dk, ik = pl.pallas_call(
        functools.partial(_sizes_kernel, k=k, caps=caps, br=br, bc=bc,
                          Lp=Lp, exclude_self=exclude_self),
        grid=(gi, gj),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((S, br, k), lambda i, j: (0, i, 0)),
            pl.BlockSpec((S, br, k), lambda i, j: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, Lp, k), jnp.float32),
            jax.ShapeDtypeStruct((S, Lp, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((br, k), jnp.float32),
            pltpu.VMEM((br, k), jnp.int32),
        ],
        interpret=interpret,
    )(D)
    ok = jnp.isfinite(dk)
    return (jnp.where(ok, dk, jnp.inf),
            jnp.where(ok, ik, jnp.int32(PAD_IDX)))
