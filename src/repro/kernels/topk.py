"""Pallas TPU kernel: small-k partial sort of a distance matrix.

The paper's Algorithm 2 (kEDM §3.3.2) uses per-thread priority queues in
GPU shared memory, merged by a team leader — and reports the queues' scratch
footprint degrading occupancy as E (hence k = E+1) grows.

Priority queues are branch-hostile on the TPU VPU, so the TPU-idiomatic
equivalent (DESIGN.md §2) is **k-pass vectorized extraction**: each grid
cell holds a (br, Lp) row block in VMEM and performs k passes of
(min, first-argmin, mask) — every pass is a full-width lane reduction, no
data-dependent control flow. k ≤ 32 in EDM (k = E+1, E ≤ 20), so the
k·Lp read traffic stays within a small constant of the queue approach
while vectorizing perfectly.

Emits Euclidean distances (sqrt — the "normalize D_k" step of Alg. 2) and
int32 indices, both sorted ascending. Self-exclusion (leave-one-out) and a
dynamic ``max_idx`` candidate cap (library-size sweeps, Tp validity) are
fused into the masking pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG_I = 2**30  # python int: jnp constants must not be captured by kernels


def _kernel(mx_ref, d_ref, dk_ref, ik_ref, *, k: int, br: int, Lp: int,
            exclude_self: bool):
    i0 = pl.program_id(0) * br
    d = d_ref[...]  # (br, Lcols)
    cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    max_idx = mx_ref[0, 0]
    invalid = (cols >= Lp) | (cols > max_idx)
    if exclude_self:
        rows = i0 + jax.lax.broadcasted_iota(jnp.int32, d.shape, 0)
        invalid = invalid | (cols == rows)
    d = jnp.where(invalid, jnp.inf, d)
    dists, idxs = [], []
    for _ in range(k):
        m = jnp.min(d, axis=1, keepdims=True)  # (br, 1)
        cand = jnp.where(d == m, cols, _BIG_I)
        idx = jnp.min(cand, axis=1, keepdims=True)  # first argmin: stable ties
        dists.append(m)
        idxs.append(idx)
        d = jnp.where(cols == idx, jnp.inf, d)
    dk_ref[...] = jnp.sqrt(jnp.maximum(jnp.concatenate(dists, axis=1), 0.0))
    ik_ref[...] = jnp.concatenate(idxs, axis=1)


@functools.partial(
    jax.jit, static_argnames=("k", "exclude_self", "block_rows", "interpret")
)
def topk_select(
    D: jax.Array,
    *,
    k: int,
    exclude_self: bool = True,
    max_idx: jax.Array | int | None = None,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """k smallest per row of a squared-distance matrix → (dists, idx).

    dists: (Lp, k) f32 Euclidean, ascending. idx: (Lp, k) int32.
    ``max_idx`` is dynamic (no re-lowering across library-size sweeps).
    """
    Lp = D.shape[0]
    br = max(1, min(block_rows, Lp))
    mx = jnp.full((1, 1), Lp - 1 if max_idx is None else max_idx, jnp.int32)
    dk, ik = pl.pallas_call(
        functools.partial(_kernel, k=k, br=br, Lp=Lp, exclude_self=exclude_self),
        grid=(pl.cdiv(Lp, br),),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # dynamic candidate cap
            pl.BlockSpec((br, Lp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Lp, k), jnp.float32),
            jax.ShapeDtypeStruct((Lp, k), jnp.int32),
        ],
        interpret=interpret,
    )(mx, D)
    return dk, ik
