"""AdamW from scratch, plus an 8-bit block-quantized variant.

The 8-bit optimizer (bitsandbytes/DeepSpeed-style: per-256-block absmax
int8 moments with an fp32 scale) is the distributed-optimization trick
that makes the llama4-maverick-400b train state fit 16 GB/chip on the
single-pod mesh: (2 + 1 + 1 + ε) bytes/param instead of (4 + 4 + 4)
(DESIGN.md §4). Moments are dequantized, updated, and requantized each
step; the quantization error is bounded by the blockwise absmax.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BLOCK = 256


# ------------------------------------------------------- int8 block codec
#
# Linear absmax codes are fine for gradients but catastrophic for Adam's
# second moment: v spans orders of magnitude within a block, small entries
# round to zero and 1/sqrt(v) explodes. Like bitsandbytes' dynamic maps we
# use nonlinear codes: signed-sqrt for m (resolution near 0) and a quartic
# map for v (positive, heavy dynamic range).
#
# Layout matters under SPMD: flattening a leaf to (blocks, 256) destroys
# its sharding — the dequantized moments then materialize REPLICATED
# (measured 515 GB/device for llama4-maverick's stacked expert banks). The
# optimizer therefore quantizes along the LAST axis only: q keeps the
# parameter's shape (int8) and scale has shape (..., last/256), so both
# inherit the parameter's PartitionSpec. Leaves whose last dim doesn't
# block (biases, norms — a negligible fraction of parameters) keep fp32
# moments. The flat (blocks, 256) codec below remains for the gradient
# wire-compression path, where the payload is transient.


def _encode(y, kind):
    if kind == "lin":
        return jnp.round(127.0 * y)
    if kind == "sq":  # signed sqrt: fine resolution near zero
        return jnp.round(127.0 * jnp.sign(y) * jnp.sqrt(jnp.abs(y)))
    if kind == "q4":  # quartic: positive values, wide dynamic range
        return jnp.round(127.0 * jnp.abs(y) ** 0.25)
    raise ValueError(kind)


def _decode(y, kind):
    if kind == "sq":
        return jnp.sign(y) * y * y
    if kind == "q4":
        return y**4
    return y


def q8_eligible(p) -> bool:
    return p.ndim >= 1 and p.shape[-1] % BLOCK == 0 and p.size >= 65536


def _quantize(x: jax.Array, kind: str = "lin") -> dict:
    """Sharding-preserving last-axis block codec (optimizer moments).
    Math runs in x.dtype (bf16 at 400B scale: fp32 codec transients were
    the dominant HBM term)."""
    *lead, last = x.shape
    b = x.reshape(*lead, last // BLOCK, BLOCK)
    amax = jnp.max(jnp.abs(b), axis=-1, keepdims=True)
    y = b / jnp.maximum(amax, jnp.asarray(1e-30, x.dtype))
    q = _encode(y, kind).astype(jnp.int8).reshape(x.shape)
    return {"q": q, "scale": amax[..., 0].astype(jnp.float32)}


def _dequantize(enc: dict, shape, size=None, kind: str = "lin",
                dtype=jnp.float32) -> jax.Array:
    *lead, last = shape
    y = enc["q"].astype(dtype).reshape(*lead, last // BLOCK, BLOCK)
    y = _decode(y / jnp.asarray(127.0, dtype), kind) \
        * enc["scale"][..., None].astype(dtype)
    return y.reshape(shape)


def _quantize_flat(x: jax.Array, kind: str = "lin") -> dict:
    """Flat (blocks, 256) codec — wire compression only (transient)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    y = blocks / jnp.maximum(amax, 1e-30)
    return {"q": _encode(y, kind).astype(jnp.int8),
            "scale": amax.astype(jnp.float32)}


def _dequantize_flat(enc: dict, shape, size, kind: str = "lin") -> jax.Array:
    y = _decode(enc["q"].astype(jnp.float32) / 127.0, kind)
    return (y * enc["scale"]).reshape(-1)[:size].reshape(shape)


# --------------------------------------------------------------- AdamW


def adamw_init(params, *, bits8: bool = False):
    def zero_like(kind):
        def f(p):
            z = jnp.zeros(p.shape, jnp.float32)
            return _quantize(z, kind) if (bits8 and q8_eligible(p)) else z
        return f

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zero_like("sq"), params),
        "v": jax.tree.map(zero_like("q4"), params),
    }


def adamw_update(
    grads,
    state,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    bits8: bool = False,
):
    """One AdamW step. Returns (new_params, new_state). ``lr`` may be a
    traced scalar (schedules)."""
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        leaf8 = bits8 and isinstance(m, dict)
        # bf16-param leaves (maverick) do the moment math in bf16: the
        # moments round-trip through int8 codes anyway, and fp32
        # intermediates were the dominant HBM transient at 400B scale
        # (several 2 GB copies per expert leaf). fp32-master models keep
        # fp32 moment math.
        ct = jnp.bfloat16 if (leaf8 and p.dtype == jnp.bfloat16) \
            else jnp.float32
        g32 = g.astype(ct)
        if leaf8:
            m_f = _dequantize(m, g.shape, kind="sq", dtype=ct)
            v_f = _dequantize(v, g.shape, kind="q4", dtype=ct)
        else:
            m_f, v_f = m, v
        m_f = (b1 * m_f + (1 - b1) * g32).astype(ct)
        v_f = (b2 * v_f + (1 - b2) * g32 * g32).astype(ct)
        upd = (m_f / c1.astype(ct)) / (jnp.sqrt(v_f / c2.astype(ct)) + eps)
        p32 = p.astype(ct)
        new_p = (p32 - jnp.asarray(lr, ct) * (upd + weight_decay * p32)).astype(
            p.dtype)
        if leaf8:
            return new_p, _quantize(m_f, "sq"), _quantize(v_f, "q4")
        return new_p, m_f, v_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    # Sequence leaf updates: nothing data-depends between leaves, so the
    # scheduler happily interleaves several multi-GB dequant/requant
    # chains; the barrier chain bounds live transients to one leaf.
    out = []
    token = None
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if token is not None and p.size > (1 << 24):
            g, _ = jax.lax.optimization_barrier((g, token))
        res = leaf(p, g, m, v)
        out.append(res)
        token = res[0]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "step": step,
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
    }
    return new_params, new_state


def make_optimizer(train_cfg):
    """(init_fn, update_fn) pair from a TrainConfig."""
    bits8 = train_cfg.optimizer == "adamw8bit"
    init = functools.partial(adamw_init, bits8=bits8)
    update = functools.partial(
        adamw_update, b1=train_cfg.b1, b2=train_cfg.b2, eps=train_cfg.eps,
        weight_decay=train_cfg.weight_decay, bits8=bits8,
    )
    return init, update
