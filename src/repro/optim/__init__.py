"""Optimizer substrate: AdamW (fp32 + 8-bit block-quantized moments),
schedules, clipping, microbatch accumulation."""

from repro.optim.adamw import adamw_init, adamw_update, make_optimizer
from repro.optim.grad_utils import (
    accumulate_microbatches,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedule import constant, warmup_cosine

__all__ = [
    "adamw_init", "adamw_update", "make_optimizer",
    "accumulate_microbatches", "clip_by_global_norm", "global_norm",
    "constant", "warmup_cosine",
]
