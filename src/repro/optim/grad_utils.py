"""Gradient utilities: global-norm clipping, microbatch accumulation."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


def accumulate_microbatches(loss_fn, params, batch, n_micro: int,
                            constrain=None, constrain_grads=None):
    """Mean loss/grads over n_micro sequential microbatches (scan).

    batch leaves must have a leading global-batch axis divisible by
    n_micro. Peak activation memory drops ~n_micro×; HLO FLOPs unchanged.

    ``constrain``: sharding-constraint fn applied per microbatch;
    ``constrain_grads``: sharding-constraint fn applied to the gradient
    carry — GSPMD otherwise replicates batch activations and gradient
    accumulators inside the scan (measured: 4.2 GB/device logits at
    llama3 train_4k; 64 GB/device full-expert grad buffers at
    llama4-maverick). Accumulation dtype follows the parameter dtype
    (f32 masters → f32 accumulation; bf16 params (maverick) accumulate
    in bf16 — 8 addends, ≲1 ulp effect, halves accumulator HBM).
    """
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def split(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

    micro = jax.tree.map(split, batch)
    ident = lambda t: t
    cg = constrain_grads or ident

    def body(carry, mb):
        if constrain is not None:
            mb = constrain(mb)
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        acc_loss, acc_grads = carry
        new_grads = cg(jax.tree.map(
            lambda a, g: a + (g / n_micro).astype(a.dtype), acc_grads, grads))
        return (acc_loss + loss / n_micro, new_grads), aux

    zero_g = cg(jax.tree.map(
        lambda p: jnp.zeros(p.shape, p.dtype), params))
    (loss, grads), auxs = jax.lax.scan(body, (jnp.zeros(()), zero_g), micro)
    aux = jax.tree.map(lambda a: a[-1], auxs)
    return (loss, aux), grads
