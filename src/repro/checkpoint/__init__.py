"""Checkpoint substrate: atomic save/restore, retention, elastic reshard."""

from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
