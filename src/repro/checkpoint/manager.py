"""Checkpointing: atomic save/restore of pytrees with elastic resharding.

Design goals for 1000+-node runs (DESIGN.md §4):
  * atomic: write to ``step_XXXX.tmp`` then rename — a preempted writer
    never corrupts the latest checkpoint;
  * auto-resume: ``latest_step()`` + ``restore()`` make restart-loops
    trivial (the training loop calls them unconditionally);
  * retention: keep the last K checkpoints;
  * elastic: arrays are stored *unsharded* (np.save per leaf) with the
    tree structure in a manifest, so a restart may load onto a different
    mesh — ``restore(shardings=...)`` device_puts each leaf with the new
    sharding. On a real multi-host pod each host would write its
    addressable shards; the manifest format already records per-leaf
    shapes/dtypes to support that extension.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = os.path.abspath(directory)
        self.keep = keep
        os.makedirs(self.dir, exist_ok=True)

    # ------------------------------------------------------------- paths

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self):
        s = self.steps()
        return s[-1] if s else None

    # -------------------------------------------------------------- save

    def save(self, step: int, state) -> str:
        leaves, treedef = _flatten(state)
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                    "step": step, "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._retain()
        return final

    def _retain(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ----------------------------------------------------------- restore

    def restore(self, like, *, step: int | None = None, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: optional matching pytree of
        jax.sharding.Sharding for elastic placement onto a new mesh."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        leaves, treedef = _flatten(like)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise ValueError(
                f"checkpoint step {step} has an unreadable manifest "
                f"({os.path.join(d, 'manifest.json')}): {e}") from e
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"target structure has {len(leaves)}")
        if len(manifest.get("leaves", ())) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint step {step} manifest is corrupt: "
                f"{len(manifest.get('leaves', ()))} leaf records for "
                f"{manifest['n_leaves']} leaves")
        shard_leaves = (treedef.flatten_up_to(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
            path = os.path.join(d, f"leaf_{i:05d}.npy")
            try:
                arr = np.load(path)
            except Exception as e:
                raise ValueError(
                    f"checkpoint step {step} leaf {i} is unreadable "
                    f"({path}): {e} — the checkpoint is corrupt; delete "
                    f"the step directory and resume from an earlier one"
                ) from e
            # The manifest recorded each leaf's shape/dtype at save time;
            # a leaf that no longer matches it was truncated or swapped
            # after the atomic publish — fail HERE with the leaf named,
            # not deep inside the consumer as a cryptic numpy error.
            meta = manifest["leaves"][i]
            if (list(arr.shape) != list(meta["shape"])
                    or str(arr.dtype) != meta["dtype"]):
                raise ValueError(
                    f"checkpoint step {step} leaf {i} ({path}) does not "
                    f"match its manifest: loaded {arr.dtype}{arr.shape}, "
                    f"manifest says {meta['dtype']}{tuple(meta['shape'])} "
                    f"— the checkpoint is corrupt")
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != {ref.shape}")
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
        return treedef.unflatten(out)
