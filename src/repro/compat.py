"""jax version-compatibility shims.

The framework targets the current public APIs (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); the container's
jax 0.4.x still has shard_map under ``jax.experimental`` (with the older
``check_rep`` spelling) and no mesh axis_types. Every mesh/shard_map call
site goes through these two helpers so the whole repo degrades together.
"""

from __future__ import annotations

import jax

try:  # jax ≥ 0.6-style public API
    _new_shard_map = jax.shard_map
    _old_shard_map = None
except AttributeError:  # jax 0.4.x
    _new_shard_map = None
    from jax.experimental.shard_map import shard_map as _old_shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` across jax versions (check_vma ↔ check_rep)."""
    if _new_shard_map is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)


def axis_size(axis_name):
    """``jax.lax.axis_size`` across jax versions (psum(1) on 0.4.x)."""
    try:
        return jax.lax.axis_size(axis_name)
    except AttributeError:
        return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across jax versions (axis_types only where it
    exists — everything here uses Auto axes, the 0.4.x default)."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)
