"""Shared schemas for the repo's machine-readable artifacts.

Two artifact families flow out of runs and benches:

* telemetry JSONL event logs (``run_dir/telemetry/events.jsonl``) —
  one JSON object per line, ``type`` either ``"span"`` or ``"event"``;
* bench snapshots (``BENCH_*.json``) — committed pairs/s guards and CI
  smoke outputs.

CI validates both after every smoke run (``python -m
repro.telemetry.schema <files...>``) so a malformed artifact fails the
build instead of silently corrupting the committed baselines or the run
inspector's view. Validators are hand-rolled — the schema is small and
the repo takes no dependency on jsonschema.
"""

from __future__ import annotations

import json
import sys

EVENT_TYPES = ("span", "event")


def _fail(msg: str, obj=None) -> str:
    if obj is not None:
        msg = f"{msg}: {json.dumps(obj)[:200]}"
    return msg


def validate_event(ev) -> list[str]:
    """Violations in one telemetry JSONL record ([] when valid)."""
    errs = []
    if not isinstance(ev, dict):
        return [_fail("record is not an object", ev)]
    t = ev.get("type")
    if t not in EVENT_TYPES:
        errs.append(_fail(f"type must be one of {EVENT_TYPES}", ev))
    if not isinstance(ev.get("name"), str) or not ev.get("name"):
        errs.append(_fail("name must be a non-empty string", ev))
    if not isinstance(ev.get("ts"), (int, float)):
        errs.append(_fail("ts must be a number", ev))
    if t == "span":
        dur = ev.get("dur_s")
        if not isinstance(dur, (int, float)) or dur < 0:
            errs.append(_fail("span dur_s must be a number >= 0", ev))
        if not isinstance(ev.get("path"), str):
            errs.append(_fail("span path must be a string", ev))
    if "attrs" in ev and not isinstance(ev["attrs"], dict):
        errs.append(_fail("attrs must be an object", ev))
    return errs


def validate_events_file(path: str) -> list[str]:
    errs = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"{path}:{i}: invalid JSON ({e})")
                continue
            errs += [f"{path}:{i}: {m}" for m in validate_event(ev)]
    return errs


def validate_bench(doc) -> list[str]:
    """Violations in one BENCH_*.json snapshot ([] when valid)."""
    errs = []
    if not isinstance(doc, dict):
        return [_fail("bench doc is not an object", doc)]
    if not isinstance(doc.get("bench"), str) or not doc.get("bench"):
        errs.append(_fail("bench must be a non-empty string", doc))
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return errs + [_fail("rows must be a non-empty array", doc)]
    for r in rows:
        if not isinstance(r, dict):
            errs.append(_fail("row is not an object", r))
            continue
        if not isinstance(r.get("name"), str) or not r.get("name"):
            errs.append(_fail("row name must be a non-empty string", r))
        us = r.get("us_per_call")
        if not isinstance(us, (int, float)) or us <= 0:
            errs.append(_fail("row us_per_call must be a number > 0", r))
        if "derived" in r and not isinstance(r["derived"], str):
            errs.append(_fail("row derived must be a string", r))
    return errs


def validate_bench_file(path: str) -> list[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    return [f"{path}: {m}" for m in validate_bench(doc)]


def validate_file(path: str) -> list[str]:
    """Dispatch on suffix: ``.jsonl`` → events, ``.json`` → bench."""
    if path.endswith(".jsonl"):
        return validate_events_file(path)
    return validate_bench_file(path)


def main(argv=None) -> int:
    paths = list(sys.argv[1:] if argv is None else argv)
    if not paths:
        print("usage: python -m repro.telemetry.schema <artifact...>",
              file=sys.stderr)
        return 2
    errs = []
    for p in paths:
        errs += validate_file(p)
    for e in errs:
        print(e, file=sys.stderr)
    if not errs:
        print(f"schema OK: {len(paths)} artifact(s)")
    return 1 if errs else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
