"""``repro.telemetry`` — zero-dependency tracing + metrics for the EDM engine.

The paper's headline claim is throughput (pairs/s), and the matrix-scale
workloads this repo targets (whole-brain CCM, 10⁵ series / 10¹⁰ pairs)
cannot be tuned or debugged from scattered one-offs — so every layer,
from the ``EDM`` session facade down to each engine launch, reports
through this one subsystem:

* **Spans** — ``with telemetry.span("engine.drive", Nl=..., B=...):``
  records wall time plus static attributes on a context-var span stack
  (nested spans carry their parent path). Span *emission* is gated by
  ``active()``: with telemetry disabled and no sinks attached (the
  default), ``span()`` returns a shared no-op context manager — the
  disabled fast path costs one attribute read per call site.
* **Counters / gauges / histograms** — a process-local metrics registry
  (``counter("edm_pairs_total")``, ``gauge("edm_batch_libs_effective")``,
  ``histogram("edm_launch_latency_seconds")``). Metric updates are plain
  dict/int operations and are ALWAYS on — they are the supported
  observation API the tests assert against (via ``Recorder`` deltas),
  replacing monkeypatched kernel shims. ``render_prom()`` exports the
  registry in Prometheus text format; journaled matrix runs fold it
  into ``run_dir/report.json``.
* **Sinks** — pluggable event consumers: ``Recorder`` (in-memory, what
  tests use), ``JsonlSink`` (one JSON object per line; journaled runs
  attach one under ``run_dir/telemetry/``), and an optional
  ``jax.profiler.TraceAnnotation`` bridge (``enable_xla_trace()``) so
  spans line up with XLA traces in TensorBoard/Perfetto.

Timing honesty: kernel dispatches (``ops.*``) run at *trace* time inside
jitted programs, where fencing ``block_until_ready`` is impossible — so
ops-level telemetry is counters + attribute events, while *timed* spans
live at the driver level (``drive_batched``, the journaled runner),
where tile landings are real device syncs.

See ``docs/ARCHITECTURE.md`` ("Observability") for the span taxonomy,
the metric name table, and the overhead contract (<2% pairs/s on the
``bench_ccm`` smoke with telemetry enabled, ~0 disabled — CI-guarded).
"""

from __future__ import annotations

import contextlib
import contextvars
import json
import os
import threading
import time

__all__ = [
    "span", "event", "active", "enable", "disable", "enable_xla_trace",
    "counter", "gauge", "histogram", "render_prom", "metrics_snapshot",
    "reset_metrics", "add_sink", "remove_sink", "record",
    "Recorder", "JsonlSink",
]

# --------------------------------------------------------------- state

_enabled = False
_xla_trace = False
_sinks: list = []
_lock = threading.Lock()          # guards sink list mutation + registry
_span_stack: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_telemetry_span_stack", default=())


def enable() -> None:
    """Turn span/event emission on globally (metrics are always on)."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enable_xla_trace(on: bool = True) -> None:
    """Bridge spans to ``jax.profiler.TraceAnnotation`` so they appear
    alongside XLA device traces in TensorBoard/Perfetto. Off by default
    (the annotation costs a TraceMe per span even without a profiler
    session attached)."""
    global _xla_trace
    _xla_trace = on


def active() -> bool:
    """Is span/event emission live (enabled, or any sink attached)?"""
    return _enabled or bool(_sinks)


def add_sink(sink) -> None:
    """Attach an event sink (an object with ``emit(event: dict)``)."""
    with _lock:
        _sinks.append(sink)


def remove_sink(sink) -> None:
    with _lock:
        if sink in _sinks:
            _sinks.remove(sink)


def _emit(ev: dict) -> None:
    for sink in list(_sinks):
        sink.emit(ev)


# --------------------------------------------------------------- spans


class _NoopSpan:
    """Shared do-nothing span: the disabled-by-default fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def annotate(self, **attrs):
        pass


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "path", "attrs", "_ts", "_t0", "_token", "_ta")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def annotate(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. a resolved B)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        stack = _span_stack.get()
        parent = stack[-1].path if stack else ""
        self.path = f"{parent}/{self.name}" if parent else self.name
        self._token = _span_stack.set(stack + (self,))
        self._ta = None
        if _xla_trace:  # pragma: no cover - needs a profiler session
            try:
                from jax.profiler import TraceAnnotation
                self._ta = TraceAnnotation(self.path)
                self._ta.__enter__()
            except Exception:
                self._ta = None
        self._ts = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        dur = time.perf_counter() - self._t0
        if self._ta is not None:  # pragma: no cover
            self._ta.__exit__(*exc)
        _span_stack.reset(self._token)
        ev = {"type": "span", "name": self.name, "path": self.path,
              "ts": self._ts, "dur_s": dur}
        if self.attrs:
            ev["attrs"] = self.attrs
        _emit(ev)
        return False


def span(name: str, **attrs):
    """Context manager timing one named region of work.

    No-op (a shared singleton, no allocation beyond the kwargs dict)
    unless ``active()``. Attributes must be cheap static values — shapes,
    batch sizes, impl names; anything costly to compute should be added
    via ``Span.annotate`` under an ``active()`` guard at the call site.
    """
    if not active():
        return _NOOP
    return _Span(name, attrs)


def current_span_path() -> str:
    """Path of the innermost live span ("" outside any span)."""
    stack = _span_stack.get()
    return stack[-1].path if stack else ""


def event(name: str, **attrs) -> None:
    """Emit one point-in-time event (no duration) to the sinks."""
    if not active():
        return
    ev = {"type": "event", "name": name, "ts": time.time(),
          "path": current_span_path()}
    if attrs:
        ev["attrs"] = attrs
    _emit(ev)


# ------------------------------------------------------------- metrics


class Counter:
    """Monotonic counter (process-local; ``inc`` is a GIL-atomic add)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins value (e.g. the engine's effective batch size)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


#: Log-spaced latency buckets (seconds) covering sub-ms kernel launches
#: through multi-minute sharded chunks.
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Histogram:
    """Fixed-bucket histogram (Prometheus-style cumulative export)."""

    __slots__ = ("name", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, buckets=DEFAULT_BUCKETS):
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


_registry: dict[str, Counter | Gauge | Histogram] = {}


def _metric(name: str, cls, **kw):
    m = _registry.get(name)
    if m is None:
        with _lock:
            m = _registry.get(name)
            if m is None:
                m = _registry[name] = cls(name, **kw)
    if not isinstance(m, cls):
        raise TypeError(
            f"metric {name!r} is already registered as "
            f"{type(m).__name__}, not {cls.__name__}")
    return m


def counter(name: str) -> Counter:
    return _metric(name, Counter)


def gauge(name: str) -> Gauge:
    return _metric(name, Gauge)


def histogram(name: str, buckets=DEFAULT_BUCKETS) -> Histogram:
    return _metric(name, Histogram, buckets=buckets)


def reset_metrics() -> None:
    """Clear the registry (test/bench isolation; not for production)."""
    with _lock:
        _registry.clear()


def _fmt(v) -> str:
    if isinstance(v, int):
        return str(v)
    return format(float(v), ".10g")


def render_prom() -> str:
    """The whole registry in Prometheus text exposition format."""
    lines = []
    for name in sorted(_registry):
        m = _registry[name]
        if isinstance(m, Counter):
            lines += [f"# TYPE {name} counter", f"{name} {_fmt(m.value)}"]
        elif isinstance(m, Gauge):
            lines += [f"# TYPE {name} gauge", f"{name} {_fmt(m.value)}"]
        else:
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for b, c in zip(m.buckets, m.counts):
                cum += c
                lines.append(f'{name}_bucket{{le="{_fmt(b)}"}} {cum}')
            cum += m.counts[-1]
            lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
            lines.append(f"{name}_sum {_fmt(m.sum)}")
            lines.append(f"{name}_count {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def metrics_snapshot() -> dict:
    """JSON-ready snapshot of every registered metric's current value."""
    out = {}
    for name, m in sorted(_registry.items()):
        if isinstance(m, (Counter, Gauge)):
            out[name] = m.value
        else:
            out[name] = {"sum": m.sum, "count": m.count,
                         "buckets": dict(zip(map(_fmt, m.buckets),
                                             m.counts))}
    return out


# --------------------------------------------------------------- sinks


def _jsonable(o):
    try:
        f = float(o)  # np scalars, 0-d arrays
    except (TypeError, ValueError):
        return str(o)
    i = int(f)
    return i if i == f else f


class JsonlSink:
    """Append each event as one JSON line (the run-journal event log)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a")
        self._wlock = threading.Lock()

    def emit(self, ev: dict) -> None:
        line = json.dumps(ev, default=_jsonable)
        with self._wlock:
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._wlock:
            if not self._f.closed:
                self._f.close()


class Recorder:
    """In-memory sink + counter-delta snapshots: the test observation API.

    Captures every span/event emitted while attached, and snapshots the
    counter registry at construction so invocation-count assertions read
    ``counter_delta`` instead of monkeypatching kernel entry points::

        with telemetry.record() as rec:
            sess.optimal_E(); sess.xmap()
        assert rec.counter_delta("edm_knn_master_builds") == 1
    """

    def __init__(self):
        self.events: list[dict] = []
        self._base = {n: m.value for n, m in _registry.items()
                      if isinstance(m, Counter)}

    def emit(self, ev: dict) -> None:
        self.events.append(ev)

    def spans(self, name: str | None = None) -> list[dict]:
        return [e for e in self.events if e["type"] == "span"
                and (name is None or e["name"] == name)]

    def events_named(self, name: str) -> list[dict]:
        return [e for e in self.events if e["type"] == "event"
                and e["name"] == name]

    def counter_delta(self, name: str) -> int | float:
        m = _registry.get(name)
        now = m.value if isinstance(m, Counter) else 0
        return now - self._base.get(name, 0)


@contextlib.contextmanager
def record():
    """Attach a fresh ``Recorder`` for the block (spans become active)."""
    rec = Recorder()
    add_sink(rec)
    try:
        yield rec
    finally:
        remove_sink(rec)
