"""S-Map: locally weighted linear forecasting (beyond-paper, cppEDM parity).

The paper validates kEDM against cppEDM; S-Map is the other core EDM
method there (and the standard EDM nonlinearity test: skill rising with
the locality parameter θ ⇒ state-dependent, nonlinear dynamics — the test
the whole-brain causal-inference workload runs per channel).

The public entry points here are thin wrappers over the batched engine
(``core/smap_engine.py``): every (query row, θ) pair's weighted Gram
matrix is accumulated in one pass (``kernels/smap_gram.py``) and all the
ridge-regularized normal-equations systems are solved by one batched
Cholesky — no host loop over θ or queries. ``smap_predict_seed`` keeps the
seed's per-query ``lstsq`` path as the parity oracle and the benchmark
baseline (``benchmarks/bench_smap.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.embedding import embed_offset, num_embedded, pred_rows
from repro.core.smap_engine import DEFAULT_THETAS, smap_fit, smap_theta_sweep
from repro.kernels import ops
from repro.kernels.ref import delay_embed


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp"))
def smap_predict_seed(
    x: jax.Array, *, E: int, tau: int = 1, Tp: int = 1, theta: float = 0.0
) -> tuple[jax.Array, jax.Array]:
    """Seed S-Map: one lstsq per query row (oracle + benchmark baseline).

    For each query j: weights w_i = exp(-θ d_ij / d̄_j) over all library
    points i (self excluded), then a weighted ridge-free least-squares fit
    ŷ = [1, z_j]·b with b = argmin Σ w_i (y_i − [1, z_i]·b)². Host-
    sequential ``lax.map`` of solves on √w-scaled design-matrix copies —
    kept verbatim so the engine's speedup stays measurable across PRs.
    """
    x = x.astype(jnp.float32)
    L = x.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    Z = delay_embed(x, E, tau)  # (Lp, E)
    y = jax.lax.dynamic_slice_in_dim(x, off, rows, axis=-1)  # truth for rows
    Zlib = Z[:rows]  # library points with a Tp-ahead value
    A = jnp.concatenate([jnp.ones((rows, 1), jnp.float32), Zlib], axis=1)

    D = ops.pairwise_distances(x, E=E, tau=tau, impl="ref")  # (Lp, Lp) sq
    d = jnp.sqrt(jnp.maximum(D[:rows, :rows], 0.0))

    def solve(j):
        dj = d[j]
        dbar = jnp.mean(dj)
        w = jnp.exp(-theta * dj / jnp.maximum(dbar, 1e-30))
        w = w.at[j].set(0.0)  # leave-one-out
        sw = jnp.sqrt(w)[:, None]
        b, *_ = jnp.linalg.lstsq(A * sw, y * sw[:, 0])
        return jnp.dot(A[j], b)

    pred = jax.lax.map(solve, jnp.arange(rows))
    return pred, y


def smap_predict(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    theta: float = 0.0,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Leave-one-out S-Map forecasts. Returns (pred, truth), shape (rows,).

    Engine-backed: one batched Gram accumulation + Cholesky solve instead
    of a per-query lstsq loop (see core/smap_engine.py).
    """
    pred, _ = smap_fit(x, x[None], E=E, tau=tau, Tp=Tp,
                       thetas=(float(theta),), ridge=ridge, impl=impl)
    rows = pred_rows(x.shape[-1], E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    truth = jax.lax.dynamic_slice_in_dim(x.astype(jnp.float32), off, rows,
                                         axis=-1)
    return pred[0, 0], truth


def smap_skill(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    theta: float = 0.0,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> jax.Array:
    pred, truth = smap_predict(x, E=E, tau=tau, Tp=Tp, theta=theta,
                               ridge=ridge, impl=impl)
    return ops.pearson_rows(pred[None, :], truth[None, :])[0]


def nonlinearity_test(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas=DEFAULT_THETAS,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> jax.Array:
    """ρ(θ) curve — increasing skill with θ indicates nonlinear dynamics.

    One jitted engine call for the whole θ grid (the seed re-entered the
    per-query solve loop once per θ).
    """
    return smap_theta_sweep(x[None, :], E=E, tau=tau, Tp=Tp,
                            thetas=tuple(float(t) for t in thetas),
                            ridge=ridge, impl=impl)[0]
