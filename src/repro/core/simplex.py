"""Simplex projection: leave-one-out forecasting and optimal-E search.

EDM step the paper relies on to pick each series' embedding dimension
(kEDM §3.4 groups CCM lookups by the *target's* optimal E, which this
module determines). Forecast skill ρ(E) is evaluated by predicting
``x(t + Tp)`` from the E-dimensional manifold with the point itself
excluded (leave-one-out), as in cppEDM's ``EmbedDimension``.

These are the facade's primitives: prefer ``repro.edm.EDM.optimal_E`` /
``.simplex``, which run the same engine once per panel and cache the
multi-E kNN tables for every later simplex/CCM call on the session.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.embedding import embed_offset, num_embedded, pred_rows
from repro.core.knn import all_knn
from repro.kernels import ops


def simplex_predict(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Leave-one-out Tp-ahead predictions for one series.

    Returns (pred, truth), both shape (Lp - Tp,): pred[j] forecasts the
    value at time j + (E-1)tau + Tp.
    """
    L = x.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    # Neighbors must themselves have a Tp-ahead value inside the series.
    table = all_knn(x, E=E, tau=tau, k=E + 1, exclude_self=True,
                    max_idx=Lp - 1 - Tp, impl=impl)
    w = table.weights[:rows]
    idx = table.idx[:rows]
    pred = ops.lookup(x[None, :], idx, w, offset=off, impl=impl)[0]
    truth = jax.lax.dynamic_slice_in_dim(x, off, rows, axis=-1)
    return pred, truth


def simplex_skill(
    x: jax.Array, *, E: int, tau: int = 1, Tp: int = 1, impl: str = "auto"
) -> jax.Array:
    """Forecast skill ρ for one (series, E)."""
    pred, truth = simplex_predict(x, E=E, tau=tau, Tp=Tp, impl=impl)
    return ops.pearson_rows(pred[None, :], truth[None, :])[0]


def optimal_E_sweep_seed(
    x: jax.Array,
    *,
    E_max: int = 20,
    tau: int = 1,
    Tp: int = 1,
    impl: str = "auto",
) -> jax.Array:
    """ρ(E) via the seed per-E pipeline — kEDM's ``edim`` structure.

    One full pairwise+top-k+lookup per E: O(ΣE·Lp²). Kept as the oracle
    and benchmark baseline for the incremental multi-E engine below.
    """
    return jnp.stack(
        [simplex_skill(x, E=E, tau=tau, Tp=Tp, impl=impl)
         for E in range(1, E_max + 1)]
    )


@functools.partial(jax.jit, static_argnames=("E_max", "tau", "Tp", "impl"))
def rho_curve(
    x: jax.Array,
    *,
    E_max: int = 20,
    tau: int = 1,
    Tp: int = 1,
    impl: str = "auto",
) -> jax.Array:
    """ρ(E) for E = 1..E_max via the incremental multi-E engine — (E_max,).

    One ``all_knn_multi_e`` call replaces the seed's E_max kernel
    pipelines: the distance recurrence D_E = D_{E-1} + one lag term makes
    the whole sweep O(E_max·Lp²). Per-E lookups are cheap static slices
    of the stacked tables.
    """
    L = x.shape[-1]
    # Neighbors must themselves have a Tp-ahead value inside the series.
    mx = tuple(num_embedded(L, E, tau) - 1 - Tp for E in range(1, E_max + 1))
    d, i = ops.all_knn_multi_e(x, E_max=E_max, tau=tau, exclude_self=True,
                               max_idx=mx, impl=impl)
    rhos = []
    for E in range(1, E_max + 1):
        rows = pred_rows(L, E, tau, Tp)
        off = embed_offset(E, tau, Tp)
        w = ops.make_weights(d[E - 1, :rows, :E + 1])
        rhos.append(
            ops.lookup_rho(x[None, :], i[E - 1, :rows, :E + 1], w,
                           offset=off, impl=impl)[0])
    return jnp.stack(rhos)


def optimal_E(
    x: jax.Array,
    *,
    E_max: int = 20,
    tau: int = 1,
    Tp: int = 1,
    impl: str = "auto",
) -> tuple[int, jax.Array]:
    """Sweep E = 1..E_max, return (best E, ρ per E) — one engine call."""
    rhos = rho_curve(x, E_max=E_max, tau=tau, Tp=Tp, impl=impl)
    return int(jnp.argmax(rhos)) + 1, rhos


@functools.partial(jax.jit, static_argnames=("E_max", "tau", "Tp", "impl"))
def _rho_curves(X, *, E_max, tau, Tp, impl):
    # jitted wrapper: an eagerly-dispatched lax.map re-traces per call
    fn = functools.partial(rho_curve, E_max=E_max, tau=tau, Tp=Tp, impl=impl)
    return jax.lax.map(fn, X)  # sequential: bounds peak memory


def optimal_E_batch(
    X: jax.Array,
    *,
    E_max: int = 20,
    tau: int = 1,
    Tp: int = 1,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Per-series optimal E for a (N, L) batch → (E_opt (N,) i32, ρ (N, E_max)).

    One multi-E engine call per series (sequential ``lax.map``: bounds
    peak memory at one series' accumulator), instead of the seed's
    E_max × N kernel pipelines.
    """
    rho = _rho_curves(X, E_max=E_max, tau=tau, Tp=Tp, impl=impl)  # (N, E_max)
    return (jnp.argmax(rho, axis=1) + 1).astype(jnp.int32), rho
