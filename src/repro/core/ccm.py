"""Convergent Cross Mapping (paper §2.1, Fig. 1; the headline workload).

Directionality convention (matches the paper): to ask whether ``target``
causally forces ``lib``, embed the *library* series, find its neighbors,
and cross-map the *target*: high skill ρ(target, target̂ | M_lib) is
evidence that information about ``target`` is encoded in ``lib``'s
dynamics, i.e. "target CCM-causes lib".

``ccm_matrix`` reproduces kEDM's pairwise CCM: one set of neighbor tables
per (library series × distinct optimal-E), batched lookups for all target
series sharing that E (§3.4's grouping), fused Pearson ρ.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import embed_offset, num_embedded, pred_rows
from repro.kernels import ops


def cross_map(
    lib: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    lib_sizes=None,
    exclude_self: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """Cross-map skill of predicting each target from ``lib``'s manifold.

    targets: (N, L) (a 1-D series is promoted). Returns (N,) ρ — or
    (num_sizes, N) when ``lib_sizes`` is given (the *convergence* sweep:
    ρ rising with library size is CCM's causality criterion). Library
    restriction is by prefix, reusing one distance matrix across sizes.
    """
    squeeze = targets.ndim == 1
    if squeeze:
        targets = targets[None, :]
    L = lib.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    k = E + 1
    D = ops.pairwise_distances(lib, E=E, tau=tau, impl=impl)
    hard_max = Lp - 1 - max(Tp, 0)

    def rho_for(max_idx) -> jax.Array:
        d, i = ops.topk_select(D, k=k, exclude_self=exclude_self,
                               max_idx=max_idx, impl=impl)
        w = ops.make_weights(d)
        return ops.lookup_rho(targets, i[:rows], w[:rows], offset=off,
                              impl=impl)

    if lib_sizes is None:
        rho = rho_for(hard_max)
        return rho[0] if squeeze else rho
    curves = jnp.stack(
        [rho_for(jnp.minimum(int(s) - 1, hard_max)) for s in lib_sizes]
    )
    return curves[:, 0] if squeeze else curves


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "impl"))
def ccm_group(
    libs: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    impl: str = "auto",
) -> jax.Array:
    """Batched CCM block: every library × every target at one E → (Nl, Nt) ρ.

    One jitted program drives the whole library axis with a sequential
    ``lax.map`` (one (Lp, Lp) distance matrix in flight — kEDM's
    per-library loop, minus the host round trip per library), replacing
    N_lib separate ``cross_map`` dispatches.
    """
    L = libs.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = Lp - 1 - max(Tp, 0)

    def one_library(x):
        D = ops.pairwise_distances(x, E=E, tau=tau, impl=impl)
        d, i = ops.topk_select(D, k=E + 1, exclude_self=True,
                               max_idx=hard_max, impl=impl)
        w = ops.make_weights(d)
        return ops.lookup_rho(targets, i[:rows], w[:rows], offset=off,
                              impl=impl)

    return jax.lax.map(one_library, libs)


def ccm_matrix(
    X: jax.Array,
    E_opt=None,
    *,
    tau: int = 1,
    Tp: int = 0,
    impl: str = "auto",
) -> np.ndarray:
    """All-pairs CCM skill matrix, shape (N_lib, N_target).

    Entry (l, t) = skill of cross-mapping series t from series l's manifold
    (evidence "t causes l"). Per kEDM §3.4: the library is embedded at each
    *target's* optimal E, targets grouped by E so each E-group is one
    batched launch over the full library axis.

    .. deprecated:: thin wrapper over ``repro.edm.EDM.xmap`` kept for
       compatibility — a session reuses its kNN master tables and E_opt
       across *every* method call instead of per ``ccm_matrix`` call;
       prefer it for anything beyond a one-shot matrix. ``E_opt=None``
       now computes the per-series optimal E through the session cache.
    """
    from repro.edm import EDM, EDMConfig

    X = jnp.asarray(X)
    if E_opt is not None:
        E_opt = np.asarray(E_opt, dtype=np.int32)
        if E_opt.shape != (X.shape[0],):
            raise ValueError(
                f"E_opt must be ({X.shape[0]},), got {E_opt.shape}")
    sess = EDM(X, EDMConfig(tau=tau, Tp_cross=Tp, impl=impl,
                            E_max=int(np.max(E_opt)) if E_opt is not None
                            else 20))
    return sess.xmap(method="simplex", E_opt=E_opt)
