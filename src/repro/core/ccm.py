"""Convergent Cross Mapping (paper §2.1, Fig. 1; the headline workload).

Directionality convention (matches the paper): to ask whether ``target``
causally forces ``lib``, embed the *library* series, find its neighbors,
and cross-map the *target*: high skill ρ(target, target̂ | M_lib) is
evidence that information about ``target`` is encoded in ``lib``'s
dynamics, i.e. "target CCM-causes lib".

``ccm_matrix`` reproduces kEDM's pairwise CCM: one set of neighbor tables
per (library series × distinct optimal-E), batched lookups for all target
series sharing that E (§3.4's grouping), fused Pearson ρ.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import embed_offset, num_embedded, pred_rows
from repro.kernels import ops


def normalize_lib_sizes(lib_sizes, *, Lp: int, Tp: int = 0):
    """Validate a convergence-sweep size list → (caps, inverse map).

    Returns ``(caps, inv)``: ``caps`` is the ascending tuple of *unique*
    inclusive neighbor-index caps (``min(size − 1, Lp − 1 − Tp)``), and
    ``inv`` maps each requested size back to its cap's position, so
    callers compute each distinct cap once and scatter results to the
    caller's order/shape. Sizes must be >= 1 (ValueError otherwise);
    unsorted, duplicate, or oversized (> the Lp − Tp usable library
    points) inputs are accepted for compatibility but draw a single
    ``UserWarning`` naming what was cleaned — they used to be silently
    recomputed per entry (duplicates) or silently clamped (oversized).
    """
    sizes = [int(s) for s in lib_sizes]
    if not sizes:
        raise ValueError("lib_sizes must not be empty")
    bad = [s for s in sizes if s < 1]
    if bad:
        raise ValueError(f"lib_sizes must all be >= 1, got {bad}")
    hard_max = Lp - 1 - max(Tp, 0)
    issues = []
    if any(b < a for a, b in zip(sizes, sizes[1:])):
        issues.append("unsorted (computed on the sorted unique caps)")
    if len(set(sizes)) != len(sizes):
        issues.append("duplicates (each cap computed once)")
    over = [s for s in sizes if s - 1 > hard_max]
    if over:
        issues.append(
            f"sizes {over} exceed the {hard_max + 1} usable library "
            f"points (clamped)")
    if issues:
        warnings.warn(
            f"lib_sizes {tuple(sizes)}: " + "; ".join(issues),
            UserWarning, stacklevel=3)
    caps_all = [min(s - 1, hard_max) for s in sizes]
    caps = tuple(sorted(set(caps_all)))
    inv = np.asarray([caps.index(c) for c in caps_all], np.int32)
    return caps, inv


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "caps",
                                             "exclude_self", "impl"))
def ccm_convergence_caps(lib, targets, *, E, tau, Tp, caps, exclude_self,
                         impl):
    """(|caps|, N) curve grid: one distance pass, one multi-cap top-k.

    The caps-level engine under ``ccm_convergence`` — callers that
    already hold normalized ascending caps (the session's
    ``_ccm_curves``, the sharded convergence blocks) enter here and do
    their own size→cap bookkeeping/warnings via
    ``normalize_lib_sizes``.
    """
    L = lib.shape[-1]
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    D = ops.pairwise_distances(lib, E=E, tau=tau, impl=impl)
    dS, iS = ops.topk_select_sizes(D, k=E + 1, max_idxs=caps,
                                   exclude_self=exclude_self, impl=impl)
    curves = []
    for s in range(len(caps)):  # static, small: unrolled per-cap lookups
        w = ops.make_weights(dS[s])
        curves.append(ops.lookup_rho(targets, iS[s, :rows], w[:rows],
                                     offset=off, impl=impl))
    return jnp.stack(curves)


def ccm_convergence(
    lib: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    lib_sizes,
    exclude_self: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """Full CCM convergence curve grid → (num_sizes, N) ρ, one program.

    The batched replacement for ``cross_map``'s per-size host loop:
    one ``pairwise_distances`` pass and ONE multi-cap streaming top-k
    (``ops.topk_select_sizes``) produce every library-prefix neighbor
    table, then each cap's batched fused-ρ lookup runs inside the same
    jitted program. Bit-identical to the legacy loop (kept as
    ``cross_map_sizes_seed``) — ρ rising with library size is CCM's
    causality criterion, so the curve grid is the unit of work for
    significance testing (``repro.edm.EDM.surrogate_test``).

    ``lib_sizes`` follows the caller's order/shape (duplicates and
    oversized entries are computed once / clamped, with a warning —
    see ``normalize_lib_sizes``).
    """
    if targets.ndim == 1:
        targets = targets[None, :]
    Lp = num_embedded(lib.shape[-1], E, tau)
    caps, inv = normalize_lib_sizes(lib_sizes, Lp=Lp, Tp=Tp)
    curves = ccm_convergence_caps(lib, targets, E=E, tau=tau, Tp=Tp,
                                  caps=caps, exclude_self=exclude_self,
                                  impl=impl)
    return curves[inv]


def cross_map_sizes_seed(
    lib: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    lib_sizes,
    exclude_self: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """The seed per-size convergence loop → (num_sizes, N) ρ.

    One full ``topk_select`` re-scan of the distance matrix per library
    size, dispatched from the host. Kept verbatim as the oracle and
    benchmark baseline for ``ccm_convergence`` (the BENCH_ccm.json
    before/after), exactly like ``smap_predict_seed`` for the S-Map
    engine.
    """
    if targets.ndim == 1:
        targets = targets[None, :]
    L = lib.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = Lp - 1 - max(Tp, 0)
    D = ops.pairwise_distances(lib, E=E, tau=tau, impl=impl)

    def rho_for(max_idx):
        d, i = ops.topk_select(D, k=E + 1, exclude_self=exclude_self,
                               max_idx=max_idx, impl=impl)
        w = ops.make_weights(d)
        return ops.lookup_rho(targets, i[:rows], w[:rows], offset=off,
                              impl=impl)

    return jnp.stack(
        [rho_for(jnp.minimum(int(s) - 1, hard_max)) for s in lib_sizes])


def cross_map(
    lib: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    lib_sizes=None,
    exclude_self: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """Cross-map skill of predicting each target from ``lib``'s manifold.

    targets: (N, L) (a 1-D series is promoted). Returns (N,) ρ — or
    (num_sizes, N) when ``lib_sizes`` is given (the *convergence* sweep:
    ρ rising with library size is CCM's causality criterion, computed by
    ``ccm_convergence``: one distance pass + one multi-cap streaming
    top-k instead of the seed's per-size re-scan loop). ``lib_sizes``
    entries are validated (>= 1), deduplicated, and clamped to the
    usable library with a warning.
    """
    squeeze = targets.ndim == 1
    if squeeze:
        targets = targets[None, :]
    if lib_sizes is not None:
        curves = ccm_convergence(
            lib, targets, E=E, tau=tau, Tp=Tp, lib_sizes=lib_sizes,
            exclude_self=exclude_self, impl=impl)
        return curves[:, 0] if squeeze else curves
    L = lib.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    D = ops.pairwise_distances(lib, E=E, tau=tau, impl=impl)
    d, i = ops.topk_select(D, k=E + 1, exclude_self=exclude_self,
                           max_idx=Lp - 1 - max(Tp, 0), impl=impl)
    w = ops.make_weights(d)
    rho = ops.lookup_rho(targets, i[:rows], w[:rows], offset=off, impl=impl)
    return rho[0] if squeeze else rho


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "impl"))
def ccm_group(
    libs: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    impl: str = "auto",
) -> jax.Array:
    """Batched CCM block: every library × every target at one E → (Nl, Nt) ρ.

    One jitted program drives the whole library axis with a sequential
    ``lax.map`` (one (Lp, Lp) distance matrix in flight — kEDM's
    per-library loop, minus the host round trip per library), replacing
    N_lib separate ``cross_map`` dispatches.
    """
    L = libs.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = Lp - 1 - max(Tp, 0)

    def one_library(x):
        D = ops.pairwise_distances(x, E=E, tau=tau, impl=impl)
        d, i = ops.topk_select(D, k=E + 1, exclude_self=True,
                               max_idx=hard_max, impl=impl)
        w = ops.make_weights(d)
        return ops.lookup_rho(targets, i[:rows], w[:rows], offset=off,
                              impl=impl)

    return jax.lax.map(one_library, libs)


def ccm_matrix(
    X: jax.Array,
    E_opt=None,
    *,
    tau: int = 1,
    Tp: int = 0,
    impl: str = "auto",
) -> np.ndarray:
    """All-pairs CCM skill matrix, shape (N_lib, N_target).

    Entry (l, t) = skill of cross-mapping series t from series l's manifold
    (evidence "t causes l"). Per kEDM §3.4: the library is embedded at each
    *target's* optimal E, targets grouped by E so each E-group is one
    batched launch over the full library axis.

    .. deprecated:: thin wrapper over ``repro.edm.EDM.xmap`` kept for
       compatibility — a session reuses its kNN master tables and E_opt
       across *every* method call instead of per ``ccm_matrix`` call;
       prefer it for anything beyond a one-shot matrix. ``E_opt=None``
       now computes the per-series optimal E through the session cache.
    """
    from repro.edm import EDM, EDMConfig

    X = jnp.asarray(X)
    if E_opt is not None:
        E_opt = np.asarray(E_opt, dtype=np.int32)
        if E_opt.shape != (X.shape[0],):
            raise ValueError(
                f"E_opt must be ({X.shape[0]},), got {E_opt.shape}")
    sess = EDM(X, EDMConfig(tau=tau, Tp_cross=Tp, impl=impl,
                            E_max=int(np.max(E_opt)) if E_opt is not None
                            else 20))
    return sess.xmap(method="simplex", E_opt=E_opt)
