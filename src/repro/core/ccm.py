"""Convergent Cross Mapping (paper §2.1, Fig. 1; the headline workload).

Directionality convention (matches the paper): to ask whether ``target``
causally forces ``lib``, embed the *library* series, find its neighbors,
and cross-map the *target*: high skill ρ(target, target̂ | M_lib) is
evidence that information about ``target`` is encoded in ``lib``'s
dynamics, i.e. "target CCM-causes lib".

``ccm_matrix`` reproduces kEDM's pairwise CCM: one set of neighbor tables
per (library series × distinct optimal-E), batched lookups for all target
series sharing that E (§3.4's grouping), fused Pearson ρ.
"""

from __future__ import annotations

import functools
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core.embedding import embed_offset, num_embedded, pred_rows
from repro.kernels import ops


def normalize_lib_sizes(lib_sizes, *, Lp: int, Tp: int = 0):
    """Validate a convergence-sweep size list → (caps, inverse map).

    Returns ``(caps, inv)``: ``caps`` is the ascending tuple of *unique*
    inclusive neighbor-index caps (``min(size − 1, Lp − 1 − Tp)``), and
    ``inv`` maps each requested size back to its cap's position, so
    callers compute each distinct cap once and scatter results to the
    caller's order/shape. Sizes must be >= 1 (ValueError otherwise);
    unsorted, duplicate, or oversized (> the Lp − Tp usable library
    points) inputs are accepted for compatibility but draw a single
    ``UserWarning`` naming what was cleaned — they used to be silently
    recomputed per entry (duplicates) or silently clamped (oversized).
    """
    sizes = [int(s) for s in lib_sizes]
    if not sizes:
        raise ValueError("lib_sizes must not be empty")
    bad = [s for s in sizes if s < 1]
    if bad:
        raise ValueError(f"lib_sizes must all be >= 1, got {bad}")
    hard_max = Lp - 1 - max(Tp, 0)
    issues = []
    if any(b < a for a, b in zip(sizes, sizes[1:])):
        issues.append("unsorted (computed on the sorted unique caps)")
    if len(set(sizes)) != len(sizes):
        issues.append("duplicates (each cap computed once)")
    over = [s for s in sizes if s - 1 > hard_max]
    if over:
        issues.append(
            f"sizes {over} exceed the {hard_max + 1} usable library "
            f"points (clamped)")
    if issues:
        warnings.warn(
            f"lib_sizes {tuple(sizes)}: " + "; ".join(issues),
            UserWarning, stacklevel=3)
    caps_all = [min(s - 1, hard_max) for s in sizes]
    caps = tuple(sorted(set(caps_all)))
    inv = np.asarray([caps.index(c) for c in caps_all], np.int32)
    return caps, inv


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "caps",
                                             "exclude_self", "impl"))
def ccm_convergence_caps(lib, targets, *, E, tau, Tp, caps, exclude_self,
                         impl):
    """(|caps|, N) curve grid: one distance pass, one multi-cap top-k.

    The caps-level engine under ``ccm_convergence`` — callers that
    already hold normalized ascending caps (the session's
    ``_ccm_curves``, the sharded convergence blocks) enter here and do
    their own size→cap bookkeeping/warnings via
    ``normalize_lib_sizes``.
    """
    L = lib.shape[-1]
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    D = ops.pairwise_distances(lib, E=E, tau=tau, impl=impl)
    dS, iS = ops.topk_select_sizes(D, k=E + 1, max_idxs=caps,
                                   exclude_self=exclude_self, impl=impl)
    curves = []
    for s in range(len(caps)):  # static, small: unrolled per-cap lookups
        w = ops.make_weights(dS[s])
        curves.append(ops.lookup_rho(targets, iS[s, :rows], w[:rows],
                                     offset=off, impl=impl))
    return jnp.stack(curves)


def ccm_convergence(
    lib: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    lib_sizes,
    exclude_self: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """Full CCM convergence curve grid → (num_sizes, N) ρ, one program.

    The batched replacement for ``cross_map``'s per-size host loop:
    one ``pairwise_distances`` pass and ONE multi-cap streaming top-k
    (``ops.topk_select_sizes``) produce every library-prefix neighbor
    table, then each cap's batched fused-ρ lookup runs inside the same
    jitted program. Bit-identical to the legacy loop (kept as
    ``cross_map_sizes_seed``) — ρ rising with library size is CCM's
    causality criterion, so the curve grid is the unit of work for
    significance testing (``repro.edm.EDM.surrogate_test``).

    ``lib_sizes`` follows the caller's order/shape (duplicates and
    oversized entries are computed once / clamped, with a warning —
    see ``normalize_lib_sizes``).
    """
    if targets.ndim == 1:
        targets = targets[None, :]
    Lp = num_embedded(lib.shape[-1], E, tau)
    caps, inv = normalize_lib_sizes(lib_sizes, Lp=Lp, Tp=Tp)
    curves = ccm_convergence_caps(lib, targets, E=E, tau=tau, Tp=Tp,
                                  caps=caps, exclude_self=exclude_self,
                                  impl=impl)
    return curves[inv]


def cross_map_sizes_seed(
    lib: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    lib_sizes,
    exclude_self: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """The seed per-size convergence loop → (num_sizes, N) ρ.

    One full ``topk_select`` re-scan of the distance matrix per library
    size, dispatched from the host. Kept verbatim as the oracle and
    benchmark baseline for ``ccm_convergence`` (the BENCH_ccm.json
    before/after), exactly like ``smap_predict_seed`` for the S-Map
    engine.
    """
    if targets.ndim == 1:
        targets = targets[None, :]
    L = lib.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = Lp - 1 - max(Tp, 0)
    D = ops.pairwise_distances(lib, E=E, tau=tau, impl=impl)

    def rho_for(max_idx):
        d, i = ops.topk_select(D, k=E + 1, exclude_self=exclude_self,
                               max_idx=max_idx, impl=impl)
        w = ops.make_weights(d)
        return ops.lookup_rho(targets, i[:rows], w[:rows], offset=off,
                              impl=impl)

    return jnp.stack(
        [rho_for(jnp.minimum(int(s) - 1, hard_max)) for s in lib_sizes])


def cross_map(
    lib: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    lib_sizes=None,
    exclude_self: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """Cross-map skill of predicting each target from ``lib``'s manifold.

    targets: (N, L) (a 1-D series is promoted). Returns (N,) ρ — or
    (num_sizes, N) when ``lib_sizes`` is given (the *convergence* sweep:
    ρ rising with library size is CCM's causality criterion, computed by
    ``ccm_convergence``: one distance pass + one multi-cap streaming
    top-k instead of the seed's per-size re-scan loop). ``lib_sizes``
    entries are validated (>= 1), deduplicated, and clamped to the
    usable library with a warning.
    """
    squeeze = targets.ndim == 1
    if squeeze:
        targets = targets[None, :]
    if lib_sizes is not None:
        curves = ccm_convergence(
            lib, targets, E=E, tau=tau, Tp=Tp, lib_sizes=lib_sizes,
            exclude_self=exclude_self, impl=impl)
        return curves[:, 0] if squeeze else curves
    L = lib.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    D = ops.pairwise_distances(lib, E=E, tau=tau, impl=impl)
    d, i = ops.topk_select(D, k=E + 1, exclude_self=exclude_self,
                           max_idx=Lp - 1 - max(Tp, 0), impl=impl)
    w = ops.make_weights(d)
    rho = ops.lookup_rho(targets, i[:rows], w[:rows], offset=off, impl=impl)
    return rho[0] if squeeze else rho


#: Default memory budgets (MB) for the library-batched engine's in-flight
#: (B, Lp, Lp) f32 distance stack. The budget counts the primary stack;
#: transient copies (mask apply, top-k candidates) put the true peak at a
#: small multiple of it. Backend-dependent on purpose: an HBM-backed
#: accelerator wants launches big enough to amortize dispatch, while on
#: XLA CPU the stack competes with the last-level cache — the
#: ``bench_ccm --sweep-batch`` curves show pairs/s *falling* once
#: B·Lp²·4 outgrows ~tens of MB (B=48 at Lp=1022 is slower than B=8).
DEFAULT_BATCH_BUDGET_MB = 256
DEFAULT_BATCH_BUDGET_MB_CPU = 32


def _default_budget_mb() -> int:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:  # pragma: no cover - no backend at all
        platform = "cpu"
    return (DEFAULT_BATCH_BUDGET_MB_CPU if platform == "cpu"
            else DEFAULT_BATCH_BUDGET_MB)


def auto_batch_libs(Lp: int, Nl: int, budget_mb: float | None = None, *,
                    per_series_bytes: int | None = None) -> int:
    """Library batch size B with B·Lp² f32 under the memory budget.

    The ISSUE 5 sizing rule: one batched engine launch holds a
    (B, Lp, Lp) squared-distance stack in flight, so B is capped at the
    largest count that keeps it under ``budget_mb`` (default: backend-
    dependent, see ``DEFAULT_BATCH_BUDGET_MB*``), clamped to [1, Nl].
    Under that cap the launches are *equalized* — B = ceil(Nl / nb) for
    the smallest launch count nb the cap allows — because the ragged
    final launch is padded to a full B: a cap of 949 against Nl = 1024
    would otherwise run one full launch plus one padded 75→949 launch,
    wasting almost half the compute (measured: 545k vs 955k pairs/s).
    Short-series panels (tiny Lp) batch large swaths of the library axis
    per launch; long series fall back toward per-series steps.

    Engines whose in-flight footprint is NOT a distance stack (the
    cached-master derivation holds O(Lp·k_master) per series) pass their
    real ``per_series_bytes`` instead of inheriting the 4·Lp² default.
    """
    budget = _default_budget_mb() if budget_mb is None else budget_mb
    per = 4 * Lp * Lp if per_series_bytes is None else max(
        1, int(per_series_bytes))
    Nl = max(Nl, 1)
    cap = max(1, min(Nl, int(budget * 2**20) // per))
    nb = -(-Nl // cap)
    return -(-Nl // nb)


def post_lookup_rho(targets, d, i, *, rows, off, impl):
    """Per-series weights + fused-ρ stage of every batched matrix engine.

    (d, i) are (B, Lp, k) neighbor tables; returns (B, Nt) ρ via a
    ``lax.map`` whose body runs on per-series shapes. This stage is THE
    load-bearing half of the batch-axis bit-parity contract — every
    rounding-sensitive op here must see shapes independent of B — so the
    direct engine (``_group_step``), the cached-master engine
    (``edm.plan._master_group_step``), and the per-shard engine
    (``distributed.sharded_ccm._local_block``) all share this one
    implementation instead of keeping three copies in sync.
    """

    def post(args):
        dB, iB = args
        w = ops.make_weights(dB)
        return ops.lookup_rho(targets, iB[:rows], w[:rows], offset=off,
                              impl=impl)

    return jax.lax.map(post, (d, i))


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "k", "impl"))
def _group_step(libs, targets, *, E, tau, Tp, k, impl):
    """One engine launch: fused distance→top-k→weights→ρ for B libraries.

    The kNN axis is batched through ``ops.all_knn_batch`` (the whole
    point — it hoists the top-k out of any ``lax.map`` body); the
    weights + fused-ρ lookup stay per-series ``lax.map`` sub-steps
    (``post_lookup_rho``) so every rounding-sensitive stage runs on
    per-series shapes, making the result bit-invariant in B (see
    kernels/ref.py).
    """
    L = libs.shape[-1]
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = num_embedded(L, E, tau) - 1 - max(Tp, 0)
    d, i = ops.all_knn_batch(libs, E=E, tau=tau, k=k, exclude_self=True,
                             max_idx=hard_max, impl=impl)
    return post_lookup_rho(targets, d, i, rows=rows, off=off, impl=impl)


def pad_batch(chunk: jax.Array, B: int) -> jax.Array:
    """Pad a ragged final batch to B rows by repeating the last series.

    Real data, so the engine needs no masking; the driver discards the
    padded rows at assembly. Keeping every launch at the same (B, L)
    shape means ONE compiled program serves the whole library axis.
    """
    n = chunk.shape[0]
    if n == B:
        return chunk
    return jnp.concatenate([chunk, jnp.repeat(chunk[-1:], B - n, axis=0)])


def drive_batched(Nl: int, B: int, launch, *, start: int = 0,
                  on_block=None, monitor=None) -> np.ndarray:
    """Double-buffered host loop over ceil((Nl − start)/B) engine launches.

    ``launch(a, b, B)`` dispatches rows [a, b) (padded to B) and returns
    the not-yet-materialized device result. JAX dispatch is async, so
    while the host converts/assembles batch i's block the device is
    already computing batch i+1 — the ROADMAP session-item-(b) overlap.
    At most two batch results are in flight.

    Fault-tolerance hooks (``repro.edm.runner``, all optional and free
    when unused):

    * ``start`` — resume offset: rows [0, start) are assumed already
      assembled elsewhere (a journaled run's committed tiles) and are
      neither dispatched nor written; the returned array's rows below
      ``start`` are uninitialized.
    * ``on_block(a, b, block)`` — called after each block's rows [a, b)
      have materialized on host (``block`` is the (b − a, …) slice), the
      tile-journal commit point. A raise here (preemption checkpoint-
      and-exit) leaves no partially-written tile behind.
    * ``monitor`` — a ``distributed.fault.StragglerMonitor`` timed over
      each loop iteration (dispatch of tile i + landing of tile i−1),
      stamped with the landed tile's row offset. One iteration is ~one
      tile of work whether the engine is async (the land is the device
      wait) or synchronous like the sharded chunk path (the dispatch is
      the compute), so a flagged entry means that tile ran slow relative
      to the run's rolling median — the per-host straggler statistic.
    """
    if start >= Nl:  # resumed run with no tiles left: nothing to drive
        return None
    out = pending = None
    # Always-on per-launch metrics (dict/int ops, no sink required):
    # end-to-end launch latency (dispatch → rows on host), pairs/s
    # numerator, and the launch count. The tile *event* and the drive
    # span are emitted only when a sink is live.
    lat_hist = telemetry.histogram("edm_launch_latency_seconds")
    pairs = telemetry.counter("edm_pairs_total")
    launches = telemetry.counter("edm_launches")

    def land(pending):
        nonlocal out
        (pa, pb), arr, t_disp = pending
        t_land = time.perf_counter()
        block = np.asarray(arr)       # the device sync point
        t_done = time.perf_counter()
        if out is None:
            out = np.empty((Nl,) + block.shape[1:], block.dtype)
        out[pa:pb] = block[: pb - pa]
        lat_hist.observe(t_done - t_disp)
        pairs.inc(int(block[: pb - pa].size))
        if telemetry.active():
            telemetry.event("engine.tile", a=pa, b=pb,
                            latency_s=t_done - t_disp,
                            sync_s=t_done - t_land)
        if on_block is not None:
            on_block(pa, pb, block[: pb - pa])

    with telemetry.span("engine.drive", Nl=Nl, B=B, start=start):
        for a in range(start, Nl, B):
            if monitor is not None:
                monitor.start()
            launches.inc()
            cur = launch(a, min(a + B, Nl), B)
            if pending is not None:
                land(pending)
                if monitor is not None:
                    monitor.stop(pending[0][0])
            pending = ((a, min(a + B, Nl)), cur, time.perf_counter())
        if monitor is not None:
            monitor.start()
        land(pending)
        if monitor is not None:
            monitor.stop(pending[0][0])
    return out


def make_group_launch(libs, targets, *, E, tau, Tp, k, impl):
    """Launch closure of the direct batched engine: ``launch(a, b, B)``.

    Factored out of ``ccm_group_batched`` so the fault-tolerant driver
    (``repro.edm.runner``) can re-drive the SAME engine at a smaller B
    after an OOM backoff — results are bit-invariant in B, so the launch
    closure is the resumable unit, not the whole group call.
    """
    impl_r = ops.resolve_impl(impl)
    group_launches = telemetry.counter("edm_group_launches")

    def launch(a, b, B):
        group_launches.inc()
        return _group_step(pad_batch(libs[a:b], B), targets, E=E, tau=tau,
                           Tp=Tp, k=k, impl=impl_r)

    return launch


def ccm_group_batched(
    libs: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    k: int | None = None,
    impl: str = "auto",
    batch_libs: int | None = None,
    budget_mb: float | None = None,
) -> np.ndarray:
    """Library-batched CCM block → (Nl, Nt) ρ (host ndarray).

    The production all-pairs engine (ISSUE 5): the library axis is cut
    into ceil(Nl/B) batches of B series (``batch_libs``, or
    ``auto_batch_libs``'s memory-budget rule), each batch is ONE jitted
    launch of fused distance→top-k→weights→``lookup_rho`` over
    ``ops.all_knn_batch``, and launches are double-buffered against host
    assembly (``drive_batched``). Results are bit-invariant in B —
    ragged final batches are padded with real data and discarded — with
    the per-series oracle being the B = 1 run; the legacy ``lax.map``
    path (``ccm_group``) agrees exactly on neighbor indices/tie order
    and to ~1 ULP on ρ (bit-equal at most shapes; see kernels/ref.py for
    the XLA-CPU map-body caveat).
    """
    libs = jnp.asarray(libs)
    targets = jnp.asarray(targets)
    if targets.ndim == 1:
        targets = targets[None, :]
    Nl = libs.shape[0]
    Lp = num_embedded(libs.shape[-1], E, tau)
    if Nl == 0:  # empty library axis: empty matrix, like ccm_group
        return np.zeros((0, targets.shape[0]), np.float32)
    B = batch_libs if batch_libs is not None else auto_batch_libs(
        Lp, Nl, budget_mb)
    B = max(1, min(int(B), Nl))
    telemetry.gauge("edm_batch_libs_effective").set(B)
    kk = E + 1 if k is None else int(k)
    launch = make_group_launch(libs, targets, E=E, tau=tau, Tp=Tp, k=kk,
                               impl=impl)
    return drive_batched(Nl, B, launch)


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "impl"))
def ccm_group(
    libs: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    impl: str = "auto",
) -> jax.Array:
    """Per-series CCM block: every library × every target at one E → (Nl, Nt).

    One jitted program drives the whole library axis with a sequential
    ``lax.map`` (one (Lp, Lp) distance matrix in flight — kEDM's
    per-library loop, minus the host round trip per library).

    .. deprecated:: kept as the legacy per-series reference; production
       callers (the session's ``xmap``, ``ccm_matrix``) use
       ``ccm_group_batched``, which batches the kNN axis B series per
       launch. Audit note (ROADMAP lax.map × XLA-CPU-TopK): beyond the
       TopK slowdown, XLA CPU also contracts the distance accumulation
       differently inside this ``lax.map`` body at some shapes (~1 ULP
       vs the identical standalone pipeline, e.g. Lp = 94), so this
       path is index-exact but not universally bit-equal to the engine.
    """
    L = libs.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = Lp - 1 - max(Tp, 0)

    def one_library(x):
        D = ops.pairwise_distances(x, E=E, tau=tau, impl=impl)
        d, i = ops.topk_select(D, k=E + 1, exclude_self=True,
                               max_idx=hard_max, impl=impl)
        w = ops.make_weights(d)
        return ops.lookup_rho(targets, i[:rows], w[:rows], offset=off,
                              impl=impl)

    return jax.lax.map(one_library, libs)


def ccm_matrix(
    X: jax.Array,
    E_opt=None,
    *,
    tau: int = 1,
    Tp: int = 0,
    impl: str = "auto",
) -> np.ndarray:
    """All-pairs CCM skill matrix, shape (N_lib, N_target).

    Entry (l, t) = skill of cross-mapping series t from series l's manifold
    (evidence "t causes l"). Per kEDM §3.4: the library is embedded at each
    *target's* optimal E, targets grouped by E so each E-group is one
    batched launch over the full library axis.

    .. deprecated:: thin wrapper over ``repro.edm.EDM.xmap`` kept for
       compatibility — a session reuses its kNN master tables and E_opt
       across *every* method call instead of per ``ccm_matrix`` call;
       prefer it for anything beyond a one-shot matrix. ``E_opt=None``
       now computes the per-series optimal E through the session cache.
    """
    from repro.edm import EDM, EDMConfig

    X = jnp.asarray(X)
    if E_opt is not None:
        E_opt = np.asarray(E_opt, dtype=np.int32)
        if E_opt.shape != (X.shape[0],):
            raise ValueError(
                f"E_opt must be ({X.shape[0]},), got {E_opt.shape}")
    sess = EDM(X, EDMConfig(tau=tau, Tp_cross=Tp, impl=impl,
                            E_max=int(np.max(E_opt)) if E_opt is not None
                            else 20))
    return sess.xmap(method="simplex", E_opt=E_opt)
