"""Batched S-Map engine: weighted normal equations + one batched Cholesky.

The seed S-Map (``core/smap.py``, kept as ``smap_predict_seed``) ran one
``jnp.linalg.lstsq`` on a √W-scaled copy of the design matrix per (query
row, θ) — a host-sequential ``lax.map`` over rows, re-entered per θ and per
series, on top of a fully materialized (Lp, Lp) distance matrix. This
engine replaces all of it with dense linear algebra over the whole
(rows × |θ| × targets) grid at once:

1. ``ops.smap_gram`` accumulates, for every (query row, θ) pair, the
   weighted Gram matrix G = AᵀWA (shape (E+1, E+1)) and moment vectors
   M = AᵀWy — streamed over library column tiles on the kernel path
   (kernels/smap_gram.py), two matmuls per θ on the ref path.
2. All rows·|θ|·N ridge-regularized systems (G + εI) b = m are solved by
   ONE batched Cholesky + ``cho_solve`` — no host loop over queries, θ, or
   targets anywhere.

Why normal equations (AᵀWA + ridge εI) instead of lstsq on √W-scaled rows
-------------------------------------------------------------------------
The √W-scaled design matrix is a (lib × E+1) *per-query* object: the seed
rebuilt and QR-factorized it rows·|θ| times, and it can never be tiled —
every query touches every library row. The Gram formulation reduces each
query's state to (E+1)² + (E+1) accumulators, which (a) stream over
library tiles with VMEM independent of library size, (b) turn the whole
fit into MXU matmuls, and (c) leave a solve so small it batches trivially.
The price is conditioning: κ(AᵀWA) = κ(√W·A)², so fp32 loses roughly twice
the digits a QR route would. That is acceptable here because E+1 is small
(≤ ~21), the εI Tikhonov term is *relative* — ε scales with tr(G)/(E+1),
the Gram's own magnitude — so near-singular neighborhoods (large θ
collapsing the effective sample, constant series, collinear lags) degrade
to shrinkage instead of NaN, and EDM skill is measured in ρ, where the
engine agrees with a float64 per-query lstsq oracle to ≤1e-4 on every
tested E/τ/Tp/θ grid. For tighter parity enable x64 and feed float64.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import embed_offset, pred_rows
from repro.kernels import ops

#: The classic nonlinearity-test locality grid (cppEDM's PredictNonlinear).
DEFAULT_THETAS = (0.0, 0.1, 0.3, 0.5, 1.0, 2.0, 4.0, 8.0)

_ABS_RIDGE = 1e-20  # floor so an all-zero Gram (no valid weight) stays SPD


def _ridge_solve(G: jax.Array, M: jax.Array, ridge: float) -> jax.Array:
    """Solve (G + εI) b = m for every (row, θ, target) → (rows, T, E+1, N).

    ε = ridge·tr(G)/(E+1) + tiny: relative to the Gram's scale, so the
    regularization strength is invariant to the series' units.
    """
    E1 = G.shape[-1]
    lam = ridge * (jnp.trace(G, axis1=-2, axis2=-1) / E1) + _ABS_RIDGE
    Greg = G + lam[..., None, None] * jnp.eye(E1, dtype=G.dtype)
    c = jnp.linalg.cholesky(Greg)
    return jax.scipy.linalg.cho_solve((c, True), jnp.swapaxes(M, -1, -2))


def _design_rows(x: jax.Array, *, E: int, tau: int, rows: int) -> jax.Array:
    """A = [1 | delay_embed(x)] restricted to the prediction rows."""
    Z = ops.delay_embed(x.astype(jnp.float32), E, tau)[:rows]
    return jnp.concatenate([jnp.ones((rows, 1), jnp.float32), Z], axis=1)


def _fit(x, Y, *, E, tau, Tp, thetas, ridge, exclude_self, impl):
    rows = pred_rows(x.shape[-1], E, tau, Tp)
    G, M = ops.smap_gram(x, Y, E=E, tau=tau, Tp=Tp, thetas=thetas,
                         exclude_self=exclude_self, impl=impl)
    B = _ridge_solve(G, M, ridge)  # (rows, T, E+1, N)
    A = _design_rows(x, E=E, tau=tau, rows=rows)
    pred = jnp.einsum("jp,jtpn->ntj", A, B)  # (N, T, rows)
    coef = jnp.transpose(B, (3, 1, 0, 2))  # (N, T, rows, E+1)
    return pred, coef


@functools.partial(
    jax.jit,
    static_argnames=("E", "tau", "Tp", "thetas", "ridge", "exclude_self",
                     "impl"))
def smap_fit(
    x: jax.Array,
    Y: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas: tuple[float, ...] = DEFAULT_THETAS,
    ridge: float = 1e-6,
    exclude_self: bool = True,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Fit S-Map on ``x``'s manifold, predict the (N, L) panel ``Y``.

    Returns (pred, coef): pred (N, T, rows) leave-one-out forecasts of each
    target at every θ; coef (N, T, rows, E+1) the fitted local coefficients
    — coef[..., 0] is the intercept, coef[..., 1:] the per-row Jacobian
    ∂ŷ(t+Tp)/∂x(t−kτ) used for interaction-strength analysis (Deyle &
    Sugihara's S-Map Jacobian method).
    """
    return _fit(x, Y, E=E, tau=tau, Tp=Tp,
                thetas=tuple(float(t) for t in thetas), ridge=ridge,
                exclude_self=exclude_self, impl=impl)


@functools.partial(
    jax.jit, static_argnames=("E", "tau", "Tp", "thetas", "ridge", "impl"))
def smap_predict_batch(
    X: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas: tuple[float, ...] = DEFAULT_THETAS,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Self-prediction θ-sweep for a (S, L) panel, ONE jitted program.

    Returns (pred (S, T, rows), truth (S, rows)): leave-one-out forecasts
    of every series at every θ in the grid. Sequential ``lax.map`` over the
    series axis bounds peak memory at one series' Gram accumulation; the θ
    axis is fully batched inside the engine (no loop anywhere).
    """
    if X.ndim != 2:
        raise ValueError(f"X must be (S, L), got {X.shape}")
    L = X.shape[-1]
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    thetas = tuple(float(t) for t in thetas)

    def one(x):
        pred, _ = _fit(x, x[None], E=E, tau=tau, Tp=Tp, thetas=thetas,
                       ridge=ridge, exclude_self=True, impl=impl)
        return pred[0]  # (T, rows)

    preds = jax.lax.map(one, X)
    truth = jax.lax.dynamic_slice_in_dim(X.astype(jnp.float32), off, rows,
                                         axis=-1)
    return preds, truth


@functools.partial(
    jax.jit, static_argnames=("E", "tau", "Tp", "thetas", "ridge", "impl"))
def smap_theta_sweep(
    X: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas: tuple[float, ...] = DEFAULT_THETAS,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> jax.Array:
    """ρ(θ) curves for a (S, L) panel → (S, T), one jitted engine call."""
    preds, truth = smap_predict_batch(X, E=E, tau=tau, Tp=Tp, thetas=thetas,
                                      ridge=ridge, impl=impl)
    return ops.pearson_rows(preds, truth[:, None, :])


@functools.partial(
    jax.jit, static_argnames=("E", "tau", "Tp", "thetas", "ridge", "impl"))
def _cross_map_rho(lib, targets, *, E, tau, Tp, thetas, ridge, impl):
    pred, _ = _fit(lib, targets, E=E, tau=tau, Tp=Tp, thetas=thetas,
                   ridge=ridge, exclude_self=True, impl=impl)  # (N, T, rows)
    rows = pred_rows(lib.shape[-1], E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    truth = jax.lax.dynamic_slice_in_dim(targets.astype(jnp.float32), off,
                                         rows, axis=-1)  # (N, rows)
    return ops.pearson_rows(jnp.swapaxes(pred, 0, 1), truth[None])  # (T, N)


def smap_cross_map(
    lib: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    theta: float = 1.0,
    thetas: tuple[float, ...] | None = None,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> jax.Array:
    """S-Map cross-mapping: fit on ``lib``'s manifold, predict the targets.

    The S-Map analog of ``core.ccm.cross_map`` (same directionality
    convention: high ρ(target, target̂ | M_lib) is evidence "target causes
    lib"), with the locality parameter θ exposed — at θ = 0 it degrades to
    a global linear autoregression, so the ρ(θ) *difference* separates
    nonlinear (state-dependent) coupling from shared linear structure.

    targets: (N, L) (a 1-D series is promoted). Returns (N,) ρ at
    ``theta``, or (T, N) when a ``thetas`` grid is given.
    """
    squeeze = targets.ndim == 1
    if squeeze:
        targets = targets[None, :]
    grid = (float(theta),) if thetas is None else tuple(
        float(t) for t in thetas)
    rho = _cross_map_rho(lib, targets, E=E, tau=tau, Tp=Tp, thetas=grid,
                         ridge=ridge, impl=impl)
    if thetas is None:
        rho = rho[0]  # (N,)
    return rho[..., 0] if squeeze else rho


@functools.partial(
    jax.jit, static_argnames=("E", "tau", "Tp", "theta", "ridge", "impl"))
def smap_group(
    libs: jax.Array,
    targets: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    theta: float = 1.0,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> jax.Array:
    """Batched S-Map CCM block: every library × every target → (Nl, Nt) ρ.

    One jitted program drives the whole library axis with a sequential
    ``lax.map`` (one library's Gram accumulation in flight at a time),
    mirroring ``core.ccm.ccm_group``.
    """
    thetas = (float(theta),)

    def one_library(x):
        return _cross_map_rho(x, targets, E=E, tau=tau, Tp=Tp, thetas=thetas,
                              ridge=ridge, impl=impl)[0]  # (Nt,)

    return jax.lax.map(one_library, libs)


def smap_matrix(
    X: jax.Array,
    E_opt=None,
    *,
    tau: int = 1,
    Tp: int = 0,
    theta: float = 1.0,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> np.ndarray:
    """All-pairs S-Map cross-map skill matrix, shape (N_lib, N_target).

    The S-Map-based causality workload beside simplex CCM: entry (l, t) is
    the skill of cross-mapping series t from series l's manifold at
    locality θ. The library is embedded at each *target's* optimal E and
    targets are grouped by E so each E-group costs one batched
    ``smap_group`` launch. ``E_opt`` may be an int (uniform E), a
    per-series (N,) array, or ``None`` to compute the optimal E through
    the session cache.

    .. deprecated:: thin wrapper over
       ``repro.edm.EDM.xmap(method="smap")`` kept for compatibility — a
       session shares E_opt/kNN state across methods; prefer it.
    """
    from repro.edm import EDM, EDMConfig

    X = jnp.asarray(X)
    if E_opt is not None:
        E_opt = np.broadcast_to(np.asarray(E_opt, dtype=np.int32),
                                (X.shape[0],))
    sess = EDM(X, EDMConfig(tau=tau, Tp_cross=Tp, theta=float(theta),
                            ridge=ridge, impl=impl,
                            E_max=int(np.max(E_opt)) if E_opt is not None
                            else 20))
    return sess.xmap(method="smap", E_opt=E_opt)


def smap_jacobian(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    theta: float = 1.0,
    ridge: float = 1e-6,
    impl: str = "auto",
) -> jax.Array:
    """Per-row S-Map Jacobian ∂x̂(t+Tp)/∂x(t−kτ), shape (rows, E).

    The fitted local linear coefficients (intercept dropped) — at large θ
    they track the true state-dependent Jacobian of the dynamics (Deyle &
    Sugihara), the standard EDM interaction-strength estimator.
    """
    _, coef = smap_fit(x, x[None], E=E, tau=tau, Tp=Tp,
                       thetas=(float(theta),), ridge=ridge, impl=impl)
    return coef[0, 0, :, 1:]
