"""Numerically stable correlation/covariance (paper §3.4, ref. [15]).

The Schubert–Gertz pairwise-merge scheme used by the fused-ρ kernel is
exposed here for tests and host-side streaming use (e.g. merging partial
statistics across devices or checkpointed shards).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.ref import pearson_rows  # noqa: F401  (canonical two-pass)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CoMoments:
    """Running (co-)moments of two aligned batches: n, means, M2s, C."""

    n: jax.Array
    mean_a: jax.Array
    mean_b: jax.Array
    m2_a: jax.Array
    m2_b: jax.Array
    c_ab: jax.Array

    @classmethod
    def zeros(cls, shape=(), dtype=jnp.float32) -> "CoMoments":
        z = jnp.zeros(shape, dtype)
        return cls(n=z, mean_a=z, mean_b=z, m2_a=z, m2_b=z, c_ab=z)

    @classmethod
    def from_batch(cls, a: jax.Array, b: jax.Array, axis: int = -1,
                   where=None) -> "CoMoments":
        """Two-pass moments of one batch (optionally masked)."""
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
        if where is None:
            n = jnp.full(a.sum(axis=axis).shape, a.shape[axis], jnp.float32)
            ma = jnp.mean(a, axis=axis)
            mb = jnp.mean(b, axis=axis)
            da, db = a - jnp.expand_dims(ma, axis), b - jnp.expand_dims(mb, axis)
        else:
            w = where.astype(jnp.float32)
            n = jnp.sum(w, axis=axis)
            ns = jnp.maximum(n, 1.0)
            ma = jnp.sum(a * w, axis=axis) / ns
            mb = jnp.sum(b * w, axis=axis) / ns
            da = (a - jnp.expand_dims(ma, axis)) * w
            db = (b - jnp.expand_dims(mb, axis)) * w
        return cls(
            n=n, mean_a=ma, mean_b=mb,
            m2_a=jnp.sum(da * da, axis=axis),
            m2_b=jnp.sum(db * db, axis=axis),
            c_ab=jnp.sum(da * db, axis=axis),
        )

    def merge(self, other: "CoMoments") -> "CoMoments":
        """Schubert & Gertz (2018) parallel merge — associative, stable."""
        n = self.n + other.n
        ns = jnp.maximum(n, 1.0)
        da = other.mean_a - self.mean_a
        db = other.mean_b - self.mean_b
        f = self.n * other.n / ns
        return CoMoments(
            n=n,
            mean_a=self.mean_a + da * other.n / ns,
            mean_b=self.mean_b + db * other.n / ns,
            m2_a=self.m2_a + other.m2_a + da * da * f,
            m2_b=self.m2_b + other.m2_b + db * db * f,
            c_ab=self.c_ab + other.c_ab + da * db * f,
        )

    @property
    def pearson(self) -> jax.Array:
        denom = jnp.sqrt(self.m2_a * self.m2_b)
        return jnp.where(denom > 0, self.c_ab / jnp.maximum(denom, 1e-30), 0.0)
