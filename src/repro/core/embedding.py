"""Time-delay embedding (Takens) — conventions and materialized helper.

Index conventions used across the whole framework (see kernels/ref.py):
embedded point ``i`` has components ``x[i + k*tau], k in [0, E)`` and
corresponds to *time* ``t = i + (E-1)*tau``. ``Lp = L - (E-1)*tau``.

The production path never materializes the embedding — the paper's core
optimization fuses it into the distance kernel — but tests, S-Map and
user-facing inspection use this helper.
"""

from __future__ import annotations

from repro.kernels.ref import delay_embed, num_embedded  # noqa: F401


def embed_offset(E: int, tau: int, Tp: int = 0) -> int:
    """Embedded-index → time-index offset used by lookups (+ horizon Tp)."""
    return (E - 1) * tau + Tp


def pred_rows(L: int, E: int, tau: int, Tp: int) -> int:
    """Number of embedded rows whose Tp-ahead truth exists in the series."""
    return num_embedded(L, E, tau) - max(Tp, 0)
