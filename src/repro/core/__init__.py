"""repro.core — the paper's contribution: EDM as a composable JAX library.

Layers (kEDM §3): fused-embedding all-kNN search, batched simplex lookups
with optional fused Pearson ρ, simplex projection (optimal-E), convergent
cross mapping, S-Map, and stable streaming statistics. The distributed
pairwise-CCM engine lives in ``repro.distributed.sharded_ccm``.
"""

from repro.core.ccm import ccm_group, ccm_matrix, cross_map
from repro.core.embedding import delay_embed, embed_offset, num_embedded, pred_rows
from repro.core.knn import KnnTable, all_knn
from repro.core.simplex import (
    optimal_E,
    optimal_E_batch,
    optimal_E_sweep_seed,
    rho_curve,
    simplex_predict,
    simplex_skill,
)
from repro.core.smap import (
    nonlinearity_test,
    smap_predict,
    smap_predict_seed,
    smap_skill,
)
from repro.core.smap_engine import (
    DEFAULT_THETAS,
    smap_cross_map,
    smap_fit,
    smap_group,
    smap_jacobian,
    smap_matrix,
    smap_predict_batch,
    smap_theta_sweep,
)
from repro.core.stats import CoMoments, pearson_rows

__all__ = [
    "KnnTable",
    "all_knn",
    "ccm_group",
    "ccm_matrix",
    "cross_map",
    "delay_embed",
    "embed_offset",
    "num_embedded",
    "pred_rows",
    "optimal_E",
    "optimal_E_batch",
    "optimal_E_sweep_seed",
    "rho_curve",
    "simplex_predict",
    "simplex_skill",
    "nonlinearity_test",
    "DEFAULT_THETAS",
    "smap_predict",
    "smap_predict_seed",
    "smap_predict_batch",
    "smap_theta_sweep",
    "smap_fit",
    "smap_cross_map",
    "smap_group",
    "smap_matrix",
    "smap_jacobian",
    "smap_skill",
    "CoMoments",
    "pearson_rows",
]
