"""repro.core — the EDM compute primitives underneath the session facade.

The user-facing entry point is ``repro.edm``: an ``EDM`` session binds a
panel + ``EDMConfig`` once, and its ``optimal_E`` / ``simplex`` / ``smap``
/ ``ccm`` / ``xmap`` methods dispatch plans that share kNN/embedding state
and pick local vs sharded placement — kEDM's "small API over one
codebase" design. This package holds the primitives those plans compose
(kEDM §3): fused-embedding all-kNN search, batched simplex lookups with
fused Pearson ρ, the incremental multi-E optimal-E sweep, convergent
cross mapping, the batched S-Map Gram engine, and stable streaming
statistics. The free functions here remain supported — the matrix
drivers (``ccm_matrix``, ``smap_matrix``) are now thin wrappers over the
facade — but new code should prefer a session: it computes neighbor
tables once per panel instead of once per call site. The zero-collective
sharded engines live in ``repro.distributed.sharded_ccm``; the migration
table from pyEDM/kEDM names is in docs/API.md.
"""

from repro.core.ccm import (
    auto_batch_libs,
    ccm_convergence,
    ccm_convergence_caps,
    ccm_group,
    ccm_group_batched,
    ccm_matrix,
    cross_map,
    cross_map_sizes_seed,
    normalize_lib_sizes,
)
from repro.core.embedding import delay_embed, embed_offset, num_embedded, pred_rows
from repro.core.knn import KnnTable, all_knn
from repro.core.simplex import (
    optimal_E,
    optimal_E_batch,
    optimal_E_sweep_seed,
    rho_curve,
    simplex_predict,
    simplex_skill,
)
from repro.core.smap import (
    nonlinearity_test,
    smap_predict,
    smap_predict_seed,
    smap_skill,
)
from repro.core.smap_engine import (
    DEFAULT_THETAS,
    smap_cross_map,
    smap_fit,
    smap_group,
    smap_jacobian,
    smap_matrix,
    smap_predict_batch,
    smap_theta_sweep,
)
from repro.core.stats import CoMoments, pearson_rows

__all__ = [
    "KnnTable",
    "all_knn",
    "auto_batch_libs",
    "ccm_convergence",
    "ccm_convergence_caps",
    "ccm_group",
    "ccm_group_batched",
    "ccm_matrix",
    "cross_map",
    "cross_map_sizes_seed",
    "normalize_lib_sizes",
    "delay_embed",
    "embed_offset",
    "num_embedded",
    "pred_rows",
    "optimal_E",
    "optimal_E_batch",
    "optimal_E_sweep_seed",
    "rho_curve",
    "simplex_predict",
    "simplex_skill",
    "nonlinearity_test",
    "DEFAULT_THETAS",
    "smap_predict",
    "smap_predict_seed",
    "smap_predict_batch",
    "smap_theta_sweep",
    "smap_fit",
    "smap_cross_map",
    "smap_group",
    "smap_matrix",
    "smap_jacobian",
    "smap_skill",
    "CoMoments",
    "pearson_rows",
]
