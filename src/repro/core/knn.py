"""All-k-nearest-neighbor search over a library series (paper §3.3)."""

from __future__ import annotations

import dataclasses

import jax

from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KnnTable:
    """Precomputed neighbor tables for one library series.

    The paper's key structural idea (§2.1): compute the all-kNN tables once
    per library and reuse them for *every* target lookup.
    """

    dists: jax.Array  # (Lp, k) Euclidean, ascending
    idx: jax.Array  # (Lp, k) int32 embedded indices
    E: int = dataclasses.field(metadata=dict(static=True))
    tau: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))

    @property
    def weights(self) -> jax.Array:
        """Normalized simplex weights, paper step (3)."""
        return ops.make_weights(self.dists)


def all_knn(
    x: jax.Array,
    *,
    E: int,
    tau: int = 1,
    k: int | None = None,
    exclude_self: bool = True,
    max_idx=None,
    impl: str = "auto",
    variant: str = "vpu",
) -> KnnTable:
    """Fused pairwise distances + top-k over one series. k defaults to E+1."""
    k = E + 1 if k is None else k
    dists, idx = ops.all_knn(
        x, E=E, tau=tau, k=k, exclude_self=exclude_self, max_idx=max_idx,
        impl=impl, variant=variant,
    )
    return KnnTable(dists=dists, idx=idx, E=E, tau=tau, k=k)
