"""Run inspector: live progress of a journaled matrix run.

``python -m repro.edm.inspect <run_dir>`` renders what a running (or
finished) ``EDM.xmap(run_dir=...)`` matrix run is doing, from artifacts
alone — no imports of the engine, no locks taken, safe to point at a
directory another process is actively writing:

* ``run.json``    — identity: run key, status, shape, attempt lineage.
* ``report.json`` — progress counters, this-attempt vs cumulative
  elapsed, pairs/s, straggler flags, the OOM backoff trail (refreshed
  at every snapshot, not just at exit).
* ``heartbeat``   — per-tile (rows_done, wall time) lines: recent
  throughput, heartbeat age (a stale age with a live process = hang),
  and the ETA extrapolated from the recent row rate.
* ``telemetry/events.jsonl`` — the span/event log; the summary shows
  the trailing straggler/OOM/lifecycle events.

Exposed as functions (``inspect_run`` → dict, ``format_summary`` →
str) so tests and dashboards consume the same logic as the CLI.
"""

from __future__ import annotations

import json
import os
import sys
import time

#: Heartbeat window (entries) for the recent-throughput estimate.
RATE_WINDOW = 20

#: Trailing telemetry events surfaced in the summary.
EVENT_TAIL = 8

#: Event names worth surfacing in a progress trail.
TRAIL_EVENTS = ("straggler.flag", "oom.backoff", "run.start", "run.resume",
                "run.preempt", "run.complete")


def _load_json(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _load_heartbeat(path: str) -> list[tuple[int, float]]:
    beats = []
    try:
        with open(path) as f:
            for line in f:
                try:
                    step, ts = line.strip().split(",")
                    beats.append((int(step), float(ts)))
                except ValueError:
                    continue  # torn final line of a live writer
    except OSError:
        pass
    return beats


def _load_event_trail(path: str) -> list[dict]:
    trail = []
    try:
        with open(path) as f:
            for line in f:
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn final line of a live writer
                if ev.get("name") in TRAIL_EVENTS:
                    trail.append(ev)
    except OSError:
        pass
    return trail[-EVENT_TAIL:]


def inspect_run(run_dir: str, *, now: float | None = None) -> dict:
    """Everything the inspector knows about ``run_dir``, as one dict.

    Never raises on missing/partial artifacts — a run that has only
    written its manifest still inspects (progress fields are None).
    ``now`` is injectable for deterministic tests.
    """
    now = time.time() if now is None else now
    manifest = _load_json(os.path.join(run_dir, "run.json"))
    report = _load_json(os.path.join(run_dir, "report.json"))
    beats = _load_heartbeat(os.path.join(run_dir, "heartbeat"))
    trail = _load_event_trail(
        os.path.join(run_dir, "telemetry", "events.jsonl"))

    info = {
        "run_dir": os.path.abspath(run_dir),
        "manifest": manifest,
        "report": report,
        "events": trail,
        "status": (manifest or {}).get("status"),
        "attempts": (manifest or {}).get("attempts", []),
        "rows_done": (report or {}).get("rows_done"),
        "rows_total": (report or {}).get("rows_total"),
        "pairs_per_s": (report or {}).get("pairs_per_s"),
        "heartbeat_age_s": None,
        "rows_per_s": None,
        "eta_s": None,
    }
    if beats:
        info["heartbeat_age_s"] = round(now - beats[-1][1], 3)
        recent = beats[-RATE_WINDOW:]
        d_rows = recent[-1][0] - recent[0][0]
        d_t = recent[-1][1] - recent[0][1]
        if d_rows > 0 and d_t > 0:
            rate = d_rows / d_t
            info["rows_per_s"] = round(rate, 3)
            if info["rows_total"] is not None:
                remaining = info["rows_total"] - recent[-1][0]
                info["eta_s"] = round(max(0, remaining) / rate, 1)
    return info


def _fmt_eta(s: float | None) -> str:
    if s is None:
        return "?"
    if s >= 3600:
        return f"{s / 3600:.1f}h"
    if s >= 60:
        return f"{s / 60:.1f}m"
    return f"{s:.0f}s"


def format_summary(info: dict) -> str:
    """Human-readable multi-line summary of ``inspect_run``'s dict."""
    lines = [f"run_dir: {info['run_dir']}"]
    m, r = info["manifest"], info["report"]
    if m is None:
        lines.append("no run.json — not a journaled run dir (yet?)")
        return "\n".join(lines)
    lines.append(f"status: {info['status']}   key: {m.get('key', '?')[:12]}…"
                 f"   shape: {m.get('shape')}")
    attempts = info["attempts"]
    if attempts:
        ids = [a.get("run_id", "?") for a in attempts]
        lines.append(f"attempts: {len(ids)} ({', '.join(ids)})")
    if r is not None:
        done, total = r.get("rows_done"), r.get("rows_total")
        pct = f" ({100.0 * done / total:.1f}%)" if total else ""
        lines.append(
            f"rows: {done}/{total}{pct}   this attempt: "
            f"{r.get('rows_this_attempt')}   resumed: "
            f"{r.get('rows_resumed')}")
        lines.append(
            f"throughput: {r.get('pairs_per_s')} pairs/s, "
            f"{r.get('tiles_per_s')} tiles/s   elapsed: "
            f"{r.get('elapsed_s')}s (cumulative "
            f"{r.get('cumulative_elapsed_s')}s)")
        flags = (r.get("stragglers") or {}).get("flagged", [])
        ooms = r.get("oom_backoff", [])
        if flags or ooms:
            lines.append(f"stragglers flagged: {len(flags)}   "
                         f"oom backoffs: {len(ooms)}")
    lines.append(
        f"heartbeat age: {_fmt_eta(info['heartbeat_age_s'])}   recent: "
        f"{info['rows_per_s']} rows/s   ETA: {_fmt_eta(info['eta_s'])}")
    for ev in info["events"]:
        attrs = ev.get("attrs", {})
        brief = ", ".join(f"{k}={v}" for k, v in list(attrs.items())[:4])
        lines.append(f"  event {ev.get('name')}: {brief}")
    return "\n".join(lines)


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    if len(args) != 1:
        print("usage: python -m repro.edm.inspect <run_dir>",
              file=sys.stderr)
        return 2
    run_dir = args[0]
    if not os.path.isdir(run_dir):
        print(f"no such run_dir: {run_dir}", file=sys.stderr)
        return 2
    print(format_summary(inspect_run(run_dir)))
    return 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
