"""repro.edm — the unified session API over the EDM toolkit.

kEDM exposes a small facade (``edm.simplex``, ``edm.smap``,
``edm.xmap``) over one performance-portable codebase; this package is
that facade for the reproduction, subsuming the free-function zoo in
``repro.core`` / ``repro.distributed``:

* ``EDMConfig`` — frozen, validated hyperparameters (E/tau/Tp/θ/k/impl/
  mesh) bound once instead of threaded through every call.
* ``Dataset``  — an (N, L) panel with cached delay embeddings.
* ``EDM``      — the session: ``optimal_E`` / ``simplex`` / ``smap`` /
  ``ccm`` / ``xmap`` / ``submit_panel``, each dispatched through a
  ``Plan`` that picks kernels + placement and reuses the session's
  cached multi-E kNN master tables.
* ``MatrixRunner`` — the fault-tolerance layer under
  ``EDM.xmap(run_dir=...)``: journaled tiles, preemption →
  checkpoint-and-exit ``PREEMPTED_EXIT``, OOM → halve-B backoff,
  bit-identical resume.

See docs/API.md for the pyEDM/kEDM migration table and
docs/ARCHITECTURE.md for the fault-tolerance design.
"""

from repro.edm.config import DEFAULT_THETAS, EDMConfig
from repro.edm.dataset import (INVALID_POLICIES, Dataset, merge_stats,
                               screen_panel, series_stats)
from repro.edm.plan import Plan
from repro.edm.runner import PREEMPTED_EXIT, MatrixRunner, RunState, run_key
from repro.edm.session import EDM, PanelResult, SurrogateResult
from repro.edm.surrogates import make_surrogates

__all__ = ["DEFAULT_THETAS", "EDM", "EDMConfig", "Dataset",
           "INVALID_POLICIES", "MatrixRunner", "PREEMPTED_EXIT",
           "PanelResult", "Plan", "RunState", "SurrogateResult",
           "make_surrogates", "merge_stats", "run_key", "screen_panel",
           "series_stats"]
