"""The ``EDM`` session — one facade over the whole EDM toolkit.

kEDM's design win is a small user-facing API (``simplex``, ``smap``,
``xmap``) over a single dispatching codebase; this session object is that
facade for the reproduction. Bind a panel and a config once::

    sess = EDM(panel, EDMConfig(E_max=8, tau=2))
    E_opt, rho = sess.optimal_E()      # one multi-E kNN pass, cached
    skill = sess.simplex()             # free: read from the cached sweep
    causal = sess.xmap()               # reuses the SAME kNN master tables
    theta_curves = sess.smap()         # batched S-Map nonlinearity test
    curve = sess.ccm(0, 1, lib_sizes=(50, 200, 500))  # convergence sweep
    sig = sess.surrogate_test(0, 1)    # CCM significance vs a null ensemble

Every method builds a ``Plan`` (``sess.plan(task)`` shows it) choosing
kernels, implementation and local-vs-sharded placement once, then
executes it. The multi-E kNN master tables built by ``optimal_E`` are
held in the session and reused by ``simplex``/``xmap`` instead of being
recomputed per call site; a ``mesh=`` in the config transparently routes
plans through the zero-collective sharded engines in
``repro.distributed.sharded_ccm``.

Implementation pinning: the session resolves ``config.impl`` once at
bind time (``ops.resolve_impl``) and passes the concrete name into every
kernel call — the reliable form of ``ops.use_impl``'s scoped default,
which cannot retroactively re-key already-traced jitted programs (see
its docstring's caveat).
"""

from __future__ import annotations

import collections
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.edm.config import EDMConfig
from repro.edm.dataset import Dataset
from repro.edm.plan import (
    Plan,
    ccm_convergence_from_master,
    ccm_group_from_master_batched,
    master_slack_covers,
    panel_master,
    panel_master_append,
    rho_curves_from_master,
    simplex_skill_from_master,
)
from repro.edm.surrogates import make_surrogates
from repro.core.embedding import num_embedded
from repro.kernels import ops


def _e_groups(E_opt, N: int):
    """Per-series E table → {E: member indices}, kEDM §3.4's grouping."""
    E_opt = np.broadcast_to(np.asarray(E_opt, np.int32), (N,)).copy()
    return E_opt, {
        int(E): np.nonzero(E_opt == E)[0]
        for E in sorted(collections.Counter(E_opt.tolist()))
    }


@dataclasses.dataclass
class SurrogateResult:
    """Outcome of one ``EDM.surrogate_test``: score, null ensemble, p."""

    rho: float | np.ndarray            # actual skill ((S,) with lib_sizes)
    surrogate_rho: np.ndarray          # (M,) or (S, M) null ensemble skills
    pvalue: float | np.ndarray         # rank-based, (1 + #{null ≥ ρ})/(1 + M)
    method: str
    num_surrogates: int

    @property
    def significant(self) -> bool | np.ndarray:
        """p < 0.05 (per size when a convergence sweep was run)."""
        return self.pvalue < 0.05


@dataclasses.dataclass
class PanelResult:
    """Results of one queued ``submit_panel`` ticket."""

    E_opt: np.ndarray | None = None
    rho: np.ndarray | None = None          # (N, E_max) optimal-E curves
    smap: np.ndarray | None = None         # (N, |thetas|) θ-sweep skill
    xmap: np.ndarray | None = None         # (N, N) cross-map matrix


class EDM:
    """Session facade: shared kNN/embedding state + plan-based dispatch."""

    def __init__(self, data, config: EDMConfig | None = None, **overrides):
        if config is None:
            config = EDMConfig(**overrides)
        elif overrides:
            config = config.replace(**overrides)
        self.data = data if isinstance(data, Dataset) else Dataset(
            data, on_invalid=config.on_invalid)
        self.config = config
        config.validate_panel(self.data.N, self.data.L)
        self._impl = ops.resolve_impl(config.impl)
        self._cache: dict[str, object] = {}
        self.stats: collections.Counter = collections.Counter()
        self._queue: list[tuple[int, jnp.ndarray, tuple[str, ...]]] = []
        self._next_ticket = 0

    def _bump(self, key: str, n: int = 1) -> None:
        """Session cache/run statistic: the in-session ``stats`` Counter
        AND the process-wide telemetry counter (``edm_<key>``) — the
        latter is the supported observation API
        (``telemetry.Recorder.counter_delta``)."""
        self.stats[key] += n
        telemetry.counter(f"edm_{key}").inc(n)

    def _plan_event(self, task: str) -> None:
        """Emit the resolved Plan as a ``plan.execute`` event (sinks
        only — ``plan()`` itself is too costly for the disabled path)."""
        if telemetry.active():
            telemetry.event("plan.execute", task=task,
                            plan=self.plan(task).describe())

    # ---------------------------------------------------- validity masking
    #
    # A Dataset bound with on_invalid="mask" keeps invalid series in the
    # panel (zeroed so kernels never see NaN) and the session NaN-flags
    # every output that touches one: per-series rows, matrix rows AND
    # columns, pairwise results. Clean panels (valid all-True) pay
    # nothing — every helper is a no-op returning its input unchanged.

    @property
    def _invalid(self):
        """Indices of masked-invalid series, or None for clean panels."""
        if self.data.num_invalid == 0:
            return None
        return np.nonzero(~self.data.valid)[0]

    def _mask_rows(self, out: np.ndarray) -> np.ndarray:
        """NaN the rows of a per-series output at invalid series."""
        bad = self._invalid
        if bad is not None:
            out = np.array(out, np.float32)
            out[bad] = np.nan
        return out

    def _mask_matrix(self, rho: np.ndarray) -> np.ndarray:
        """NaN the rows and columns of an (N, N) matrix at invalid series
        (applied at delivery — a journaled run's checkpoints hold the
        raw computed tiles, the mask is a view-level policy)."""
        bad = self._invalid
        if bad is not None:
            rho = np.array(rho, np.float32)
            rho[bad, :] = np.nan
            rho[:, bad] = np.nan
        return rho

    def _pair_invalid(self, *indices) -> bool:
        return any(not self.data.is_valid(i) for i in indices)

    # ------------------------------------------------------------- plans

    def plan(self, task: str, *, E=None) -> Plan:
        """The Plan a method call would execute (introspection)."""
        c = self.config
        sharded = c.mesh is not None
        placement = "sharded" if sharded else "local"
        cached = c.cache and not sharded
        have_master = "master" in self._cache
        have_rho = "rho" in self._cache
        if task == "optimal_E":
            return Plan(
                task=task, impl=self._impl, placement=placement,
                E=f"sweep:1..{c.E_max}", Tp=c.Tp,
                reuse=(("rho",) if have_rho else
                       ("master",) if (cached and have_master) else ()),
                builds=() if have_rho else (
                    ("master", "rho") if cached else ("rho",)),
                detail="sharded_optimal_E" if sharded else (
                    "derive per-E tables from kNN master" if cached
                    else "legacy optimal_E_batch"),
            )
        if task == "simplex":
            e_desc = (f"fixed:{E or c.E}" if (E or c.E) else "per-series")
            return Plan(
                task=task, impl=self._impl, placement="local",
                E=e_desc, Tp=c.Tp,
                reuse=(("master",) if (cached and (E or c.E)) else ("rho",)),
                builds=(),
                detail=("skill read off the cached ρ(E) sweep"
                        if not (E or c.E) else
                        "indices from kNN master, k distances recomputed"
                        if cached else "legacy per-series simplex_skill"),
            )
        if task == "smap":
            e_desc = f"fixed:{E or c.E}" if (E or c.E) else "per-series"
            return Plan(
                task=task, impl=self._impl, placement=placement,
                E=e_desc, Tp=c.Tp,
                reuse=() if (E or c.E) else ("rho",),
                builds=(),
                detail="sharded_smap_theta per E-group" if sharded
                else "batched Gram engine per E-group",
            )
        if task == "ccm":
            return Plan(
                task=task, impl=self._impl, placement="local",
                E=f"fixed:{E or c.E}" if (E or c.E) else "per-series",
                Tp=c.Tp_cross,
                reuse=(("master",) if (cached and have_master) else ())
                + (() if (E or c.E) else ("rho",)), builds=(),
                detail="sweep: capped tables from kNN master when "
                       "k_master slack covers, else one-pass multi-cap "
                       "convergence engine",
            )
        if task == "xmap":
            # Coverage for the DEFAULT call (E_opt=None): fixed E, else
            # the cached optimal-E table (which _rho would build —
            # together with the master — before the matrix runs anyway).
            # An explicit deeper `E_opt=` argument can still fall back
            # to the direct engine at execution time.
            hit = self._cache.get("master")
            levels = (c.E if c.E else
                      int(self._cache["rho"][0].max()) if have_rho
                      else c.E_max)
            covered = hit is not None and hit[3] >= levels
            master_next = cached and (
                covered or self.stats["xmap_direct_runs"] > 0
                or not (c.E or have_rho))
            return Plan(
                task=task, impl=self._impl, placement=placement,
                E=f"fixed:{c.E}" if c.E else "per-series", Tp=c.Tp_cross,
                reuse=(("master",) if (cached and covered) else ()) + (
                    () if c.E else ("rho",)),
                builds=(("master",) if (master_next and not covered)
                        else ()) + (() if (c.E or have_rho) else ("rho",)),
                detail="E-grouped sharded matrix, zero collectives"
                if sharded else (
                    "library-batched lookups on cached kNN master"
                    if master_next
                    else "library-batched direct engine, ceil(N/B) "
                         "launches per E-group"),
            )
        raise ValueError(f"unknown task {task!r}")

    # ------------------------------------------------------------ caches

    def _master(self, E_levels: int):
        """Multi-E kNN master tables covering levels 1..E_levels.

        Returns (dists, idx, k_master, levels). Built lazily at the
        highest level any method has needed so far: a fixed-E session
        never pays for (or crashes on) a full E_max sweep it will not
        use, and a later, deeper request rebuilds once and re-caches —
        reusing a master below the requested level would silently gather
        the wrong table (jnp clamps out-of-range indices).
        """
        c = self.config
        hit = self._cache.get("master")
        if hit is not None and hit[3] >= E_levels:
            self._bump("knn_master_hits")
            return hit
        k_m = max(E_levels + 1, c.k or 0) + c.slack
        with telemetry.span("session.master_build", E_levels=E_levels,
                            k_master=k_m, N=self.data.N):
            dM, iM = panel_master(self.data.panel, E_max=E_levels,
                                  tau=c.tau, k=k_m, impl=self._impl)
        self._bump("knn_master_builds")
        hit = self._cache["master"] = (dM, iM, k_m, E_levels)
        return hit

    def master_nbytes(self) -> int:
        """Resident bytes of the cached multi-E kNN master (0 if none).

        The serving LRU's accounting unit: the master is the session's
        only O(N·E·Lp·k) cache (distances + indices), everything else
        held here is O(N·E_max) or smaller.
        """
        hit = self._cache.get("master")
        if hit is None:
            return 0
        dM, iM = hit[0], hit[1]
        return int(dM.nbytes) + int(iM.nbytes)

    def evict_master(self) -> int:
        """Drop the cached kNN master; returns the bytes freed.

        Purely a memory event: the next method that needs the master
        lazily rebuilds it from the *current* panel (``_master``), and
        the incremental-append contract (append ≡ cold rebuild, bit
        identical) makes every later answer — and every later append —
        bit-identical to a never-evicted session. The serving layer's
        LRU byte budget calls this on cold panels.
        """
        freed = self.master_nbytes()
        if freed:
            self._cache.pop("master", None)
            self._bump("knn_master_evictions")
        return freed

    def append(self, delta) -> list[dict]:
        """Grow the bound panel by Δt points, updating caches in place.

        The serving tick primitive: screening covers only the new
        columns (``Dataset.append``), and a cached kNN master is grown
        by ``panel_master_append`` — O(Lp·Δt) stream-in/merge per
        series, bit-identical to the cold O(Lp²) rebuild — so a warm
        session absorbs a tick without repaying its build. Derived
        caches that summarize the whole panel (the optimal-E rho
        curves) are invalidated; the master survives. Under
        ``on_invalid="drop"`` the master rows of dropped series are
        compacted to match the panel. Returns ``Dataset.append``'s
        records of series this delta invalidated (pre-append indices).
        """
        c = self.config
        old_N = self.data.N
        with telemetry.span("session.append", N=old_N):
            records = self.data.append(delta)  # raises before mutating
            self._cache.pop("rho", None)
            hit = self._cache.get("master")
            if hit is not None and c.cache:
                dM, iM, k_m, lv = hit
                if len(records) and self.data.N != old_N:  # drop compaction
                    keep = np.setdiff1d(
                        np.arange(old_N), [r["index"] for r in records])
                    dM, iM = dM[keep], iM[keep]
                dt = int(self.data.L) - int(dM.shape[2])
                with telemetry.span("session.master_append", dt=dt,
                                    E_levels=lv, N=self.data.N):
                    dM, iM = panel_master_append(
                        self.data.panel, dM, iM, tau=c.tau, impl=self._impl)
                self._cache["master"] = (dM, iM, k_m, lv)
                self._bump("knn_master_appends")
            else:
                self._cache.pop("master", None)
            self._bump("appends")
        return records

    def _rho(self):
        """Cached (E_opt, rho-curve) pair, computing it on first use."""
        hit = self._cache.get("rho")
        if hit is None:
            hit = self._cache["rho"] = self._run_optimal_E()
        else:
            self._bump("rho_hits")
        return hit

    # ---------------------------------------------------------- optimal E

    def _run_optimal_E(self) -> tuple[np.ndarray, np.ndarray]:
        c = self.config
        X = self.data.panel
        if c.mesh is not None:
            from repro.distributed.sharded_ccm import (
                pad_to_multiple, sharded_optimal_E)
            size = c.mesh_axis_size(c.lib_axes)
            Xp = pad_to_multiple(X, size, axis=0)
            E_opt, rho = sharded_optimal_E(
                Xp, E_max=c.E_max, tau=c.tau, Tp=c.Tp, mesh=c.mesh,
                axes=c.lib_axes, impl=self._impl)
            E_opt = np.asarray(E_opt)[: self.data.N]
            rho = np.asarray(rho)[: self.data.N]
        elif c.cache:
            dM, iM, _, lv = self._master(c.E_max)
            rho = np.asarray(rho_curves_from_master(
                X, dM[:, :c.E_max], iM[:, :c.E_max], E_max=c.E_max,
                tau=c.tau, Tp=c.Tp, impl=self._impl))
            E_opt = (np.argmax(rho, axis=1) + 1).astype(np.int32)
        else:
            from repro.core.simplex import optimal_E_batch
            E_opt, rho = optimal_E_batch(
                X, E_max=c.E_max, tau=c.tau, Tp=c.Tp, impl=self._impl)
            E_opt, rho = np.asarray(E_opt), np.asarray(rho)
        bad = self._invalid
        if bad is not None:
            # Masked-invalid series: pin E to 1 (a deterministic group —
            # the zeroed data's argmax is meaningless) and NaN the ρ(E)
            # curve so everything read off the cache inherits the flag.
            E_opt = E_opt.copy()
            E_opt[bad] = 1
            rho = np.array(rho, np.float32)
            rho[bad] = np.nan
        return E_opt, rho

    def optimal_E(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-series optimal embedding dimension and the full ρ(E) sweep.

        Returns (E_opt (N,) int32, rho (N, E_max)). Cached: later
        ``simplex``/``smap``/``ccm``/``xmap`` calls reuse both the result
        and (locally) the kNN master tables built here.
        """
        with telemetry.span("session.optimal_E", E_max=self.config.E_max,
                            N=self.data.N):
            self._plan_event("optimal_E")
            E_opt, rho = self._rho()
        return E_opt.copy(), rho.copy()

    # ------------------------------------------------------------ simplex

    def simplex(self, E: int | None = None) -> np.ndarray:
        """Leave-one-out simplex forecast skill per series → (N,) ρ.

        ``E=None`` with a per-series config reads the skill straight off
        the cached optimal-E sweep (no compute); a fixed E reuses the
        cached kNN master (indices derived, k distances recomputed).
        """
        c = self.config
        E = E if E is not None else c.E
        with telemetry.span("session.simplex", N=self.data.N,
                            E=E or "per-series"):
            if E is None:
                E_opt, rho = self._rho()
                return rho[np.arange(self.data.N), E_opt - 1].copy()
            if c.cache and c.mesh is None:
                _, iM, _, _ = self._master(E)
                return self._mask_rows(np.asarray(
                    simplex_skill_from_master(
                        self.data.panel, iM[:, E - 1], E=E, tau=c.tau,
                        Tp=c.Tp, k=c.k_for(E), impl=self._impl)))
            from repro.core.simplex import simplex_skill
            return self._mask_rows(np.asarray([
                simplex_skill(x, E=E, tau=c.tau, Tp=c.Tp, impl=self._impl)
                for x in self.data.panel]))

    # -------------------------------------------------------------- smap

    def smap(self, E: int | None = None, thetas=None) -> np.ndarray:
        """S-Map θ-sweep (nonlinearity test) per series → (N, |θ|) ρ.

        Per-series E (the default) groups series by their cached optimal
        E so each group is ONE batched Gram-engine launch; a mesh routes
        each group through ``sharded_smap_theta`` (zero collectives).
        """
        c = self.config
        thetas = c.thetas if thetas is None else tuple(
            float(t) for t in thetas)
        E = E if E is not None else c.E
        with telemetry.span("session.smap", N=self.data.N,
                            E=E or "per-series", thetas=len(thetas)):
            if E is not None:
                groups = {int(E): np.arange(self.data.N)}
            else:
                E_opt, _ = self._rho()
                _, groups = _e_groups(E_opt, self.data.N)
            out = np.zeros((self.data.N, len(thetas)), np.float32)
            for Eg, members in groups.items():
                out[members] = self._smap_group_sweep(Eg, members, thetas)
            return self._mask_rows(out)

    def _smap_group_sweep(self, E, members, thetas) -> np.ndarray:
        c = self.config
        X = self.data.panel[np.asarray(members)]
        if c.mesh is not None:
            from repro.distributed.sharded_ccm import (
                pad_members, sharded_smap_theta)
            size = c.mesh_axis_size(c.lib_axes)
            padded = pad_members(np.arange(len(members)), size)
            rho = sharded_smap_theta(
                X[padded], E=E, tau=c.tau, Tp=c.Tp, thetas=thetas,
                ridge=c.ridge, mesh=c.mesh, axes=c.lib_axes,
                impl=self._impl)
            return np.asarray(rho)[: len(members)]
        from repro.core.smap_engine import smap_theta_sweep
        return np.asarray(smap_theta_sweep(
            X, E=E, tau=c.tau, Tp=c.Tp, thetas=thetas, ridge=c.ridge,
            impl=self._impl))

    # --------------------------------------------------------------- ccm

    def _resolve_pair_E(self, target_index: int, E: int | None) -> int:
        """E for a pairwise call: arg > config > target's cached optimum."""
        if E is None:
            E = self.config.E
        if E is None:
            E_opt, _ = self._rho()
            E = int(E_opt[target_index])
        return int(E)

    def ccm(self, lib, target, *, lib_sizes=None,
            E: int | None = None) -> np.ndarray:
        """Convergence cross-mapping between two panel series.

        Embeds series ``lib``'s manifold and cross-maps ``target`` (high
        skill = evidence "target causes lib"). ``lib_sizes`` returns the
        convergence curve — ρ rising with library size is CCM's causality
        criterion. E defaults to the *target's* cached optimal E (kEDM
        §3.4's convention).

        A sweep never re-scans per size: when the cached kNN master's
        slack covers every cap (``master_slack_covers``) the per-size
        tables are derived from it with zero additional kNN work,
        otherwise ONE multi-cap convergence-engine pass handles all
        sizes. Both are bit-identical to the legacy per-size loop.
        """
        c = self.config
        li = self.data.index_of(lib)
        ti = self.data.index_of(target)
        if self._pair_invalid(li, ti):  # masked series: NaN, no engine run
            if lib_sizes is None:
                return np.float32(np.nan)
            return np.full(len(tuple(lib_sizes)), np.nan, np.float32)
        E = self._resolve_pair_E(ti, E)
        with telemetry.span("session.ccm", lib=li, target=ti, E=E,
                            sweep=lib_sizes is not None):
            self._plan_event("ccm")
            return self._ccm_pair(li, ti, E, lib_sizes)

    def _ccm_pair(self, li, ti, E, lib_sizes) -> np.ndarray:
        c = self.config
        if lib_sizes is None:
            # Single full-library cap through the same curves path a
            # sweep uses: a covering cached master supplies the
            # neighbors with zero kNN work (exactly what plan("ccm")
            # advertises); without one it is one engine pass, same as
            # the legacy cross_map — and bit-identical either way.
            Lp = num_embedded(self.data.L, E, c.tau)
            curves = self._ccm_curves(
                li, self.data.panel[ti][None, :], E=E,
                lib_sizes=(Lp - max(c.Tp_cross, 0),))
            return curves[0, 0]
        curves = self._ccm_curves(li, self.data.panel[ti][None, :], E=E,
                                  lib_sizes=lib_sizes)
        return curves[:, 0]

    def _ccm_curves(self, li: int, targets, *, E: int,
                    lib_sizes) -> np.ndarray:
        """(num_sizes, N) convergence grid vs library ``li``'s manifold.

        Master-derived when the cached master's slack rule covers every
        requested cap; one multi-cap engine pass otherwise. k is the
        simplex default E + 1 (what the legacy ``cross_map`` sweep used),
        independent of ``config.k``.
        """
        from repro.core.ccm import ccm_convergence_caps, normalize_lib_sizes
        c = self.config
        x = self.data.panel[li]
        Lp = num_embedded(self.data.L, E, c.tau)
        caps, inv = normalize_lib_sizes(lib_sizes, Lp=Lp, Tp=c.Tp_cross)
        k = E + 1
        hit = self._cache.get("master")
        if (c.cache and c.mesh is None and hit is not None
                and hit[3] >= E
                and master_slack_covers(caps, Lp=Lp, k=k, k_master=hit[2])):
            self._bump("knn_master_hits")
            curves = ccm_convergence_from_master(
                x, hit[1][li, E - 1], targets, E=E, tau=c.tau,
                Tp=c.Tp_cross, caps=caps, k=k, impl=self._impl)
        else:
            curves = ccm_convergence_caps(
                x, targets, E=E, tau=c.tau, Tp=c.Tp_cross, caps=caps,
                exclude_self=True, impl=self._impl)
        return np.asarray(curves)[inv]

    def ccm_batch(self, pairs, *, E: int) -> np.ndarray:
        """Full-library CCM skill for many (lib, target) pairs → (n,) ρ.

        The serving primitive: n compatible requests (same panel, same
        E) become ONE library-batched engine launch
        (``ccm_group_from_master_batched`` — the xmap matrix engine)
        instead of n single-pair passes, ~20× the pairs/s on saturated
        queues. Its bit contract is *batch invariance*: the launch
        always cross-maps against the full panel's target set and the
        library axis is batch-invariant, so a pair's ρ is a pure
        function of (library state, lib, target, E) — the same bits no
        matter which other requests share its batch.
        ``ccm_batch([(l, t)], E=E)`` is therefore the quiesced oracle
        for any batched call. Values agree with the classic
        convergence-path ``ccm`` to the final ULP (different engines
        round differently); serving pins its answers to THIS method.
        Pairs touching masked-invalid series come back NaN; without a
        covering cached master (tiny panels, slack exhausted) it falls
        back to per-pair classic ``ccm``.
        """
        c = self.config
        E = int(E)
        idx = [(self.data.index_of(l), self.data.index_of(t))
               for l, t in pairs]
        out = np.full(len(idx), np.nan, np.float32)
        live = [(j, li, ti) for j, (li, ti) in enumerate(idx)
                if not self._pair_invalid(li, ti)]
        if not live:
            return out
        Lp = num_embedded(self.data.L, E, c.tau)
        cap = Lp - max(c.Tp_cross, 0)
        k = E + 1
        hit = (self._master(E) if c.cache and c.mesh is None else None)
        if hit is None or not master_slack_covers(
                (cap,), Lp=Lp, k=k, k_master=hit[2]):
            for j, li, ti in live:
                out[j] = self.ccm(li, ti, E=E)
            return out
        libs = sorted({li for _, li, _ in live})
        lpos = {li: i for i, li in enumerate(libs)}
        la = jnp.asarray(libs)
        with telemetry.span("session.ccm_batch", pairs=len(idx),
                            libs=len(libs), E=E):
            self._plan_event("ccm")
            g = np.asarray(ccm_group_from_master_batched(
                self.data.panel[la], hit[1][la, E - 1], self.data.panel,
                E=E, tau=c.tau, Tp=c.Tp_cross, k=k, impl=self._impl))
        for j, li, ti in live:
            out[j] = g[lpos[li], ti]
        self._bump("ccm_batch_pairs", len(live))
        return out

    def surrogate_test(self, lib, target, *, num_surrogates: int = 100,
                       method: str = "shuffle", period: int | None = None,
                       lib_sizes=None, E: int | None = None,
                       seed: int = 0) -> SurrogateResult:
        """CCM significance: rank the real skill against a null ensemble.

        Generates ``num_surrogates`` null versions of ``target``
        (``method="shuffle"`` destroys all temporal structure;
        ``"seasonal"`` permutes within phases of ``period`` so shared
        seasonal forcing survives into the null — the classic CCM false
        positive) and cross-maps ALL of them plus the real series as one
        (M+1)-target batch through a single jitted curve-grid program —
        the same batching discipline as ``submit_panel``, and the
        library's neighbor tables (session master or one engine pass)
        are shared by the whole ensemble. Returns a ``SurrogateResult``
        with the one-sided rank p-value ``(1 + #{ρ_null ≥ ρ}) / (1 + M)``
        (per size when ``lib_sizes`` is given).
        """
        c = self.config
        li = self.data.index_of(lib)
        ti = self.data.index_of(target)
        if self._pair_invalid(li, ti):  # masked series: NaN verdict
            if lib_sizes is None:
                return SurrogateResult(
                    float("nan"),
                    np.full(num_surrogates, np.nan, np.float32),
                    float("nan"), method, num_surrogates)
            S = len(tuple(lib_sizes))
            return SurrogateResult(
                np.full(S, np.nan, np.float32),
                np.full((S, num_surrogates), np.nan, np.float32),
                np.full(S, np.nan), method, num_surrogates)
        E = self._resolve_pair_E(ti, E)
        with telemetry.span("session.surrogate_test", lib=li, target=ti,
                            E=E, M=num_surrogates, method=method):
            y = np.asarray(self.data.panel[ti])
            surr = make_surrogates(y, num_surrogates, method=method,
                                   period=period, seed=seed)
            targets = jnp.concatenate(
                [jnp.asarray(y)[None, :], jnp.asarray(surr)], axis=0)
            squeeze = lib_sizes is None
            if squeeze:  # one cap: the full usable library
                Lp = num_embedded(self.data.L, E, c.tau)
                lib_sizes = (Lp - max(c.Tp_cross, 0),)
            curves = self._ccm_curves(li, targets, E=E,
                                      lib_sizes=lib_sizes)
        rho = curves[:, 0]
        null = curves[:, 1:]
        pval = ((1.0 + (null >= rho[:, None]).sum(axis=1))
                / (1.0 + num_surrogates))
        self._bump("surrogate_tests")
        if squeeze:
            return SurrogateResult(float(rho[0]), null[0], float(pval[0]),
                                   method, num_surrogates)
        return SurrogateResult(rho, null, pval, method, num_surrogates)

    # -------------------------------------------------------------- xmap

    def xmap(self, method: str = "simplex", *, E_opt=None,
             theta: float | None = None,
             run_dir: str | None = None) -> np.ndarray:
        """All-pairs cross-map skill matrix → (N, N) ρ.

        Entry (l, t) = skill of cross-mapping series t from series l's
        manifold at t's optimal E (evidence "t causes l"). The whole-
        brain CCM workload. ``method="simplex"`` is classic CCM;
        ``method="smap"`` swaps the lookup for the batched S-Map engine
        at locality ``theta`` (per-target optimal-E S-Map CCM).

        Each E-group is driven by the library-batched matrix engine —
        ceil(N/B) fused distance→top-k→lookup launches (``batch_libs`` /
        the memory-budget auto rule) with device compute double-buffered
        against host assembly, instead of N sequential per-series steps.
        Local sessions holding a cached multi-E kNN master (simplex
        method) derive neighbor indices from it with zero kNN work; mesh
        configs route through the E-grouped zero-collective sharded
        engines, whose per-shard inner loop uses the same batched
        engine.

        ``run_dir=`` makes the run **fault-tolerant and resumable**
        (``repro.edm.runner``): every engine tile is journaled under
        that directory, SIGTERM/SIGINT checkpoints and exits with code
        ``runner.PREEMPTED_EXIT`` (17), a device OOM halves the batch
        and retries, and calling again with the same run_dir resumes
        bit-identically from the last committed tile — a completed
        journal short-circuits to the stored matrix with zero compute.
        The journal is keyed by a content hash of panel + config + task,
        so a stale run_dir (anything changed) is refused, never reused.
        Masked-invalid series are NaN rows/columns in the returned
        matrix (and named in ``run_dir/report.json``).
        """
        if method not in ("simplex", "smap"):
            raise ValueError(f"unknown xmap method {method!r}")
        c = self.config
        N = self.data.N
        with telemetry.span("session.xmap", method=method, N=N,
                            journaled=run_dir is not None,
                            placement=("sharded" if c.mesh is not None
                                       else "local")):
            self._plan_event("xmap")
            if E_opt is None:
                E_opt = np.full(N, c.E, np.int32) if c.E else self._rho()[0]
            E_opt, groups = _e_groups(E_opt, N)
            if c.mesh is not None:
                rho = self._xmap_sharded(method, E_opt, theta, run_dir)
            else:
                rho = self._xmap_local(method, groups, theta, run_dir,
                                       E_opt)
        return self._mask_matrix(rho)

    def _xmap_group_launch(self, method, E, members, theta, iM):
        """One E-group's engine as a ``launch(a, b, B)`` closure + its B.

        The (launch, B) pair is the resumable unit the fault-tolerant
        runner re-drives (at any batch size — the engines are
        bit-invariant in B); the plain path drives the same closure
        through ``drive_batched`` directly, so journaled and
        un-journaled runs execute byte-identical launches.
        """
        c = self.config
        X = self.data.panel
        N = self.data.N
        tgts = X[np.asarray(members)]
        Lp = num_embedded(self.data.L, E, c.tau)
        if method == "smap":
            from repro.core.ccm import pad_batch
            from repro.core.smap_engine import smap_group
            th = float(c.theta if theta is None else theta)
            B = min(N, c.batch_libs) if c.batch_libs else N

            def launch(a, b, B):
                return smap_group(
                    pad_batch(X[a:b], B), tgts, E=E, tau=c.tau,
                    Tp=c.Tp_cross, theta=th, ridge=c.ridge,
                    impl=self._impl)

            return launch, B
        if iM is not None:
            from repro.core.ccm import auto_batch_libs
            from repro.edm.plan import (make_master_group_launch,
                                        master_group_batch_bytes)
            launch = make_master_group_launch(
                X, iM[:, E - 1], tgts, E=E, tau=c.tau, Tp=c.Tp_cross,
                k=c.k_for(E), impl=self._impl)
            B = c.batch_libs or auto_batch_libs(
                Lp, N, c.batch_budget_mb,
                per_series_bytes=master_group_batch_bytes(
                    Lp, iM.shape[-1]))
            return launch, max(1, min(int(B), N))
        from repro.core.ccm import auto_batch_libs, make_group_launch
        launch = make_group_launch(X, tgts, E=E, tau=c.tau, Tp=c.Tp_cross,
                                   k=c.k_for(E), impl=self._impl)
        B = c.batch_libs or auto_batch_libs(Lp, N, c.batch_budget_mb)
        return launch, max(1, min(int(B), N))

    def _xmap_local(self, method, groups, theta, run_dir=None,
                    E_opt=None) -> np.ndarray:
        """Local all-pairs matrix: library-batched engine per E-group.

        Each E-group runs as ceil(N/B) batched engine launches
        (``batch_libs`` / the auto memory-budget rule) with device
        compute double-buffered against host block assembly. A cached
        kNN master that covers the needed levels supplies the neighbor
        indices (zero kNN work); otherwise the direct
        ``ops.all_knn_batch`` engine runs — a one-shot matrix no longer
        pays for building a master it would use once. With ``run_dir``
        the same launches run under the journaled ``MatrixRunner``.
        """
        from repro.core.ccm import drive_batched
        c = self.config
        N = self.data.N
        hit = self._cache.get("master")
        use_master = method == "simplex" and c.cache and hit is not None \
            and hit[3] >= max(groups)
        if (method == "simplex" and c.cache and not use_master
                and self.stats["xmap_direct_runs"] > 0):
            # Second no-master xmap on a caching session: the workload is
            # repeating, so pay for the master NOW and derive this and
            # every later call from it — a one-shot matrix stays on the
            # direct engine, a repeated one keeps the amortization the
            # session API promises.
            use_master = True
        if use_master:
            iM = self._master(max(groups))[1]
        else:
            iM = None
            if method == "simplex" and c.cache:
                self._bump("xmap_direct_runs")
        entries = [
            (E, members) + self._xmap_group_launch(
                method, E, members, theta, iM)
            for E, members in groups.items()]
        if run_dir is not None:
            return self._run_journaled(run_dir, method, theta, entries,
                                       (N, N), E_opt)
        rho = np.zeros((N, N), np.float32)
        for E, members, launch, B in entries:
            rho[:, members] = drive_batched(N, B, launch)
        return rho

    def _xmap_sharded(self, method, E_opt, theta, run_dir=None) -> np.ndarray:
        c = self.config
        X = self.data.panel
        N = self.data.N
        from repro.distributed.sharded_ccm import (
            _egroup_layout, mesh_axes_size, sharded_ccm_matrix,
            sharded_smap_matrix)

        def matrix(X_lib, layout=None):
            if method == "smap":
                return np.asarray(sharded_smap_matrix(
                    X_lib, X, E_opt=E_opt, tau=c.tau, Tp=c.Tp_cross,
                    theta=float(c.theta if theta is None else theta),
                    ridge=c.ridge, mesh=c.mesh, lib_axes=c.lib_axes,
                    tgt_axes=c.tgt_axes, impl=self._impl, layout=layout))
            return np.asarray(sharded_ccm_matrix(
                X_lib, X, E_opt=E_opt, tau=c.tau, Tp=c.Tp_cross,
                mesh=c.mesh, lib_axes=c.lib_axes, tgt_axes=c.tgt_axes,
                impl=self._impl, batch_libs=c.batch_libs,
                batch_budget_mb=c.batch_budget_mb, layout=layout))

        if run_dir is None:
            return matrix(X)[:N]
        # Journaled mesh run: the lib axis is cut into row chunks and
        # each chunk is ONE SPMD matrix call (libraries auto-pad over
        # the lib shards; rows are independent, so chunking is
        # bit-identical) — completed chunks persist as journal tiles.
        # The static E-group target layout is computed once and reused
        # across every chunk instead of re-derived per call.
        S_l = c.mesh_axis_size(c.lib_axes)
        S_t = mesh_axes_size(c.mesh, c.tgt_axes)
        layout = _egroup_layout(
            jnp.broadcast_to(jnp.asarray(E_opt, jnp.int32), (N,)), S_t)
        tile = c.run_tile_rows or max(S_l, -(-N // 8))
        tile = -(-int(tile) // S_l) * S_l  # round up to full lib shards

        def launch(a, b, B):
            return matrix(X[a:b], layout=layout)

        entries = [(0, np.arange(N), launch, tile)]
        return self._run_journaled(run_dir, method, theta, entries, (N, N),
                                   E_opt)

    def _run_journaled(self, run_dir, method, theta, entries,
                       shape, E_opt) -> np.ndarray:
        """Drive xmap tile groups through a journaled ``MatrixRunner``."""
        from repro.edm.runner import MatrixRunner, run_key
        c = self.config
        groups_sig = [[E, len(members)] for E, members, _, _ in entries]
        th = (float(c.theta if theta is None else theta)
              if method == "smap" else None)
        # The task signature hashes the FULL per-series E table, not a
        # group-size summary: E_opt=[2,3] vs [3,2] keep group sizes but
        # assign different manifolds, and must key to different runs.
        e_table = np.ascontiguousarray(
            np.broadcast_to(np.asarray(E_opt, np.int32), (self.data.N,)))
        key = run_key(self.data.panel, c,
                      ("xmap", method, th, e_table.tobytes()))
        runner = MatrixRunner(
            run_dir, key=key, shape=shape, groups_sig=groups_sig,
            keep=c.checkpoint_keep, checkpoint_every=c.checkpoint_every,
            oom_retries=c.oom_retries,
            invalid_series=self.data.invalid_report,
            straggler_threshold=c.straggler_threshold)
        if runner.complete:
            # Finished journal: the stored matrix IS the result — zero
            # engine launches (restart loops may re-run unconditionally).
            self._bump("runs_short_circuited")
            runner.close()  # release the run_dir lock
            return runner.result()
        with runner:
            for g, (E, members, launch, B) in enumerate(entries):
                runner.drive_group(g, launch, B, members)
            out = runner.finalize()
        self._bump("rows_resumed", runner.resumed_rows)
        return out

    # ------------------------------------------------------ batched entry

    def submit_panel(self, panel, tasks=("optimal_E",)) -> int:
        """Queue a panel for batched execution; returns a ticket id.

        The serving-style entry point: queued panels of the same length
        are concatenated and driven through ONE jitted program per task
        at ``flush()`` (and every flush reuses the programs this
        session's config already compiled), instead of paying a dispatch
        + trace per panel.
        """
        allowed = ("optimal_E", "smap", "xmap")
        tasks = tuple(tasks)
        for t in tasks:
            if t not in allowed:
                raise ValueError(f"unknown task {t!r}; expected {allowed}")
        panel = jnp.asarray(panel)
        if panel.ndim == 1:
            panel = panel[None, :]
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, panel, tasks))
        return ticket

    def flush(self) -> dict[int, PanelResult]:
        """Run every queued panel; returns {ticket: PanelResult}.

        Matrix tasks inherit the engine's double-buffered dispatch
        (ROADMAP session item (b)): each panel's xmap runs as batched
        launches with the device computing batch i+1 while the host
        assembles batch i's block (``core.ccm.drive_batched``).
        """
        queue, self._queue = self._queue, []
        with telemetry.span("session.flush", panels=len(queue)):
            return self._flush_batches(queue)

    def _flush_batches(self, queue) -> dict[int, PanelResult]:
        results = {t: PanelResult() for t, _, _ in queue}
        batches: dict[tuple, list] = collections.defaultdict(list)
        for ticket, panel, tasks in queue:
            batches[(panel.shape[1], tasks)].append((ticket, panel))
        for (L, tasks), items in batches.items():
            big = jnp.concatenate([p for _, p in items], axis=0)
            sess = EDM(big, self.config)
            offs = np.cumsum([0] + [p.shape[0] for _, p in items])
            if "optimal_E" in tasks:
                E_opt, rho = sess.optimal_E()
                for (ticket, _), a, b in zip(items, offs, offs[1:]):
                    results[ticket].E_opt = E_opt[a:b]
                    results[ticket].rho = rho[a:b]
            if "smap" in tasks:
                sweep = sess.smap()
                for (ticket, _), a, b in zip(items, offs, offs[1:]):
                    results[ticket].smap = sweep[a:b]
            if "xmap" in tasks:
                # cross terms force per-panel matrices, but the batch
                # session's per-series state slices cleanly: hand each
                # panel its E_opt slice and its rows of the kNN master
                # instead of re-running the multi-E engine per panel.
                E_all = None if self.config.E else sess._rho()[0]
                master = sess._cache.get("master")
                for (ticket, panel), a, b in zip(items, offs, offs[1:]):
                    psess = EDM(panel, self.config)
                    if master is not None:
                        dM, iM, k_m, lv = master
                        psess._cache["master"] = (dM[a:b], iM[a:b], k_m, lv)
                    results[ticket].xmap = psess.xmap(
                        E_opt=None if E_all is None else E_all[a:b])
            self._bump("panels_flushed", len(items))
        return results
