"""``EDMConfig`` — one frozen, validated home for every EDM hyperparameter.

The free-function era threaded ``E/tau/Tp/theta/k/impl`` through ~25
signatures; a config object is bound to a panel once (``repro.edm.EDM``)
and every method derives what it needs from it. Validation happens in two
stages: ``__post_init__`` checks everything that is knowable without data,
``validate_panel`` checks the config against a concrete (N, L) panel
(neighbor counts vs library size, mesh divisibility).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.embedding import num_embedded, pred_rows
from repro.core.smap_engine import DEFAULT_THETAS
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class EDMConfig:
    """Frozen EDM session configuration (kEDM's knobs, validated once).

    E:        fixed embedding dimension; ``None`` means "per-series
              optimal E" (the session sweeps 1..E_max and caches it).
    E_max:    upper bound of the optimal-E sweep.
    tau:      time-delay lag.
    Tp:       forecast horizon for simplex / optimal-E / S-Map sweeps.
    Tp_cross: cross-map horizon for ccm / xmap (kEDM uses 0).
    theta:    S-Map locality for single-θ tasks (xmap method="smap").
    thetas:   θ grid for the S-Map sweep / nonlinearity test.
    k:        neighbor count; ``None`` means the simplex default E + 1.
    extra_slack: additional kNN-master slack columns beyond the horizon
              minimum. A convergence sweep can derive a library cap at
              index m from the master only when ``k_master >= k +
              (Lp − 1 − m)`` (``edm.plan.master_slack_covers``), so
              sessions planning ``ccm(lib_sizes=...)`` /
              ``surrogate_test`` sweeps down to caps Δ short of the full
              library should set ``extra_slack≈Δ``; smaller caps fall
              back to the one-pass multi-cap engine (never a per-size
              loop).
    batch_libs: library batch size B of the all-pairs matrix engine —
              each ``xmap`` E-group runs as ceil(N/B) batched
              distance→top-k→lookup launches (``core.ccm._group_step``)
              instead of N sequential ``lax.map`` steps. ``None`` (the
              default) sizes B automatically so the in-flight B·Lp²
              f32 distance stack stays under ``batch_budget_mb``
              (``core.ccm.auto_batch_libs``). Results are bit-invariant
              in B, so this is purely a memory/throughput knob.
    batch_budget_mb: memory budget (MB) for that auto rule; ``None``
              picks the backend default (32 on XLA CPU, where the stack
              competes with the last-level cache; 256 on accelerators).
    ridge:    relative Tikhonov strength of the S-Map normal equations.
    impl:     kernel implementation ("auto" | "pallas" | "interpret" |
              "ref"); plans resolve it once via ``ops.resolve_impl``.
    mesh:     a ``jax.sharding.Mesh`` routes every plan through the
              zero-collective sharded engines; ``None`` stays local.
    lib_axes / tgt_axes: mesh axis names of the library / target
              decomposition (matching ``distributed.sharded_ccm``).
    pad:      auto-pad panels to mesh multiples (``False`` = reject
              panels the mesh does not divide evenly).
    cache:    hold multi-E kNN master tables / E_opt in the session and
              reuse them across methods (the facade's raison d'être).
    on_invalid: panel-ingestion policy for NaN/Inf/constant series
              ("raise" | "mask" | "drop", see ``edm.dataset.Dataset``);
              applied when the session wraps a raw array in a Dataset
              (an explicit ``Dataset`` keeps its own policy).
    checkpoint_keep: journaled matrix runs (``xmap(run_dir=...)``) keep
              the last K run-state snapshots on disk
              (``checkpoint.CheckpointManager`` retention).
    checkpoint_every: commit a run-state snapshot every Nth completed
              tile. ``None`` (default) auto-sizes the cadence to ~8
              snapshots per tile group, bounding journal overhead on
              many-tile runs (measured <5% of engine throughput, the
              ``bench_ccm --resume-overhead`` guard); 1 = every tile.
              A *preemption* always snapshots immediately regardless of
              cadence — only a hard crash (SIGKILL) can redo up to
              cadence − 1 tiles.
    oom_retries: max RESOURCE_EXHAUSTED → halve-B backoff retries per
              tile group before the error propagates (the degradation
              ladder bottoms out at B = 1).
    run_tile_rows: journal tile height (library rows) of a *sharded*
              ``xmap(run_dir=...)`` run — the mesh path runs one SPMD
              program per lib-row chunk so completed chunks persist;
              ``None`` auto-sizes ~8 tiles rounded to the lib-shard
              count. Local runs tile at the engine's launch batch B and
              ignore this.
    straggler_threshold: a journaled run's ``StragglerMonitor`` flags a
              tile launch slower than this multiple of the rolling
              median launch time (flags land in the run report and as
              ``straggler.flag`` telemetry events). Perf-observation
              only — never part of the run key, so resuming with a
              different threshold is legal.
    """

    E: int | None = None
    E_max: int = 20
    tau: int = 1
    Tp: int = 1
    Tp_cross: int = 0
    theta: float = 1.0
    thetas: tuple[float, ...] = DEFAULT_THETAS
    k: int | None = None
    extra_slack: int = 0
    batch_libs: int | None = None
    batch_budget_mb: float | None = None
    ridge: float = 1e-6
    impl: str = "auto"
    mesh: Any = None
    lib_axes: tuple[str, ...] = ("data",)
    tgt_axes: tuple[str, ...] = ("model",)
    pad: bool = True
    cache: bool = True
    on_invalid: str = "raise"
    checkpoint_keep: int = 3
    checkpoint_every: int | None = None
    oom_retries: int = 4
    run_tile_rows: int | None = None
    straggler_threshold: float = 2.0

    def __post_init__(self):
        if self.E is not None and self.E < 1:
            raise ValueError(f"E must be >= 1, got {self.E}")
        if self.E_max < 1:
            raise ValueError(f"E_max must be >= 1, got {self.E_max}")
        if self.E is not None and self.E > self.E_max:
            object.__setattr__(self, "E_max", self.E)
        if self.tau < 1:
            raise ValueError(f"tau must be >= 1, got {self.tau}")
        if self.Tp < 0 or self.Tp_cross < 0:
            raise ValueError(
                f"horizons must be >= 0, got Tp={self.Tp}, "
                f"Tp_cross={self.Tp_cross}")
        if self.theta < 0:
            raise ValueError(f"theta must be >= 0, got {self.theta}")
        thetas = tuple(float(t) for t in self.thetas)
        if not thetas:
            raise ValueError("thetas grid must not be empty")
        if any(t < 0 for t in thetas):
            raise ValueError(f"thetas must all be >= 0, got {thetas}")
        object.__setattr__(self, "thetas", thetas)
        if self.k is not None and self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.extra_slack < 0:
            raise ValueError(
                f"extra_slack must be >= 0, got {self.extra_slack}")
        if self.batch_libs is not None and self.batch_libs < 1:
            raise ValueError(
                f"batch_libs must be >= 1, got {self.batch_libs}")
        if self.batch_budget_mb is not None and self.batch_budget_mb <= 0:
            raise ValueError(
                f"batch_budget_mb must be > 0, got {self.batch_budget_mb}")
        if self.ridge < 0:
            raise ValueError(f"ridge must be >= 0, got {self.ridge}")
        if self.impl not in ops.IMPLS:
            raise ValueError(
                f"unknown impl {self.impl!r}; expected one of {ops.IMPLS}")
        from repro.edm.dataset import INVALID_POLICIES
        if self.on_invalid not in INVALID_POLICIES:
            raise ValueError(
                f"unknown on_invalid policy {self.on_invalid!r}; expected "
                f"one of {INVALID_POLICIES}")
        if self.checkpoint_keep < 1:
            raise ValueError(
                f"checkpoint_keep must be >= 1, got {self.checkpoint_keep}")
        if self.checkpoint_every is not None and self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {self.checkpoint_every}")
        if self.oom_retries < 0:
            raise ValueError(
                f"oom_retries must be >= 0, got {self.oom_retries}")
        if self.run_tile_rows is not None and self.run_tile_rows < 1:
            raise ValueError(
                f"run_tile_rows must be >= 1, got {self.run_tile_rows}")
        if not self.straggler_threshold > 0:
            raise ValueError(
                f"straggler_threshold must be > 0, got "
                f"{self.straggler_threshold}")
        object.__setattr__(self, "lib_axes", tuple(self.lib_axes))
        object.__setattr__(self, "tgt_axes", tuple(self.tgt_axes))
        if self.mesh is not None:
            names = tuple(self.mesh.axis_names)
            for ax in self.lib_axes + self.tgt_axes:
                if ax not in names:
                    raise ValueError(
                        f"mesh has axes {names}, missing {ax!r}")

    # ------------------------------------------------------------ derived

    def k_for(self, E: int) -> int:
        """Neighbor count at dimension E (simplex default E + 1)."""
        return (E + 1) if self.k is None else self.k

    @property
    def slack(self) -> int:
        """Extra master-table columns so every planned ``max_idx`` cap can
        be applied post hoc: one candidate is lost per horizon step, plus
        ``extra_slack`` for convergence-sweep library caps."""
        return max(1, self.Tp, self.Tp_cross) + self.extra_slack

    def mesh_axis_size(self, axes: tuple[str, ...]) -> int:
        from repro.distributed.sharded_ccm import mesh_axes_size
        return mesh_axes_size(self.mesh, axes)

    # --------------------------------------------------------- validation

    def validate_panel(self, N: int, L: int) -> None:
        """Bind-time checks against a concrete (N, L) panel."""
        E_chk = self.E if self.E is not None else self.E_max
        num_embedded(L, E_chk, self.tau)  # raises "series too short"
        rows = pred_rows(L, E_chk, self.tau, self.Tp)
        if self.k is not None and self.k > rows:
            raise ValueError(
                f"k={self.k} exceeds the {rows} prediction rows of an "
                f"(L={L}, E={E_chk}, tau={self.tau}, Tp={self.Tp}) panel")
        if self.mesh is not None and not self.pad:
            for axes in (self.lib_axes, self.tgt_axes):
                size = self.mesh_axis_size(axes)
                if N % size != 0:
                    raise ValueError(
                        f"mesh axes {axes} (size {size}) do not divide the "
                        f"{N}-series panel; pass pad=True or pad the panel")

    def replace(self, **changes) -> "EDMConfig":
        """A copy with ``changes`` applied (and re-validated)."""
        return dataclasses.replace(self, **changes)
