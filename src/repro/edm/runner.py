"""Fault-tolerant matrix runs: tile journal, preemption, OOM backoff.

The workloads the batched matrix engine targets are exactly the ones
that get preempted — whole-brain CCM at 10⁵ series is 10¹⁰ pairs of
tiled launches, hours to days of wall time — so ``EDM.xmap(...,
run_dir=...)`` journals every (lib-batch × tgt-group) tile through a
``MatrixRunner`` and a preempted job restarts at the last committed
tile instead of from zero.

Journal format (everything lives under ``run_dir``):

* ``run.json`` — the run manifest: a **content hash** of the panel
  bytes + the numeric-semantics fields of the ``EDMConfig`` + the task
  signature (method, θ, the **full per-series E table** — not a
  group-size summary, so reassigning manifolds while keeping group
  sizes still changes the key), the matrix shape, and the group
  layout. A resume whose recomputed key differs is REFUSED with a
  clear error — a stale journal (edited panel, changed config, changed
  ``E_opt``) can never silently leak rows into a fresh run.
* ``state/step_*`` — run-state snapshots via
  ``checkpoint.CheckpointManager`` (atomic tmp+rename publish, last-K
  retention, manifest-validated restore): the partial ρ matrix plus a
  per-(group, lib-row) done mask. Committed every
  ``checkpoint_every``-th tile; a crash between snapshots redoes at
  most that many tiles.
* ``heartbeat`` — one appended line per committed tile
  (``distributed.fault.Heartbeat``) so an external watchdog can detect
  a hang (no heartbeat progress) as opposed to a crash (process gone).
* ``lock`` — an advisory ``flock`` held for the runner's lifetime: a
  restart loop relaunching before the dying process has fully exited
  would otherwise interleave two writers over ``run.json`` and the
  snapshot dirs. The second process fails fast with a clear error.
* ``report.json`` — the run report: progress counters, straggler
  flags (``StragglerMonitor`` over the engine launch timings), the OOM
  backoff decision trail, and the dataset's invalid-series records.

Correctness contract: tiles are committed only after their rows have
materialized on host, done-ness is tracked per *library row* (so the
tile shape may change across resumes — the engines are bit-invariant
in batch size B), and a resumed run is **bit-identical** to an
uninterrupted one because every committed row is replayed from the
journal verbatim and every recomputed row runs the same engine on the
same inputs.

Graceful degradation:

* **Preemption** — a ``PreemptionGuard`` turns SIGTERM/SIGINT into a
  flag polled at each tile commit; the runner snapshots the state,
  writes the report, and exits with code ``PREEMPTED_EXIT`` (17) — the
  restart loop's "resume me" signal — instead of dying mid-launch.
* **OOM backoff** — a RESOURCE_EXHAUSTED (or any out-of-memory) error
  around a launch halves the library batch B (re-equalized over the
  remaining rows, the ``auto_batch_libs`` discipline) and retries, at
  most ``oom_retries`` times, logging each decision; a budget
  misestimate degrades to smaller launches instead of killing the job.
"""

from __future__ import annotations

import fcntl
import hashlib
import json
import os
import signal
import time
import uuid

import numpy as np

from repro import telemetry
from repro.checkpoint import CheckpointManager
from repro.core.ccm import drive_batched
from repro.distributed.fault import (Heartbeat, PreemptionGuard,
                                     StragglerMonitor)

#: Exit code of a preempted run that checkpointed cleanly (restart loops
#: treat it as "resume from run_dir", distinct from crash codes).
PREEMPTED_EXIT = 17

#: EDMConfig fields hashed into the run key — everything that changes
#: numeric results or the task decomposition. Deliberately excluded:
#: perf-only knobs (batch_libs, batch_budget_mb, checkpoint_*,
#: oom_retries, run_tile_rows, pad) — results are invariant in them, so
#: resuming with a different batch size or snapshot cadence is legal —
#: and the mesh object itself (its axis layout is keyed separately).
KEYED_CONFIG_FIELDS = ("E", "E_max", "tau", "Tp", "Tp_cross", "theta",
                       "thetas", "k", "extra_slack", "ridge", "impl",
                       "cache", "on_invalid")


def config_fingerprint(config) -> str:
    """Deterministic string of the result-relevant config fields."""
    parts = [f"{f}={getattr(config, f)!r}" for f in KEYED_CONFIG_FIELDS]
    if config.mesh is not None:
        parts.append(f"mesh={tuple(config.mesh.shape.items())!r}"
                     f"/lib={config.lib_axes!r}/tgt={config.tgt_axes!r}")
    return ";".join(parts)


def run_key(panel, config, task_sig) -> str:
    """Content hash identifying one (panel, config, task) matrix run.

    The staleness rule: a journal written under a different key — the
    panel's bytes changed, a numeric config knob changed, the task or
    its E-group structure changed — must be refused, never resumed.
    """
    arr = np.ascontiguousarray(np.asarray(panel))
    h = hashlib.sha256()
    h.update(f"{arr.dtype}{arr.shape}".encode())
    h.update(arr.tobytes())
    h.update(config_fingerprint(config).encode())
    h.update(repr(task_sig).encode())
    return h.hexdigest()[:32]


#: Allocator-failure markers, ANCHORED: a message must start with one
#: (the XLA status prefix / allocator message itself) or carry it right
#: after a ``": "`` wrapper separator. An error that merely *mentions*
#: memory mid-sentence is not an OOM and must not burn backoff retries.
OOM_MARKERS = ("RESOURCE_EXHAUSTED", "Out of memory",
               "CUDA_ERROR_OUT_OF_MEMORY")


def is_oom_error(e: BaseException) -> bool:
    """Does this look like a device/host allocation failure?

    XLA surfaces device OOM as ``XlaRuntimeError`` with a
    ``RESOURCE_EXHAUSTED:`` status prefix (at dispatch or at the async
    result's materialization); host-side failures come as
    ``MemoryError`` or allocator messages. Matching on the anchored
    status/allocator text keeps this backend-agnostic — the error class
    moved modules across jaxlib versions — without misclassifying
    unrelated errors whose text happens to mention memory.
    """
    if isinstance(e, MemoryError):
        return True
    msg = str(e)
    return any(msg.startswith(m) or f": {m}" in msg for m in OOM_MARKERS)


def halved_batch(B: int, remaining: int) -> int:
    """The OOM ladder's next rung: halve B, re-equalize the launches.

    Same discipline as ``auto_batch_libs``: under the new cap
    ``max(1, B // 2)``, pick B = ceil(remaining / nb) for the smallest
    launch count nb the cap allows, so the ragged final launch never
    wastes a near-full padded batch.
    """
    cap = max(1, B // 2)
    remaining = max(1, remaining)
    cap = min(cap, remaining)
    nb = -(-remaining // cap)
    return -(-remaining // nb)


class RunState:
    """The journaled state of one matrix run (a checkpointable pytree).

    rho:  (N_lib, N_tgt) f32 — committed tiles' values, verbatim.
    done: (n_groups, N_lib) bool — which library rows of which tile
          group have been committed. Row-level (not tile-level) so a
          resume may re-tile with a different B (bit-invariance in B
          makes that legal).
    """

    def __init__(self, shape: tuple[int, int], n_groups: int):
        self.rho = np.zeros(shape, np.float32)
        self.done = np.zeros((n_groups, shape[0]), bool)

    def tree(self) -> dict:
        return {"rho": self.rho, "done": self.done}

    def load(self, tree: dict) -> None:
        # np.array, not asarray: restore() hands back device arrays whose
        # host view is read-only, and committed tiles write into these.
        self.rho = np.array(tree["rho"], np.float32)
        self.done = np.array(tree["done"], bool)

    @property
    def rows_done(self) -> int:
        return int(self.done.sum())

    @property
    def complete(self) -> bool:
        return bool(self.done.all())


class MatrixRunner:
    """Journaled driver for one all-pairs matrix run under ``run_dir``.

    Built by ``EDM.xmap(run_dir=...)`` (not usually directly): the
    session resolves the task into tile groups — per-E-group for the
    local engines, one lib-chunked group for the sharded path — and
    calls ``drive_group`` per group between ``start()``/``finalize()``.
    See the module docstring for the journal format and the guarantees.
    """

    def __init__(self, run_dir: str, *, key: str,
                 shape: tuple[int, int], groups_sig,
                 keep: int = 3, checkpoint_every: int | None = None,
                 oom_retries: int = 4, invalid_series=(),
                 straggler_threshold: float = 2.0):
        self.dir = os.path.abspath(run_dir)
        os.makedirs(self.dir, exist_ok=True)
        self.key = key
        self.shape = tuple(int(s) for s in shape)
        self.groups_sig = [[int(E), int(n)] for E, n in groups_sig]
        self.checkpoint_every = (None if checkpoint_every is None
                                 else int(checkpoint_every))
        self.oom_retries = int(oom_retries)
        self.ckpt = CheckpointManager(os.path.join(self.dir, "state"),
                                      keep=keep)
        self.heartbeat = Heartbeat(os.path.join(self.dir, "heartbeat"))
        self.monitor = StragglerMonitor(threshold=straggler_threshold)
        self.oom_trail: list[dict] = []
        self.invalid_series = list(invalid_series)
        self.state = RunState(self.shape, len(self.groups_sig))
        self._tiles = 0            # committed this process
        self._since_snapshot = 0
        self._t0 = time.monotonic()
        self._guard: PreemptionGuard | None = None
        self.resumed_rows = 0
        #: this attempt's identity + the journal's prior-attempt trail —
        #: the resume lineage the run report and inspector surface.
        self.run_id = uuid.uuid4().hex[:12]
        self.prior_attempts: list[dict] = []
        self._sink: telemetry.JsonlSink | None = None
        self._lock = None
        self._acquire_lock()
        try:
            self._load_manifest()
        except BaseException:
            self._release_lock()
            raise
        self._pairs_resumed = self._pairs_done()
        if not self.complete:
            # One JSONL event log per journaled run, shared across
            # attempts (append mode): every span/event emitted anywhere
            # in the process while this runner is live lands here.
            self._sink = telemetry.JsonlSink(
                os.path.join(self.dir, "telemetry", "events.jsonl"))
            telemetry.add_sink(self._sink)
            telemetry.counter("edm_runs_started").inc()
            telemetry.event(
                "run.resume" if self.resumed_rows else "run.start",
                run_id=self.run_id, key=self.key,
                rows_resumed=self.resumed_rows,
                prior_run_ids=[a["run_id"] for a in self.prior_attempts])

    # --------------------------------------------------------------- lock

    def _acquire_lock(self) -> None:
        """Advisory single-writer lock on ``run_dir`` (fail fast).

        The preemption/restart-loop design (exit 17, controller
        relaunches) makes it plausible for a resume process to race a
        still-dying predecessor; two writers would interleave
        ``run.json``/``report.json`` replaces and snapshot dirs. flock
        is per open file description, so this also catches two runners
        in one process.
        """
        f = open(os.path.join(self.dir, "lock"), "w")
        try:
            fcntl.flock(f, fcntl.LOCK_EX | fcntl.LOCK_NB)
        except OSError:
            f.close()
            raise RuntimeError(
                f"run_dir {self.dir} is locked by another live run — a "
                f"previous process is still writing this journal. Wait "
                f"for it to exit (or kill it) before resuming.") from None
        self._lock = f

    def _release_lock(self) -> None:
        if self._lock is not None:
            fcntl.flock(self._lock, fcntl.LOCK_UN)
            self._lock.close()
            self._lock = None

    # ---------------------------------------------------- manifest/journal

    @property
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "run.json")

    def _load_manifest(self) -> None:
        path = self._manifest_path
        if not os.path.exists(path):
            self._status = "running"
            self._write_manifest()
            return
        with open(path) as f:
            manifest = json.load(f)
        if manifest.get("key") != self.key:
            raise ValueError(
                f"run_dir {self.dir} holds a journal for a DIFFERENT run "
                f"(key {manifest.get('key')!r}, this run {self.key!r}): "
                f"the panel, config, or task changed since it was "
                f"written. Refusing to resume from a stale journal — "
                f"point run_dir at a fresh directory or delete this one.")
        if (manifest.get("shape") != list(self.shape)
                or manifest.get("groups") != self.groups_sig):
            raise ValueError(
                f"run_dir {self.dir} journal layout does not match this "
                f"run (shape {manifest.get('shape')} vs "
                f"{list(self.shape)}) despite an identical key — the "
                f"journal is corrupt; delete it and rerun")
        self._status = manifest.get("status", "running")
        self.prior_attempts = list(manifest.get("attempts", []))
        step = self.ckpt.latest_step()
        if step is not None:
            self.state.load(self.ckpt.restore(self.state.tree(), step=step))
            self._since_snapshot = 0
            self.resumed_rows = self.state.rows_done
        if not self.complete:
            # a live attempt: reopen the manifest under this run_id
            self._status = "running"
            self._write_manifest()

    def _pairs_done(self) -> int:
        """Matrix cells committed so far (each group's done rows cover
        only that group's member columns — not the full target axis)."""
        return int(sum(self.state.done[g].sum() * n
                       for g, (_, n) in enumerate(self.groups_sig)))

    def _attempt_record(self) -> dict:
        return {"run_id": self.run_id, "status": self._status,
                "rows_resumed": self.resumed_rows,
                "elapsed_s": round(time.monotonic() - self._t0, 3)}

    def _write_manifest(self) -> None:
        tmp = self._manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"key": self.key, "shape": list(self.shape),
                       "groups": self.groups_sig,
                       "status": self._status,
                       "attempts": (self.prior_attempts
                                    + [self._attempt_record()])}, f)
        os.replace(tmp, self._manifest_path)

    def _snapshot(self) -> None:
        self.ckpt.save(self.state.rows_done, self.state.tree())
        self._since_snapshot = 0
        # refresh the report on every snapshot so the run inspector
        # (python -m repro.edm.inspect) sees live progress, not just the
        # terminal states
        self.write_report()

    @property
    def complete(self) -> bool:
        return self._status == "complete" and self.state.complete

    def result(self) -> np.ndarray:
        return self.state.rho

    # ------------------------------------------------------------ running

    def start(self) -> "MatrixRunner":
        """Install the preemption guard (SIGTERM/SIGINT → checkpoint)."""
        if self._guard is None:
            self._guard = PreemptionGuard(
                signals=(signal.SIGTERM, signal.SIGINT))
        return self

    def close(self) -> None:
        if self._guard is not None:
            self._guard.restore()
            self._guard = None
        if self._sink is not None:
            telemetry.remove_sink(self._sink)
            self._sink.close()
            self._sink = None
        self._release_lock()

    def __enter__(self) -> "MatrixRunner":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def drive_group(self, g: int, launch, B: int, members) -> None:
        """Drive tile group ``g`` to completion, journaled and guarded.

        ``launch(a, b, B)`` must return matrix rows [a, b) of the group's
        column block (the engines' launch closures); ``members`` are the
        target columns the block lands in. Already-done rows (a resumed
        journal) are skipped; each landed tile commits rows + done-mask,
        beats the heartbeat, snapshots on cadence, and polls the
        preemption guard. RESOURCE_EXHAUSTED triggers the halve-B
        ladder (``oom_retries`` rungs, logged in the run report) before
        propagating.
        """
        cols = np.asarray(members)
        done = self.state.done[g]
        Nl = self.shape[0]
        B = max(1, min(int(B), Nl))
        attempts = 0
        cadence = self.checkpoint_every

        def commit(a, b, block):
            self.state.rho[a:b, cols] = block
            done[a:b] = True
            self._tiles += 1
            self._since_snapshot += 1
            telemetry.counter("edm_tiles_committed").inc()
            telemetry.event("tile.commit", group=g, a=a, b=b,
                            rows_done=self.state.rows_done)
            self.heartbeat.beat(self.state.rows_done)
            # auto cadence: ~8 snapshots per group — bounds journal I/O
            # to a few % of engine time on many-tile runs while a
            # preemption still snapshots immediately (below); only a
            # hard crash redoes up to cadence − 1 tiles.
            every = cadence or max(1, -(-(-(-Nl // B)) // 8))
            if self._since_snapshot >= every:
                self._snapshot()
            if self._guard is not None and self._guard.requested:
                self._preempt()

        while True:
            todo = np.nonzero(~done)[0]
            if len(todo) == 0:
                return
            start = int(todo[0])  # commits are in order: ~done is a suffix
            try:
                drive_batched(Nl, B, launch, start=start, on_block=commit,
                              monitor=self.monitor)
                return
            except Exception as e:  # noqa: BLE001 — filtered to OOM below
                if not is_oom_error(e):
                    if "out of memory" in str(e).lower():
                        # Mentions memory but fails the anchored match:
                        # propagate unretried, with a trail entry so the
                        # report explains why no backoff was attempted.
                        self.oom_trail.append(
                            {"group": g, "B": B, "action": "unclassified",
                             "error": str(e)[:200]})
                        self.write_report()
                    raise
                if attempts >= self.oom_retries or B <= 1:
                    self.oom_trail.append(
                        {"group": g, "B": B, "action": "give_up",
                         "attempt": attempts, "error": str(e)[:200]})
                    self.write_report()
                    raise
                remaining = Nl - int(np.nonzero(~done)[0][0])
                newB = halved_batch(B, remaining)
                self.oom_trail.append(
                    {"group": g, "B": B, "to_B": newB, "action": "halve",
                     "attempt": attempts, "rows_remaining": remaining,
                     "error": str(e)[:200]})
                telemetry.counter("edm_oom_backoffs").inc()
                telemetry.event("oom.backoff", group=g, B=B, to_B=newB,
                                rows_remaining=remaining)
                attempts += 1
                B = newB

    def _preempt(self):
        """Commit the journal and exit PREEMPTED_EXIT (restart-loop ABI)."""
        self._status = "preempted"
        self._snapshot()
        self._write_manifest()
        self.write_report()
        telemetry.counter("edm_runs_preempted").inc()
        telemetry.event("run.preempt", run_id=self.run_id,
                        rows_done=self.state.rows_done)
        self.close()
        raise SystemExit(PREEMPTED_EXIT)

    def finalize(self) -> np.ndarray:
        """Final snapshot + report; marks the manifest complete."""
        if not self.state.complete:
            raise RuntimeError(
                f"finalize() with {int((~self.state.done).sum())} rows "
                f"not driven — a tile group was skipped")
        self._status = "complete"
        self._snapshot()
        self._write_manifest()
        self.write_report()
        telemetry.event("run.complete", run_id=self.run_id,
                        rows_done=self.state.rows_done,
                        tiles=self._tiles)
        self.close()
        return self.state.rho

    # ------------------------------------------------------------- report

    def write_report(self) -> dict:
        rows_total = int(self.state.done.size)
        elapsed = time.monotonic() - self._t0
        pairs_done = self._pairs_done()
        pairs_this = pairs_done - self._pairs_resumed
        prior_elapsed = sum(a.get("elapsed_s") or 0.0
                            for a in self.prior_attempts)
        report = {
            "key": self.key,
            "status": self._status,
            "run_id": self.run_id,
            "prior_run_ids": [a.get("run_id")
                              for a in self.prior_attempts],
            "rows_done": self.state.rows_done,
            "rows_total": rows_total,
            "rows_resumed": self.resumed_rows,
            "rows_this_attempt": self.state.rows_done - self.resumed_rows,
            "tiles_committed": self._tiles,
            "pairs_done": pairs_done,
            # this-attempt throughput and monotonic durations: elapsed_s
            # is THIS attempt's monotonic clock; cumulative_elapsed_s
            # adds every prior attempt's recorded duration so the
            # inspector can show cumulative vs this-attempt progress.
            "pairs_per_s": (round(pairs_this / elapsed, 3)
                            if elapsed > 0 else None),
            "tiles_per_s": (round(self._tiles / elapsed, 3)
                            if elapsed > 0 else None),
            "elapsed_s": round(elapsed, 3),
            "cumulative_elapsed_s": round(prior_elapsed + elapsed, 3),
            "stragglers": self.monitor.report(),
            "oom_backoff": self.oom_trail,
            "invalid_series": self.invalid_series,
            # the whole process-local metrics registry, Prometheus text
            # exposition format (edm_pairs_total, the per-launch latency
            # histogram, cache/run counters, ...)
            "metrics_prom": telemetry.render_prom(),
        }
        tmp = os.path.join(self.dir, "report.json.tmp")
        with open(tmp, "w") as f:
            json.dump(report, f, indent=1)
        os.replace(tmp, os.path.join(self.dir, "report.json"))
        return report
