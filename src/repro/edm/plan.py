"""Plan layer: what each session method will run, and the cached-table
drivers it dispatches to.

Every ``EDM`` method builds a ``Plan`` first — which kernels at which
implementation, local vs sharded placement, and which session-cached
state it can reuse — then executes it. The expensive shared state is the
**multi-E kNN master table**: one uncapped ``ops.all_knn_multi_e`` pass
per series (k_master = max needed k + slack columns) from which every
per-(E, Tp) neighbor table the session needs is derived *post hoc*,
bit-identically:

* neighbor **indices**: the master rows are globally sorted by
  (distance, index) — exactly ``lax.top_k``'s tie order — so filtering
  out entries past a ``max_idx`` horizon cap and keeping the first k is
  identical to running the capped top-k directly, as long as the master
  carries ``slack`` >= number of excluded candidates spare columns
  (one per horizon step).
* neighbor **distances**: two bit-exact sources, matched to what the
  legacy path being replaced used. The optimal-E sweep reads the master
  distances directly (same multi-E accumulator the legacy sweep ran);
  simplex/CCM lookups recompute just the k selected distances in the
  same accumulation order as ``ops.pairwise_distances`` — O(rows·k·E)
  instead of O(E·Lp²) — because the per-E pipeline's floats differ from
  the multi-E accumulator's by ~1 ULP (negated-accumulator streams fuse
  differently) and parity with the legacy free functions is bit-exact,
  not approximate.

Memory: a master table holds 2 · N · E_max · L · k_master values (f32 +
i32). That is the deliberate price of "compute neighbors once, reuse
everywhere" (kEDM §2.1); sessions on panels too big for it set
``cache=False`` or a mesh (sharded plans keep state device-resident).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core.embedding import embed_offset, num_embedded, pred_rows
from repro.kernels import ops
from repro.kernels.ref import strict_sq


@dataclasses.dataclass(frozen=True)
class Plan:
    """What a session method resolved to run (introspectable, hashable)."""

    task: str              # "optimal_E" | "simplex" | "smap" | "ccm" | "xmap"
    impl: str              # concrete kernel implementation (never "auto")
    placement: str         # "local" | "sharded"
    E: str                 # "fixed:<n>" | "per-series" | "sweep:1..<E_max>"
    Tp: int
    reuse: tuple[str, ...]  # session cache keys this plan reads
    builds: tuple[str, ...]  # session cache keys this plan populates
    detail: str = ""

    def describe(self) -> str:
        reuse = ", ".join(self.reuse) if self.reuse else "nothing"
        builds = ", ".join(self.builds) if self.builds else "nothing"
        return (f"{self.task}[{self.placement}/{self.impl}] E={self.E} "
                f"Tp={self.Tp} reuses {reuse}; builds {builds}"
                + (f" ({self.detail})" if self.detail else ""))


# ---------------------------------------------------------------- master


@functools.partial(jax.jit, static_argnames=("E_max", "tau", "k", "impl"))
def panel_master(X, *, E_max, tau, k, impl):
    """Uncapped multi-E kNN master tables for a whole (N, L) panel.

    One ``all_knn_multi_e`` pass per series (sequential ``lax.map``
    bounds peak memory at one series' accumulator) →
    (dists, idx), both (N, E_max, L, k).
    """

    def one(x):
        return ops.all_knn_multi_e(x, E_max=E_max, tau=tau, k=k,
                                   exclude_self=True, max_idx=None, impl=impl)

    return jax.lax.map(one, X)


@functools.partial(jax.jit, static_argnames=("tau", "impl"))
def panel_master_append(X, dM, iM, *, tau, impl):
    """Grow a whole panel's master tables to cover appended points.

    ``X`` is the grown (N, L_new) panel; ``dM``/``iM`` the stored
    ``panel_master`` tables of its (N, L_old) prefix. One
    ``ops.master_append`` merge per series (sequential ``lax.map``, as
    in ``panel_master``) → (N, E_max, L_new, k) tables bit-identical to
    ``panel_master`` on the grown panel, at O(Lp·(k+Δt)) per level
    instead of O(Lp²). The serving path's per-tick master update
    (``EDM.append``); k_master is preserved, so the
    ``master_slack_covers`` slack rule carries over unchanged.
    """

    def one(args):
        x, d, i = args
        return ops.master_append(x, d, i, tau=tau, impl=impl)

    return jax.lax.map(one, (X, dM, iM))




def _derive_idx(iE, *, k, max_idx):
    """First k master indices surviving a ``max_idx`` cap (stable order).

    iE: master index level rows, (…, rows, k_master) — one series or a
    (B, rows, k_master) batch; all ops are row-independent along the
    last axis, so the batched call equals the per-series calls
    bit-for-bit. Returns ((…, rows, k) idx with -1 in slots lacking a
    valid candidate, validity mask) — index-identical to a capped
    ``topk_select``.
    """
    valid = (iE >= 0) & (iE <= max_idx)
    order = jnp.argsort(jnp.where(valid, 0, 1).astype(jnp.int32),
                        axis=-1)[..., :k]  # jnp.argsort is stable
    ok = jnp.take_along_axis(valid, order, axis=-1)
    return jnp.where(ok, jnp.take_along_axis(iE, order, axis=-1), -1), ok


def _derive(dE, iE, *, k, max_idx):
    """Like ``_derive_idx`` but also carrying the master distances —
    bit-identical to a capped ``topk_select`` (see module docstring)."""
    valid = (iE >= 0) & (iE <= max_idx)
    order = jnp.argsort(jnp.where(valid, 0, 1).astype(jnp.int32),
                        axis=1)[:, :k]
    ok = jnp.take_along_axis(valid, order, axis=1)
    d = jnp.where(ok, jnp.take_along_axis(dE, order, axis=1), jnp.inf)
    i = jnp.where(ok, jnp.take_along_axis(iE, order, axis=1), -1)
    return d, i, ok


def _gathered_dists(x, idx, ok, *, E, tau):
    """Euclidean distances of the selected neighbor pairs only.

    Same accumulation order as ``ops.pairwise_distances`` (acc += d²
    per lag k), so the values are bit-identical to the per-E pipeline's
    at O(rows·k·E) instead of O(E·Lp²). Invalid slots → inf.
    """
    Lp = num_embedded(x.shape[-1], E, tau)
    rows = idx.shape[0]
    ii = jnp.arange(rows, dtype=jnp.int32)[:, None]
    jj = jnp.maximum(idx, 0)
    acc = jnp.zeros(idx.shape, jnp.float32)
    xf = x.astype(jnp.float32)
    for lag in range(E):
        xk = jax.lax.dynamic_slice_in_dim(xf, lag * tau, Lp, axis=-1)
        d = xk[ii] - xk[jj]
        acc = acc + strict_sq(d)
    return jnp.where(ok, jnp.sqrt(jnp.maximum(acc, 0.0)), jnp.inf)


# ---------------------------------------------------- cached-table drivers


@functools.partial(jax.jit,
                   static_argnames=("E_max", "tau", "Tp", "impl"))
def rho_curves_from_master(X, dM, iM, *, E_max, tau, Tp, impl):
    """ρ(E) for every series from the master tables → (N, E_max).

    Reads the master's own distances (the legacy sweep ran the same
    multi-E accumulator, so this is bit-identical to
    ``core.simplex.rho_curve``) and derives each level's Tp-capped
    table post hoc instead of re-running the engine.
    """
    L = X.shape[-1]

    def one(args):
        x, d, i = args
        rhos = []
        for E in range(1, E_max + 1):
            rows = pred_rows(L, E, tau, Tp)
            mx = num_embedded(L, E, tau) - 1 - Tp
            off = embed_offset(E, tau, Tp)
            dk, ik, _ = _derive(d[E - 1, :rows], i[E - 1, :rows],
                                k=E + 1, max_idx=mx)
            w = ops.make_weights(dk)
            rhos.append(
                ops.lookup_rho(x[None, :], ik, w, offset=off, impl=impl)[0])
        return jnp.stack(rhos)

    return jax.lax.map(one, (X, dM, iM))


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "k", "impl"))
def simplex_skill_from_master(X, iM_E, *, E, tau, Tp, k, impl):
    """Leave-one-out simplex skill per series from cached indices → (N,).

    iM_E: (N, L, k_master) master index level E. Bit-identical to
    ``core.simplex.simplex_skill`` per series (indices derived, selected
    distances recomputed in pairwise order).
    """
    L = X.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)

    def one(args):
        x, iE = args
        ik, ok = _derive_idx(iE[:Lp], k=k, max_idx=Lp - 1 - Tp)
        d = _gathered_dists(x, ik, ok, E=E, tau=tau)
        w = ops.make_weights(d)
        return ops.lookup_rho(x[None, :], ik[:rows], w[:rows], offset=off,
                              impl=impl)[0]

    return jax.lax.map(one, (X, iM_E))


def master_slack_covers(caps, *, Lp: int, k: int, k_master: int) -> bool:
    """The k_master-slack rule for post-hoc library caps (ROADMAP (c)).

    Deriving a capped neighbor table from the uncapped master keeps the
    first k master entries with index <= cap. That equals the true
    capped top-k iff the master still *contains* k valid entries in the
    worst case: a cap at index m excludes the ``Lp − 1 − m`` columns
    beyond it, and all of them may outrank every valid candidate, so
    the master must carry ``k_master >= k + (Lp − 1 − min(caps))``
    columns. Large (near-full-library) convergence sizes satisfy this
    with the session's default slack; small sizes fall back to the
    one-pass multi-cap engine (``core.ccm.ccm_convergence``) — never to
    a per-size re-scan loop.
    """
    return k_master >= k + (Lp - 1 - min(caps))


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "caps", "k",
                                             "impl"))
def ccm_convergence_from_master(x, iM_E, targets, *, E, tau, Tp, caps, k,
                                impl):
    """Convergence curve grid from cached master indices → (|caps|, N).

    The cached-session counterpart of ``core.ccm.ccm_convergence``: each
    library-prefix cap's neighbor table is derived post hoc from ONE
    master index level (callers must check ``master_slack_covers``
    first), and only the k selected distances are recomputed — no
    pairwise pass, no top-k, bit-identical ρ to the legacy per-size
    sweep (see module docstring).
    """
    L = x.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    iE = iM_E[:Lp]
    curves = []
    for m in caps:  # static, small: unrolled per-cap derivations
        ik, ok = _derive_idx(iE, k=k, max_idx=m)
        d = _gathered_dists(x, ik, ok, E=E, tau=tau)
        w = ops.make_weights(d)
        curves.append(ops.lookup_rho(targets, ik[:rows], w[:rows],
                                     offset=off, impl=impl))
    return jnp.stack(curves)


def _gathered_dists_batch(X, idx, ok, *, E, tau):
    """Batched ``_gathered_dists``: selected-pair distances for B series.

    Same per-lag accumulation order on the gathered values; gathers are
    exact, so only the (B, rows, k)-shaped f32 chain is rounding-
    sensitive (bit-invariant in B in practice — the k axis, not the
    batch axis, is minor).
    """
    Lp = num_embedded(X.shape[-1], E, tau)
    B, rows, k = idx.shape
    jj = jnp.maximum(idx, 0).reshape(B, rows * k)
    acc = jnp.zeros(idx.shape, jnp.float32)
    xf = X.astype(jnp.float32)
    for lag in range(E):
        xk = jax.lax.dynamic_slice_in_dim(xf, lag * tau, Lp, axis=-1)
        d = (xk[:, :rows, None]
             - jnp.take_along_axis(xk, jj, axis=-1).reshape(B, rows, k))
        acc = acc + strict_sq(d)
    return jnp.where(ok, jnp.sqrt(jnp.maximum(acc, 0.0)), jnp.inf)


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "k", "impl"))
def _master_group_step(Xb, iMb, targets, *, E, tau, Tp, k, impl):
    """One master-derived engine launch: (B, Nt) ρ for B libraries.

    The cached-session twin of ``core.ccm._group_step``: neighbor
    indices come from the batched stable filter over the master levels
    (zero kNN work), the k selected distances are recomputed in pairwise
    accumulation order, and weights + fused-ρ lookups run as per-series
    ``lax.map`` sub-steps (per-series shapes ⇒ bit-invariant in B).
    """
    from repro.core.ccm import post_lookup_rho

    L = Xb.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = Lp - 1 - max(Tp, 0)
    ik, ok = _derive_idx(iMb[:, :Lp], k=k, max_idx=hard_max)
    d = _gathered_dists_batch(Xb, ik, ok, E=E, tau=tau)
    return post_lookup_rho(targets, d, ik, rows=rows, off=off, impl=impl)


def make_master_group_launch(X, iM_E, targets, *, E, tau, Tp, k, impl):
    """Launch closure of the master-derived engine: ``launch(a, b, B)``.

    The cached-master twin of ``core.ccm.make_group_launch``, factored
    out for the fault-tolerant driver (``repro.edm.runner``) — bit-
    invariance in B makes the closure re-drivable at any batch size
    after an OOM backoff or a resume.
    """
    from repro.core.ccm import pad_batch

    impl_r = ops.resolve_impl(impl)
    master_launches = telemetry.counter("edm_master_launches")

    def launch(a, b, B):
        master_launches.inc()
        return _master_group_step(
            pad_batch(X[a:b], B), pad_batch(iM_E[a:b], B), targets, E=E,
            tau=tau, Tp=Tp, k=k, impl=impl_r)

    return launch


def master_group_batch_bytes(Lp: int, k_master: int) -> int:
    """Per-series in-flight bytes of one master-derived launch.

    ~4 live (B, Lp, k_master)-sized buffers per launch (validity, sort
    keys/order, gathered dists) — the footprint ``auto_batch_libs``
    should size against for this engine (NOT the direct engine's
    (B, Lp, Lp) distance stack, which derivation never holds).
    """
    return 16 * Lp * int(k_master)


def ccm_group_from_master_batched(X, iM_E, targets, *, E, tau, Tp, k, impl,
                                  batch_libs=None,
                                  budget_mb=None) -> "np.ndarray":
    """Library-batched CCM block from cached master indices → (N, Nt) ρ.

    The cached-session counterpart of ``core.ccm.ccm_group_batched``:
    ceil(N/B) double-buffered ``_master_group_step`` launches instead of
    N sequential ``lax.map`` steps. B is sized against this engine's
    *actual* in-flight footprint — O(B·Lp·k_master) for the batched
    stable-filter sort plus gathered-distance stage, NOT the direct
    engine's (B, Lp, Lp) distance stack (which derivation never holds):
    sizing by the distance-stack rule would collapse B to 1 on long
    series exactly where batching the derivation is cheapest.
    """
    from repro.core.ccm import auto_batch_libs, drive_batched

    import numpy as np

    X = jnp.asarray(X)
    iM_E = jnp.asarray(iM_E)
    Nl = X.shape[0]
    Lp = num_embedded(X.shape[-1], E, tau)
    if Nl == 0:  # empty library axis: empty matrix, like the legacy path
        return np.zeros((0, targets.shape[0]), np.float32)
    if batch_libs is not None:
        B = batch_libs
    else:
        B = auto_batch_libs(
            Lp, Nl, budget_mb,
            per_series_bytes=master_group_batch_bytes(Lp, iM_E.shape[-1]))
    B = max(1, min(int(B), max(Nl, 1)))
    telemetry.gauge("edm_batch_libs_effective").set(B)
    launch = make_master_group_launch(X, iM_E, targets, E=E, tau=tau, Tp=Tp,
                                      k=k, impl=impl)
    return drive_batched(Nl, B, launch)


@functools.partial(jax.jit, static_argnames=("E", "tau", "Tp", "k", "impl"))
def ccm_group_from_master(X, iM_E, targets, *, E, tau, Tp, k, impl):
    """Per-series CCM block from cached neighbor indices → (N_lib, N_tgt).

    The cached-session counterpart of ``core.ccm.ccm_group``: instead of
    one O(E·Lp²) pairwise + top-k pipeline per library, each library's
    neighbors are derived from its master index level (iM_E, (N, L,
    k_master)) and only the k selected distances are recomputed —
    bit-identical output (see module docstring). Kept as the legacy
    per-series reference; the session dispatches
    ``ccm_group_from_master_batched``.
    """
    L = X.shape[-1]
    Lp = num_embedded(L, E, tau)
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = Lp - 1 - max(Tp, 0)

    def one_library(args):
        x, iE = args
        ik, ok = _derive_idx(iE[:Lp], k=k, max_idx=hard_max)
        d = _gathered_dists(x, ik, ok, E=E, tau=tau)
        w = ops.make_weights(d)
        return ops.lookup_rho(targets, ik[:rows], w[:rows], offset=off,
                              impl=impl)

    return jax.lax.map(one_library, (X, iM_E))
