"""``Dataset`` — a panel of time series plus cached delay embeddings.

Ingestion is hardened (ISSUE 6): every panel is screened for non-finite
values and constant series at construction, under an explicit
``on_invalid`` policy, instead of letting one corrupt electrode trace
NaN-poison an entire all-pairs matrix silently:

* ``"raise"`` (default) — refuse the panel with the offending series
  named. The safe default for pipelines that expect clean data.
* ``"mask"``  — keep the panel shape; non-finite entries are zeroed for
  compute (so sorts/top-k never see NaN) and the per-series validity
  mask propagates through the session: every output touching an invalid
  series is NaN, and the run report names the series.
* ``"drop"``  — remove invalid series before binding; indices/names of
  the surviving panel are compacted, the report records what was
  dropped (by original index and name).

``dataset.valid`` is the (N,) validity mask (all-True for clean
panels), ``dataset.invalid_report`` the JSON-ready list of
``{index, name, reason}`` records the fault-tolerant runner copies into
its run report.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

#: Accepted ``on_invalid`` policies, in documentation order.
INVALID_POLICIES = ("raise", "mask", "drop")


def screen_panel(panel: np.ndarray) -> list[dict]:
    """Invalid-series records of an (N, L) panel (empty = clean).

    A series is invalid when it contains non-finite values (NaN/Inf —
    dead channel, transmission glitch) or is constant (zero variance —
    a flatlined electrode: every delay vector coincides, distances
    degenerate to ties and Pearson ρ divides by zero).

    Vectorized over the whole panel (no float64 copy, no per-series
    Python loop): at the 10⁵-series panels this module targets, the
    screen runs on every Dataset construction and must stay O(panel)
    flops with O(N) extra memory.
    """
    arr = np.asarray(panel)
    if arr.size == 0:
        return []
    bad_counts = (~np.isfinite(arr)).sum(axis=1)
    with np.errstate(invalid="ignore", over="ignore"):  # inf-inf in ptp
        const = (np.ptp(arr, axis=1) == 0) & (bad_counts == 0)
    return [{"index": int(i), "name": None,
             "reason": (f"{int(bad_counts[i])} non-finite values"
                        if bad_counts[i] else "constant series")}
            for i in np.nonzero((bad_counts > 0) | const)[0]]


class Dataset:
    """An (N, L) panel of equal-length series with embedding caches.

    The facade's unit of state: every ``EDM`` session method operates on
    one Dataset, and materialized delay embeddings (used by S-Map design
    matrices and user inspection — the distance kernels fuse theirs) are
    computed once per (E, tau) and held here. ``on_invalid`` sets the
    NaN/Inf/constant-series policy (module docstring).
    """

    def __init__(self, panel, *, names=None, on_invalid: str = "raise"):
        if on_invalid not in INVALID_POLICIES:
            raise ValueError(
                f"unknown on_invalid policy {on_invalid!r}; expected one "
                f"of {INVALID_POLICIES}")
        panel = jnp.asarray(panel)
        if panel.ndim == 1:
            panel = panel[None, :]
        if panel.ndim != 2:
            raise ValueError(f"panel must be (N, L) or (L,), got {panel.shape}")
        if names is not None:
            names = list(names)
            if len(names) != panel.shape[0]:
                raise ValueError(
                    f"{len(names)} names for {panel.shape[0]} series")
        self.on_invalid = on_invalid
        report = screen_panel(np.asarray(panel))
        for r in report:
            r["name"] = names[r["index"]] if names is not None else None
        self.invalid_report = report
        valid = np.ones(panel.shape[0], bool)
        for r in report:
            valid[r["index"]] = False
        if report and on_invalid == "raise":
            what = "; ".join(
                f"series {r['name'] if r['name'] is not None else r['index']}"
                f": {r['reason']}" for r in report)
            raise ValueError(
                f"panel contains invalid series ({what}); pass "
                f"on_invalid='mask' to NaN-flag them in outputs or "
                f"on_invalid='drop' to remove them")
        if report and on_invalid == "drop":
            panel = panel[np.nonzero(valid)[0]]
            if names is not None:
                names = [n for n, ok in zip(names, valid) if ok]
            if panel.shape[0] == 0:
                raise ValueError(
                    "every series in the panel is invalid; nothing left "
                    "after on_invalid='drop'")
            valid = np.ones(panel.shape[0], bool)
        elif report:  # mask: zero non-finite entries so kernels/top-k
            panel = jnp.nan_to_num(  # never see NaN; outputs touching
                panel, nan=0.0, posinf=0.0, neginf=0.0)  # them are NaN'd
        self.panel = panel
        self.names = names
        self.valid = valid
        self._embeddings: dict[tuple[int, int], jax.Array] = {}

    @property
    def N(self) -> int:
        return self.panel.shape[0]

    @property
    def L(self) -> int:
        return self.panel.shape[1]

    @property
    def num_invalid(self) -> int:
        """Invalid series still in the panel (0 under raise/drop)."""
        return int((~self.valid).sum())

    def is_valid(self, i: int) -> bool:
        return bool(self.valid[i])

    def index_of(self, key) -> int:
        """Series index for an int position or a name."""
        if isinstance(key, str):
            if self.names is None:
                raise KeyError(f"panel has no names (asked for {key!r})")
            return self.names.index(key)
        return int(key)

    def series(self, key) -> jax.Array:
        return self.panel[self.index_of(key)]

    def embedding(self, E: int, tau: int = 1) -> jax.Array:
        """Cached (N, Lp, E) delay embeddings of every series."""
        key = (int(E), int(tau))
        if key not in self._embeddings:
            self._embeddings[key] = jax.vmap(
                lambda x: ops.delay_embed(x, E, tau))(self.panel)
        return self._embeddings[key]

    def __len__(self) -> int:
        return self.N

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        bad = f", invalid={self.num_invalid}" if self.num_invalid else ""
        return f"Dataset(N={self.N}, L={self.L}{bad})"
