"""``Dataset`` — a panel of time series plus cached delay embeddings.

Ingestion is hardened (ISSUE 6): every panel is screened for non-finite
values and constant series at construction, under an explicit
``on_invalid`` policy, instead of letting one corrupt electrode trace
NaN-poison an entire all-pairs matrix silently:

* ``"raise"`` (default) — refuse the panel with the offending series
  named. The safe default for pipelines that expect clean data.
* ``"mask"``  — keep the panel shape; non-finite entries are zeroed for
  compute (so sorts/top-k never see NaN) and the per-series validity
  mask propagates through the session: every output touching an invalid
  series is NaN, and the run report names the series.
* ``"drop"``  — remove invalid series before binding; indices/names of
  the surviving panel are compacted, the report records what was
  dropped (by original index and name).

``dataset.valid`` is the (N,) validity mask (all-True for clean
panels), ``dataset.invalid_report`` the JSON-ready list of
``{index, name, reason}`` records the fault-tolerant runner copies into
its run report.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

#: Accepted ``on_invalid`` policies, in documentation order.
INVALID_POLICIES = ("raise", "mask", "drop")


def series_stats(arr: np.ndarray) -> dict:
    """Running per-series screening stats of an (N, dt) column block.

    ``{"cnt": non-finite count, "lo"/"hi": finite min/max}`` — the
    sufficient statistic for the screen's two invalidity predicates
    (non-finite entries; constant series). Stats of column blocks
    compose via ``merge_stats``, which is what lets ``Dataset.append``
    re-screen a grown panel from only the Δt new columns in O(N·Δt).
    """
    arr = np.asarray(arr)
    finite = np.isfinite(arr)
    return {
        "cnt": (~finite).sum(axis=1).astype(np.int64),
        "lo": np.min(np.where(finite, arr, np.inf), axis=1,
                     initial=np.inf),
        "hi": np.max(np.where(finite, arr, -np.inf), axis=1,
                     initial=-np.inf),
    }


def merge_stats(a: dict, b: dict) -> dict:
    """Stats of the column-concatenation of two blocks."""
    return {"cnt": a["cnt"] + b["cnt"],
            "lo": np.minimum(a["lo"], b["lo"]),
            "hi": np.maximum(a["hi"], b["hi"])}


def _records(cnt, lo, hi, delta_cnt=None) -> list[dict]:
    """Invalid-series records from screening stats (empty = clean).

    ``delta_cnt`` (delta mode) attributes non-finite faults introduced
    by an appended block, so the report names where the corruption
    arrived.
    """
    bad = cnt > 0
    const = ~bad & (lo >= hi)  # no finite spread (lo > hi: no data)
    recs = []
    for i in np.nonzero(bad | const)[0]:
        if not bad[i]:
            reason = "constant series"
        elif delta_cnt is not None and delta_cnt[i] > 0:
            reason = (f"{int(delta_cnt[i])} non-finite values in "
                      f"appended delta")
        else:
            reason = f"{int(cnt[i])} non-finite values"
        recs.append({"index": int(i), "name": None, "reason": reason})
    return recs


def screen_panel(panel: np.ndarray, *, prior: dict | None = None
                 ) -> list[dict]:
    """Invalid-series records of an (N, L) panel (empty = clean).

    A series is invalid when it contains non-finite values (NaN/Inf —
    dead channel, transmission glitch) or is constant (zero variance —
    a flatlined electrode: every delay vector coincides, distances
    degenerate to ties and Pearson ρ divides by zero).

    Vectorized over the whole panel (no float64 copy, no per-series
    Python loop): at the 10⁵-series panels this module targets, the
    screen runs on every Dataset construction and must stay O(panel)
    flops with O(N) extra memory.

    Delta mode: with ``prior=`` (running ``series_stats`` of the
    already-screened columns), ``panel`` is only the appended (N, Δt)
    block and the screen is O(N·Δt) — the grown panel is judged from
    merged stats, with delta-introduced non-finite faults named as
    such. Used by ``Dataset.append``.
    """
    arr = np.asarray(panel)
    if arr.size == 0 and prior is None:
        return []
    stats = series_stats(arr)
    if prior is None:
        return _records(stats["cnt"], stats["lo"], stats["hi"])
    if len(prior["cnt"]) != arr.shape[0]:
        raise ValueError(
            f"delta has {arr.shape[0]} series but prior stats cover "
            f"{len(prior['cnt'])}")
    m = merge_stats(prior, stats)
    return _records(m["cnt"], m["lo"], m["hi"], delta_cnt=stats["cnt"])


class Dataset:
    """An (N, L) panel of equal-length series with embedding caches.

    The facade's unit of state: every ``EDM`` session method operates on
    one Dataset, and materialized delay embeddings (used by S-Map design
    matrices and user inspection — the distance kernels fuse theirs) are
    computed once per (E, tau) and held here. ``on_invalid`` sets the
    NaN/Inf/constant-series policy (module docstring).
    """

    def __init__(self, panel, *, names=None, on_invalid: str = "raise"):
        if on_invalid not in INVALID_POLICIES:
            raise ValueError(
                f"unknown on_invalid policy {on_invalid!r}; expected one "
                f"of {INVALID_POLICIES}")
        panel = jnp.asarray(panel)
        if panel.ndim == 1:
            panel = panel[None, :]
        if panel.ndim != 2:
            raise ValueError(f"panel must be (N, L) or (L,), got {panel.shape}")
        if names is not None:
            names = list(names)
            if len(names) != panel.shape[0]:
                raise ValueError(
                    f"{len(names)} names for {panel.shape[0]} series")
        self.on_invalid = on_invalid
        stats = series_stats(np.asarray(panel))
        report = screen_panel(np.asarray(panel))
        for r in report:
            r["name"] = names[r["index"]] if names is not None else None
        self.invalid_report = report
        valid = np.ones(panel.shape[0], bool)
        for r in report:
            valid[r["index"]] = False
        if report and on_invalid == "raise":
            what = "; ".join(
                f"series {r['name'] if r['name'] is not None else r['index']}"
                f": {r['reason']}" for r in report)
            raise ValueError(
                f"panel contains invalid series ({what}); pass "
                f"on_invalid='mask' to NaN-flag them in outputs or "
                f"on_invalid='drop' to remove them")
        if report and on_invalid == "drop":
            panel = panel[np.nonzero(valid)[0]]
            stats = {k: v[valid] for k, v in stats.items()}
            if names is not None:
                names = [n for n, ok in zip(names, valid) if ok]
            if panel.shape[0] == 0:
                raise ValueError(
                    "every series in the panel is invalid; nothing left "
                    "after on_invalid='drop'")
            valid = np.ones(panel.shape[0], bool)
        elif report:  # mask: zero non-finite entries so kernels/top-k
            panel = jnp.nan_to_num(  # never see NaN; outputs touching
                panel, nan=0.0, posinf=0.0, neginf=0.0)  # them are NaN'd
        self.panel = panel
        self.names = names
        self.valid = valid
        self._stats = stats  # running series_stats of the raw panel
        self._embeddings: dict[tuple[int, int], jax.Array] = {}

    def append(self, delta) -> list[dict]:
        """Grow every series by Δt points under the bound policy.

        The screen is O(N·Δt), not O(N·L): the running per-series stats
        kept since construction absorb only the new columns
        (``screen_panel`` delta mode). ``"raise"`` rejects the delta
        BEFORE mutating any state, naming the offending series;
        ``"mask"`` zeroes non-finite delta entries and flags the series
        invalid; ``"drop"`` removes series the delta invalidated.

        Returns the invalid-series records introduced by this delta.
        Indices are PRE-append — positions in the panel as it was when
        the call started — so callers holding per-series caches (the
        ``EDM`` session's kNN master) can compact them to match.
        Embedding caches are cleared; stats are computed on the raw
        delta, so a masked series never silently "heals".
        """
        delta = jnp.asarray(delta)
        if delta.ndim == 1:
            delta = delta[None, :]
        if delta.ndim != 2 or delta.shape[0] != self.N:
            raise ValueError(
                f"delta must be ({self.N}, dt), got {tuple(delta.shape)}")
        if delta.shape[1] < 1:
            raise ValueError("delta must append at least one point")
        arr = np.asarray(delta)
        fresh = [dict(r) for r in screen_panel(arr, prior=self._stats)
                 if self.valid[r["index"]]]
        for r in fresh:
            r["name"] = (self.names[r["index"]]
                         if self.names is not None else None)
        if fresh and self.on_invalid == "raise":
            what = "; ".join(
                f"series {r['name'] if r['name'] is not None else r['index']}"
                f": {r['reason']}" for r in fresh)
            raise ValueError(
                f"append rejected: delta would invalidate series ({what}); "
                f"bind the panel with on_invalid='mask' or 'drop' to accept "
                f"faulty ticks")
        merged = merge_stats(self._stats, series_stats(arr))
        if self.num_invalid or fresh:  # mask policy: keep NaN out of kernels
            delta = jnp.nan_to_num(delta, nan=0.0, posinf=0.0, neginf=0.0)
        panel = jnp.concatenate([self.panel, delta], axis=1)
        if fresh and self.on_invalid == "drop":
            bad = {r["index"] for r in fresh}
            keep = np.array([i for i in range(self.N) if i not in bad], int)
            if keep.size == 0:
                raise ValueError(
                    "append would invalidate every remaining series; "
                    "refusing to drop the whole panel")
            panel = panel[keep]
            merged = {k: v[keep] for k, v in merged.items()}
            if self.names is not None:
                self.names = [self.names[i] for i in keep]
            self.valid = np.ones(panel.shape[0], bool)
        else:
            self.valid = np.asarray(
                (merged["cnt"] == 0) & (merged["lo"] < merged["hi"]))
        self.panel = panel
        self._stats = merged
        self.invalid_report = self.invalid_report + fresh
        self._embeddings.clear()
        return fresh

    @property
    def N(self) -> int:
        return self.panel.shape[0]

    @property
    def L(self) -> int:
        return self.panel.shape[1]

    @property
    def num_invalid(self) -> int:
        """Invalid series still in the panel (0 under raise/drop)."""
        return int((~self.valid).sum())

    def is_valid(self, i: int) -> bool:
        return bool(self.valid[i])

    def index_of(self, key) -> int:
        """Series index for an int position or a name."""
        if isinstance(key, str):
            if self.names is None:
                raise KeyError(f"panel has no names (asked for {key!r})")
            return self.names.index(key)
        return int(key)

    def series(self, key) -> jax.Array:
        return self.panel[self.index_of(key)]

    def embedding(self, E: int, tau: int = 1) -> jax.Array:
        """Cached (N, Lp, E) delay embeddings of every series."""
        key = (int(E), int(tau))
        if key not in self._embeddings:
            self._embeddings[key] = jax.vmap(
                lambda x: ops.delay_embed(x, E, tau))(self.panel)
        return self._embeddings[key]

    def __len__(self) -> int:
        return self.N

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        bad = f", invalid={self.num_invalid}" if self.num_invalid else ""
        return f"Dataset(N={self.N}, L={self.L}{bad})"
