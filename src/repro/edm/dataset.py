"""``Dataset`` — a panel of time series plus cached delay embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


class Dataset:
    """An (N, L) panel of equal-length series with embedding caches.

    The facade's unit of state: every ``EDM`` session method operates on
    one Dataset, and materialized delay embeddings (used by S-Map design
    matrices and user inspection — the distance kernels fuse theirs) are
    computed once per (E, tau) and held here.
    """

    def __init__(self, panel, *, names=None):
        panel = jnp.asarray(panel)
        if panel.ndim == 1:
            panel = panel[None, :]
        if panel.ndim != 2:
            raise ValueError(f"panel must be (N, L) or (L,), got {panel.shape}")
        self.panel = panel
        if names is not None:
            names = list(names)
            if len(names) != panel.shape[0]:
                raise ValueError(
                    f"{len(names)} names for {panel.shape[0]} series")
        self.names = names
        self._embeddings: dict[tuple[int, int], jax.Array] = {}

    @property
    def N(self) -> int:
        return self.panel.shape[0]

    @property
    def L(self) -> int:
        return self.panel.shape[1]

    def index_of(self, key) -> int:
        """Series index for an int position or a name."""
        if isinstance(key, str):
            if self.names is None:
                raise KeyError(f"panel has no names (asked for {key!r})")
            return self.names.index(key)
        return int(key)

    def series(self, key) -> jax.Array:
        return self.panel[self.index_of(key)]

    def embedding(self, E: int, tau: int = 1) -> jax.Array:
        """Cached (N, Lp, E) delay embeddings of every series."""
        key = (int(E), int(tau))
        if key not in self._embeddings:
            self._embeddings[key] = jax.vmap(
                lambda x: ops.delay_embed(x, E, tau))(self.panel)
        return self._embeddings[key]

    def __len__(self) -> int:
        return self.N

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Dataset(N={self.N}, L={self.L})"
