"""Surrogate-series ensembles for CCM significance testing.

A CCM score alone is not evidence: weak coupling, shared seasonality, or
plain autocorrelation can all produce ρ > 0. The standard gate (used at
scale by the whole-brain CCM study that drove mpEDM/kEDM — every one of
its ~10⁸ pairwise scores is tested) is a *surrogate ensemble*: re-run the
cross map against many null versions of the target series and report the
rank of the real score as a p-value.

Two null models:

* ``"shuffle"``  — full random permutation: destroys ALL temporal
  structure. Null hypothesis: the score is explained by the marginal
  value distribution alone.
* ``"seasonal"`` — permutes values only within the same phase of a
  cycle of the given ``period`` (values at t ≡ p mod period are
  exchanged among themselves). Preserves the mean seasonal profile, so
  shared periodic forcing — the classic CCM false positive (Sugihara
  et al. 2012's "mirage correlations") — survives into the null and is
  discounted.

Generation is host-side numpy (cheap, O(M·L)); the expensive part — one
cross-map per surrogate — is batched by the session into a single
(M+1)-target jitted curve-grid program (see ``EDM.surrogate_test``).
"""

from __future__ import annotations

import numpy as np

#: Surrogate null models understood by ``make_surrogates``.
METHODS = ("shuffle", "seasonal")


def make_surrogates(
    y,
    num: int,
    *,
    method: str = "shuffle",
    period: int | None = None,
    seed: int = 0,
) -> np.ndarray:
    """``num`` surrogate copies of a series → (num, L) float32.

    ``method="seasonal"`` requires ``period`` (in samples). Deterministic
    for a given ``seed``.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected {METHODS}")
    if num < 1:
        raise ValueError(f"num must be >= 1, got {num}")
    y = np.asarray(y, np.float32)
    if y.ndim != 1:
        raise ValueError(f"y must be 1-D, got shape {y.shape}")
    L = y.shape[0]
    rng = np.random.default_rng(seed)
    out = np.empty((num, L), np.float32)
    if method == "shuffle":
        for m in range(num):
            out[m] = y[rng.permutation(L)]
        return out
    if period is None or period < 1:
        raise ValueError(
            f"seasonal surrogates need period >= 1, got {period}")
    for m in range(num):
        perm = np.arange(L)
        for p in range(min(period, L)):
            phase = np.arange(p, L, period)
            perm[phase] = rng.permutation(phase)
        out[m] = y[perm]
    return out
