"""Continuous batching scheduler for EDM serving.

One FIFO queue, one worker thread, and a coalescing rule:

* Every request carries a **signature** captured at submit time. For a
  default-cap CCM request that is ``("ccm", panel, E, queued_version)``
  — the compatibility class the ISSUE names: same panel, same embedding
  geometry, same library state.
* The worker always dequeues the HEAD request (FIFO — a long-queued
  request is never starved by later arrivals) and then pulls every
  other queued request with the *same signature* into its batch, in
  arrival order. Compatible requests that arrived while earlier work
  was executing ride the next launch — continuous batching, not fixed
  windows.
* A batch of n compatible CCM requests becomes ONE ``EDM.ccm_batch``
  launch (the library-batched matrix engine,  ``drive_batched``'s
  dispatch/assemble overlap underneath) instead of n single-pair engine
  passes. ``ccm_batch``'s bit contract is batch invariance: a pair's ρ
  never depends on which other requests share its launch, so
  ``ccm_batch([(l, t)])`` is the quiesced oracle for every served
  answer — batching changes throughput, never answers. Solo default-cap
  requests go through the same method for the same reason.
* An **append is a version barrier**: submitting it bumps the panel's
  ``queued_version``, so requests behind it carry a signature no
  earlier batch can match, and the FIFO order does the rest. Appends
  themselves never coalesce.
* Whole-panel ops (``xmap``, ``simplex``, ``optimal_E``,
  ``surrogate_test``) coalesce only as exact duplicates — identical
  params on the same version — which collapses request stampedes into
  one execution fanned out to every waiting future.

Telemetry: ``serve_queue_depth`` / ``serve_batch_occupancy`` gauges,
``serve_latency_ms_<op>`` histograms, ``serve_requests`` /
``serve_batches`` / ``serve_launches_saved`` counters, and a span per
batch with per-request events.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro import telemetry
from repro.serving.state import PanelEntry, Registry

#: Ops a request may carry; anything else is rejected at submit.
OPS = ("ccm", "xmap", "simplex", "surrogate_test", "optimal_E", "append")


@dataclasses.dataclass
class Request:
    ticket: int
    op: str
    panel: str
    params: dict
    signature: tuple
    future: Future
    t_submit: float


def _frozen(params: dict) -> tuple:
    """Hashable, order-insensitive view of request params."""
    out = []
    for k in sorted(params):
        v = params[k]
        if isinstance(v, (list, tuple)):
            v = tuple(v)
        elif isinstance(v, np.ndarray):
            v = ("array", v.shape, v.tobytes())
        out.append((k, v))
    return tuple(out)


class Scheduler:
    """FIFO queue + single drain worker over a panel ``Registry``."""

    def __init__(self, registry: Registry, *, autostart: bool = True,
                 max_batch: int = 64):
        self.registry = registry
        self.max_batch = max_batch
        self._q: collections.deque[Request] = collections.deque()
        self._cv = threading.Condition()
        self._next_ticket = 0
        self._closed = False
        self._worker = None
        if autostart:
            self._worker = threading.Thread(
                target=self._run, name="edm-serve-worker", daemon=True)
            self._worker.start()

    # ------------------------------------------------------------ submit

    def submit(self, op: str, panel: str, **params) -> Future:
        """Enqueue a request; thread-safe; returns its ``Future``.

        The coalescing signature (and, for appends, the version bump
        that makes them barriers) is fixed here, under the queue lock —
        after ``submit`` returns, no later request can be batched ahead
        of this one's library state.
        """
        return self.submit_many(op, panel, [params])[0]

    def submit_many(self, op: str, panel: str,
                    params_list: list[dict]) -> list[Future]:
        """Enqueue a burst of same-op requests under ONE lock acquisition.

        The bulk path for saturating clients: signatures are still
        per-request (so coalescing semantics are identical to n
        ``submit`` calls in the same order), but queue-lock traffic,
        telemetry, and worker wakeup are paid once per burst. The
        scheduler takes ownership of the param dicts — callers must not
        mutate them after submitting.
        """
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
        entry = self.registry.get(panel)  # raises for unknown panels
        futs = [Future() for _ in params_list]
        now = time.perf_counter()
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            for params, fut in zip(params_list, futs):
                ticket = self._next_ticket
                self._next_ticket += 1
                if op == "append":
                    entry.queued_version += 1
                    sig = ("append", panel, ticket)
                elif (op == "ccm" and params.get("E") is not None
                        and params.get("lib_sizes") is None):
                    sig = ("ccm", panel, int(params["E"]),
                           entry.queued_version)
                else:  # sweeps / E-to-resolve CCM: solo. Panel ops: dedup.
                    sig = ((op, panel, ticket) if op == "ccm"
                           else (op, panel, entry.queued_version,
                                 _frozen(params)))
                self._q.append(Request(ticket, op, panel, params,
                                       sig, fut, now))
            telemetry.gauge("serve_queue_depth").set(len(self._q))
            telemetry.counter("serve_requests").inc(len(futs))
            self._cv.notify()
        return futs

    # ------------------------------------------------------------- drain

    def drain_once(self, timeout: float | None = 0.0) -> int:
        """Process one batch in the calling thread; returns its size.

        The deterministic test/bench entry (``autostart=False``): the
        exact coalescing the worker would perform, minus the thread.
        """
        batch = self._take_batch(timeout)
        if not batch:
            return 0
        self._execute(batch)
        return len(batch)

    def _run(self) -> None:
        while True:
            batch = self._take_batch(timeout=0.1)
            if batch is None:  # closed and drained
                return
            if batch:
                self._execute(batch)

    def _take_batch(self, timeout) -> list[Request] | None:
        """Pop the head request plus every queued signature-match."""
        with self._cv:
            if not self._q:
                if self._closed:
                    return None
                self._cv.wait(timeout)
                if not self._q:
                    return None if self._closed else []
            head = self._q.popleft()
            batch = [head]
            if head.op != "append":
                rest = collections.deque()
                while self._q and len(batch) < self.max_batch:
                    r = self._q.popleft()
                    if r.signature == head.signature:
                        batch.append(r)
                    else:
                        rest.append(r)
                rest.extend(self._q)
                self._q = rest
            telemetry.gauge("serve_queue_depth").set(len(self._q))
        telemetry.gauge("serve_batch_occupancy").set(len(batch))
        telemetry.histogram("serve_batch_occupancy_hist").observe(len(batch))
        if len(batch) > 1:
            telemetry.counter("serve_launches_saved").inc(len(batch) - 1)
        return batch

    # ----------------------------------------------------------- execute

    def _execute(self, batch: list[Request]) -> None:
        head = batch[0]
        entry = self.registry.get(head.panel)
        t0 = time.perf_counter()
        try:
            with telemetry.span("serve.batch", op=head.op, panel=head.panel,
                                size=len(batch)):
                if head.op == "ccm" and len(batch) > 1:
                    results = self._exec_ccm_batch(entry, batch)
                else:
                    results = [self._exec_one(entry, r) for r in batch]
        except Exception as exc:  # noqa: BLE001 — failures go to futures
            telemetry.counter("serve_errors").inc()
            for r in batch:
                r.future.set_exception(exc)
            return
        done = time.perf_counter()
        ms = (done - t0) * 1e3
        hist = telemetry.histogram(f"serve_latency_ms_{head.op}")
        live = telemetry.active()  # per-request events only under a sink
        for r, res in zip(batch, results):
            if live:
                telemetry.event("serve.request", op=r.op, ticket=r.ticket,
                                batched_with=len(batch) - 1,
                                queued_ms=(t0 - r.t_submit) * 1e3,
                                exec_ms=ms)
            hist.observe((done - r.t_submit) * 1e3)
            r.future.set_result(res)
        telemetry.counter("serve_batches").inc()

    def _exec_one(self, entry: PanelEntry, r: Request):
        sess = entry.sess
        p = r.params
        if r.op == "append":
            records = sess.append(np.asarray(p["delta"], np.float32))
            entry.version += 1
            telemetry.counter("serve_appends").inc()
            return {"records": records, "version": entry.version,
                    "N": sess.data.N, "L": sess.data.L}
        if r.op == "ccm":
            if p.get("lib_sizes") is not None:  # sweep: classic engine
                return sess.ccm(p["lib"], p["target"],
                                lib_sizes=p["lib_sizes"], E=p.get("E"))
            # Default-cap requests ALWAYS go through the batch engine —
            # solo or coalesced, a pair's answer has the same bits.
            E = p.get("E")
            if E is None:
                E = sess._resolve_pair_E(sess.data.index_of(p["target"]),
                                         None)
            return sess.ccm_batch([(p["lib"], p["target"])], E=E)[0]
        if r.op == "xmap":
            return sess.xmap(p.get("method", "simplex"),
                             theta=p.get("theta"))
        if r.op == "simplex":
            return sess.simplex(p.get("E"))
        if r.op == "optimal_E":
            return sess.optimal_E()
        if r.op == "surrogate_test":
            return sess.surrogate_test(
                p["lib"], p["target"],
                num_surrogates=p.get("num_surrogates", 100),
                method=p.get("method", "shuffle"),
                period=p.get("period"), seed=p.get("seed", 0))
        raise AssertionError(f"unreachable op {r.op!r}")

    def _exec_ccm_batch(self, entry: PanelEntry, batch: list[Request]):
        """n compatible CCM pairs as ONE coalesced engine launch.

        ``EDM.ccm_batch`` owns the bit contract (batch-invariant
        answers; see its docstring) — the scheduler only supplies the
        coalesced pair list and the telemetry.
        """
        sess = entry.sess
        E = int(batch[0].params["E"])
        pairs = [(r.params["lib"], r.params["target"]) for r in batch]
        rho = sess.ccm_batch(pairs, E=E)
        telemetry.counter("serve_ccm_group_launches").inc()
        self._bump_session(sess, "ccm_coalesced", len(batch))
        return list(rho)  # np.float32 scalars, no copies

    @staticmethod
    def _bump_session(sess, key, n) -> None:
        sess.stats[key] += n
        telemetry.counter(f"edm_{key}").inc(n)

    # ------------------------------------------------------------- close

    def close(self) -> None:
        """Stop accepting work; fail queued requests; join the worker."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            pending = list(self._q)
            self._q.clear()
            self._cv.notify_all()
        for r in pending:
            r.future.set_exception(RuntimeError("scheduler closed"))
        if self._worker is not None:
            self._worker.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
