"""Continuous batching scheduler for EDM serving: per-panel drains.

PR 8's scheduler was ONE FIFO queue drained by ONE worker, so
independent panels serialized behind each other. This version keeps
every per-panel guarantee of that design and adds cross-panel
concurrency:

* **One FIFO queue per panel.** Every request carries a **signature**
  captured at submit time under the scheduler lock. For a default-cap
  CCM request that is ``("ccm", panel, E, queued_version)`` — the
  compatibility class: same panel, same embedding geometry, same
  library state.
* **A worker pool drains panels concurrently.** A panel with queued
  work sits on a ready list; a free worker claims it (round-robin
  across panels — a busy panel cannot starve the others), drains ONE
  batch, and returns the panel to the ready list if work remains. At
  most one worker drains a given panel at any moment, so per-panel
  execution stays serial: FIFO order, signature coalescing, and the
  append version barrier are per-panel properties and survive the pool
  unchanged. Distinct panels execute on distinct workers concurrently.
* **Batching is unchanged.** The drain takes the panel's HEAD request
  and pulls every queued signature-match into its batch, in arrival
  order. n compatible CCM requests become ONE ``EDM.ccm_batch`` launch;
  ``ccm_batch``'s bit contract is batch invariance, so
  ``ccm_batch([(l, t)])`` is the quiesced oracle for every served
  answer. Whole-panel ops coalesce only as exact duplicates. An
  **append is a version barrier**: submitting it bumps the panel's
  ``queued_version`` so requests behind it can never be batched ahead
  of it.
* **Failures are per-request, never structural.** An op raising in a
  loop-executed batch fails only that request's future; a coalesced
  single-launch batch fails all of its futures (they shared the
  launch); either way the panel queue keeps draining and the version
  barrier stays consistent (a failed append leaves the committed
  version untouched — later requests simply sign with the already-bumped
  queued version and execute normally). A worker killed by a
  ``BaseException`` fails its in-flight batch, releases the panel, and
  is reported dead by ``worker_stats()`` / ``health()`` until
  ``revive_workers()`` — or the supervisor — respawns it.
* **Memory budget hook.** After each batch the worker touches the
  panel's LRU slot and calls ``Registry.enforce_budget()`` — cold
  panels' cached kNN masters are evicted until the byte budget holds
  (see ``state.py``; rebuild-on-demand is bit-identical).

New in PR 10, the overload/failure contract — **every submitted request
resolves**, with a typed error when it cannot resolve with a result:

* **Admission control** — ``max_queue_depth`` / ``max_queued_bytes``
  bound the total queued work; a burst that would exceed either is
  rejected *whole* at submit with ``Overloaded`` carrying a
  ``retry_after_s`` estimate derived from the ``serve_latency_ms``
  histograms (HTTP maps it to 429 + ``Retry-After``).
* **Deadlines** — a per-request ``deadline_s`` starts at submit; a
  request still queued past its deadline is failed with
  ``DeadlineExceeded`` at claim time, before it wastes a launch
  (HTTP 504). Deadlines never enter coalescing signatures.
* **Quarantine** — ``quarantine_after`` consecutive *batch-level*
  failures (shared-launch exceptions or worker deaths; per-request
  loop errors don't count) quarantine the panel: queued requests fail
  immediately and later submits raise ``PanelQuarantined`` with the
  last error, so one poisoned panel cannot grind the pool.
  ``clear_quarantine`` is the operator reset. A WAL write failure
  quarantines unconditionally — the in-memory library is ahead of the
  log and serving it would break the recovery bit-contract.
* **Supervision** — ``supervise=True`` runs a daemon thread that
  auto-revives dead drain workers with capped exponential backoff
  (``serve_worker_revives`` counter; backoff resets once the revived
  worker completes a batch).
* **Graceful drain** — ``drain()`` stops admission (``Draining``,
  HTTP 503) and waits for the per-panel queues to empty; the server
  layer then fsyncs WALs and exits 0 on SIGTERM.
* **Fault injection** — ``faults=FaultInjector(...)`` threads the five
  deterministic injection points of ``serving.faultinject`` through
  claim/execute (the chaos suite's entry).

Telemetry: ``serve_queue_depth`` / ``serve_queued_bytes`` /
``serve_batch_occupancy`` / ``serve_master_bytes`` gauges,
``serve_latency_ms_<op>`` histograms, ``serve_requests`` /
``serve_batches`` / ``serve_launches_saved`` / ``serve_evictions`` /
``serve_worker_deaths`` / ``serve_worker_revives`` / ``serve_rejected``
/ ``serve_deadline_exceeded`` / ``serve_quarantined`` counters, and a
span per batch with per-request events.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro import telemetry
from repro.serving.state import PanelEntry, Registry

#: Ops a request may carry; anything else is rejected at submit.
OPS = ("ccm", "xmap", "simplex", "surrogate_test", "optimal_E", "append",
       "subscribe")

#: Default worker-pool size (per-panel drains; panels > workers queue).
DEFAULT_WORKERS = 4

#: Consecutive batch-level failures before a panel is quarantined.
DEFAULT_QUARANTINE_AFTER = 3


class Overloaded(RuntimeError):
    """Admission refused: the queue bound would be exceeded.

    ``retry_after_s`` estimates when capacity should exist again
    (queue depth x mean request latency / workers) — the HTTP layer
    sends it as ``Retry-After`` on the 429.
    """

    def __init__(self, msg: str, retry_after_s: float = 1.0):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


class DeadlineExceeded(TimeoutError):
    """The request's ``deadline_s`` elapsed while it was still queued."""


class Draining(RuntimeError):
    """The scheduler is draining for shutdown; admission is closed."""


class PanelQuarantined(RuntimeError):
    """The panel's batches crashed repeatedly (or its WAL broke); it
    fails fast with the last error until ``clear_quarantine``."""

    def __init__(self, msg: str, last_error: BaseException | None = None):
        super().__init__(msg)
        self.last_error = last_error


@dataclasses.dataclass
class Request:
    ticket: int
    op: str
    panel: str
    params: dict
    signature: tuple
    future: Future
    t_submit: float
    deadline: float | None = None
    cost: int = 0


class _PanelQueue:
    """One panel's FIFO + the flag serializing its drains."""

    __slots__ = ("name", "q", "draining", "fail_streak", "quarantined")

    def __init__(self, name: str):
        self.name = name
        self.q: collections.deque[Request] = collections.deque()
        self.draining = False
        self.fail_streak = 0
        self.quarantined: BaseException | None = None


def _frozen(params: dict) -> tuple:
    """Hashable, order-insensitive view of request params."""
    out = []
    for k in sorted(params):
        v = params[k]
        if isinstance(v, (list, tuple)):
            v = tuple(tuple(x) if isinstance(x, (list, tuple)) else x
                      for x in v)
        elif isinstance(v, np.ndarray):
            v = ("array", v.shape, v.tobytes())
        out.append((k, v))
    return tuple(out)


def _cost(params: dict) -> int:
    """Queued-bytes estimate of a request: array payloads + overhead."""
    nbytes = 256
    for v in params.values():
        if isinstance(v, np.ndarray):
            nbytes += v.nbytes
        elif isinstance(v, (list, tuple)) and v \
                and isinstance(v[0], (list, tuple)):
            nbytes += 8 * sum(len(x) for x in v)
    return nbytes


class Scheduler:
    """Per-panel FIFO queues + a drain worker pool over a ``Registry``."""

    def __init__(self, registry: Registry, *, autostart: bool = True,
                 max_batch: int = 64, workers: int = DEFAULT_WORKERS,
                 subscriptions=None,
                 max_queue_depth: int | None = None,
                 max_queued_bytes: int | None = None,
                 quarantine_after: int = DEFAULT_QUARANTINE_AFTER,
                 supervise: bool = False,
                 supervise_interval: float = 0.25,
                 revive_backoff_s: tuple[float, float] = (0.2, 30.0),
                 faults=None):
        self.registry = registry
        self.max_batch = max_batch
        self.num_workers = max(1, int(workers))
        self.subscriptions = subscriptions
        self.max_queue_depth = max_queue_depth
        self.max_queued_bytes = max_queued_bytes
        self.quarantine_after = max(1, int(quarantine_after))
        self.supervise = bool(supervise)
        self.supervise_interval = float(supervise_interval)
        self.revive_backoff_s = (float(revive_backoff_s[0]),
                                 float(revive_backoff_s[1]))
        self.faults = faults
        self._queues: dict[str, _PanelQueue] = {}
        self._ready: collections.deque[_PanelQueue] = collections.deque()
        self._cv = threading.Condition()
        self._next_ticket = 0
        self._queued_bytes = 0
        self._closed = False
        self._draining = False
        self._threads: list[threading.Thread | None] = []
        self._wstats: list[dict] = []
        self._sup_thread: threading.Thread | None = None
        self._sup_stop = threading.Event()
        self._revive_state: dict[int, dict] = {}
        if autostart:
            self.start()

    # ------------------------------------------------------------- pool

    def start(self) -> None:
        """Spin up the worker pool (idempotent; ``autostart=False``
        constructions call this to go live after preloading queues)."""
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            while len(self._threads) < self.num_workers:
                self._spawn(len(self._threads))
        if self.supervise and self._sup_thread is None:
            self._sup_thread = threading.Thread(
                target=self._supervise_loop, name="edm-serve-supervisor",
                daemon=True)
            self._sup_thread.start()

    def _spawn(self, wid: int) -> None:
        """Start worker ``wid`` (caller holds the lock)."""
        st = {"name": f"edm-serve-worker-{wid}", "alive": True,
              "batches": 0, "last_beat": time.monotonic(), "error": None}
        t = threading.Thread(target=self._run, args=(wid,),
                             name=st["name"], daemon=True)
        if wid < len(self._threads):
            self._threads[wid] = t
            self._wstats[wid] = st
        else:
            self._threads.append(t)
            self._wstats.append(st)
        t.start()

    def worker_stats(self) -> list[dict]:
        """Per-worker liveness snapshot (the ``/healthz`` payload rows).

        ``alive`` is the thread's actual ``is_alive()`` — a worker that
        died without running its own epilogue (or was never started on
        an ``autostart=False`` scheduler) still reads dead here.
        """
        with self._cv:
            out = []
            for t, st in zip(self._threads, self._wstats):
                d = dict(st)
                d["alive"] = bool(st["alive"] and t is not None
                                  and t.is_alive())
                d["age_s"] = time.monotonic() - st["last_beat"]
                out.append(d)
            return out

    def queue_depths(self) -> dict[str, int]:
        with self._cv:
            return {name: len(pq.q) for name, pq in self._queues.items()}

    def quarantined_panels(self) -> dict[str, str]:
        with self._cv:
            return {name: f"{type(pq.quarantined).__name__}: "
                          f"{pq.quarantined}"
                    for name, pq in self._queues.items()
                    if pq.quarantined is not None}

    def health(self) -> dict:
        """Liveness + queue depths; ``ok`` is False when any spawned
        worker is dead (a dead drain thread must NOT answer healthy —
        its panels would wedge silently)."""
        ws = self.worker_stats()
        ok = (not self._closed
              and len(ws) == self.num_workers
              and all(w["alive"] for w in ws))
        return {"ok": bool(ok), "workers": ws,
                "queues": self.queue_depths(), "closed": self._closed,
                "draining": self._draining,
                "quarantined": self.quarantined_panels()}

    def revive_workers(self) -> int:
        """Respawn dead workers; returns how many were restarted."""
        revived = 0
        with self._cv:
            if self._closed:
                return 0
            for wid, (t, st) in enumerate(zip(self._threads, self._wstats)):
                if t is not None and not t.is_alive():
                    self._spawn(wid)
                    revived += 1
        if revived:
            telemetry.counter("serve_worker_revivals").inc(revived)
        return revived

    def _supervise_loop(self) -> None:
        """Auto-revive dead workers with capped exponential backoff.

        A worker that dies again before completing a batch doubles its
        backoff (up to the cap); finishing a batch resets it — the PR-6
        retry discipline applied to thread liveness.
        """
        base, cap = self.revive_backoff_s
        while not self._sup_stop.wait(self.supervise_interval):
            revived = 0
            try:
                now = time.monotonic()
                with self._cv:
                    if self._closed:
                        return
                    for wid, (t, st) in enumerate(
                            zip(self._threads, self._wstats)):
                        rs = self._revive_state.get(wid)
                        if t is None or t.is_alive():
                            if rs and st["batches"] > 0:
                                del self._revive_state[wid]
                            continue
                        if rs is None:
                            rs = self._revive_state[wid] = {
                                "streak": 0, "not_before": now}
                        if now < rs["not_before"]:
                            continue
                        self._spawn(wid)
                        rs["streak"] += 1
                        rs["not_before"] = now + min(
                            cap, base * (2 ** (rs["streak"] - 1)))
                        revived += 1
            except Exception:  # noqa: BLE001 — the supervisor never dies
                pass
            if revived:
                telemetry.counter("serve_worker_revives").inc(revived)
                telemetry.event("serve.worker_revive", n=revived)

    # ------------------------------------------------------------ submit

    def submit(self, op: str, panel: str, **params) -> Future:
        """Enqueue a request; thread-safe; returns its ``Future``.

        The coalescing signature (and, for appends, the version bump
        that makes them barriers) is fixed here, under the scheduler
        lock — after ``submit`` returns, no later request can be batched
        ahead of this one's library state. The returned future carries
        its queue position as ``fut.ticket`` (global submit order — the
        per-panel linearization tests key on it).

        ``deadline_s=`` (optional, never part of the coalescing
        signature) bounds the time the request may sit queued; past it,
        the claim path fails the future with ``DeadlineExceeded``
        instead of launching. Raises ``Overloaded`` / ``Draining`` /
        ``PanelQuarantined`` when admission is refused.
        """
        return self.submit_many(op, panel, [params])[0]

    def submit_many(self, op: str, panel: str,
                    params_list: list[dict]) -> list[Future]:
        """Enqueue a burst of same-op requests under ONE lock acquisition.

        The bulk path for saturating clients: signatures are still
        per-request (so coalescing semantics are identical to n
        ``submit`` calls in the same order), but queue-lock traffic,
        telemetry, and worker wakeup are paid once per burst. The
        scheduler takes ownership of the param dicts — callers must not
        mutate them after submitting. Admission bounds apply to the
        burst as a whole: it is accepted or ``Overloaded`` entirely.
        """
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
        entry = self.registry.get(panel)  # raises for unknown panels
        deadlines = [p.pop("deadline_s", None) for p in params_list]
        costs = [_cost(p) for p in params_list]
        futs = [Future() for _ in params_list]
        now = time.perf_counter()
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self._draining:
                raise Draining(
                    "server is draining for shutdown; not accepting work")
            pq = self._queues.get(panel)
            if pq is None:
                pq = self._queues[panel] = _PanelQueue(panel)
            if pq.quarantined is not None:
                raise PanelQuarantined(
                    f"panel {panel!r} is quarantined: "
                    f"{type(pq.quarantined).__name__}: {pq.quarantined}",
                    pq.quarantined)
            depth = sum(len(q.q) for q in self._queues.values())
            if (self.max_queue_depth is not None
                    and depth + len(params_list) > self.max_queue_depth):
                telemetry.counter("serve_rejected").inc(len(params_list))
                raise Overloaded(
                    f"queue depth {depth}+{len(params_list)} would exceed "
                    f"max_queue_depth={self.max_queue_depth}",
                    self._retry_after(op, depth))
            add = sum(costs)
            if (self.max_queued_bytes is not None
                    and self._queued_bytes + add > self.max_queued_bytes):
                telemetry.counter("serve_rejected").inc(len(params_list))
                raise Overloaded(
                    f"queued bytes {self._queued_bytes}+{add} would exceed "
                    f"max_queued_bytes={self.max_queued_bytes}",
                    self._retry_after(op, depth))
            was_empty = not pq.q
            for params, fut, dl, cost in zip(params_list, futs,
                                             deadlines, costs):
                ticket = self._next_ticket
                self._next_ticket += 1
                if op == "append":
                    entry.queued_version += 1
                    sig = ("append", panel, ticket)
                elif (op == "ccm" and params.get("E") is not None
                        and params.get("lib_sizes") is None):
                    sig = ("ccm", panel, int(params["E"]),
                           entry.queued_version)
                elif op in ("ccm", "subscribe"):
                    # sweeps / E-to-resolve CCM and subscribe: solo.
                    sig = (op, panel, ticket)
                else:  # whole-panel ops: dedup exact duplicates only.
                    sig = (op, panel, entry.queued_version,
                           _frozen(params))
                fut.ticket = ticket  # type: ignore[attr-defined]
                pq.q.append(Request(
                    ticket, op, panel, params, sig, fut, now,
                    deadline=None if dl is None else now + float(dl),
                    cost=cost))
            self._queued_bytes += add
            if was_empty and not pq.draining:
                self._ready.append(pq)
            telemetry.gauge("serve_queue_depth").set(
                sum(len(q.q) for q in self._queues.values()))
            telemetry.gauge("serve_queued_bytes").set(self._queued_bytes)
            telemetry.counter("serve_requests").inc(len(futs))
            self._cv.notify(len(futs))
        return futs

    def _retry_after(self, op: str, depth: int) -> float:
        """Retry-After estimate: queued work x mean latency / workers."""
        h = telemetry.histogram(f"serve_latency_ms_{op}")
        mean_ms = (h.sum / h.count) if h.count else 50.0
        est = (depth + 1) * mean_ms / 1e3 / max(self.num_workers, 1)
        return float(min(60.0, max(0.1, est)))

    # ------------------------------------------------------------- drain

    def drain_once(self, timeout: float | None = 0.0) -> int:
        """Process one batch in the calling thread; returns how many
        requests were retired (executed + expired).

        The deterministic test/bench entry (``autostart=False``): the
        exact claim → coalesce → execute → release cycle a pool worker
        performs, minus the thread. Panels are visited in ready-list
        (round-robin) order.
        """
        claim = self._claim(timeout)
        if claim is None:
            return 0
        pq, batch, expired = claim
        try:
            if batch:
                self._execute(batch, pq)
        finally:
            self._release(pq)
        return len(batch) + expired

    def _run(self, wid: int) -> None:
        st = self._wstats[wid]
        while True:
            with self._cv:
                while not self._ready and not self._closed:
                    self._cv.wait(0.1)
                    st["last_beat"] = time.monotonic()
                if self._closed and not self._ready:
                    return
            claim = self._claim(timeout=0.0)
            if claim is None:
                continue
            pq, batch, _ = claim
            try:
                if batch:
                    self._execute(batch, pq)
                    st["batches"] += 1
                    st["last_beat"] = time.monotonic()
            except BaseException as exc:  # worker is dying: fail the
                # in-flight futures rather than hanging their clients,
                # then report dead until revive_workers()/supervisor.
                err = RuntimeError(
                    f"serve worker died: {type(exc).__name__}: {exc}")
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(err)
                st["alive"] = False
                st["error"] = f"{type(exc).__name__}: {exc}"
                telemetry.counter("serve_worker_deaths").inc()
                self._note_batch_failure(pq, exc)
                return
            finally:
                self._release(pq)

    def _claim(self, timeout
               ) -> tuple[_PanelQueue, list[Request], int] | None:
        """Claim the next ready panel and coalesce one batch from it.

        Returns ``(panel_queue, batch, n_expired)`` with the panel
        marked as draining — the caller MUST ``_release`` it — or None
        if nothing became ready within ``timeout``. Requests whose
        deadline passed while queued are failed with
        ``DeadlineExceeded`` here, before they cost a launch.
        """
        with self._cv:
            if not self._ready:
                if self._closed:
                    return None
                self._cv.wait(timeout)
                if not self._ready:
                    return None
            pq = self._ready.popleft()
            pq.draining = True
            now = time.perf_counter()
            expired: list[Request] = []
            batch: list[Request] = []
            while pq.q:
                r = pq.q.popleft()
                if r.deadline is not None and now > r.deadline:
                    expired.append(r)
                    continue
                batch.append(r)
                break
            if batch and batch[0].op != "append":
                head = batch[0]
                rest = collections.deque()
                while pq.q and len(batch) < self.max_batch:
                    r = pq.q.popleft()
                    if r.deadline is not None and now > r.deadline:
                        expired.append(r)
                    elif r.signature == head.signature:
                        batch.append(r)
                    else:
                        rest.append(r)
                rest.extend(pq.q)
                pq.q = rest
            self._queued_bytes -= (sum(r.cost for r in batch)
                                   + sum(r.cost for r in expired))
            telemetry.gauge("serve_queue_depth").set(
                sum(len(q.q) for q in self._queues.values()))
            telemetry.gauge("serve_queued_bytes").set(self._queued_bytes)
        if expired:
            err_by = time.perf_counter()
            for r in expired:
                r.future.set_exception(DeadlineExceeded(
                    f"request {r.ticket} ({r.op} on {r.panel!r}) "
                    f"spent {err_by - r.t_submit:.3f}s queued, past its "
                    f"deadline"))
            telemetry.counter("serve_deadline_exceeded").inc(len(expired))
        if batch:
            telemetry.gauge("serve_batch_occupancy").set(len(batch))
            telemetry.histogram("serve_batch_occupancy_hist").observe(
                len(batch))
            if len(batch) > 1:
                telemetry.counter("serve_launches_saved").inc(
                    len(batch) - 1)
        return pq, batch, len(expired)

    def _release(self, pq: _PanelQueue) -> None:
        """Return a drained panel to the ready list if work remains."""
        with self._cv:
            pq.draining = False
            if pq.q and not self._closed:
                self._ready.append(pq)
                self._cv.notify()

    # ------------------------------------------------- quarantine logic

    def _note_batch_failure(self, pq: _PanelQueue | None,
                            exc: BaseException) -> None:
        """Count a batch-level failure; quarantine past the threshold.

        Called by the panel's single active drainer (or its dying
        worker), so the streak needs no extra lock.
        """
        if pq is None:
            return
        pq.fail_streak += 1
        if pq.fail_streak >= self.quarantine_after:
            self._quarantine(pq.name, exc)

    def _note_batch_success(self, pq: _PanelQueue | None) -> None:
        if pq is not None:
            pq.fail_streak = 0

    def _quarantine(self, panel: str, exc: BaseException) -> None:
        """Fail the panel fast: flush its queue, refuse new submits."""
        with self._cv:
            pq = self._queues.get(panel)
            if pq is None:
                pq = self._queues[panel] = _PanelQueue(panel)
            if pq.quarantined is not None:
                return
            pq.quarantined = exc
            pending = list(pq.q)
            pq.q.clear()
            self._queued_bytes -= sum(r.cost for r in pending)
        err = PanelQuarantined(
            f"panel {panel!r} quarantined: "
            f"{type(exc).__name__}: {exc}", exc)
        for r in pending:
            if not r.future.done():
                r.future.set_exception(err)
        telemetry.counter("serve_quarantined").inc()
        telemetry.event("serve.quarantine", panel=panel,
                        error=f"{type(exc).__name__}: {exc}")

    def clear_quarantine(self, panel: str) -> bool:
        """Operator reset; returns whether the panel was quarantined."""
        with self._cv:
            pq = self._queues.get(panel)
            if pq is None or pq.quarantined is None:
                return False
            pq.quarantined = None
            pq.fail_streak = 0
            return True

    # ----------------------------------------------------------- execute

    def _execute(self, batch: list[Request],
                 pq: _PanelQueue | None = None) -> None:
        head = batch[0]
        entry = self.registry.get(head.panel)
        t0 = time.perf_counter()
        with entry.exec_lock:  # excludes the eviction path, nothing else
            if self.faults is not None:
                # BaseException: rides the real worker-death path.
                self.faults.check("worker_death", detail=head.panel)
            try:
                with telemetry.span("serve.batch", op=head.op,
                                    panel=head.panel, size=len(batch)):
                    if head.op == "ccm" and len(batch) > 1:
                        results = self._exec_ccm_batch(entry, batch)
                    else:
                        # Loop path: failures stay per-request — one op
                        # raising must not poison its batch peers.
                        results = []
                        for r in batch:
                            try:
                                results.append(self._exec_one(entry, r))
                            except Exception as exc:  # noqa: BLE001
                                telemetry.counter("serve_errors").inc()
                                results.append(exc)
            except Exception as exc:  # noqa: BLE001 — shared-launch failure
                telemetry.counter("serve_errors").inc()
                for r in batch:
                    r.future.set_exception(exc)
                self._note_batch_failure(pq, exc)
                self._after_batch(entry)
                return
        done = time.perf_counter()
        ms = (done - t0) * 1e3
        hist = telemetry.histogram(f"serve_latency_ms_{head.op}")
        live = telemetry.active()  # per-request events only under a sink
        for r, res in zip(batch, results):
            if live:
                telemetry.event("serve.request", op=r.op, ticket=r.ticket,
                                batched_with=len(batch) - 1,
                                queued_ms=(t0 - r.t_submit) * 1e3,
                                exec_ms=ms)
            hist.observe((done - r.t_submit) * 1e3)
            if isinstance(res, Exception):
                r.future.set_exception(res)
            else:
                r.future.set_result(res)
        telemetry.counter("serve_batches").inc()
        self._note_batch_success(pq)
        self._after_batch(entry)

    def _after_batch(self, entry: PanelEntry) -> None:
        """LRU touch + byte-budget enforcement after every batch."""
        self.registry.touch(entry)
        self.registry.enforce_budget(protect=entry.name)

    def _exec_one(self, entry: PanelEntry, r: Request):
        sess = entry.sess
        p = r.params
        if self.faults is not None:
            self.faults.check("slow_launch")
            self.faults.check("launch_error", detail=f"{r.op}:{r.panel}")
            self.faults.check("launch_oom", detail=f"{r.op}:{r.panel}")
        if r.op == "append":
            delta = np.asarray(p["delta"], np.float32)
            records = sess.append(delta)
            new_version = entry.version + 1
            if entry.wal is not None:
                # WAL before the future resolves. On write failure the
                # in-memory library is ahead of the log: quarantine —
                # serving it would break the recovery bit-contract.
                try:
                    entry.wal.log_append(delta, new_version)
                except Exception as exc:
                    self._quarantine(entry.name, exc)
                    raise
                if entry.wal.should_compact():
                    entry.wal.compact(sess, new_version)
            entry.version = new_version
            telemetry.counter("serve_appends").inc()
            out = {"records": records, "version": entry.version,
                   "N": sess.data.N, "L": sess.data.L}
            if self.subscriptions is not None:
                self.subscriptions.on_append(entry)
            return out
        if r.op == "subscribe":
            if self.subscriptions is None:
                raise RuntimeError("this scheduler has no subscription hub")
            return self.subscriptions.open(
                entry, pairs=p["pairs"], E=p.get("E"))
        if r.op == "ccm":
            if p.get("lib_sizes") is not None:  # sweep: classic engine
                return sess.ccm(p["lib"], p["target"],
                                lib_sizes=p["lib_sizes"], E=p.get("E"))
            # Default-cap requests ALWAYS go through the batch engine —
            # solo or coalesced, a pair's answer has the same bits.
            E = p.get("E")
            if E is None:
                E = sess._resolve_pair_E(sess.data.index_of(p["target"]),
                                         None)
            return sess.ccm_batch([(p["lib"], p["target"])], E=E)[0]
        if r.op == "xmap":
            return sess.xmap(p.get("method", "simplex"),
                             theta=p.get("theta"))
        if r.op == "simplex":
            return sess.simplex(p.get("E"))
        if r.op == "optimal_E":
            return sess.optimal_E()
        if r.op == "surrogate_test":
            return sess.surrogate_test(
                p["lib"], p["target"],
                num_surrogates=p.get("num_surrogates", 100),
                method=p.get("method", "shuffle"),
                period=p.get("period"), seed=p.get("seed", 0))
        raise AssertionError(f"unreachable op {r.op!r}")

    def _exec_ccm_batch(self, entry: PanelEntry, batch: list[Request]):
        """n compatible CCM pairs as ONE coalesced engine launch.

        ``EDM.ccm_batch`` owns the bit contract (batch-invariant
        answers; see its docstring) — the scheduler only supplies the
        coalesced pair list and the telemetry.
        """
        sess = entry.sess
        if self.faults is not None:
            self.faults.check("slow_launch")
            self.faults.check("launch_error",
                              detail=f"ccm_batch:{entry.name}")
            self.faults.check("launch_oom",
                              detail=f"ccm_batch:{entry.name}")
        E = int(batch[0].params["E"])
        pairs = [(r.params["lib"], r.params["target"]) for r in batch]
        rho = sess.ccm_batch(pairs, E=E)
        telemetry.counter("serve_ccm_group_launches").inc()
        self._bump_session(sess, "ccm_coalesced", len(batch))
        return list(rho)  # np.float32 scalars, no copies

    @staticmethod
    def _bump_session(sess, key, n) -> None:
        sess.stats[key] += n
        telemetry.counter(f"edm_{key}").inc(n)

    # -------------------------------------------------- drain and close

    def drain(self, timeout: float | None = None) -> bool:
        """Stop admission and wait for the queues to empty.

        New submits raise ``Draining`` immediately; already-queued
        requests keep executing (workers stay up). Returns True once
        every per-panel queue is empty and idle, False on timeout.
        """
        with self._cv:
            self._draining = True
        telemetry.event("serve.drain_begin")
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            with self._cv:
                busy = any(pq.q or pq.draining
                           for pq in self._queues.values())
            if not busy:
                telemetry.event("serve.drain_done")
                return True
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(0.02)

    def close(self) -> None:
        """Stop accepting work; fail queued requests; join the pool."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            pending = [r for pq in self._queues.values() for r in pq.q]
            for pq in self._queues.values():
                pq.q.clear()
            self._queued_bytes = 0
            self._ready.clear()
            threads = [t for t in self._threads if t is not None]
            self._cv.notify_all()
        self._sup_stop.set()
        for r in pending:
            r.future.set_exception(RuntimeError("scheduler closed"))
        for t in threads:
            t.join(timeout=5.0)
        if self._sup_thread is not None:
            self._sup_thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
