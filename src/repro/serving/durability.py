"""Crash durability for the EDM server: per-panel write-ahead logs.

Under ``EDMServer(state_dir=...)`` every panel registration and every
*accepted* append delta is made durable before its future resolves, so
``EDMServer.recover(state_dir)`` after any crash (kill -9 included)
rebuilds every panel at its exact pre-crash library version — and by
the append≡rebuild contract (``plan.panel_master_append`` is
bit-identical to a cold rebuild), every served answer after recovery is
bit-identical to an uninterrupted session.

On-disk layout, one directory per panel under ``<state_dir>/panels/``::

    <slug>/                      # atomic: written as <slug>.tmp, renamed
      meta.json                  # name, names, config fields, fingerprint
      base.npy                   # the raw registered panel (float32)
      snap-0000000012/           # newest compaction snapshot (version 12)
        state.npz                # panel, valid mask, running screen stats
        snap.json                # version, names, invalid_report
      wal-0000000012.log         # append records with version > 12

The **fingerprint** reuses the PR-6 ``run_key`` hashing idiom: sha256
over the panel's dtype/shape/bytes plus ``config_fingerprint`` of the
resolved session config — recovery refuses a state dir whose base panel
or config no longer hashes to what was registered.

**WAL records** are length-prefixed, CRC-framed segments::

    b"EDMW" | u32 header_len | u32 payload_len | u32 crc32 | header | payload

where the header is a JSON dict ``{"v": version, "shape": [N, dt]}``
and the payload is the delta's float32 bytes. A torn tail (the crash
landed mid-write) fails its CRC: recovery replays to the last complete
record and warns — exactly the PR-6 journal posture. Corruption
*before* the tail is refused loudly (``WalError``).

**Compaction**: every ``compact_every`` logged records the owner
snapshots the live ``Dataset`` state (panel + validity mask + running
screen stats + invalid report — sufficient to continue ``append``
bit-identically) into an atomic tmp+rename directory, rotates to a
fresh WAL, and deletes older segments — recovery cost is
O(snapshot + log tail), not O(append history).

**Write/fsync discipline**: records are written and flushed before the
append future resolves — durable against process death (the OS page
cache survives kill -9). ``wal_fsync=True`` additionally fsyncs per
record (power-loss durability at a per-append fsync cost); the default
fsyncs at compaction, drain, and close. Registration and snapshots are
always fsynced before their atomic rename publishes them.

Failure honesty: if a WAL write fails *after* the in-memory append was
applied, memory is ahead of the log — the scheduler quarantines the
panel (fail fast with the WAL error) rather than serving answers a
recovery could never reproduce.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import re
import struct
import threading
import warnings
import zlib

import numpy as np

from repro import telemetry
from repro.edm.config import EDMConfig
from repro.edm.session import EDM

_MAGIC = b"EDMW"
_FRAME = struct.Struct("<III")  # header_len, payload_len, crc32

#: Default records-per-WAL before compaction into a snapshot.
COMPACT_EVERY = 64


class WalError(RuntimeError):
    """A state dir that cannot be recovered (corruption before the
    tail, a version gap, or a fingerprint mismatch)."""


def panel_fingerprint(panel: np.ndarray, config: EDMConfig) -> str:
    """Identity of (panel bytes, resolved config) — the ``run_key``
    hashing idiom from ``edm.runner``, minus the task signature."""
    from repro.edm.runner import config_fingerprint
    arr = np.ascontiguousarray(np.asarray(panel, np.float32))
    h = hashlib.sha256()
    h.update(f"{arr.dtype}|{arr.shape}|".encode())
    h.update(arr.tobytes())
    h.update(config_fingerprint(config).encode())
    return h.hexdigest()[:32]


def _config_dict(config: EDMConfig) -> dict:
    d = {f: getattr(config, f) for f in config.__dataclass_fields__}
    if d.pop("mesh", None) is not None:
        raise ValueError(
            "a config carrying a live device mesh cannot be made "
            "durable; register without mesh= when state_dir is set")
    return {k: (list(v) if isinstance(v, tuple) else v)
            for k, v in d.items()}


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _slug(name: str) -> str:
    safe = re.sub(r"[^A-Za-z0-9._-]", "_", name)[:48]
    return f"{safe}-{hashlib.sha256(name.encode()).hexdigest()[:8]}"


def _frame_record(version: int, delta: np.ndarray) -> bytes:
    header = json.dumps(
        {"v": int(version), "shape": list(delta.shape)}).encode()
    payload = delta.tobytes()
    crc = zlib.crc32(header + payload)
    return _MAGIC + _FRAME.pack(len(header), len(payload), crc) \
        + header + payload


def _read_frames(path: str) -> tuple[list[tuple[int, np.ndarray]], int]:
    """Parse one WAL file; returns (records, torn_tail_bytes).

    Stops at the first frame that is incomplete or fails its CRC; the
    caller decides whether a torn tail is tolerable (last segment) or
    corruption (an earlier one).
    """
    records: list[tuple[int, np.ndarray]] = []
    with open(path, "rb") as f:
        data = f.read()
    off, n = 0, len(data)
    while off < n:
        head_end = off + len(_MAGIC) + _FRAME.size
        if data[off:off + len(_MAGIC)] != _MAGIC or head_end > n:
            break
        hlen, plen, crc = _FRAME.unpack(data[off + len(_MAGIC):head_end])
        end = head_end + hlen + plen
        if end > n:
            break
        blob = data[head_end:end]
        if zlib.crc32(blob) != crc:
            break
        header = json.loads(blob[:hlen])
        delta = np.frombuffer(
            blob[hlen:], np.float32).reshape(header["shape"]).copy()
        records.append((int(header["v"]), delta))
        off = end
    return records, n - off


def _restore_dataset(npz, snap: dict, on_invalid: str):
    """Rebuild a ``Dataset`` from snapshot state without re-screening.

    The snapshot holds the *live* dataset fields (post-mask/drop panel,
    validity mask, running screen stats, accumulated invalid report) —
    restoring them verbatim is what keeps later ``append`` calls
    bit-identical to the uninterrupted session.
    """
    import jax.numpy as jnp
    from repro.edm.dataset import Dataset
    ds = Dataset.__new__(Dataset)
    ds.on_invalid = on_invalid
    ds.panel = jnp.asarray(np.asarray(npz["panel"], np.float32))
    ds.names = snap["names"]
    ds.valid = np.asarray(npz["valid"], bool)
    ds._stats = {"cnt": np.asarray(npz["cnt"]),
                 "lo": np.asarray(npz["lo"]),
                 "hi": np.asarray(npz["hi"])}
    ds.invalid_report = list(snap["invalid_report"])
    ds._embeddings = {}
    return ds


class PanelLog:
    """One panel's durable state: meta + base + snapshots + active WAL."""

    def __init__(self, pdir: str, *, compact_every: int = COMPACT_EVERY,
                 wal_fsync: bool = False, faults=None):
        self.pdir = pdir
        self.compact_every = max(1, int(compact_every))
        self.wal_fsync = bool(wal_fsync)
        self.faults = faults
        self._lock = threading.Lock()
        self._wal: io.BufferedWriter | None = None
        self._wal_path: str | None = None
        self._since_snap = 0
        self.broken: Exception | None = None

    # ------------------------------------------------------ registration

    @classmethod
    def create(cls, panels_dir: str, name: str, panel: np.ndarray,
               names, config: EDMConfig, **kw) -> "PanelLog":
        """Durably publish a registration (atomic tmp+rename)."""
        pdir = os.path.join(panels_dir, _slug(name))
        if os.path.isdir(pdir):
            raise ValueError(
                f"state dir already holds panel {name!r}; use "
                f"EDMServer.recover() to reload it")
        tmp = pdir + ".tmp"
        if os.path.isdir(tmp):
            import shutil
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arr = np.ascontiguousarray(np.asarray(panel, np.float32))
        meta = {"format": 1, "name": name,
                "names": list(names) if names is not None else None,
                "config": _config_dict(config),
                "fingerprint": panel_fingerprint(arr, config)}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        np.save(os.path.join(tmp, "base.npy"), arr)
        _fsync_file(os.path.join(tmp, "base.npy"))
        _fsync_dir(tmp)
        os.rename(tmp, pdir)
        _fsync_dir(panels_dir)
        log = cls(pdir, **kw)
        log._open_wal(0)
        return log

    @classmethod
    def open_dir(cls, pdir: str, **kw) -> "PanelLog":
        if not os.path.isfile(os.path.join(pdir, "meta.json")):
            raise WalError(f"{pdir} has no meta.json — not a panel dir")
        return cls(pdir, **kw)

    def meta(self) -> dict:
        with open(os.path.join(self.pdir, "meta.json")) as f:
            return json.load(f)

    # -------------------------------------------------------- WAL writes

    def _wal_name(self, base_version: int) -> str:
        return os.path.join(self.pdir, f"wal-{base_version:010d}.log")

    def _open_wal(self, base_version: int) -> None:
        self._wal_path = self._wal_name(base_version)
        self._wal = open(self._wal_path, "ab")
        self._since_snap = 0

    def log_append(self, delta: np.ndarray, version: int) -> None:
        """Durably frame one accepted delta; called BEFORE the append
        future resolves. Raises on write failure (the caller must then
        quarantine the panel: memory is ahead of the log)."""
        with self._lock:
            if self.broken is not None:
                raise WalError(
                    f"panel WAL is broken: {self.broken}") from self.broken
            if self._wal is None:
                self._open_wal(0)
            frame = _frame_record(
                version, np.ascontiguousarray(delta, dtype=np.float32))
            try:
                if self.faults is not None:
                    self.faults.check("wal_write", detail=self.pdir)
                self._wal.write(frame)
                self._wal.flush()
                if self.wal_fsync:
                    os.fsync(self._wal.fileno())
            except Exception as exc:
                self.broken = exc
                raise
            self._since_snap += 1
            telemetry.counter("serve_wal_bytes").inc(len(frame))
            telemetry.counter("serve_wal_records").inc()

    def should_compact(self) -> bool:
        return self.broken is None and self._since_snap >= self.compact_every

    # ------------------------------------------------------- compaction

    def compact(self, sess: EDM, version: int) -> None:
        """Snapshot the live dataset state at ``version`` and rotate the
        WAL. Crash-safe at every step: recovery is version-driven, so a
        half-finished compaction is at worst ignored."""
        with self._lock:
            if self._wal is not None:
                self._wal.flush()
                os.fsync(self._wal.fileno())
            snap = os.path.join(self.pdir, f"snap-{version:010d}")
            if not os.path.isdir(snap):
                # A snapshot at this version may already exist (the
                # post-recovery compaction re-compacts the recovered
                # version). Same version == same durable state, so the
                # existing one stands — replacing it would open a crash
                # window with no snapshot at all.
                tmp = snap + ".tmp"
                if os.path.isdir(tmp):
                    import shutil
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                ds = sess.data
                np.savez(os.path.join(tmp, "state.npz"),
                         panel=np.asarray(ds.panel, np.float32),
                         valid=np.asarray(ds.valid, bool),
                         cnt=ds._stats["cnt"], lo=ds._stats["lo"],
                         hi=ds._stats["hi"])
                with open(os.path.join(tmp, "snap.json"), "w") as f:
                    json.dump({"version": int(version), "names": ds.names,
                               "invalid_report": ds.invalid_report}, f)
                    f.flush()
                    os.fsync(f.fileno())
                _fsync_file(os.path.join(tmp, "state.npz"))
                _fsync_dir(tmp)
                os.rename(tmp, snap)
                _fsync_dir(self.pdir)
            if self._wal is not None:
                self._wal.close()
            self._open_wal(version)
            self._gc(keep_version=version)
            telemetry.event("serve.wal_compact", panel_dir=self.pdir,
                            version=int(version))

    def _gc(self, keep_version: int) -> None:
        """Drop snapshots and WAL segments older than ``keep_version``."""
        for fn in os.listdir(self.pdir):
            m = re.match(r"(snap|wal)-(\d{10})(?:\.log)?$", fn)
            if m and int(m.group(2)) < keep_version:
                path = os.path.join(self.pdir, fn)
                if m.group(1) == "snap":
                    import shutil
                    shutil.rmtree(path, ignore_errors=True)
                else:
                    os.unlink(path)

    # --------------------------------------------------------- recovery

    def _snapshots(self) -> list[tuple[int, str]]:
        out = []
        for fn in os.listdir(self.pdir):
            m = re.match(r"snap-(\d{10})$", fn)
            if m and os.path.isfile(
                    os.path.join(self.pdir, fn, "snap.json")):
                out.append((int(m.group(1)), os.path.join(self.pdir, fn)))
        return sorted(out)

    def _wal_files(self) -> list[tuple[int, str]]:
        out = []
        for fn in os.listdir(self.pdir):
            m = re.match(r"wal-(\d{10})\.log$", fn)
            if m:
                out.append((int(m.group(1)), os.path.join(self.pdir, fn)))
        return sorted(out)

    def recover(self) -> tuple[EDM, int, dict]:
        """Rebuild the session through the normal append path.

        Returns ``(session, version, info)`` where the session is
        bit-identical to the pre-crash one at ``version`` (the last
        durably logged append). After this, call
        ``reset_after_recovery`` to rotate a clean WAL before serving.
        """
        meta = self.meta()
        base = np.load(os.path.join(self.pdir, "base.npy"))
        config = EDMConfig(**{
            k: v for k, v in meta["config"].items() if k != "mesh"})
        fp = panel_fingerprint(base, config)
        if fp != meta["fingerprint"]:
            raise WalError(
                f"panel {meta['name']!r}: base panel/config fingerprint "
                f"mismatch ({fp} != {meta['fingerprint']}) — the state "
                f"dir does not belong to this registration")
        snaps = self._snapshots()
        if snaps:
            v0, sdir = snaps[-1]
            with np.load(os.path.join(sdir, "state.npz")) as npz:
                with open(os.path.join(sdir, "snap.json")) as f:
                    sj = json.load(f)
                ds = _restore_dataset(npz, sj, config.on_invalid)
        else:
            from repro.edm.dataset import Dataset
            v0 = 0
            ds = Dataset(base, names=meta["names"],
                         on_invalid=config.on_invalid)
        sess = EDM(ds, config)
        version, replayed, torn = v0, 0, 0
        wals = self._wal_files()
        for i, (_, path) in enumerate(wals):
            records, tail = _read_frames(path)
            if tail:
                if i != len(wals) - 1:
                    raise WalError(
                        f"{path}: {tail} undecodable bytes before the "
                        f"final WAL segment — state dir is corrupt")
                torn = tail
                warnings.warn(
                    f"{path}: torn tail ({tail} bytes) — recovering to "
                    f"the last complete record", stacklevel=2)
                telemetry.event("serve.wal_torn_tail",
                                panel_dir=self.pdir, bytes=int(tail))
            for v, delta in records:
                if v <= version:
                    continue  # already inside the snapshot
                if v != version + 1:
                    raise WalError(
                        f"{path}: version gap (have {version}, record "
                        f"claims {v})")
                sess.append(delta)
                version, replayed = v, replayed + 1
        return sess, version, {"name": meta["name"], "version": version,
                               "replayed": replayed, "snapshot": v0,
                               "torn_tail_bytes": torn}

    def reset_after_recovery(self, sess: EDM, version: int) -> None:
        """Post-recovery compaction: snapshot the recovered state and
        rotate a fresh WAL (also truncates any torn tail for good)."""
        self.compact(sess, version)

    # ------------------------------------------------------------ flush

    def fsync(self) -> None:
        with self._lock:
            if self._wal is not None and self.broken is None:
                self._wal.flush()
                os.fsync(self._wal.fileno())

    def close(self) -> None:
        with self._lock:
            if self._wal is not None:
                try:
                    self._wal.flush()
                    os.fsync(self._wal.fileno())
                except OSError:
                    pass
                self._wal.close()
                self._wal = None


class Durability:
    """All panels' logs under one ``state_dir`` (the server-level knob)."""

    def __init__(self, state_dir: str, *,
                 compact_every: int = COMPACT_EVERY,
                 wal_fsync: bool = False, faults=None):
        self.state_dir = state_dir
        self.panels_dir = os.path.join(state_dir, "panels")
        os.makedirs(self.panels_dir, exist_ok=True)
        self.compact_every = compact_every
        self.wal_fsync = wal_fsync
        self.faults = faults
        self._lock = threading.Lock()
        self._logs: dict[str, PanelLog] = {}

    def _kw(self) -> dict:
        return dict(compact_every=self.compact_every,
                    wal_fsync=self.wal_fsync, faults=self.faults)

    def register(self, name: str, panel, names,
                 config: EDMConfig) -> PanelLog:
        log = PanelLog.create(self.panels_dir, name, panel, names,
                              config, **self._kw())
        with self._lock:
            self._logs[name] = log
        return log

    def adopt(self, name: str, log: PanelLog) -> None:
        with self._lock:
            self._logs[name] = log

    def scan(self) -> list[PanelLog]:
        """Panel logs found on disk (the recovery entry point)."""
        out = []
        for fn in sorted(os.listdir(self.panels_dir)):
            pdir = os.path.join(self.panels_dir, fn)
            if fn.endswith(".tmp") or not os.path.isdir(pdir):
                continue
            if os.path.isfile(os.path.join(pdir, "meta.json")):
                out.append(PanelLog.open_dir(pdir, **self._kw()))
        return out

    def get(self, name: str) -> PanelLog | None:
        with self._lock:
            return self._logs.get(name)

    def drop(self, name: str) -> None:
        with self._lock:
            log = self._logs.pop(name, None)
        if log is not None:
            log.close()

    def fsync_all(self) -> None:
        with self._lock:
            logs = list(self._logs.values())
        for log in logs:
            log.fsync()

    def close(self) -> None:
        with self._lock:
            logs = list(self._logs.values())
            self._logs.clear()
        for log in logs:
            log.close()
