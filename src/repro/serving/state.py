"""Panel registry for the EDM server: warm sessions, versioning, LRU.

One ``PanelEntry`` per registered panel, owning the long-lived ``EDM``
session (so its kNN master, optimal-E curves, and jit caches stay warm
across requests) and the two version counters the scheduler's
coalescing rule is built on:

* ``version``          — committed library state, bumped when an append
                         EXECUTES. Results are tagged with it.
* ``queued_version``   — what a request submitted *now* will observe,
                         bumped when an append is ENQUEUED. Requests
                         capture it in their coalescing signature, so a
                         query behind a pending append can never be
                         pulled into a batch that runs ahead of it: the
                         append is a version barrier by construction.

**Session memory management.** Every warm session's multi-E kNN master
is ``2·N·E_max·Lp·k_master`` float32/int32 values — at whole-brain
panel counts cold panels cannot all keep theirs resident. The registry
enforces an LRU **byte budget** over cached masters
(``EDMServer(master_budget_mb=...)`` → ``set_budget``): after each
executed batch the scheduler touches the panel's LRU slot and calls
``enforce_budget``, which evicts the least-recently-used panels'
masters (``EDM.evict_master``) until the budget holds. The
most-recently-used panel is never evicted — a single working panel
larger than the budget must not thrash. Eviction is *only* a memory
event: the next request on an evicted panel lazily rebuilds the master
from the current panel (``EDM._master``), and because the incremental
append path is bit-identical to a cold rebuild, every answer (and every
later append) is bit-identical to a never-evicted session. Telemetry:
``serve_evictions`` counter, ``serve_master_bytes`` gauge.

Concurrency: registry mutation goes through the registry lock; session
state is touched only by the panel's single active drain worker (the
scheduler serializes per-panel execution) and by the evictor — the two
exclude each other through ``PanelEntry.exec_lock``, and the evictor
only ever tries that lock non-blocking (a busy panel is hot, skip it).
"""

from __future__ import annotations

import threading

import numpy as np

from repro import telemetry
from repro.edm.config import EDMConfig
from repro.edm.session import EDM


class PanelEntry:
    """A registered panel: warm session + version counters + LRU slot."""

    def __init__(self, name: str, sess: EDM):
        self.name = name
        self.sess = sess
        self.version = 0
        self.queued_version = 0
        self.last_used = 0           # registry LRU tick, monotonic
        self.evictions = 0
        self.wal = None              # durability.PanelLog when durable
        # Held by the active drain worker for the whole batch and by the
        # evictor around evict_master(): execution and eviction exclude
        # each other; per-panel drains are already serial above this.
        self.exec_lock = threading.Lock()

    def master_nbytes(self) -> int:
        return self.sess.master_nbytes()

    def info(self) -> dict:
        """JSON-ready description (the ``/panels`` listing row)."""
        return {
            "name": self.name,
            "N": self.sess.data.N,
            "L": self.sess.data.L,
            "version": self.version,
            "num_invalid": self.sess.data.num_invalid,
            "E_max": self.sess.config.E_max,
            "tau": self.sess.config.tau,
            "master_bytes": self.master_nbytes(),
            "evictions": self.evictions,
        }


class Registry:
    """Name → ``PanelEntry`` map behind one lock, plus the LRU budget."""

    def __init__(self, *, master_budget_bytes: int | None = None):
        self._lock = threading.Lock()
        self._panels: dict[str, PanelEntry] = {}
        self._budget = master_budget_bytes
        self._tick = 0

    @property
    def lock(self) -> threading.Lock:
        return self._lock

    def register(self, name: str, panel, *, names=None,
                 config: EDMConfig | None = None, **overrides) -> dict:
        """Bind a panel under ``name``; rejects duplicates.

        Construction (including the Dataset screen) happens outside the
        registry lock — a big panel must not stall the scheduler — and
        the name is claimed atomically afterwards.
        """
        panel = np.asarray(panel, np.float32)
        if config is None:
            config = EDMConfig(**overrides)
        elif overrides:
            config = config.replace(**overrides)
        from repro.edm.dataset import Dataset
        sess = EDM(Dataset(panel, names=names,
                           on_invalid=config.on_invalid), config)
        entry = PanelEntry(name, sess)
        with self._lock:
            if name in self._panels:
                raise ValueError(f"panel {name!r} is already registered")
            self._tick += 1
            entry.last_used = self._tick
            self._panels[name] = entry
        return entry.info()

    def adopt(self, name: str, sess: EDM, *, version: int = 0
              ) -> PanelEntry:
        """Claim ``name`` for an already-built session (the recovery
        path: ``EDMServer.recover`` replays a WAL into a session and
        binds it here at its recovered library version)."""
        entry = PanelEntry(name, sess)
        entry.version = entry.queued_version = int(version)
        with self._lock:
            if name in self._panels:
                raise ValueError(f"panel {name!r} is already registered")
            self._tick += 1
            entry.last_used = self._tick
            self._panels[name] = entry
        return entry

    def remove(self, name: str) -> None:
        """Unbind a panel (the rollback when a durable registration's
        WAL publish fails after the name was claimed)."""
        with self._lock:
            self._panels.pop(name, None)

    def get(self, name: str) -> PanelEntry:
        with self._lock:
            try:
                return self._panels[name]
            except KeyError:
                raise KeyError(f"no panel registered as {name!r}") from None

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._panels)

    def infos(self) -> list[dict]:
        with self._lock:
            entries = list(self._panels.values())
        return [e.info() for e in entries]

    # -------------------------------------------------- LRU byte budget

    def set_budget(self, nbytes: int | None) -> None:
        with self._lock:
            self._budget = nbytes

    @property
    def budget_bytes(self) -> int | None:
        return self._budget

    def touch(self, entry: PanelEntry) -> None:
        """Mark ``entry`` most-recently-used (called after each batch)."""
        with self._lock:
            self._tick += 1
            entry.last_used = self._tick

    def master_bytes_total(self) -> int:
        with self._lock:
            entries = list(self._panels.values())
        return sum(e.master_nbytes() for e in entries)

    def evict(self, entry: PanelEntry, *, blocking: bool = True) -> int:
        """Evict one panel's cached kNN master; returns bytes freed.

        Takes the entry's ``exec_lock`` so eviction never races the
        panel's drain worker mid-batch. Non-blocking mode (the budget
        enforcer) skips a busy panel — it is hot by definition.
        """
        if not entry.exec_lock.acquire(blocking=blocking):
            return 0
        try:
            freed = entry.sess.evict_master()
        finally:
            entry.exec_lock.release()
        if freed:
            entry.evictions += 1
            telemetry.counter("serve_evictions").inc()
            telemetry.event("serve.evict", panel=entry.name, bytes=freed)
        return freed

    def enforce_budget(self, *, protect: str | None = None) -> list[str]:
        """Evict cold masters (LRU-first) until the byte budget holds.

        ``protect`` (the panel a batch just executed on) and, in any
        case, the most-recently-used cached master are exempt — the
        budget bounds *cold* state, it never deadlocks the working set.
        Returns the names evicted. Refreshes ``serve_master_bytes``.
        """
        with self._lock:
            budget = self._budget
            entries = sorted(self._panels.values(),
                             key=lambda e: e.last_used)
        sizes = {e.name: e.master_nbytes() for e in entries}
        total = sum(sizes.values())
        evicted: list[str] = []
        if budget is not None and total > budget:
            cached = [e for e in entries if sizes[e.name] > 0]
            for e in cached[:-1]:  # never the MRU cached master
                if e.name == protect:
                    continue
                freed = self.evict(e, blocking=False)
                if freed:
                    total -= freed
                    evicted.append(e.name)
                if total <= budget:
                    break
        telemetry.gauge("serve_master_bytes").set(total)
        return evicted
