"""Panel registry for the EDM server: warm sessions + append versioning.

One ``PanelEntry`` per registered panel, owning the long-lived ``EDM``
session (so its kNN master, optimal-E curves, and jit caches stay warm
across requests) and the two version counters the scheduler's
coalescing rule is built on:

* ``version``          — committed library state, bumped when an append
                         EXECUTES. Results are tagged with it.
* ``queued_version``   — what a request submitted *now* will observe,
                         bumped when an append is ENQUEUED. Requests
                         capture it in their coalescing signature, so a
                         query behind a pending append can never be
                         pulled into a batch that runs ahead of it: the
                         append is a version barrier by construction.

All mutation goes through the registry lock; the scheduler's single
worker thread is the only caller that touches sessions after
registration.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.edm.config import EDMConfig
from repro.edm.session import EDM


class PanelEntry:
    """A registered panel: warm session + version counters."""

    def __init__(self, name: str, sess: EDM):
        self.name = name
        self.sess = sess
        self.version = 0
        self.queued_version = 0

    def info(self) -> dict:
        """JSON-ready description (the ``/panels`` listing row)."""
        return {
            "name": self.name,
            "N": self.sess.data.N,
            "L": self.sess.data.L,
            "version": self.version,
            "num_invalid": self.sess.data.num_invalid,
            "E_max": self.sess.config.E_max,
            "tau": self.sess.config.tau,
        }


class Registry:
    """Name → ``PanelEntry`` map behind one lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._panels: dict[str, PanelEntry] = {}

    @property
    def lock(self) -> threading.Lock:
        return self._lock

    def register(self, name: str, panel, *, names=None,
                 config: EDMConfig | None = None, **overrides) -> dict:
        """Bind a panel under ``name``; rejects duplicates.

        Construction (including the Dataset screen) happens outside the
        registry lock — a big panel must not stall the scheduler — and
        the name is claimed atomically afterwards.
        """
        panel = np.asarray(panel, np.float32)
        if config is None:
            config = EDMConfig(**overrides)
        elif overrides:
            config = config.replace(**overrides)
        from repro.edm.dataset import Dataset
        sess = EDM(Dataset(panel, names=names,
                           on_invalid=config.on_invalid), config)
        entry = PanelEntry(name, sess)
        with self._lock:
            if name in self._panels:
                raise ValueError(f"panel {name!r} is already registered")
            self._panels[name] = entry
        return entry.info()

    def get(self, name: str) -> PanelEntry:
        with self._lock:
            try:
                return self._panels[name]
            except KeyError:
                raise KeyError(f"no panel registered as {name!r}") from None

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._panels)

    def infos(self) -> list[dict]:
        with self._lock:
            entries = list(self._panels.values())
        return [e.info() for e in entries]
