"""Batched serving engine: prefill + greedy/temperature decode.

Fixed-slot batching: requests are grouped into a batch, caches allocated
to ``s_max``, prompts prefilled (equal-length fast path) or replayed
token-by-token (ragged path — correct for any lengths), then decoded
together until every slot hits EOS or max_new. The decode step is the
same ``serve_step`` the dry-run lowers at scale.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf


@dataclasses.dataclass
class GenerationResult:
    tokens: list[list[int]]
    steps: int


class ServeEngine:
    def __init__(self, cfg, params, *, s_max: int = 256):
        self.cfg = cfg
        self.params = params
        self.s_max = s_max
        self._decode = jax.jit(
            lambda p, t, c, pos: tf.decode_step(p, cfg, t, c, pos))

    def generate(
        self,
        prompts: list[list[int]],
        *,
        max_new: int = 32,
        temperature: float = 0.0,
        eos_id: int | None = None,
        seed: int = 0,
    ) -> GenerationResult:
        cfg = self.cfg
        B = len(prompts)
        lens = [len(p) for p in prompts]
        max_len = max(lens)
        if max_len + max_new > self.s_max:
            raise ValueError("s_max too small for prompt + max_new")
        cache = tf.init_cache(cfg, B, self.s_max)
        # Left-pad with the row's first token so all rows end at the same
        # position; padded prefix tokens are part of the replay but the
        # generated continuation starts from the true prompt ending.
        toks = np.zeros((B, max_len), np.int32)
        for i, p in enumerate(prompts):
            toks[i, max_len - len(p):] = p
            toks[i, : max_len - len(p)] = p[0]
        logits = None
        for t in range(max_len):
            logits, cache = self._decode(
                self.params, jnp.asarray(toks[:, t:t + 1]), cache,
                jnp.int32(t))
        out = [list(p) for p in prompts]
        rng = np.random.default_rng(seed)
        done = np.zeros(B, bool)
        steps = 0
        for t in range(max_new):
            lg = np.asarray(logits[:, 0], np.float32)
            if temperature > 0:
                z = lg / temperature
                z = z - z.max(-1, keepdims=True)
                prob = np.exp(z) / np.exp(z).sum(-1, keepdims=True)
                nxt = np.array(
                    [rng.choice(cfg.vocab_size, p=prob[i]) for i in range(B)],
                    np.int32)
            else:
                nxt = lg.argmax(-1).astype(np.int32)
            for i in range(B):
                if not done[i]:
                    out[i].append(int(nxt[i]))
                    if eos_id is not None and nxt[i] == eos_id:
                        done[i] = True
            steps += 1
            if done.all():
                break
            logits, cache = self._decode(
                self.params, jnp.asarray(nxt[:, None]), cache,
                jnp.int32(max_len + t))
        return GenerationResult(tokens=out, steps=steps)
