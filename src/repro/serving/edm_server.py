"""EDM-as-a-service: warm sessions behind a batching worker pool.

``EDMServer`` is the embeddable server object — register panels, submit
``ccm``/``xmap``/``simplex``/``surrogate_test``/``optimal_E``/``append``
requests from any number of threads, get ``Future``s back. Requests
flow through ``scheduler.Scheduler``: per-panel FIFO queues with
signature coalescing drained by a worker pool, so distinct panels
execute concurrently while each panel's FIFO + append-barrier semantics
hold (see that module's docstring). ``master_budget_mb`` puts an LRU
byte budget on the cached kNN masters (``state.py``): cold panels are
evicted and lazily rebuilt bit-identically. ``subscribe`` registers a
(lib, tgt) watch list whose re-scored ρ is pushed on every append tick
(``subscriptions.py``).

Durability and overload control (PR 10):

* ``state_dir=`` makes the server crash-durable: registrations and
  accepted appends hit a per-panel write-ahead log before their futures
  resolve, and ``EDMServer.recover(state_dir)`` rebuilds every panel
  bit-identically at its pre-crash library version (``durability.py``).
* ``max_queue_depth`` / ``max_queued_bytes`` bound admission
  (``Overloaded`` → HTTP 429 + Retry-After), per-request ``deadline_s``
  bounds queueing (``DeadlineExceeded`` → 504), ``request_timeout_s``
  bounds the HTTP thread's blocking wait (503 on a wedged panel).
* ``supervise=True`` auto-revives dead drain workers; repeatedly
  crashing panels are quarantined (fail fast, 503).
* ``drain()`` stops admission, waits the queues out and fsyncs WALs —
  ``run_until_terminated`` wires it to SIGTERM for a clean exit 0.

``serve_http`` wraps a server in a stdlib ``ThreadingHTTPServer`` JSON
front end — each connection thread blocks on its request's future while
the worker pool batches across connections:

* ``POST /v1/register``     {"panel": name, "data": [[...]], ...config}
* ``POST /v1/<op>``         {"panel": name, ...params} → {"result": ...}
* ``POST /v1/append``       {"panel": name, "delta": [[...]]}
* ``POST /v1/subscribe``    {"panel": name, "pairs": [[l,t],...], "E": 3}
* ``POST /v1/unsubscribe``  {"id": sub_id}
* ``GET  /v1/subscriptions/<id>?timeout=25``  long-poll pending ticks
* ``GET  /panels``          registry listing
* ``GET  /metrics``         Prometheus text (``telemetry.render_prom()``)
* ``GET  /healthz``         per-worker liveness + queue depths; HTTP 503
                            when any drain worker is dead or the server
                            is draining

No third-party dependencies: stdlib HTTP, JSON bodies, numpy arrays
serialized as nested lists (NaN encoded ``null`` per strict JSON).
"""

from __future__ import annotations

import dataclasses
import json
import math
import threading
import time
import urllib.parse
from concurrent.futures import TimeoutError as _FutureTimeout
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro import telemetry
from repro.serving.durability import Durability
from repro.serving.scheduler import (DEFAULT_WORKERS, OPS, DeadlineExceeded,
                                     Draining, Overloaded, PanelQuarantined,
                                     Scheduler)
from repro.serving.state import Registry
from repro.serving.subscriptions import SubscriptionHub


class EDMServer:
    """Warm EDM sessions + the batching worker pool, one object."""

    def __init__(self, *, autostart: bool = True, max_batch: int = 64,
                 workers: int = DEFAULT_WORKERS,
                 master_budget_mb: float | None = None,
                 state_dir: str | None = None,
                 compact_every: int = 64, wal_fsync: bool = False,
                 max_queue_depth: int | None = None,
                 max_queued_bytes: int | None = None,
                 quarantine_after: int = 3, supervise: bool = False,
                 revive_backoff_s: tuple[float, float] = (0.2, 30.0),
                 faults=None):
        budget = (None if master_budget_mb is None
                  else int(master_budget_mb * 2**20))
        self.registry = Registry(master_budget_bytes=budget)
        self.subscriptions = SubscriptionHub()
        self.durability = (None if state_dir is None else Durability(
            state_dir, compact_every=compact_every, wal_fsync=wal_fsync,
            faults=faults))
        self.scheduler = Scheduler(self.registry, autostart=autostart,
                                   max_batch=max_batch, workers=workers,
                                   subscriptions=self.subscriptions,
                                   max_queue_depth=max_queue_depth,
                                   max_queued_bytes=max_queued_bytes,
                                   quarantine_after=quarantine_after,
                                   supervise=supervise,
                                   revive_backoff_s=revive_backoff_s,
                                   faults=faults)
        self.recovery_report: dict[str, dict] = {}

    # ---------------------------------------------------------- recovery

    @classmethod
    def recover(cls, state_dir: str, **kw) -> "EDMServer":
        """Rebuild a server from a ``state_dir`` after a crash.

        Every panel found on disk is replayed — snapshot, then WAL tail
        — through the normal ``Dataset.append`` path, so the recovered
        session is bit-identical to the pre-crash one at its last
        durably-logged version (the append≡rebuild contract makes the
        lazily rebuilt kNN master bit-identical too). A torn final WAL
        record (the crash landed mid-write) is dropped with a warning.
        ``srv.recovery_report`` maps panel → replay info.
        """
        srv = cls(state_dir=state_dir, **kw)
        assert srv.durability is not None
        for log in srv.durability.scan():
            name = log.meta()["name"]
            with telemetry.span("serve.recover", panel=name):
                sess, version, info = log.recover()
                log.reset_after_recovery(sess, version)
                entry = srv.registry.adopt(name, sess, version=version)
                entry.wal = log
                srv.durability.adopt(name, log)
                telemetry.event(
                    "serve.recovered", panel=name,
                    version=info["version"], replayed=info["replayed"],
                    torn_tail_bytes=info["torn_tail_bytes"])
            srv.recovery_report[name] = info
        return srv

    def register_panel(self, name: str, panel, **kw) -> dict:
        with telemetry.span("serve.register", panel=name):
            arr = np.asarray(panel, np.float32)
            info = self.registry.register(name, arr, **kw)
            if self.durability is not None:
                entry = self.registry.get(name)
                try:
                    entry.wal = self.durability.register(
                        name, arr, kw.get("names"), entry.sess.config)
                except Exception:
                    self.registry.remove(name)
                    raise
            return info

    def submit(self, op: str, panel: str, **params):
        """Thread-safe enqueue; returns a ``concurrent.futures.Future``."""
        return self.scheduler.submit(op, panel, **params)

    def submit_many(self, op: str, panel: str, params_list: list[dict]):
        """Bulk enqueue (one lock/wakeup); returns one Future per entry."""
        return self.scheduler.submit_many(op, panel, params_list)

    def call(self, op: str, panel: str, timeout: float | None = None,
             **params):
        """Submit and block for the result (the one-client convenience).

        ``timeout`` bounds the blocking wait only — the request itself
        stays queued (pass ``deadline_s=`` to bound that instead).
        """
        return self.submit(op, panel, **params).result(timeout=timeout)

    # ----------------------------------------------------- subscriptions

    def subscribe(self, panel: str, pairs, *, E: int | None = None) -> dict:
        """Register a (lib, tgt) watch list; blocks for the baseline tick.

        Routed through the scheduler like any op, so it linearizes with
        the panel's append stream: the returned dict's ``rho`` is the
        watch list scored at the current library version, and every
        later append pushes a re-scored tick to
        ``self.subscription(id)`` / ``GET /v1/subscriptions/<id>``.
        """
        return self.call("subscribe", panel, pairs=list(pairs), E=E)

    def subscription(self, sid: str):
        """The live ``Subscription`` (``.poll(timeout)`` for ticks)."""
        return self.subscriptions.get(sid)

    def unsubscribe(self, sid: str) -> None:
        self.subscriptions.close_sub(sid)

    # ------------------------------------------------------------ memory

    def evict_panel(self, name: str) -> int:
        """Force-evict one panel's cached kNN master; returns bytes freed.

        Thread-safe (waits for any in-flight batch on that panel). The
        operator's knob; the LRU budget does this automatically. Purely
        a memory event — the master rebuilds bit-identically on demand.
        """
        return self.registry.evict(self.registry.get(name), blocking=True)

    def clear_quarantine(self, name: str) -> bool:
        """Re-admit a quarantined panel (operator override). Note that
        after a WAL write failure the in-memory library is ahead of the
        log — prefer ``EDMServer.recover`` for the durable state."""
        return self.scheduler.clear_quarantine(name)

    # ----------------------------------------------------- observability

    def health(self) -> dict:
        """Scheduler liveness + queue depths + memory/subscription state."""
        h = self.scheduler.health()
        if h.get("draining"):
            h["ok"] = False
        h["master_bytes"] = self.registry.master_bytes_total()
        h["master_budget_bytes"] = self.registry.budget_bytes
        h["subscriptions"] = self.subscriptions.count()
        return h

    def metrics_text(self) -> str:
        return telemetry.render_prom()

    # ----------------------------------------------------------- shutdown

    def drain(self, timeout: float | None = 30.0) -> bool:
        """Graceful shutdown, phase 1: stop admission (new submits
        raise ``Draining`` → HTTP 503), wait the per-panel queues out,
        then fsync every WAL. Returns False if queues did not empty in
        ``timeout`` — callers should still ``close()`` after."""
        ok = self.scheduler.drain(timeout=timeout)
        if self.durability is not None:
            self.durability.fsync_all()
        return ok

    def close(self) -> None:
        self.scheduler.close()
        self.subscriptions.close_all()
        if self.durability is not None:
            self.durability.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def run_until_terminated(edm: EDMServer, httpd=None, *,
                         poll_s: float = 0.25,
                         drain_timeout: float = 30.0) -> int:
    """Serve until SIGTERM/SIGINT, then drain gracefully; returns the
    process exit code (0 on a clean drain).

    The ``PreemptionGuard`` pattern from ``distributed.fault``: the
    signal only sets a flag; this loop notices it, stops admission
    (in-flight and queued requests still finish), fsyncs the WALs and
    shuts the HTTP front end down.
    """
    import signal as _signal

    from repro.distributed.fault import PreemptionGuard
    with PreemptionGuard(signals=(_signal.SIGTERM, _signal.SIGINT)) as g:
        while not g.requested:
            time.sleep(poll_s)
    telemetry.event("serve.terminate_requested")
    ok = edm.drain(timeout=drain_timeout)
    if httpd is not None:
        httpd.shutdown()
    edm.close()
    return 0 if ok else 1


# ------------------------------------------------------------------ JSON


def _jsonable(obj):
    """Results → strict-JSON values (arrays to lists, NaN to None)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray) or hasattr(obj, "__array__"):
        return _jsonable(np.asarray(obj).tolist())
    if isinstance(obj, (np.floating, float)):
        f = float(obj)
        return f if np.isfinite(f) else None
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    return obj


class _Handler(BaseHTTPRequestHandler):
    server_version = "edm-serve/3"

    # The EDMServer rides on the HTTP server object (set by serve_http).
    @property
    def edm(self) -> EDMServer:
        return self.server.edm_server  # type: ignore[attr-defined]

    def log_message(self, fmt, *args):  # quiet; telemetry covers it
        pass

    def _reply(self, code: int, payload, *, raw: str | None = None,
               headers: dict | None = None) -> None:
        body = (raw if raw is not None
                else json.dumps(payload)).encode("utf-8")
        try:
            self.send_response(code)
            self.send_header("Content-Type",
                             "text/plain; charset=utf-8" if raw is not None
                             else "application/json")
            self.send_header("Content-Length", str(len(body)))
            for k, v in (headers or {}).items():
                self.send_header(k, v)
            self.end_headers()
            self.wfile.write(body)
        except (BrokenPipeError, ConnectionResetError,
                ConnectionAbortedError):
            # The client hung up mid-long-poll or mid-body: count it,
            # drop the connection quietly — never a stderr traceback.
            telemetry.counter("serve_client_disconnects").inc()
            self.close_connection = True

    def do_GET(self):  # noqa: N802 — stdlib API
        url = urllib.parse.urlparse(self.path)
        if url.path == "/metrics":
            self._reply(200, None, raw=self.edm.metrics_text())
        elif url.path == "/panels":
            self._reply(200, {"panels": self.edm.registry.infos()})
        elif url.path == "/healthz":
            h = self.edm.health()
            self._reply(200 if h["ok"] else 503, _jsonable(h))
        elif url.path.startswith("/v1/subscriptions/"):
            sid = url.path[len("/v1/subscriptions/"):]
            q = urllib.parse.parse_qs(url.query)
            timeout = min(float(q.get("timeout", ["25"])[0]), 60.0)
            maxn = (int(q["max"][0]) if "max" in q else None)
            try:
                sub = self.edm.subscription(sid)
            except KeyError as exc:
                self._reply(404, {"error": str(exc)})
                return
            ticks = sub.poll(timeout=timeout, max_ticks=maxn)
            self._reply(200, {"id": sid, "closed": sub.closed,
                              "ticks": _jsonable(ticks)})
        else:
            self._reply(404, {"error": f"unknown path {self.path}"})

    def do_POST(self):  # noqa: N802 — stdlib API
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
            if not isinstance(body, dict):
                self._reply(400, {"error": "body must be a JSON object"})
                return
            if not self.path.startswith("/v1/"):
                self._reply(404, {"error": f"unknown path {self.path}"})
                return
            op = self.path[len("/v1/"):]
            if op == "unsubscribe":  # addressed by id, not panel
                if "id" not in body:
                    self._reply(400, {"error": "missing 'id'"})
                    return
                self.edm.unsubscribe(body["id"])
                self._reply(200, {"result": {"closed": body["id"]}})
                return
            panel = body.pop("panel", None)
            if panel is None:
                self._reply(400, {"error": "missing 'panel'"})
                return
            if op == "register":
                if "data" not in body:
                    self._reply(400, {"error": "missing 'data'"})
                    return
                data = body.pop("data")
                info = self.edm.register_panel(panel, np.asarray(
                    data, np.float32), **body)
                self._reply(200, {"result": info})
                return
            if op not in OPS:
                self._reply(404, {"error": f"unknown op {op!r}"})
                return
            if op == "append":
                if "delta" not in body:
                    self._reply(400, {"error": "missing 'delta'"})
                    return
                body["delta"] = np.asarray(body["delta"], np.float32)
            timeout = getattr(self.server, "request_timeout_s", None)
            result = self.edm.call(op, panel, timeout=timeout, **body)
            self._reply(200, {"result": _jsonable(result)})
        except Overloaded as exc:
            self._reply(429, {"error": str(exc),
                              "retry_after_s": exc.retry_after_s},
                        headers={"Retry-After":
                                 str(max(1, math.ceil(exc.retry_after_s)))})
        except DeadlineExceeded as exc:
            self._reply(504, {"error": str(exc)})
        except (Draining, PanelQuarantined) as exc:
            self._reply(503, {"error": str(exc)})
        except _FutureTimeout:
            telemetry.counter("serve_request_timeouts").inc()
            self._reply(503, {"error": "request timed out waiting for a "
                                       "drain worker (panel may be "
                                       "wedged)"})
        except (KeyError, ValueError) as exc:
            self._reply(400, {"error": str(exc)})
        except Exception as exc:  # noqa: BLE001 — surface, don't crash
            self._reply(500, {"error": f"{type(exc).__name__}: {exc}"})


def serve_http(edm: EDMServer, host: str = "127.0.0.1", port: int = 0, *,
               request_timeout_s: float | None = 120.0
               ) -> ThreadingHTTPServer:
    """Start the JSON front end on a daemon thread; returns the HTTP
    server (``.server_address`` has the bound port; ``.shutdown()``
    stops it). ``port=0`` binds an ephemeral port — the test/CI mode.
    ``request_timeout_s`` bounds each connection thread's blocking wait
    on its future: a wedged panel returns 503 instead of hanging the
    connection forever."""
    httpd = ThreadingHTTPServer((host, port), _Handler)
    httpd.edm_server = edm  # type: ignore[attr-defined]
    httpd.request_timeout_s = request_timeout_s  # type: ignore[attr-defined]
    threading.Thread(target=httpd.serve_forever, name="edm-serve-http",
                     daemon=True).start()
    return httpd
