"""Serving substrate: batched prefill/decode engine + the EDM server.

Two tenants share this package: the transformer ``ServeEngine``
(fixed-slot prefill/decode batching) and the EDM session server
(``EDMServer`` — warm per-panel sessions, FIFO + signature-coalescing
scheduler, incremental library append; see ``edm_server``/
``scheduler``/``state``).
"""

from repro.serving.edm_server import EDMServer, serve_http
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Scheduler
from repro.serving.state import Registry

__all__ = ["EDMServer", "Registry", "Scheduler", "ServeEngine",
           "serve_http"]
