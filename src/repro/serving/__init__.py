"""Serving substrate: batched prefill/decode engine + the EDM server.

Two tenants share this package: the transformer ``ServeEngine``
(fixed-slot prefill/decode batching) and the EDM session server
(``EDMServer`` — warm per-panel sessions drained by a worker pool with
signature coalescing and append version barriers, an LRU byte budget
over cached kNN masters, incremental library append, and streaming
append subscriptions; see ``edm_server``/``scheduler``/``state``/
``subscriptions``).
"""

from repro.serving.edm_server import EDMServer, serve_http
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Scheduler
from repro.serving.state import Registry
from repro.serving.subscriptions import Subscription, SubscriptionHub

__all__ = ["EDMServer", "Registry", "Scheduler", "ServeEngine",
           "Subscription", "SubscriptionHub", "serve_http"]
