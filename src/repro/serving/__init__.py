"""Serving substrate: batched prefill/decode engine."""

from repro.serving.engine import ServeEngine

__all__ = ["ServeEngine"]
