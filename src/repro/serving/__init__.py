"""Serving substrate: batched prefill/decode engine + the EDM server.

Two tenants share this package: the transformer ``ServeEngine``
(fixed-slot prefill/decode batching) and the EDM session server
(``EDMServer`` — warm per-panel sessions drained by a worker pool with
signature coalescing and append version barriers, an LRU byte budget
over cached kNN masters, incremental library append, streaming append
subscriptions, per-panel WAL durability with crash recovery, admission
control and deadlines, and deterministic fault injection; see
``edm_server``/``scheduler``/``state``/``subscriptions``/
``durability``/``faultinject``).
"""

from repro.serving.durability import Durability, PanelLog, WalError
from repro.serving.edm_server import (EDMServer, run_until_terminated,
                                      serve_http)
from repro.serving.engine import ServeEngine
from repro.serving.faultinject import FaultInjector
from repro.serving.scheduler import (DeadlineExceeded, Draining, Overloaded,
                                     PanelQuarantined, Scheduler)
from repro.serving.state import Registry
from repro.serving.subscriptions import Subscription, SubscriptionHub

__all__ = ["DeadlineExceeded", "Draining", "Durability", "EDMServer",
           "FaultInjector", "Overloaded", "PanelLog", "PanelQuarantined",
           "Registry", "Scheduler", "ServeEngine", "Subscription",
           "SubscriptionHub", "WalError", "run_until_terminated",
           "serve_http"]
