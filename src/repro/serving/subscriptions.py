"""Streaming append subscriptions: re-scored ρ pushed on every tick.

A client registers a (lib, tgt) watch list on a panel and receives a
tick of re-scored CCM skills every time that panel's library grows —
the streaming shape of the whole-brain workload: recordings arrive
continuously, and the causal map is re-evaluated per append instead of
per request. The O(Lp·Δt) incremental master append makes the per-tick
re-score cheap: scoring rides ``EDM.ccm_batch`` on the already-merged
master, so a tick costs one group launch per distinct E in the watch
list, not a rebuild.

Execution model: ``open`` and ``on_append`` run ONLY inside the panel's
drain worker (the scheduler serializes them with every other op on that
panel), so ticks are linearized against the append stream — tick k
scores exactly library version k, and the pushed values are
bit-identical to ``ccm_batch`` on a quiesced, never-evicted session at
that version. Consumers poll from any thread: ``Subscription.poll`` is
a long-poll (block until a tick or timeout), mirrored over HTTP as
``GET /v1/subscriptions/<id>``.

Bounded queues: a consumer that stops polling loses OLDEST ticks first
(``serve_sub_dropped`` counter) — the subscription never grows without
bound and never blocks the drain worker.
"""

from __future__ import annotations

import collections
import itertools
import threading
import time

import numpy as np

from repro import telemetry

#: Per-subscription tick buffer; beyond it, oldest ticks are dropped.
MAX_PENDING = 256


class Subscription:
    """One watch list on one panel + its pending-tick queue."""

    def __init__(self, sid: str, panel: str, pairs, groups):
        self.id = sid
        self.panel = panel
        self.pairs = pairs                  # [(lib_idx, tgt_idx), ...]
        self.groups = groups                # {E: [positions into pairs]}
        self.closed = False
        self._cv = threading.Condition()
        self._ticks: collections.deque[dict] = collections.deque()
        self._seq = 0
        self.last_rho: np.ndarray | None = None

    def push(self, version: int, L: int, rho: np.ndarray) -> None:
        """Queue one re-scored tick (drain-worker side)."""
        with self._cv:
            if self.closed:
                return
            d_rho = (None if self.last_rho is None
                     else rho - self.last_rho)
            self.last_rho = rho
            self._ticks.append({
                "seq": self._seq, "version": version, "L": L,
                "pairs": self.pairs, "rho": rho, "d_rho": d_rho})
            self._seq += 1
            if len(self._ticks) > MAX_PENDING:
                self._ticks.popleft()
                telemetry.counter("serve_sub_dropped").inc()
            self._cv.notify_all()
        telemetry.counter("serve_sub_ticks").inc()

    def poll(self, timeout: float = 0.0,
             max_ticks: int | None = None) -> list[dict]:
        """Long-poll: block up to ``timeout`` s for ticks, pop them all
        (or the oldest ``max_ticks``). Returns [] on timeout/close.

        Loops on a monotonic deadline: a spurious wakeup (or an
        unrelated ``notify_all`` — ``close`` broadcasts on the same
        condition) re-waits for the remaining time instead of returning
        early with nothing.
        """
        with self._cv:
            deadline = time.monotonic() + max(0.0, timeout)
            while not self._ticks and not self.closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
            n = len(self._ticks) if max_ticks is None else min(
                max_ticks, len(self._ticks))
            return [self._ticks.popleft() for _ in range(n)]

    def close(self) -> None:
        with self._cv:
            self.closed = True
            self._ticks.clear()
            self._cv.notify_all()


class SubscriptionHub:
    """All live subscriptions, indexed by id and by panel."""

    def __init__(self):
        self._lock = threading.Lock()
        self._subs: dict[str, Subscription] = {}
        self._by_panel: dict[str, list[Subscription]] = {}
        self._ids = itertools.count()

    # ------------------------------------------------- drain-worker side

    def open(self, entry, *, pairs, E=None) -> dict:
        """Create a subscription and push its baseline tick.

        Runs inside the panel's drain worker (it touches the session):
        pairs are resolved to indices, E per pair (explicit ``E``, else
        the config's, else the target's cached optimal E), and the
        baseline scores — ``ccm_batch`` at the current library version —
        are both returned and queued as tick 0, so a consumer's first
        poll establishes the reference the deltas are against.
        """
        sess = entry.sess
        if not pairs:
            raise ValueError("subscription needs at least one (lib, tgt) "
                             "pair")
        idx = [(sess.data.index_of(l), sess.data.index_of(t))
               for l, t in pairs]
        groups: dict[int, list[int]] = collections.defaultdict(list)
        for j, (_, ti) in enumerate(idx):
            Ej = int(E) if E is not None else sess._resolve_pair_E(ti, None)
            groups[Ej].append(j)
        sub = Subscription(f"sub-{next(self._ids)}", entry.name, idx,
                           dict(groups))
        rho = self._score(sess, sub)
        with self._lock:
            self._subs[sub.id] = sub
            self._by_panel.setdefault(entry.name, []).append(sub)
            telemetry.gauge("serve_subscriptions").set(len(self._subs))
        sub.push(entry.version, int(sess.data.L), rho)
        telemetry.event("serve.subscribe", panel=entry.name, id=sub.id,
                        pairs=len(idx))
        return {"id": sub.id, "panel": entry.name, "pairs": idx,
                "E_groups": {str(k): v for k, v in sub.groups.items()},
                "version": entry.version, "rho": rho}

    def on_append(self, entry) -> None:
        """Re-score every watch list on this panel (drain-worker side,
        called right after the append executes — the scores are of the
        just-committed library version, linearized by construction)."""
        with self._lock:
            subs = list(self._by_panel.get(entry.name, ()))
        for sub in subs:
            if sub.closed:
                continue
            rho = self._score(entry.sess, sub)
            sub.push(entry.version, int(entry.sess.data.L), rho)

    @staticmethod
    def _score(sess, sub: Subscription) -> np.ndarray:
        """One ``ccm_batch`` group launch per distinct E in the list."""
        rho = np.full(len(sub.pairs), np.nan, np.float32)
        for Ej, members in sub.groups.items():
            got = sess.ccm_batch([sub.pairs[j] for j in members], E=Ej)
            for j, v in zip(members, got):
                rho[j] = v
        return rho

    # ---------------------------------------------------- consumer side

    def get(self, sid: str) -> Subscription:
        with self._lock:
            try:
                return self._subs[sid]
            except KeyError:
                raise KeyError(f"no subscription {sid!r}") from None

    def close_sub(self, sid: str) -> None:
        with self._lock:
            sub = self._subs.pop(sid, None)
            if sub is None:
                raise KeyError(f"no subscription {sid!r}")
            panel_subs = self._by_panel.get(sub.panel, [])
            if sub in panel_subs:
                panel_subs.remove(sub)
            telemetry.gauge("serve_subscriptions").set(len(self._subs))
        sub.close()

    def count(self) -> int:
        with self._lock:
            return len(self._subs)

    def close_all(self) -> None:
        with self._lock:
            subs = list(self._subs.values())
            self._subs.clear()
            self._by_panel.clear()
            telemetry.gauge("serve_subscriptions").set(0)
        for sub in subs:
            sub.close()
