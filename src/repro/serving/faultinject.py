"""Deterministic fault injection for the serving stack.

The chaos suite (tests/test_serving_chaos.py) needs to kill workers,
fail launches, starve memory, slow batches down, and break WAL writes
*on purpose*, reproducibly, without monkeypatching scheduler internals.
``FaultInjector`` is the one knob: construct it with a seed and a rate
per injection point, hand it to ``EDMServer(faults=...)``, and the
scheduler / durability layers consult it at five fixed points:

=================  =====================================================
point              where it fires
=================  =====================================================
``worker_death``   start of a drain batch — raises a ``BaseException``
                   so the worker dies exactly like a real crash (its
                   in-flight futures fail with "serve worker died", the
                   panel is released, the supervisor may revive it).
``launch_error``   inside op execution — an ordinary ``Exception``; a
                   coalesced launch fails the whole batch, a loop-path
                   op fails only its own request.
``launch_oom``     same site, but the message carries the anchored
                   ``RESOURCE_EXHAUSTED`` marker ``edm.runner
                   .is_oom_error`` keys on — the allocator-failure
                   shape.
``slow_launch``    sleeps ``slow_s`` before executing — the straggler /
                   deadline-pressure shape.
``wal_write``      inside ``durability.PanelLog.log_append`` before any
                   bytes hit the file — an ``OSError``: the append is
                   applied in memory but NOT durable, which must
                   quarantine the panel (memory is ahead of the log).
=================  =====================================================

Determinism: every point owns an independent ``numpy`` Generator seeded
``(seed, point_index)``, so the k-th *draw at a given point* is a pure
function of the seed — independent of what the other points are doing.
Under a thread pool the mapping of draws to requests still depends on
scheduling, so a chaos scenario is *statistically* reproducible (same
number of fires per point for the same draw count) while every assert
stays schedule-independent (linearization against ticket order).

``max_fires`` caps total fires per point — scenarios can guarantee
"exactly one worker death" shapes. ``fired`` / ``calls`` counters are
exposed for assertions.
"""

from __future__ import annotations

import threading
import time

import numpy as np

#: The fixed injection points, in (seed-stream) order.
POINTS = ("worker_death", "launch_error", "launch_oom", "slow_launch",
          "wal_write")


class InjectedWorkerDeath(BaseException):
    """Raised at the ``worker_death`` point; a ``BaseException`` so it
    rides the scheduler's real worker-death path (which deliberately
    does not catch ``Exception``-only)."""


class InjectedFault(RuntimeError):
    """An injected launch failure (``launch_error`` / ``launch_oom``)."""


class InjectedWalError(OSError):
    """An injected WAL write failure (``wal_write`` point)."""


class FaultInjector:
    """Seeded, rate-based fault source for the five serving points."""

    def __init__(self, seed: int = 0, *, rates: dict | None = None,
                 slow_s: float = 0.02, max_fires: int | None = None):
        rates = dict(rates or {})
        unknown = set(rates) - set(POINTS)
        if unknown:
            raise ValueError(f"unknown fault points {sorted(unknown)}; "
                             f"expected among {POINTS}")
        self.rates = {p: float(rates.get(p, 0.0)) for p in POINTS}
        self.slow_s = float(slow_s)
        self.max_fires = max_fires
        self._lock = threading.Lock()
        self._rngs = {p: np.random.default_rng((int(seed), i))
                      for i, p in enumerate(POINTS)}
        self.calls = {p: 0 for p in POINTS}
        self.fired = {p: 0 for p in POINTS}

    def fire(self, point: str) -> bool:
        """Draw the point's next Bernoulli sample; True means inject."""
        with self._lock:
            self.calls[point] += 1
            if self.rates[point] <= 0.0:
                return False
            if (self.max_fires is not None
                    and self.fired[point] >= self.max_fires):
                return False
            hit = bool(self._rngs[point].random() < self.rates[point])
            if hit:
                self.fired[point] += 1
            return hit

    def check(self, point: str, *, detail: str = "") -> None:
        """Consult one point; raises (or sleeps) when it fires."""
        if not self.fire(point):
            return
        where = f" [{detail}]" if detail else ""
        if point == "worker_death":
            raise InjectedWorkerDeath(f"injected worker death{where}")
        if point == "launch_error":
            raise InjectedFault(f"injected launch failure{where}")
        if point == "launch_oom":
            raise InjectedFault(
                f"RESOURCE_EXHAUSTED: injected allocation failure{where}")
        if point == "slow_launch":
            time.sleep(self.slow_s)
            return
        if point == "wal_write":
            raise InjectedWalError(f"injected WAL write failure{where}")
        raise AssertionError(f"unreachable fault point {point!r}")
