"""Training substrate: step construction + fault-tolerant loop."""

from repro.training.loop import train
from repro.training.step import make_train_step

__all__ = ["train", "make_train_step"]
