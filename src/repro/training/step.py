"""train_step construction: loss → (microbatched) grads → EF-compression →
clip → AdamW, as one jit-able pure function of (state, batch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.compression import ef_compress_tree, init_error_buf
from repro.models import transformer as tf
from repro.optim import (
    accumulate_microbatches,
    clip_by_global_norm,
    make_optimizer,
    warmup_cosine,
)


def make_train_step(cfg, tcfg, batch_constraint=None,
                    grad_constraint=None):
    """Returns (init_state(key) → state, train_step(state, batch) →
    (state, metrics)). Both pure; train_step is safe to jit/pjit.
    ``batch_constraint``: optional per-microbatch sharding-constraint fn
    (built by the launcher from the production mesh)."""
    opt_init, opt_update = make_optimizer(tcfg)
    sched = functools.partial(
        warmup_cosine, peak_lr=tcfg.learning_rate,
        warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps)

    def init_state(key):
        params = tf.init_params(cfg, key)
        state = {"params": params, "opt": opt_init(params)}
        if tcfg.grad_compression != "none":
            state["ebuf"] = init_error_buf(params)
        return state

    def abstract_state():
        params = tf.abstract_params(cfg)
        state = {"params": params,
                 "opt": jax.eval_shape(opt_init, params)}
        if tcfg.grad_compression != "none":
            state["ebuf"] = jax.eval_shape(init_error_buf, params)
        return state

    def loss_fn(params, batch):
        return tf.loss_fn(params, cfg, batch, zloss=tcfg.zloss)

    def train_step(state, batch):
        (loss, metrics), grads = accumulate_microbatches(
            loss_fn, state["params"], batch, max(tcfg.microbatch, 1),
            constrain=batch_constraint, constrain_grads=grad_constraint)
        new_state = dict(state)
        if tcfg.grad_compression != "none":
            grads, new_state["ebuf"] = ef_compress_tree(
                grads, state["ebuf"], tcfg.grad_compression)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = sched(state["opt"]["step"])
        params, opt = opt_update(grads, state["opt"], state["params"], lr=lr)
        new_state["params"] = params
        new_state["opt"] = opt
        metrics = dict(metrics)
        metrics["loss"] = loss  # accumulated mean, not last-microbatch
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return new_state, metrics

    return init_state, train_step, abstract_state
