"""Fault-tolerant training loop.

Restart-safe by construction: state lives in CheckpointManager (atomic,
retained), data is a pure function of (seed, step), and the loop always
resumes from ``latest_step()``. SIGTERM triggers checkpoint-and-exit
(preemption); per-step wall times feed the straggler monitor; heartbeats
let an external watchdog detect hangs.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.distributed.fault import Heartbeat, PreemptionGuard, StragglerMonitor
from repro.training.step import make_train_step


def train(
    cfg,
    tcfg,
    pipeline,
    *,
    workdir: str,
    num_steps: int,
    ckpt_every: int = 50,
    log_every: int = 10,
    resume: bool = True,
    handle_preemption: bool = True,
    donate: bool = True,
    verbose: bool = True,
):
    """Run (or resume) a training job. Returns (state, history list)."""
    init_state, train_step, _ = make_train_step(cfg, tcfg)
    step_fn = jax.jit(train_step, donate_argnums=(0,) if donate else ())
    manager = CheckpointManager(os.path.join(workdir, "ckpt"), keep=3)
    monitor = StragglerMonitor()
    heartbeat = Heartbeat(os.path.join(workdir, "heartbeat.csv"))
    guard = PreemptionGuard() if handle_preemption else None

    start = 0
    state = init_state(jax.random.key(tcfg.seed))
    if resume and manager.latest_step() is not None:
        start = manager.latest_step()
        state = manager.restore(state)
        if verbose:
            print(f"[loop] resumed from step {start}")

    history = []
    preempted = False
    for step in range(start, num_steps):
        batch_np = pipeline.global_batch(step)
        batch = jax.tree.map(jnp.asarray, batch_np)
        monitor.start()
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        straggler = monitor.stop(step)
        heartbeat.beat(step)
        metrics.update(step=step, straggler=straggler)
        history.append(metrics)
        if verbose and (step % log_every == 0 or step == num_steps - 1):
            print(f"[loop] step {step} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['grad_norm']:.3f} lr {metrics['lr']:.2e}"
                  + (" STRAGGLER" if straggler else ""))
        if (step + 1) % ckpt_every == 0:
            manager.save(step + 1, state)
        if guard is not None and guard.requested:
            manager.save(step + 1, state)
            preempted = True
            if verbose:
                print(f"[loop] preemption: checkpointed at {step + 1}, "
                      "exiting cleanly")
            break

    if not preempted:
        manager.save(num_steps, state)
    if guard is not None:
        guard.restore()
    if monitor.flagged and verbose:
        print(f"[loop] {len(monitor.flagged)} straggler steps flagged: "
              f"{[(s, round(t, 3)) for s, t, _ in monitor.flagged[:5]]}")
    return state, history
