"""Synthetic dynamical systems for EDM validation and benchmarks.

These replace the paper's microscopy datasets (not shippable here) with
systems whose causal structure / embedding dimension is known analytically,
so the paper's claims can be validated rather than eyeballed:

* coupled logistic maps (Sugihara et al. 2012, the canonical CCM system)
  with tunable one-way or two-way forcing;
* the Lorenz-63 attractor (known E≈3 embedding);
* tent-map panels for throughput benchmarks shaped like the paper's
  datasets (Table 1) and synthetic sweeps (Figs. 2–5).
"""

from __future__ import annotations

import numpy as np


def coupled_logistic(
    n_steps: int,
    *,
    r_x: float = 3.8,
    r_y: float = 3.5,
    b_xy: float = 0.02,
    b_yx: float = 0.1,
    x0: float = 0.4,
    y0: float = 0.2,
    discard: int = 100,
    seed: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Two coupled logistic maps.

    x(t+1) = x(t)·(r_x − r_x·x(t) − b_xy·y(t))
    y(t+1) = y(t)·(r_y − r_y·y(t) − b_yx·x(t))

    With b_xy=0, b_yx>0: X forces Y (only), so CCM skill of cross-mapping
    X from Y's manifold is high and the converse low — Sugihara 2012 Fig 3.
    """
    if seed is not None:
        rng = np.random.default_rng(seed)
        x0 = float(rng.uniform(0.1, 0.9))
        y0 = float(rng.uniform(0.1, 0.9))
    n = n_steps + discard
    x = np.empty(n, np.float64)
    y = np.empty(n, np.float64)
    x[0], y[0] = x0, y0
    for t in range(n - 1):
        x[t + 1] = x[t] * (r_x - r_x * x[t] - b_xy * y[t])
        y[t + 1] = y[t] * (r_y - r_y * y[t] - b_yx * x[t])
    return (x[discard:].astype(np.float32), y[discard:].astype(np.float32))


def logistic_map(n_steps: int, *, r: float = 3.8, x0: float = 0.23,
                 discard: int = 100) -> np.ndarray:
    """Chaotic 1-D logistic map (true embedding dimension 1–2)."""
    x, _ = coupled_logistic(n_steps, r_x=r, b_xy=0.0, b_yx=0.0, x0=x0,
                            discard=discard)
    return x


def lorenz63(
    n_steps: int,
    *,
    dt: float = 0.02,
    sigma: float = 10.0,
    rho: float = 28.0,
    beta: float = 8.0 / 3.0,
    discard: int = 500,
) -> np.ndarray:
    """Lorenz-63 trajectory, RK4, returns (3, n_steps) float32."""
    n = n_steps + discard
    out = np.empty((n, 3), np.float64)
    s = np.array([1.0, 1.0, 1.0])

    def f(s):
        x, y, z = s
        return np.array([sigma * (y - x), x * (rho - z) - y, x * y - beta * z])

    for t in range(n):
        out[t] = s
        k1 = f(s)
        k2 = f(s + 0.5 * dt * k1)
        k3 = f(s + 0.5 * dt * k2)
        k4 = f(s + dt * k3)
        s = s + (dt / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4)
    return out[discard:].T.astype(np.float32)


def tent_map_panel(n_series: int, n_steps: int, *, seed: int = 0,
                   discard: int = 64) -> np.ndarray:
    """(N, L) panel of independent chaotic tent maps — benchmark filler
    shaped like the paper's synthetic sweeps (10⁵ series × 10⁴ steps)."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.01, 0.99, size=n_series)
    n = n_steps + discard
    out = np.empty((n_series, n), np.float32)
    mu = 1.9999
    for t in range(n):
        out[:, t] = x
        x = mu * np.minimum(x, 1.0 - x)
        # fold numerical escape back into (0, 1)
        x = np.clip(x, 1e-9, 1.0 - 1e-9)
    return out[:, discard:]


def forced_network_panel(
    n_series: int,
    n_steps: int,
    *,
    n_drivers: int = 2,
    coupling: float = 0.08,
    seed: int = 0,
    discard: int = 100,
) -> tuple[np.ndarray, np.ndarray]:
    """Panel of logistic maps where the first ``n_drivers`` series force all
    others (star topology) — ground truth for all-pairs CCM matrices.

    Returns (panel (N, L) float32, adjacency (N, N) bool) with
    adjacency[i, j] = True iff series i forces series j.
    """
    rng = np.random.default_rng(seed)
    n = n_steps + discard
    r = rng.uniform(3.6, 3.9, size=n_series)
    x = rng.uniform(0.2, 0.8, size=n_series)
    # per-(driver, follower) coupling weights: identical common drive would
    # synchronize the followers and confound CCM (common-cause effect)
    w = rng.uniform(0.5, 1.5, size=(n_drivers, n_series))
    out = np.empty((n_series, n), np.float32)
    adj = np.zeros((n_series, n_series), bool)
    for d in range(n_drivers):
        adj[d, n_drivers:] = True
    for t in range(n):
        out[:, t] = x
        force = coupling * (w * x[:n_drivers, None]).sum(axis=0)
        x_new = x * (r - r * x)
        x_new[n_drivers:] = x[n_drivers:] * (
            r[n_drivers:] - r[n_drivers:] * x[n_drivers:]
            - force[n_drivers:]
        )
        x = np.clip(x_new, 1e-6, 1.0 - 1e-6)
    return out[:, discard:], adj
