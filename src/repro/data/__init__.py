"""Data substrate: synthetic dynamical systems (EDM) and the token
pipeline (LM training)."""

from repro.data.timeseries import (
    coupled_logistic,
    forced_network_panel,
    logistic_map,
    lorenz63,
    tent_map_panel,
)

__all__ = [
    "coupled_logistic",
    "forced_network_panel",
    "logistic_map",
    "lorenz63",
    "tent_map_panel",
]
