"""Deterministic token pipeline for LM training.

Requirements at fleet scale: (1) bitwise-deterministic batches as a pure
function of (seed, step) — restarts and elastic resizes revisit exactly
the data they should, with no pipeline state to checkpoint; (2) shard
awareness — each data-parallel rank materializes only its slice;
(3) a file-backed mode (memmapped token arrays) with the same interface.

The synthetic source is a mixture of Zipf-distributed unigrams with a
Markov component — enough structure that a ~100M model visibly learns
(examples/train_lm.py), while requiring no external assets.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch: int  # global batch
    seq_len: int
    seed: int = 0
    source: str = "synthetic"  # synthetic | file
    path: str | None = None
    _tokens: np.ndarray | None = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        if self.source == "file":
            if not self.path:
                raise ValueError("file source needs path")
            self._tokens = np.load(self.path, mmap_mode="r")

    # ------------------------------------------------------------ access

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        toks = self.batch_slice(step, rank=0, world=1)
        return toks

    def batch_slice(self, step: int, *, rank: int, world: int) -> dict:
        """The (batch/world)-sized slice owned by data-parallel ``rank``."""
        if self.batch % world:
            raise ValueError(f"batch {self.batch} not divisible by {world}")
        per = self.batch // world
        if self.source == "file":
            toks = self._file_batch(step, rank, per)
        else:
            toks = self._synth_batch(step, rank, per)
        return {"tokens": toks}

    def _synth_batch(self, step, rank, per):
        out = np.empty((per, self.seq_len), np.int32)
        for i in range(per):
            # one RNG per (step, global row): restart/elastic invariant
            row = rank * per + i
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, row]))
            out[i] = self._synth_row(rng)
        return out

    def _synth_row(self, rng):
        V = self.vocab_size
        S = self.seq_len
        # Zipf unigram base
        base = rng.zipf(1.3, size=S).astype(np.int64) % V
        # Markov component: with p=0.5 repeat previous token + small delta
        rep = rng.random(S) < 0.5
        delta = rng.integers(0, 4, S)
        toks = base.copy()
        for t in range(1, S):
            if rep[t]:
                toks[t] = (toks[t - 1] + delta[t]) % V
        return toks.astype(np.int32)

    def _file_batch(self, step, rank, per):
        n = self._tokens.shape[0]
        out = np.empty((per, self.seq_len), np.int32)
        for i in range(per):
            row = rank * per + i
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, row]))
            start = int(rng.integers(0, max(n - self.seq_len, 1)))
            out[i] = np.asarray(
                self._tokens[start:start + self.seq_len], np.int32)
        return out


def embeds_pipeline(d_model: int, batch: int, seq_len: int, seed: int = 0):
    """Frontend-stub pipeline for audio/VLM archs: deterministic
    (B, S, d_model) float32 'embeddings' plus integer labels."""

    def get(step: int, vocab_size: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, 77]))
        return {
            "embeds": rng.normal(
                size=(batch, seq_len, d_model)).astype(np.float32),
            "labels": rng.integers(
                0, vocab_size, size=(batch, seq_len)).astype(np.int32),
        }

    return get
