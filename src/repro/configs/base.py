"""Config dataclasses for the architecture zoo.

One ``ModelConfig`` describes any of the 10 assigned architectures (plus
reduced smoke variants). Layer heterogeneity (hybrid/MoE interleaves) is
expressed as a repeating ``pattern`` of block kinds; the stack scans over
pattern repeats so HLO size stays O(pattern), not O(n_layers).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0  # always-on experts (DeepSeek-style)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model / 16)
    chunk: int = 128  # scan chunk (memory/recompute tradeoff)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor_m: float = 2.0  # mLSTM block up-projection
    proj_factor_s: float = 4.0 / 3.0  # sLSTM post-FFN factor
    chunk: int = 64  # mLSTM chunked-parallel length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 → d_model // n_heads

    # Block pattern: tuple of kinds, cycled to n_layers. Kinds:
    #   "attn"   – attention + dense MLP
    #   "attn_moe" – attention + MoE
    #   "mamba" / "mamba_moe" – Mamba mixer + dense/MoE MLP-free block
    #   "mlstm" / "slstm"      – xLSTM blocks
    pattern: tuple[str, ...] = ("attn",)

    attention: str = "gqa"  # gqa | mla
    causal: bool = True
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mlp: str = "swiglu"  # swiglu | relu2
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # Modality stub: inputs are precomputed (B, S, d_model) embeddings
    # (audio frames / vision patches) instead of token ids.
    embed_inputs: bool = False

    # MLA (DeepSeek)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # numerics / compile strategy
    dtype: str = "bfloat16"  # activations/params compute dtype
    param_dtype: str = "float32"  # master params
    remat: bool = True
    scan_layers: bool = True
    attn_chunk_q: int = 512  # chunked attention for long prefill
    attn_full_max: int = 1024  # full S×S attention at or below this

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"pattern length {len(self.pattern)}"
            )

    @property
    def n_units(self) -> int:
        """Number of scanned pattern repeats."""
        return self.n_layers // len(self.pattern)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba.expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        r = self.mamba.dt_rank
        return r if r else math.ceil(self.d_model / 16)

    def param_count(self) -> int:
        """Analytic parameter count (drives 6ND roofline numbers)."""
        from repro.models.transformer import abstract_params  # lazy
        import jax

        params = abstract_params(self)
        return sum(math.prod(p.shape) for p in jax.tree.leaves(params))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared of routed ffn)."""
        total = self.param_count()
        if self.moe is None:
            return total
        moe_layers = sum(k.endswith("_moe") for k in self.pattern) * self.n_units
        per_expert = 3 * self.d_model * self.moe.d_ff_expert
        inactive = (
            moe_layers
            * (self.moe.num_experts - self.moe.top_k)
            * per_expert
        )
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    optimizer: str = "adamw"  # adamw | adamw8bit
    microbatch: int = 0  # 0 → no gradient accumulation
    seed: int = 0
    # distributed-optimization knobs
    grad_compression: str = "none"  # none | bf16 | int8
    zloss: float = 0.0
