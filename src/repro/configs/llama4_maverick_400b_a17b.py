"""llama4-maverick-400b-a17b — MoE decoder, 128 experts top-1, alternating
dense/MoE layers, early-fusion multimodal (frontend stubbed out of scope).
[hf:meta-llama/Llama-4-Scout-17B-16E (family); assignment sheet]

48L, d_model 5120, 40 heads (kv=8), expert d_ff 8192 (dense layers use
2×8192), vocab 202048. ~400B total / ~17B active params. Params are kept
in bf16 with the 8-bit block-quantized Adam (repro.optim) so the training
state fits 16 GB/chip on the single-pod mesh (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=16384, vocab_size=202048, rope_theta=500_000.0,
        pattern=("attn_moe", "attn"),
        moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                      num_shared=1),
        param_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=128, pattern=("attn_moe", "attn"),
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=128,
                      num_shared=1),
        dtype="float32", param_dtype="float32",
    )
