"""xlstm-125m — sLSTM + mLSTM recurrent LM. [arXiv:2405.04517]

12L, d_model 768, 4 heads, vocab 50304, no separate FFN (d_ff=0; the
xLSTM blocks carry their own up/down projections). Block ratio ≈ the
paper's xLSTM[7:1]: one sLSTM block (index 6) among 11 mLSTM blocks.
Runs long_500k (O(1)-state recurrent decode); sLSTM is strictly
sequential (lax.scan) — the paper's own parallelization caveat.
"""

from repro.configs.base import ModelConfig, XLSTMConfig

_PATTERN = ("mlstm",) * 6 + ("slstm",) + ("mlstm",) * 5


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304, pattern=_PATTERN,
        xlstm=XLSTMConfig(),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=128, pattern=("mlstm", "slstm", "mlstm"),
        xlstm=XLSTMConfig(chunk=16),
        dtype="float32", param_dtype="float32",
    )
