"""Architecture registry: ``--arch <id>`` → ModelConfig.

Ten assigned architectures plus the paper's own workload (``edm_ccm``).
Every entry exposes ``config()`` (full, dry-run only) and
``smoke_config()`` (reduced, runs on one CPU device).
"""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    MambaConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SHAPES,
    TrainConfig,
    XLSTMConfig,
)

ARCHS = (
    "qwen1.5-4b",
    "llama3-8b",
    "yi-6b",
    "nemotron-4-15b",
    "jamba-v0.1-52b",
    "hubert-xlarge",
    "llava-next-mistral-7b",
    "xlstm-125m",
    "llama4-maverick-400b-a17b",
    "deepseek-v2-lite-16b",
)

# Shape-cell applicability (DESIGN.md §5): encoder-only archs have no
# decode step; long_500k needs sub-quadratic decode.
SKIP_CELLS = {
    "hubert-xlarge": {"decode_32k", "long_500k"},
    "qwen1.5-4b": {"long_500k"},
    "llama3-8b": {"long_500k"},
    "yi-6b": {"long_500k"},
    "nemotron-4-15b": {"long_500k"},
    "llava-next-mistral-7b": {"long_500k"},
    "llama4-maverick-400b-a17b": {"long_500k"},
    "deepseek-v2-lite-16b": {"long_500k"},
}


def _module(arch: str):
    mod = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    m = _module(arch)
    return m.smoke_config() if smoke else m.config()


def cells(arch: str) -> list[str]:
    """Applicable shape-cell names for an architecture."""
    skip = SKIP_CELLS.get(arch, set())
    return [s for s in SHAPES if s not in skip]


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in cells(a)]
