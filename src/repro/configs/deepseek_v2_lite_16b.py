"""deepseek-v2-lite-16b — MLA attention + fine-grained MoE.
[arXiv:2405.04434; hf]

27L, d_model 2048, 16 heads, MLA kv_lora_rank 512 (qk_nope 128, qk_rope
64, v 128), vocab 102400. Per the assignment sheet: uniform MoE, 64
routed experts top-6 + 2 shared, expert d_ff 1408. (Deviations from the
HF reference, recorded in DESIGN.md: the reference's layer 0 is a dense
d_ff=10944 MLP — the assignment specifies uniform MoE, which also lets
the 27 layers scan (unrolling kept ~90 dispatch buffers live → 36 GB/
device); the sheet's "160 routed" is DeepSeek-V2-full, -Lite has 64.)
"""

from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102400, pattern=("attn_moe",),
        attention="mla", kv_lora_rank=512, qk_nope_dim=128,
        qk_rope_dim=64, v_head_dim=128,
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared=2),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=128, pattern=("attn", "attn_moe", "attn_moe"),
        attention="mla", kv_lora_rank=32, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, num_shared=1),
        dtype="float32", param_dtype="float32",
    )
