"""qwen1.5-4b — dense GQA decoder with QKV bias. [hf:Qwen/Qwen1.5-4B; hf]

40L, d_model 2560, 20 heads (kv=20 → MHA), d_ff 6912, vocab 151936,
rope_theta 5e6, SwiGLU. Note: 20 heads do not divide the 16-way model
axis; sharding falls back to flattened-projection sharding (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
        d_ff=6912, vocab_size=151936, qkv_bias=True,
        rope_theta=5_000_000.0, pattern=("attn",),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=128, qkv_bias=True, pattern=("attn",),
        dtype="float32", param_dtype="float32",
    )
