"""jamba-v0.1-52b — hybrid Mamba+attention (1:7) with MoE (16e top-2).
[arXiv:2403.19887; hf]

32L, d_model 4096, 32 heads (kv=8) in the attention layers, d_ff 14336,
vocab 65536. Pattern per Jamba block (8 layers): attention at index 4,
MoE every other layer; 4 blocks scanned. Runs the long_500k cell
(sub-quadratic decode: 28/32 layers are O(1)-state Mamba).
"""

from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

_PATTERN = ("mamba", "mamba_moe", "mamba", "mamba_moe",
            "attn", "mamba_moe", "mamba", "mamba_moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=65536, pattern=_PATTERN,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336),
        mamba=MambaConfig(),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=128, pattern=_PATTERN,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        mamba=MambaConfig(d_state=4, d_conv=2, chunk=16),
        dtype="float32", param_dtype="float32",
    )
