"""yi-6b — llama-architecture GQA decoder. [arXiv:2403.04652; hf]

32L, d_model 4096, 32 heads (kv=4), d_ff 11008, vocab 64000,
rope_theta 5e6, SwiGLU.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=64000, rope_theta=5_000_000.0,
        pattern=("attn",),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=128, pattern=("attn",),
        dtype="float32", param_dtype="float32",
    )
