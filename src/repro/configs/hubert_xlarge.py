"""hubert-xlarge — encoder-only audio transformer backbone.
[arXiv:2106.07447]

48L, d_model 1280, 16 heads (kv=16), d_ff 5120, 504 masked-prediction
classes, GELU MLP, bidirectional. The conv waveform frontend is a STUB:
input_specs() provides precomputed (B, S, 1280) frame embeddings.
Encoder-only → decode_32k / long_500k cells are skipped (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab_size=504, mlp="gelu", causal=False,
        embed_inputs=True, pattern=("attn",),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=32, mlp="gelu", causal=False,
        embed_inputs=True, pattern=("attn",),
        dtype="float32", param_dtype="float32",
    )
