"""nemotron-4-15b — dense GQA decoder with squared-ReLU MLP.
[arXiv:2402.16819]

32L, d_model 6144, 48 heads (kv=8), d_ff 24576, vocab 256000, ReLU².
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=24576, vocab_size=256000, mlp="relu2",
        rope_theta=10_000.0, pattern=("attn",),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=128, mlp="relu2", pattern=("attn",),
        dtype="float32", param_dtype="float32",
    )
