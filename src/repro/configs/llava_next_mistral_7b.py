"""llava-next-mistral-7b — VLM with Mistral-7B text backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

32L, d_model 4096, 32 heads (kv=8), d_ff 14336, vocab 32000. The anyres
vision tower + projector is a STUB: input_specs() provides precomputed
(B, S, 4096) patch+text embeddings for train/prefill; decode consumes
text token ids (the 32k-vocab embedding table exists for generation).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000, rope_theta=1_000_000.0,
        embed_inputs=True, pattern=("attn",),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=64, embed_inputs=True, pattern=("attn",),
        dtype="float32", param_dtype="float32",
    )
