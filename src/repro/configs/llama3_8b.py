"""llama3-8b — dense GQA decoder, 128k vocab. [arXiv:2407.21783]

32L, d_model 4096, 32 heads (kv=8), d_ff 14336, vocab 128256,
rope_theta 5e5, SwiGLU.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256, rope_theta=500_000.0,
        pattern=("attn",),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=128, pattern=("attn",),
        dtype="float32", param_dtype="float32",
    )
