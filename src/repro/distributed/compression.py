"""Gradient compression with error feedback (distributed-optimization).

Wire-format compression for data-parallel gradient exchange: bf16
truncation or blockwise-int8 quantization, with an error-feedback buffer
(the residual is added back before the next compression, preserving
convergence — Seide et al. / EF-SGD). ``allreduce_compressed`` is the
shard_map building block: it all-gathers the quantized payload over the
data axis and dequantize-reduces locally, so ICI bytes drop 2×/4× vs
fp32 all-reduce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import _dequantize_flat as _dequantize, _quantize_flat as _quantize


def compress(g: jax.Array, kind: str):
    if kind == "bf16":
        return g.astype(jnp.bfloat16)
    if kind == "int8":
        return _quantize(g.astype(jnp.float32))
    raise ValueError(kind)


def decompress(payload, kind: str, shape, size):
    if kind == "bf16":
        return payload.astype(jnp.float32)
    return _dequantize(payload, shape, size)


def ef_compress_tree(grads, error_buf, kind: str):
    """Error-feedback compression of a gradient pytree.

    Returns (compressed-and-decompressed grads — what the wire delivers,
    new error buffer). kind="none" passes through.
    """
    if kind == "none":
        return grads, error_buf

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        wire = decompress(compress(g32, kind), kind, g32.shape, g32.size)
        return wire, g32 - wire

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_buf)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_error_buf(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def allreduce_compressed(g: jax.Array, axis: str, kind: str):
    """Mean-all-reduce over a shard_map axis with a compressed wire format.

    int8: all_gather the (q, scale) payload (1 byte + 4/256 per element)
    and dequantize-sum locally. bf16: psum in bf16. none: psum fp32.
    """
    from repro.compat import axis_size
    n = axis_size(axis)
    if kind == "none":
        return jax.lax.pmean(g, axis)
    if kind == "bf16":
        return jax.lax.pmean(g.astype(jnp.bfloat16), axis).astype(g.dtype)
    enc = compress(g.astype(jnp.float32), "int8")
    qs = jax.lax.all_gather(enc["q"], axis)        # (n, blocks, 256) int8
    ss = jax.lax.all_gather(enc["scale"], axis)    # (n, blocks, 1) f32
    total = jnp.sum(qs.astype(jnp.float32) / 127.0 * ss, axis=0)
    return (total.reshape(-1)[: g.size].reshape(g.shape) / n).astype(g.dtype)
