"""Multi-pod pairwise CCM via shard_map (mpEDM's MPI design, SPMD-native).

2-D decomposition of the (library × target) skill matrix over the mesh:
library series are sharded across ``lib_axes`` (default "data", plus "pod"
on multi-pod meshes) and target series across ``tgt_axes`` (default
"model"). Each device drives its local library block through the
library-batched inner engine — local libraries go B at a time through
``ops.all_knn_batch`` (one fused distance + streaming top-k launch per
batch, B from ``core.ccm.auto_batch_libs``' memory budget) plus batched
fused-ρ lookups — and owns the matching ρ-matrix tile. No collective is
needed in the inner loop at all: the only data movement is the initial
placement of the two (replicated-axis) input views, matching mpEDM's
embarrassingly-parallel MPI layout.

Two embedding-dimension modes: a fixed E (the paper's synthetic
benchmarks), or a per-target ``E_opt`` table — targets are then laid out
so every shard owns an identical *static* segment structure of E-groups
(see ``_egroup_layout``) and the inner loop switches E per segment with
still zero collectives. The facade (``repro.edm.EDM.xmap``) feeds
``sharded_optimal_E``'s output straight into the ``E_opt`` mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import telemetry
from repro.core.embedding import embed_offset, num_embedded, pred_rows
from repro.kernels import ops

from repro.compat import make_mesh as make_ccm_mesh  # noqa: F401 (re-export)
from repro.compat import shard_map as _shard_map


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = 0) -> jax.Array:
    """Zero-pad ``axis`` up to a multiple (devices need equal blocks)."""
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def mesh_axes_size(mesh, axes) -> int:
    """Total device count across the named mesh axes."""
    shape = dict(mesh.shape)
    size = 1
    for ax in axes:
        size *= int(shape[ax])
    return size


def pad_members(members: np.ndarray, multiple: int) -> np.ndarray:
    """Pad an index list to a multiple by repeating its last entry
    (real data — padded slots' results are discarded by the caller)."""
    pad = (-len(members)) % multiple
    if pad == 0:
        return members
    return np.concatenate([members, np.repeat(members[-1:], pad)])


def _egroup_layout(E_opt, S: int):
    """Device-side target layout giving every shard identical E-groups.

    Sharding a contiguously E-sorted target axis would hand each device
    an arbitrary mix of groups (data-dependent, untraceable). Instead
    each group's member list is padded to a multiple of the S target
    shards (repeating its last member — real data, results discarded)
    and split into S equal chunks; shard d's block is its chunk of every
    group in order. Every shard then shares ONE static segment structure
    ``segs = ((E, width), ...)``, so the SPMD inner loop switches E per
    segment with no collective and no data-dependent shapes.

    The (N,)-int ``E_opt`` table never round-trips to host (the old
    PR-3 layout pulled it back to form the permutation): the group
    order comes from a stable device-side argsort (ascending E, then
    index — identical to the old per-E ``nonzero`` concatenation), and
    only a per-level histogram (E_max + 1 ints, unavoidable — the
    segment structure must be static for tracing) crosses the boundary
    before compute. The padded gather pattern is pure host arithmetic
    on those static counts.

    Returns (perm, keep, segs): permuted-target order as a DEVICE array
    (``jnp.take(X, perm)`` stays on device; materialize it at result
    delivery for the host unpermute), the per-slot "not a pad" mask
    (static np bool), and the per-shard segments.
    """
    E_opt = jnp.asarray(E_opt, jnp.int32)
    hist = np.asarray(jnp.bincount(E_opt, length=int(E_opt.max()) + 1))
    order = jnp.argsort(E_opt)  # stable: groups ascending E, index tie order
    seg_gather, seg_keep, segs = [], [], []
    o = 0
    for E, cnt in enumerate(hist.tolist()):
        if cnt == 0:
            continue
        padded = cnt + (-cnt) % S
        gi = o + np.minimum(np.arange(padded), cnt - 1)  # repeat last member
        keep = np.arange(padded) < cnt
        w = padded // S
        segs.append((int(E), w))
        seg_gather.append(gi.reshape(S, w))
        seg_keep.append(keep.reshape(S, w))
        o += cnt
    gather = np.concatenate(seg_gather, axis=1).reshape(-1)
    keep = np.concatenate(seg_keep, axis=1).reshape(-1)
    perm = jnp.take(order, jnp.asarray(gather))
    return perm, keep, tuple(segs)


def _local_block(libs, tgts, *, E, tau, Tp, rows, off, hard_max, impl,
                 batch_libs=None, budget_mb=None):
    """ρ tile for (local libraries × local targets): (nl, nt).

    The per-shard inner engine is library-batched (ISSUE 5): local
    libraries are processed B at a time through ``ops.all_knn_batch``
    (one fused distance + streaming top-k launch per batch — the top-k
    never sits inside a per-series ``lax.map`` body), with B from the
    same memory-budget rule as the local engine
    (``core.ccm.auto_batch_libs``). Peak memory per device is one
    (B, Lp, Lp) distance stack; everything stays shard-local, so the
    zero-collective property is untouched.
    """
    from repro.core.ccm import auto_batch_libs, pad_batch, post_lookup_rho

    nl, L = libs.shape
    Lp = num_embedded(L, E, tau)
    B = batch_libs if batch_libs is not None else auto_batch_libs(
        Lp, nl, budget_mb)
    B = max(1, min(int(B), nl))
    nb = -(-nl // B)
    # ragged final batch: repeat real series, drop their rows below
    libs = pad_batch(libs, nb * B)

    def one_batch(lb):
        d, ix = ops.all_knn_batch(lb, E=E, tau=tau, k=E + 1,
                                  exclude_self=True, max_idx=hard_max,
                                  impl=impl)
        return post_lookup_rho(tgts, d, ix, rows=rows, off=off, impl=impl)

    out = jax.lax.map(one_batch, libs.reshape(nb, B, L))
    return out.reshape(nb * B, -1)[:nl]


def sharded_ccm_matrix(
    X_lib: jax.Array,
    X_tgt: jax.Array,
    *,
    E: int | None = None,
    tau: int = 1,
    Tp: int = 0,
    mesh: jax.sharding.Mesh,
    lib_axes=("data",),
    tgt_axes=("model",),
    impl: str = "ref",
    E_opt=None,
    batch_libs: int | None = None,
    batch_budget_mb: float | None = None,
    layout=None,
):
    """All-pairs CCM skill matrix on a device mesh.

    X_lib: (N_lib, L) — N_lib must divide evenly over ``lib_axes``.
    X_tgt: (N_tgt, L) — likewise over ``tgt_axes`` (use pad_to_multiple).

    Fixed-E mode (``E=``): returns (N_lib, N_tgt) ρ sharded as
    P(lib_axes, tgt_axes), never leaving the devices.
    Per-target optimal-E mode (``E_opt=`` (N_tgt,) table): targets are
    laid out per ``_egroup_layout`` so each shard runs identical static
    E-segments (zero collectives; libraries are auto-padded over
    ``lib_axes``); returns a host (N_lib, N_tgt) np.ndarray in the
    original target order. ``batch_libs`` / ``batch_budget_mb`` size the
    per-shard library-batched inner engine (see ``_local_block``).
    """
    L = X_lib.shape[-1]
    if X_tgt.shape[-1] != L:
        raise ValueError("library/target series length mismatch")
    if (E is None) == (E_opt is None):
        raise ValueError("pass exactly one of E= or E_opt=")

    def block_fn(Eb):
        return functools.partial(
            _local_block, E=Eb, tau=tau, Tp=Tp,
            rows=pred_rows(L, Eb, tau, Tp), off=embed_offset(Eb, tau, Tp),
            hard_max=num_embedded(L, Eb, tau) - 1 - max(Tp, 0), impl=impl,
            batch_libs=batch_libs, budget_mb=batch_budget_mb)

    telemetry.counter("edm_sharded_launches").inc()
    with telemetry.span("sharded.ccm_matrix", N_lib=int(X_lib.shape[0]),
                        N_tgt=int(X_tgt.shape[0]), fixed_E=E is not None):
        if E_opt is None:
            mapped = _shard_map(
                block_fn(E),
                mesh=mesh,
                in_specs=(P(lib_axes, None), P(tgt_axes, None)),
                out_specs=P(lib_axes, tgt_axes),
            )
            return mapped(X_lib, X_tgt)
        return _egrouped_matrix(X_lib, X_tgt, block_fn, E_opt=E_opt,
                                mesh=mesh, lib_axes=lib_axes,
                                tgt_axes=tgt_axes, layout=layout)


def _egrouped_matrix(X_lib, X_tgt, block_fn, *, E_opt, mesh, lib_axes,
                     tgt_axes, curves: bool = False,
                     layout=None) -> np.ndarray:
    """Shared E-grouped driver: per-shard static E-segments, one SPMD
    program, no collectives; host unpermute at result delivery.

    ``block_fn(E)`` maps (local libs, local target segment) to a
    (nl, w) ρ tile — or, with ``curves=True``, to a (S, nl, w)
    convergence tile whose leading size axis is replicated (the
    ``sharded_ccm_convergence`` layout); targets stay the minor axis.

    ``E_opt`` (and the permutation derived from it) stays on device
    until result delivery — the host sees only the static layout
    metadata before compute (see ``_egroup_layout``). ``layout`` is an
    optional precomputed ``_egroup_layout(E_opt, S_t)`` triple: callers
    slicing the library axis into many calls over the SAME targets (the
    journaled chunked runs of ``edm.runner``) derive it once instead of
    re-sorting E_opt per chunk.
    """
    N_lib, N_tgt = X_lib.shape[0], X_tgt.shape[0]
    E_opt = jnp.broadcast_to(jnp.asarray(E_opt, jnp.int32), (N_tgt,))
    S_t = mesh_axes_size(mesh, tgt_axes)
    S_l = mesh_axes_size(mesh, lib_axes)
    perm_d, keep, segs = (_egroup_layout(E_opt, S_t)
                          if layout is None else layout)
    Xl = pad_to_multiple(X_lib, S_l, axis=0)
    Xt = jnp.take(jnp.asarray(X_tgt), perm_d, axis=0)

    def local(libs, tgts):
        outs, o = [], 0
        for Eg, w in segs:
            seg = jax.lax.slice_in_dim(tgts, o, o + w, axis=0)
            outs.append(block_fn(Eg)(libs, seg))
            o += w
        return jnp.concatenate(outs, axis=-1)

    mapped = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(lib_axes, None), P(tgt_axes, None)),
        out_specs=P(None, lib_axes, tgt_axes) if curves
        else P(lib_axes, tgt_axes),
    )
    R = np.asarray(mapped(Xl, Xt))
    perm = np.asarray(perm_d)  # delivered WITH the results, not before
    if curves:
        rho = np.zeros((R.shape[0], N_lib, N_tgt), np.float32)
        rho[:, :, perm[keep]] = R[:, :N_lib, keep]
    else:
        rho = np.zeros((N_lib, N_tgt), np.float32)
        rho[:, perm[keep]] = R[:N_lib, keep]
    return rho


def sharded_ccm_convergence(
    X_lib: jax.Array,
    X_tgt: jax.Array,
    *,
    lib_sizes,
    E: int | None = None,
    tau: int = 1,
    Tp: int = 0,
    mesh: jax.sharding.Mesh,
    lib_axes=("data",),
    tgt_axes=("model",),
    impl: str = "ref",
    E_opt=None,
):
    """All-pairs CCM *convergence* grids on a device mesh.

    The sharded counterpart of ``core.ccm.ccm_convergence``: every
    (library, target) pair's full library-size curve, shape
    (num_sizes, N_lib, N_tgt), with the same 2-D (library × target)
    decomposition and zero-collective inner loop as
    ``sharded_ccm_matrix``. Each device runs ONE multi-cap streaming
    top-k per local library (``ops.topk_select_sizes``) — never a
    per-size re-scan — and owns its curve tile; the size axis is
    replicated (it is |sizes| ≪ N² and shared by every pair).

    Fixed-E mode (``E=``): returns (S, N_lib, N_tgt) ρ sharded as
    P(None, lib_axes, tgt_axes). Per-target optimal-E mode (``E_opt=``
    (N_tgt,) table): targets are laid out per ``_egroup_layout`` so
    each shard runs identical static E-segments (zero collectives;
    sizes re-clamped per segment E); returns a host np.ndarray in the
    original target order. ``lib_sizes`` follows the caller's
    order/shape (validated / deduped / clamped as in
    ``core.ccm.normalize_lib_sizes``).
    """
    from repro.core.ccm import ccm_convergence_caps, normalize_lib_sizes

    L = X_lib.shape[-1]
    if X_tgt.shape[-1] != L:
        raise ValueError("library/target series length mismatch")
    if (E is None) == (E_opt is None):
        raise ValueError("pass exactly one of E= or E_opt=")

    def block_fn(Eb):
        caps, inv = normalize_lib_sizes(
            lib_sizes, Lp=num_embedded(L, Eb, tau), Tp=Tp)
        inv_j = jnp.asarray(inv)

        def block(libs, tgts):
            def one_library(x):
                return ccm_convergence_caps(
                    x, tgts, E=Eb, tau=tau, Tp=Tp, caps=caps,
                    exclude_self=True, impl=impl)  # (|caps|, nt)

            cur = jax.lax.map(one_library, libs)  # (nl, |caps|, nt)
            return jnp.take(jnp.moveaxis(cur, 1, 0), inv_j, axis=0)

        return block

    telemetry.counter("edm_sharded_launches").inc()
    with telemetry.span("sharded.ccm_convergence",
                        N_lib=int(X_lib.shape[0]),
                        N_tgt=int(X_tgt.shape[0])):
        if E_opt is None:
            mapped = _shard_map(
                block_fn(E),
                mesh=mesh,
                in_specs=(P(lib_axes, None), P(tgt_axes, None)),
                out_specs=P(None, lib_axes, tgt_axes),
            )
            return mapped(X_lib, X_tgt)
        return _egrouped_matrix(X_lib, X_tgt, block_fn, E_opt=E_opt,
                                mesh=mesh, lib_axes=lib_axes,
                                tgt_axes=tgt_axes, curves=True)


def sharded_optimal_E(
    X: jax.Array,
    *,
    E_max: int = 20,
    tau: int = 1,
    Tp: int = 1,
    mesh: jax.sharding.Mesh,
    axes=("data",),
    impl: str = "ref",
) -> tuple[jax.Array, jax.Array]:
    """Per-series optimal E on a device mesh → (E_opt (N,), ρ (N, E_max)).

    Series are sharded over ``axes``; each device runs the incremental
    multi-E engine (ONE all-kNN pass per local series instead of E_max
    pipelines — see kernels/knn_multi_e.py) on its shard with no
    collectives at all. This is the in-shard front half of the whole-brain
    CCM workload: the E_opt it emits feeds ``core.ccm.ccm_matrix``'s
    E-grouping or per-group ``sharded_ccm_matrix`` calls.

    N must divide evenly over ``axes`` (use pad_to_multiple).
    """
    from repro.core.simplex import optimal_E_batch

    def local(Xl):  # the local driver, verbatim, on the shard's series
        return optimal_E_batch(Xl, E_max=E_max, tau=tau, Tp=Tp, impl=impl)

    telemetry.counter("edm_sharded_launches").inc()
    with telemetry.span("sharded.optimal_E", N=int(X.shape[0]),
                        E_max=E_max):
        mapped = _shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None),),
            out_specs=(P(axes), P(axes, None)),
        )
        return mapped(X)


def sharded_smap_theta(
    X: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas: tuple[float, ...] | None = None,
    ridge: float = 1e-6,
    mesh: jax.sharding.Mesh,
    axes=("data",),
    impl: str = "ref",
) -> jax.Array:
    """Per-series S-Map θ-sweeps on a device mesh → ρ (N, |θ|).

    The nonlinearity-test half of the whole-brain workload: series are
    sharded over ``axes`` and each device runs the batched S-Map engine
    (one Gram accumulation + one batched Cholesky per local series, every
    θ at once — core/smap_engine.py) on its shard with no collectives at
    all. N must divide evenly over ``axes`` (use pad_to_multiple).
    """
    from repro.core.smap_engine import DEFAULT_THETAS, smap_theta_sweep

    thetas = DEFAULT_THETAS if thetas is None else tuple(
        float(t) for t in thetas)

    def local(Xl):  # the local engine, verbatim, on the shard's series
        return smap_theta_sweep(Xl, E=E, tau=tau, Tp=Tp, thetas=thetas,
                                ridge=ridge, impl=impl)

    telemetry.counter("edm_sharded_launches").inc()
    with telemetry.span("sharded.smap_theta", N=int(X.shape[0]), E=E,
                        thetas=len(thetas)):
        mapped = _shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None),),
            out_specs=P(axes, None),
        )
        return mapped(X)


def sharded_smap_matrix(
    X_lib: jax.Array,
    X_tgt: jax.Array,
    *,
    E: int | None = None,
    tau: int = 1,
    Tp: int = 0,
    theta: float = 1.0,
    ridge: float = 1e-6,
    mesh: jax.sharding.Mesh,
    lib_axes=("data",),
    tgt_axes=("model",),
    impl: str = "ref",
    E_opt=None,
    layout=None,
):
    """All-pairs S-Map cross-map skill matrix on a device mesh.

    Same 2-D (library × target) decomposition and zero-collective inner
    loop as ``sharded_ccm_matrix``, with the simplex lookup replaced by
    the batched S-Map engine (fit on each local library's manifold,
    predict the local targets).

    Fixed-E mode (``E=``): returns (N_lib, N_tgt) ρ sharded as
    P(lib_axes, tgt_axes). Per-target optimal-E mode (``E_opt=`` (N_tgt,)
    table — ROADMAP item (b), fed by ``sharded_optimal_E``): each shard
    fits its local libraries at every E-segment of its static layout
    (see ``_egroup_layout``), still zero collectives; returns a host
    (N_lib, N_tgt) np.ndarray in the original target order. Exposed as
    ``repro.edm.EDM.xmap(method="smap")`` on mesh sessions.
    """
    from repro.core.smap_engine import smap_group

    if X_tgt.shape[-1] != X_lib.shape[-1]:
        raise ValueError("library/target series length mismatch")
    if (E is None) == (E_opt is None):
        raise ValueError("pass exactly one of E= or E_opt=")

    def block_fn(Eb):
        def block(libs, tgts):
            return smap_group(libs, tgts, E=Eb, tau=tau, Tp=Tp,
                              theta=float(theta), ridge=ridge, impl=impl)
        return block

    telemetry.counter("edm_sharded_launches").inc()
    with telemetry.span("sharded.smap_matrix", N_lib=int(X_lib.shape[0]),
                        N_tgt=int(X_tgt.shape[0]), fixed_E=E is not None):
        if E_opt is None:
            mapped = _shard_map(
                block_fn(E),
                mesh=mesh,
                in_specs=(P(lib_axes, None), P(tgt_axes, None)),
                out_specs=P(lib_axes, tgt_axes),
            )
            return mapped(X_lib, X_tgt)
        return _egrouped_matrix(X_lib, X_tgt, block_fn, E_opt=E_opt,
                                mesh=mesh, lib_axes=lib_axes,
                                tgt_axes=tgt_axes, layout=layout)


def ccm_step(X: jax.Array, *, E: int, tau: int, mesh: jax.sharding.Mesh,
             lib_axes=("data",), tgt_axes=("model",), impl: str = "ref"):
    """Dry-run entry point: all-pairs CCM of one (N, L) panel (lib == tgt)."""
    return sharded_ccm_matrix(
        X, X, E=E, tau=tau, mesh=mesh, lib_axes=lib_axes, tgt_axes=tgt_axes,
        impl=impl,
    )
