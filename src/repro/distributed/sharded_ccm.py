"""Multi-pod pairwise CCM via shard_map (mpEDM's MPI design, SPMD-native).

2-D decomposition of the (library × target) skill matrix over the mesh:
library series are sharded across ``lib_axes`` (default "data", plus "pod"
on multi-pod meshes) and target series across ``tgt_axes`` (default
"model"). Each device loops over its local library block — one fused
all-kNN + one batched fused-ρ lookup per library — and owns the matching
ρ-matrix tile. No collective is needed in the inner loop at all: the only
data movement is the initial placement of the two (replicated-axis) input
views, matching mpEDM's embarrassingly-parallel MPI layout.

The engine uses a fixed embedding dimension E (the paper's synthetic
benchmarks do the same); per-target optimal-E grouping is handled at the
driver level (repro.core.ccm.ccm_matrix) by calling this once per E-group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.embedding import embed_offset, num_embedded, pred_rows
from repro.kernels import ops

from repro.compat import make_mesh as make_ccm_mesh  # noqa: F401 (re-export)
from repro.compat import shard_map as _shard_map


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = 0) -> jax.Array:
    """Zero-pad ``axis`` up to a multiple (devices need equal blocks)."""
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _local_block(libs, tgts, *, E, tau, Tp, rows, off, hard_max, impl):
    """ρ tile for (local libraries × local targets): (nl, nt)."""

    def one_library(x):
        D = ops.pairwise_distances(x, E=E, tau=tau, impl=impl)
        d, ix = ops.topk_select(D, k=E + 1, exclude_self=True,
                                max_idx=hard_max, impl=impl)
        w = ops.make_weights(d)
        return ops.lookup_rho(tgts, ix[:rows], w[:rows], offset=off, impl=impl)

    # Sequential over local libraries: bounds peak memory at one (Lp, Lp)
    # distance matrix per device, exactly like kEDM's per-library loop.
    return jax.lax.map(one_library, libs)


def sharded_ccm_matrix(
    X_lib: jax.Array,
    X_tgt: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    mesh: jax.sharding.Mesh,
    lib_axes=("data",),
    tgt_axes=("model",),
    impl: str = "ref",
) -> jax.Array:
    """All-pairs CCM skill matrix on a device mesh.

    X_lib: (N_lib, L) — N_lib must divide evenly over ``lib_axes``.
    X_tgt: (N_tgt, L) — likewise over ``tgt_axes`` (use pad_to_multiple).
    Returns (N_lib, N_tgt) ρ sharded as P(lib_axes, tgt_axes).
    """
    L = X_lib.shape[-1]
    if X_tgt.shape[-1] != L:
        raise ValueError("library/target series length mismatch")
    rows = pred_rows(L, E, tau, Tp)
    off = embed_offset(E, tau, Tp)
    hard_max = num_embedded(L, E, tau) - 1 - max(Tp, 0)
    fn = functools.partial(
        _local_block, E=E, tau=tau, Tp=Tp, rows=rows, off=off,
        hard_max=hard_max, impl=impl,
    )
    mapped = _shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(lib_axes, None), P(tgt_axes, None)),
        out_specs=P(lib_axes, tgt_axes),
    )
    return mapped(X_lib, X_tgt)


def sharded_optimal_E(
    X: jax.Array,
    *,
    E_max: int = 20,
    tau: int = 1,
    Tp: int = 1,
    mesh: jax.sharding.Mesh,
    axes=("data",),
    impl: str = "ref",
) -> tuple[jax.Array, jax.Array]:
    """Per-series optimal E on a device mesh → (E_opt (N,), ρ (N, E_max)).

    Series are sharded over ``axes``; each device runs the incremental
    multi-E engine (ONE all-kNN pass per local series instead of E_max
    pipelines — see kernels/knn_multi_e.py) on its shard with no
    collectives at all. This is the in-shard front half of the whole-brain
    CCM workload: the E_opt it emits feeds ``core.ccm.ccm_matrix``'s
    E-grouping or per-group ``sharded_ccm_matrix`` calls.

    N must divide evenly over ``axes`` (use pad_to_multiple).
    """
    from repro.core.simplex import optimal_E_batch

    def local(Xl):  # the local driver, verbatim, on the shard's series
        return optimal_E_batch(Xl, E_max=E_max, tau=tau, Tp=Tp, impl=impl)

    mapped = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes, None),),
        out_specs=(P(axes), P(axes, None)),
    )
    return mapped(X)


def sharded_smap_theta(
    X: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 1,
    thetas: tuple[float, ...] | None = None,
    ridge: float = 1e-6,
    mesh: jax.sharding.Mesh,
    axes=("data",),
    impl: str = "ref",
) -> jax.Array:
    """Per-series S-Map θ-sweeps on a device mesh → ρ (N, |θ|).

    The nonlinearity-test half of the whole-brain workload: series are
    sharded over ``axes`` and each device runs the batched S-Map engine
    (one Gram accumulation + one batched Cholesky per local series, every
    θ at once — core/smap_engine.py) on its shard with no collectives at
    all. N must divide evenly over ``axes`` (use pad_to_multiple).
    """
    from repro.core.smap_engine import DEFAULT_THETAS, smap_theta_sweep

    thetas = DEFAULT_THETAS if thetas is None else tuple(
        float(t) for t in thetas)

    def local(Xl):  # the local engine, verbatim, on the shard's series
        return smap_theta_sweep(Xl, E=E, tau=tau, Tp=Tp, thetas=thetas,
                                ridge=ridge, impl=impl)

    mapped = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes, None),),
        out_specs=P(axes, None),
    )
    return mapped(X)


def sharded_smap_matrix(
    X_lib: jax.Array,
    X_tgt: jax.Array,
    *,
    E: int,
    tau: int = 1,
    Tp: int = 0,
    theta: float = 1.0,
    ridge: float = 1e-6,
    mesh: jax.sharding.Mesh,
    lib_axes=("data",),
    tgt_axes=("model",),
    impl: str = "ref",
) -> jax.Array:
    """All-pairs S-Map cross-map skill matrix on a device mesh.

    Same 2-D (library × target) decomposition and zero-collective inner
    loop as ``sharded_ccm_matrix``, with the simplex lookup replaced by
    the batched S-Map engine (fit on each local library's manifold,
    predict the local targets). Returns (N_lib, N_tgt) ρ sharded as
    P(lib_axes, tgt_axes).
    """
    from repro.core.smap_engine import smap_group

    if X_tgt.shape[-1] != X_lib.shape[-1]:
        raise ValueError("library/target series length mismatch")

    def local(libs, tgts):
        return smap_group(libs, tgts, E=E, tau=tau, Tp=Tp,
                          theta=float(theta), ridge=ridge, impl=impl)

    mapped = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(lib_axes, None), P(tgt_axes, None)),
        out_specs=P(lib_axes, tgt_axes),
    )
    return mapped(X_lib, X_tgt)


def ccm_step(X: jax.Array, *, E: int, tau: int, mesh: jax.sharding.Mesh,
             lib_axes=("data",), tgt_axes=("model",), impl: str = "ref"):
    """Dry-run entry point: all-pairs CCM of one (N, L) panel (lib == tgt)."""
    return sharded_ccm_matrix(
        X, X, E=E, tau=tau, mesh=mesh, lib_axes=lib_axes, tgt_axes=tgt_axes,
        impl=impl,
    )
