"""Distributed runtime: sharded CCM engine, collectives, compression, fault
tolerance. The shard_map CCM engine is the multi-node scale story of the
paper's predecessor (mpEDM on ABCI: whole-brain causal maps) expressed as
one SPMD program instead of MPI ranks."""

from repro.distributed.sharded_ccm import (
    make_ccm_mesh,
    pad_to_multiple,
    sharded_ccm_convergence,
    sharded_ccm_matrix,
    sharded_optimal_E,
    sharded_smap_matrix,
    sharded_smap_theta,
)

__all__ = ["make_ccm_mesh", "sharded_ccm_convergence", "sharded_ccm_matrix",
           "sharded_optimal_E", "sharded_smap_matrix", "sharded_smap_theta",
           "pad_to_multiple"]
