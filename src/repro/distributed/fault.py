"""Fault tolerance: preemption handling, heartbeats, straggler detection.

On a real 1000-node fleet these hooks feed the cluster controller; here
they are fully functional in-process so the behaviours are testable:

  * ``PreemptionGuard`` — converts SIGTERM/SIGINT into a "checkpoint and
    exit cleanly" request the training loop polls each step.
  * ``StragglerMonitor`` — rolling median of step times; flags steps
    slower than ``threshold ×`` median (on TPU pods the same statistic,
    gathered per host, identifies the slow worker for replacement) and
    records them for the run report.
  * ``Heartbeat`` — appends (step, wall-time) to a file so an external
    watchdog can detect hangs and restart the job (restart-safety is
    provided by CheckpointManager's atomic auto-resume).
"""

from __future__ import annotations

import os
import signal
import statistics
import time


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a polled "checkpoint and exit" flag.

    Usable as a context manager: handlers are installed on ``__enter__``
    (or construction) and the previous handlers restored on ``__exit__``
    — the ``repro.edm.runner`` drivers poll ``requested`` between tile
    launches and turn a preemption into "commit the journal, exit 17"
    instead of lost work.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._prev = {}
        for sig in signals:
            self._prev[sig] = signal.signal(sig, self._handler)

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.restore()
        return False


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.times: list[float] = []
        self.flagged: list[tuple[int, float, float]] = []
        self._t0 = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> bool:
        dt = time.monotonic() - self._t0
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))
                return True
        return False

    def report(self) -> dict:
        """JSON-ready summary for a run report: per-step stats + flags."""
        return {
            "steps": len(self.times),
            "median_s": (statistics.median(self.times)
                         if self.times else None),
            "max_s": max(self.times) if self.times else None,
            "threshold": self.threshold,
            "flagged": [
                {"step": s, "seconds": dt, "rolling_median_s": med}
                for s, dt, med in self.flagged
            ],
        }


class Heartbeat:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int):
        with open(self.path, "a") as f:
            f.write(f"{step},{time.time():.3f}\n")
