"""Fault tolerance: preemption handling, heartbeats, straggler detection.

On a real 1000-node fleet these hooks feed the cluster controller; here
they are fully functional in-process so the behaviours are testable:

  * ``PreemptionGuard`` — converts SIGTERM/SIGINT into a "checkpoint and
    exit cleanly" request the training loop polls each step.
  * ``StragglerMonitor`` — rolling median of step times; flags steps
    slower than ``threshold ×`` median (on TPU pods the same statistic,
    gathered per host, identifies the slow worker for replacement) and
    records them for the run report.
  * ``Heartbeat`` — appends (step, wall-time) to a file so an external
    watchdog can detect hangs and restart the job (restart-safety is
    provided by CheckpointManager's atomic auto-resume).
"""

from __future__ import annotations

import os
import signal
import statistics
import time

from repro import telemetry


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a polled "checkpoint and exit" flag.

    Usable as a context manager: handlers are installed on ``__enter__``
    (or construction) and the previous handlers restored on ``__exit__``
    — the ``repro.edm.runner`` drivers poll ``requested`` between tile
    launches and turn a preemption into "commit the journal, exit 17"
    instead of lost work.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._prev = {}
        for sig in signals:
            self._prev[sig] = signal.signal(sig, self._handler)

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.restore()
        return False


class StragglerMonitor:
    """Rolling-median launch timer; flags launches ``threshold ×`` slower.

    ``threshold`` is configurable per run (``EDMConfig(
    straggler_threshold=...)`` threads it through ``EDM.xmap(run_dir=
    ...)``); ``clock`` is injectable so regression tests can replay a
    synthetic timing sequence deterministically. Each flagged launch is
    also published as a ``straggler.flag`` telemetry event and counted
    in ``edm_stragglers_flagged``.
    """

    def __init__(self, threshold: float = 2.0, window: int = 50,
                 clock=time.monotonic):
        if not threshold > 0:
            raise ValueError(f"threshold must be > 0, got {threshold}")
        self.threshold = float(threshold)
        self.window = window
        self.clock = clock
        self.times: list[float] = []
        self.flagged: list[tuple[int, float, float]] = []
        self._t0 = None

    def start(self):
        self._t0 = self.clock()

    def stop(self, step: int) -> bool:
        dt = self.clock() - self._t0
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))
                telemetry.counter("edm_stragglers_flagged").inc()
                telemetry.event("straggler.flag", step=step, seconds=dt,
                                rolling_median_s=med,
                                threshold=self.threshold)
                return True
        return False

    def report(self) -> dict:
        """JSON-ready summary for a run report: per-step stats + flags."""
        return {
            "steps": len(self.times),
            "median_s": (statistics.median(self.times)
                         if self.times else None),
            "max_s": max(self.times) if self.times else None,
            "threshold": self.threshold,
            "flagged": [
                {"step": s, "seconds": dt, "rolling_median_s": med}
                for s, dt, med in self.flagged
            ],
        }


class Heartbeat:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int):
        with open(self.path, "a") as f:
            f.write(f"{step},{time.time():.3f}\n")
