"""Mamba (S6) selective-state-space mixer — the Jamba hybrid's workhorse.

Training uses a chunked sequential scan: the (B, d_inner, N) state is
carried across chunks and each chunk body is rematerialized in the
backward pass (jax.checkpoint), so activation memory is O(S/chunk · state)
instead of O(S · state). Decode is a single-step state update with a
rolling conv window — O(1) in context length, which is what makes the
``long_500k`` cell runnable for hybrid/SSM archs (DESIGN.md §5).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _init, dense, dense_init


def mamba_init(key, cfg, dtype):
    D = cfg.d_model
    d_in = cfg.mamba_d_inner
    N, R, K = cfg.mamba.d_state, cfg.mamba_dt_rank, cfg.mamba.d_conv
    ks = jax.random.split(key, 6)
    # dt bias: softplus⁻¹ of ~[1e-3, 1e-1] (standard Mamba init)
    u = jax.random.uniform(ks[5], (d_in,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    return {
        "in_proj": dense_init(ks[0], D, 2 * d_in, dtype),
        "conv_w": _init(ks[1], (K, d_in), 1.0 / math.sqrt(K), dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], d_in, R + 2 * N, dtype),
        "dt_proj": dense_init(ks[3], R, d_in, dtype),
        "dt_bias": (dt0 + jnp.log(-jnp.expm1(-dt0))).astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                  (d_in, 1))),
        "D_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], d_in, D, dtype),
    }


def _conv_causal(p, x):
    """Depthwise causal conv over (B, S, d_in) with taps K (K small)."""
    K = p["conv_w"].shape[0]
    w = p["conv_w"].astype(x.dtype)
    y = x * w[K - 1]
    for i in range(1, K):  # unrolled: K = 4
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted * w[K - 1 - i]
    return y + p["conv_b"].astype(x.dtype)


def _ssm_inputs(p, cfg, xc):
    """dt (B,S,d_in) f32, Bp/Cp (B,S,N) f32, A (d_in,N) f32."""
    N, R = cfg.mamba.d_state, cfg.mamba_dt_rank
    proj = dense(p["x_proj"], xc)
    dt_r, Bp, Cp = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        dense(p["dt_proj"], dt_r).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    return dt, Bp.astype(jnp.float32), Cp.astype(jnp.float32), A


def _scan_chunk(carry, inp, A):
    """One chunk of the selective scan. carry: state (B, d_in, N)."""

    def step(state, t):
        dt_t, bx_t, c_t = t  # (B,d_in), (B,d_in? ...)
        dA = jnp.exp(dt_t[..., None] * A)  # (B, d_in, N)
        state = dA * state + bx_t
        y = jnp.einsum("bdn,bn->bd", state, c_t)
        return state, y

    return jax.lax.scan(step, carry, inp)


def mamba_train(p, cfg, x):
    """x: (B, S, D) → (B, S, D). S must divide by cfg.mamba.chunk."""
    B, S, D = x.shape
    d_in = cfg.mamba_d_inner
    N = cfg.mamba.d_state
    xz = dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv_causal(p, x_in))
    dt, Bp, Cp, A = _ssm_inputs(p, cfg, xc)
    # precompute dt·x·B (B,S,d_in,N) lazily per chunk to bound memory
    ck = min(cfg.mamba.chunk, S)
    nchunk = S // ck if S % ck == 0 else 1
    ck = S // nchunk

    xc32 = xc.astype(jnp.float32)

    def chunk_body(state, sl):
        dt_c, bx_c, c_c = sl  # (ck, B, ...) time-major
        return _scan_chunk(state, (dt_c, bx_c, c_c), A)

    # time-major chunked tensors
    dt_t = dt.transpose(1, 0, 2).reshape(nchunk, ck, B, d_in)
    bx = (dt * xc32)[..., None] * Bp[:, :, None, :]  # (B,S,d_in,N)
    bx_t = bx.transpose(1, 0, 2, 3).reshape(nchunk, ck, B, d_in, N)
    c_t = Cp.transpose(1, 0, 2).reshape(nchunk, ck, B, N)

    state0 = jnp.zeros((B, d_in, N), jnp.float32)
    body = jax.checkpoint(chunk_body) if cfg.remat else chunk_body
    _, ys = jax.lax.scan(body, state0, (dt_t, bx_t, c_t))
    y = ys.reshape(S, B, d_in).transpose(1, 0, 2)  # (B,S,d_in)
    y = y + p["D_skip"] * xc32
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return dense(p["out_proj"], y)


def mamba_decode(p, cfg, x, cache):
    """Single-token step. x: (B, 1, D); cache {conv (B,K-1,d_in),
    ssm (B,d_in,N)} → (out (B,1,D), new cache)."""
    B = x.shape[0]
    K = cfg.mamba.d_conv
    xz = dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B,1,d_in)
    window = jnp.concatenate([cache["conv"], x_in], axis=1)  # (B,K,d_in)
    w = p["conv_w"].astype(x.dtype)
    xc = jax.nn.silu(
        jnp.einsum("bkd,kd->bd", window, w)[:, None, :]
        + p["conv_b"].astype(x.dtype))
    dt, Bp, Cp, A = _ssm_inputs(p, cfg, xc)
    dA = jnp.exp(dt[:, 0, :, None] * A)
    bx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bp[:, 0, None, :]
    state = dA * cache["ssm"] + bx
    y = jnp.einsum("bdn,bn->bd", state, Cp[:, 0])[:, None, :]
    y = y + p["D_skip"] * xc.astype(jnp.float32)
    out = dense(p["out_proj"], y.astype(x.dtype) * jax.nn.silu(z))
    return out, {"conv": window[:, 1:], "ssm": state}


def mamba_cache_shape(cfg, batch, dtype):
    return {
        "conv": jax.ShapeDtypeStruct(
            (batch, cfg.mamba.d_conv - 1, cfg.mamba_d_inner), dtype),
        "ssm": jax.ShapeDtypeStruct(
            (batch, cfg.mamba_d_inner, cfg.mamba.d_state), jnp.float32),
    }
