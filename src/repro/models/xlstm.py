"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM: per-head outer-product memory C ∈ R^{dk×dv} with exponential
input/forget gates, stabilized in log space (Beck et al. 2024). Training
runs a chunk-rematerialized sequential scan (same memory strategy as the
Mamba block); decode is an O(1) state update — the property that makes
xlstm-125m a ``long_500k``-capable arch.

sLSTM: scalar-memory recurrence with per-head block-diagonal recurrent
weights. Strictly sequential by construction (the paper's own caveat) —
implemented as lax.scan; noted in DESIGN.md §5.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _init, dense, dense_init, rmsnorm, rmsnorm_init


# ----------------------------------------------------------------- mLSTM


def mlstm_init(key, cfg, dtype):
    D, H = cfg.d_model, cfg.n_heads
    pf = cfg.xlstm.proj_factor_m
    d_in = int(pf * D)
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d_in)
    return {
        "up": dense_init(ks[0], D, 2 * d_in, dtype),
        "wq": dense_init(ks[1], d_in, d_in, dtype),
        "wk": dense_init(ks[2], d_in, d_in, dtype),
        "wv": dense_init(ks[3], d_in, d_in, dtype),
        "wi": {"w": _init(ks[4], (d_in, H), s, jnp.float32),
               "b": jnp.zeros((H,), jnp.float32)},
        "wf": {"w": _init(ks[5], (d_in, H), s, jnp.float32),
               "b": 3.0 + jnp.arange(H, dtype=jnp.float32)},  # open forget
        "norm": rmsnorm_init(d_in, dtype),
        "down": dense_init(ks[6], d_in, D, dtype),
    }


def _mlstm_gates(p, u):
    """log-input/forget gate pre-activations per head: (B, S, H) f32."""
    u32 = u.astype(jnp.float32)
    logi = u32 @ p["wi"]["w"] + p["wi"]["b"]
    logf = jax.nn.log_sigmoid(u32 @ p["wf"]["w"] + p["wf"]["b"])
    return logi, logf


def _mlstm_qkv(p, cfg, u):
    B, S, d_in = u.shape
    H = cfg.n_heads
    dh = d_in // H
    q = dense(p["wq"], u).reshape(B, S, H, dh)
    k = dense(p["wk"], u).reshape(B, S, H, dh) / math.sqrt(dh)
    v = dense(p["wv"], u).reshape(B, S, H, dh)
    return q, k, v


def _mlstm_step(carry, t):
    """carry: (C (B,H,dk,dv), n (B,H,dk), m (B,H)); t: per-step tensors."""
    C, n, m = carry
    q, k, v, logi, logf = t  # (B,H,dk),(B,H,dk),(B,H,dv),(B,H),(B,H)
    m_new = jnp.maximum(logf + m, logi)
    i_ = jnp.exp(logi - m_new)[..., None]
    f_ = jnp.exp(logf + m - m_new)[..., None]
    C = f_[..., None] * C + i_[..., None] * (k[..., :, None] * v[..., None, :])
    n = f_ * n + i_ * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    return (C, n, m_new), num / den[..., None]


def mlstm_train(p, cfg, x):
    B, S, D = x.shape
    H = cfg.n_heads
    u, z = jnp.split(dense(p["up"], x), 2, axis=-1)  # (B,S,d_in) each
    d_in = u.shape[-1]
    dh = d_in // H
    q, k, v = _mlstm_qkv(p, cfg, u)
    logi, logf = _mlstm_gates(p, u)

    tm = lambda a: jnp.moveaxis(a.astype(jnp.float32), 1, 0)  # time-major
    ck = min(cfg.xlstm.chunk, S)
    nchunk = S // ck if S % ck == 0 else 1
    ck = S // nchunk

    def chunk(carry, sl):
        return jax.lax.scan(_mlstm_step, carry, sl)

    body = jax.checkpoint(chunk) if cfg.remat else chunk
    resh = lambda a: a.reshape((nchunk, ck) + a.shape[1:])
    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    (_, _, _), ys = jax.lax.scan(
        body, (C0, n0, m0),
        tuple(resh(tm(a)) for a in (q, k, v, logi, logf)))
    y = jnp.moveaxis(ys.reshape(S, B, H, dh), 0, 1).reshape(B, S, d_in)
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    return dense(p["down"], y * jax.nn.silu(z))


def mlstm_decode(p, cfg, x, cache):
    B = x.shape[0]
    u, z = jnp.split(dense(p["up"], x), 2, axis=-1)
    q, k, v = _mlstm_qkv(p, cfg, u)
    logi, logf = _mlstm_gates(p, u)
    sq = lambda a: a[:, 0].astype(jnp.float32)
    carry = (cache["C"], cache["n"], cache["m"])
    (C, n, m), y = _mlstm_step(
        carry, (sq(q), sq(k), sq(v), sq(logi), sq(logf)))
    y = y.reshape(B, 1, -1).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = dense(p["down"], y * jax.nn.silu(z))
    return out, {"C": C, "n": n, "m": m}


def mlstm_cache_shape(cfg, batch, dtype):
    H = cfg.n_heads
    dh = int(cfg.xlstm.proj_factor_m * cfg.d_model) // H
    return {
        "C": jax.ShapeDtypeStruct((batch, H, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, H, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, H), jnp.float32),
    }


# ----------------------------------------------------------------- sLSTM


def slstm_init(key, cfg, dtype):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    pf = cfg.xlstm.proj_factor_s
    d_ff = int(2 * pf * D) // 2 * 2
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(D)
    gates = {}
    for name, kk in zip(("z", "i", "f", "o"), jax.random.split(ks[0], 4)):
        k1, k2 = jax.random.split(kk)
        gates[name] = {
            "w": _init(k1, (D, D), s, dtype),
            "r": _init(k2, (H, dh, dh), 1.0 / math.sqrt(dh), dtype),
            "b": (3.0 * jnp.ones((D,), jnp.float32) if name == "f"
                  else jnp.zeros((D,), jnp.float32)),
        }
    return {
        "gates": gates,
        "ffn_gate": dense_init(ks[1], D, d_ff, dtype),
        "ffn_up": dense_init(ks[2], D, d_ff, dtype),
        "ffn_down": dense_init(ks[3], d_ff, D, dtype),
        "norm": rmsnorm_init(D, dtype),
    }


def _slstm_pre(p, x):
    """Input contributions of all four gates: (B, S, D) each, f32."""
    g = p["gates"]
    pre = {n: dense(g[n], x).astype(jnp.float32) + g[n]["b"]
           for n in ("z", "i", "f", "o")}
    return pre


def _slstm_step(p, cfg, carry, pre_t):
    """carry: (h, c, n, m) all (B, D) f32."""
    h, c, n, m = carry
    H = cfg.n_heads
    B, D = h.shape
    dh = D // H
    g = p["gates"]
    hh = h.reshape(B, H, dh)

    def rec(name):
        r = g[name]["r"].astype(jnp.float32)
        return jnp.einsum("bhd,hde->bhe", hh, r).reshape(B, D)

    z = jnp.tanh(pre_t["z"] + rec("z"))
    o = jax.nn.sigmoid(pre_t["o"] + rec("o"))
    logi = pre_t["i"] + rec("i")
    logf = jax.nn.log_sigmoid(pre_t["f"] + rec("f"))
    m_new = jnp.maximum(logf + m, logi)
    i_ = jnp.exp(logi - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c = f_ * c + i_ * z
    n = f_ * n + i_
    h_new = o * c / jnp.maximum(n, 1.0)
    return (h_new, c, n, m_new), h_new


def slstm_train(p, cfg, x):
    B, S, D = x.shape
    pre = _slstm_pre(p, x)
    pre_tm = {k: jnp.moveaxis(v, 1, 0) for k, v in pre.items()}
    z0 = jnp.zeros((B, D), jnp.float32)
    carry0 = (z0, z0, z0, jnp.full((B, D), -1e30, jnp.float32))

    def step(carry, t):
        return _slstm_step(p, cfg, carry, t)

    _, hs = jax.lax.scan(step, carry0, pre_tm)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    h = jax.nn.silu(dense(p["ffn_gate"], y)) * dense(p["ffn_up"], y)
    return dense(p["ffn_down"], h)


def slstm_decode(p, cfg, x, cache):
    pre = {k: v[:, 0] for k, v in _slstm_pre(p, x).items()}
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    (h, c, n, m), y = _slstm_step(p, cfg, carry, pre)
    y = rmsnorm(p["norm"], y[:, None, :].astype(x.dtype), cfg.norm_eps)
    hgate = jax.nn.silu(dense(p["ffn_gate"], y)) * dense(p["ffn_up"], y)
    return dense(p["ffn_down"], hgate), {"h": h, "c": c, "n": n, "m": m}


def slstm_cache_shape(cfg, batch, dtype):
    D = cfg.d_model
    f32 = jnp.float32
    return {k: jax.ShapeDtypeStruct((batch, D), f32)
            for k in ("h", "c", "n", "m")}
