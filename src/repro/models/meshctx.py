"""Activation-sharding context (MaxText-style logical axis rules).

FSDP shards weight d_model over the "data" axis while activations shard
batch over the same axis; GSPMD's cost model then prefers all-gathering
the (smaller) activations — replicating the batch and blowing past HBM
(measured: 4.2 GB/device logits at llama3 train_4k). Pinning activation
shardings at block boundaries forces the weight-gather instead, which is
the FSDP contract.

Launchers call ``set_mesh(mesh)`` before tracing; without a mesh set (unit
tests, single-device runs) every constraint is a no-op.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH: jax.sharding.Mesh | None = None


def set_mesh(mesh):
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh):
    prev = _MESH
    set_mesh(mesh)
    try:
        yield
    finally:
        set_mesh(prev)


def _axes(logical: str | None):
    if logical == "dp":
        return ("pod", "data") if "pod" in _MESH.axis_names else ("data",)
    if logical == "model":
        return ("model",)
    return None


def constrain(x, *logical):
    """with_sharding_constraint by logical dims ('dp' | 'model' | None per
    array axis); skips non-divisible dims and is a no-op without a mesh."""
    if _MESH is None or not hasattr(x, "ndim") or x.ndim != len(logical):
        return x
    spec = []
    for dim_size, name in zip(x.shape, logical):
        axes = _axes(name)
        if axes is None:
            spec.append(None)
            continue
        n = 1
        for a in axes:
            n *= _MESH.shape[a]
        spec.append(axes if dim_size % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*spec)))


# --- serving toggles (set by launchers; default off) -------------------

_SEQPAR_DECODE = False


def set_seqpar_decode(on: bool):
    """Enable sequence-parallel KV decode attention (shard_map flash-
    combine over the cache's model-sharded sequence axis)."""
    global _SEQPAR_DECODE
    _SEQPAR_DECODE = on


def seqpar_decode() -> bool:
    return _SEQPAR_DECODE and _MESH is not None
