"""Shared neural layers: norms, rotary embeddings, MLPs, init helpers.

Functional style: params are plain dicts of jnp arrays; every layer is a
pure function ``f(params, x, ...)``. Initializers return shape/dtype trees
that double as the abstract (ShapeDtypeStruct) description for dry-runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _init(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2, 2, shape,
                                                jnp.float32)).astype(dtype)


def dense_init(key, d_in, d_out, dtype, *, bias=False, scale=None):
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    p = {"w": _init(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(x.dtype) * p["g"].astype(x.dtype)


def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings, shape (d_head//2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, n_heads, d_head); positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    sin = jnp.sin(ang)[..., None, :]  # (..., S, 1, d/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mlp_init(key, d_model, d_ff, kind, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    if kind in ("relu2", "gelu"):  # Nemotron-4 squared-ReLU / HuBERT GELU
        return {
            "w_up": dense_init(k1, d_model, d_ff, dtype),
            "w_down": dense_init(k2, d_ff, d_model, dtype),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp(p, x, kind):
    if kind == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(dense(p["w_up"], x)))
    elif kind == "gelu":
        h = jax.nn.gelu(dense(p["w_up"], x))
    else:
        raise ValueError(kind)
    return dense(p["w_down"], h)


def embedding_init(key, vocab, d_model, dtype, scale: float = 1.0):
    return {"table": _init(key, (vocab, d_model), scale, dtype)}


def embed(p, tokens, dtype=None):
    """Token embedding gather. Converting the table to the compute dtype
    BEFORE the gather matters under SPMD: a vocab-sharded table lowers to
    masked-gather + all-reduce of the (B, S, D) output, and the AR should
    move bf16, not f32 (measured 2× collective bytes at prefill)."""
    table = p["table"] if dtype is None else p["table"].astype(dtype)
    return jnp.take(table, tokens, axis=0)


def unembed(p, x):
    """Project to vocab logits in float32 (loss numerics)."""
    return x.astype(jnp.float32) @ p["table"].T.astype(jnp.float32)
