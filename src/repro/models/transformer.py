"""Model assembly: pattern-based block stacks with scan-over-units.

A config's ``pattern`` (e.g. ``("attn",)``, ``("attn_moe", "attn")``,
Jamba's 8-layer hybrid unit) is instantiated once and scanned
``n_units = n_layers / len(pattern)`` times with stacked parameters, so
HLO size is O(|pattern|) regardless of depth and FSDP-style parameter
gathering happens per scan step. Each unit body is rematerialized
(jax.checkpoint) when cfg.remat.

Entry points:
  * ``init_params`` / ``abstract_params`` — concrete or ShapeDtypeStruct
    parameter trees (dry-runs never allocate).
  * ``loss_fn`` — next-token (causal) or framewise (encoder) CE + MoE aux.
  * ``prefill`` — forward returning per-layer caches (attention KV /
    SSM states) padded into S_max buffers.
  * ``decode_step`` — one token against the cache (serve_step of the
    decode_* and long_500k cells).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.meshctx import constrain
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import (
    dense,
    embed,
    embedding_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)

ATTN_KINDS = ("attn", "attn_moe")


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------ init


def _layer_init(kind, key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if kind in ATTN_KINDS:
        init = attn.mla_init if cfg.attention == "mla" else attn.gqa_init
        p["mix"] = init(k1, cfg, dtype)
    elif kind in ("mamba", "mamba_moe"):
        p["mix"] = mb.mamba_init(k1, cfg, dtype)
    elif kind == "mlstm":
        p["mix"] = xl.mlstm_init(k1, cfg, dtype)
        return p  # single-residual block
    elif kind == "slstm":
        p["mix"] = xl.slstm_init(k1, cfg, dtype)
        return p
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
    if kind.endswith("_moe"):
        p["mlp"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def _unit_init(key, cfg, dtype):
    keys = jax.random.split(key, len(cfg.pattern))
    return {f"l{i}": _layer_init(kind, keys[i], cfg, dtype)
            for i, kind in enumerate(cfg.pattern)}


def init_params(cfg, key):
    dtype = _pdtype(cfg)
    k_embed, k_units, k_head = jax.random.split(key, 3)
    params = {}
    if not cfg.embed_inputs or cfg.family == "vlm":
        params["embed"] = embedding_init(k_embed, cfg.vocab_size,
                                         cfg.d_model, dtype)
    unit_keys = jax.random.split(k_units, cfg.n_units)
    if cfg.scan_layers:
        params["units"] = jax.vmap(
            lambda k: _unit_init(k, cfg, dtype))(unit_keys)
    else:
        params["units"] = [
            _unit_init(k, cfg, dtype) for k in unit_keys]
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.tie_embeddings and "embed" in params:
        pass  # reuse embed table for the head
    else:
        # 1/√d head init keeps init CE ≈ log V (logits O(1))
        params["lm_head"] = embedding_init(
            k_head, cfg.vocab_size, cfg.d_model, dtype,
            scale=cfg.d_model ** -0.5)
    return params


def abstract_params(cfg):
    """Parameter tree of ShapeDtypeStructs — no allocation (dry-run path)."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(functools.partial(init_params, cfg), key)


# --------------------------------------------------------------- forward


def _apply_layer_train(kind, p, *, cfg, x, positions, mode):
    """mode: 'train' (full attention) or 'prefill' (chunked + cache out)."""
    cache = None
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        if cfg.attention == "mla":
            if mode == "prefill":
                y, cache = attn.mla_full(p["mix"], cfg, h, positions,
                                         return_cache=True)
            else:
                y = attn.mla_full(p["mix"], cfg, h, positions)
        else:
            if mode == "prefill":
                y, cache = attn.gqa_prefill(p["mix"], cfg, h, positions)
            else:
                y = attn.gqa_full(p["mix"], cfg, h, positions)
    elif kind in ("mamba", "mamba_moe"):
        y = mb.mamba_train(p["mix"], cfg, h)
    elif kind == "mlstm":
        return x + xl.mlstm_train(p["mix"], cfg, h), 0.0, None
    elif kind == "slstm":
        return x + xl.slstm_train(p["mix"], cfg, h), 0.0, None
    x = x + y
    h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
    aux = 0.0
    if kind.endswith("_moe"):
        y2, aux = moe_mod.moe_apply(p["mlp"], cfg, h2)
    else:
        y2 = mlp(p["mlp"], h2, cfg.mlp)
    return x + y2, aux, cache


def _unit_apply_train(uparams, cfg, x, positions, mode):
    aux_total = 0.0
    caches = {}
    x = constrain(x, "dp", None, None)  # pin batch over data (FSDP contract)
    for i, kind in enumerate(cfg.pattern):
        # Remat at LAYER granularity: unit-level checkpoint keeps the whole
        # unit's recomputed activations live in its backward (243 GB/device
        # for deepseek's 27-layer pattern); per-layer checkpoints bound the
        # live set to one layer.
        layer = functools.partial(_apply_layer_train, kind, cfg=cfg,
                                  mode=mode)
        if cfg.remat:
            layer = jax.checkpoint(layer)
        x, aux, cache = layer(uparams[f"l{i}"], x=x, positions=positions)
        aux_total = aux_total + aux
        if cache is not None:
            caches[f"l{i}"] = cache
    return x, aux_total, caches


def _stack_forward(params, cfg, x, positions, mode):
    """Scan the unit over its stacked params. Returns (x, aux, caches)."""
    if not cfg.scan_layers:
        aux_total = 0.0
        caches = []
        for uparams in params["units"]:
            x, aux, c = _unit_apply_train(uparams, cfg, x, positions, mode)
            aux_total += aux
            caches.append(c)
        return x, aux_total, caches

    def body(carry, uparams):
        x, aux = carry
        x, aux_u, caches = _unit_apply_train(uparams, cfg, x, positions, mode)
        return (x, aux + aux_u), caches

    # remat happens per layer inside the unit; the scan body itself is not
    # checkpointed (scan already bounds residuals to per-unit carries).
    (x, aux), caches = jax.lax.scan(body, (x, 0.0), params["units"])
    return x, aux, caches


def _inputs_to_h(params, cfg, batch):
    if cfg.embed_inputs:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = embed(params["embed"], batch["tokens"], _dtype(cfg))
    x = constrain(x, "dp", None, None)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def _head(params, cfg, x):
    x = constrain(x, "dp", None, None)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if (cfg.tie_embeddings and "embed" in params) \
        else params["lm_head"]
    return constrain(unembed(table, x), "dp", None, "model")  # (B, S, V) f32


def forward_train(params, cfg, batch):
    x, positions = _inputs_to_h(params, cfg, batch)
    x, aux, _ = _stack_forward(params, cfg, x, positions, mode="train")
    return _head(params, cfg, x), aux


def loss_fn(params, cfg, batch, *, aux_weight: float = 0.01,
            zloss: float = 0.0):
    """Mean CE (+ MoE aux, + optional z-loss). Returns (loss, metrics)."""
    logits, aux = forward_train(params, cfg, batch)
    labels = batch["labels"] if "labels" in batch else batch["tokens"]
    if cfg.causal:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = ce.mean()
    metrics = {"ce": loss, "aux": aux}
    if any(k.endswith("_moe") for k in cfg.pattern):
        loss = loss + aux_weight * aux
    if zloss:
        lse = jax.nn.logsumexp(logits, axis=-1)
        loss = loss + zloss * jnp.mean(lse**2)
    metrics["loss"] = loss
    return loss, metrics


# --------------------------------------------------------------- serving


def prefill(params, cfg, batch, *, s_max: int | None = None):
    """Forward pass that also returns decode caches (padded to s_max)."""
    x, positions = _inputs_to_h(params, cfg, batch)
    x, _, caches = _stack_forward(params, cfg, x, positions, mode="prefill")
    logits = _head(params, cfg, x[:, -1:, :])
    S = positions.shape[1]
    s_max = s_max or S
    # scan-stacked caches carry a leading (units,) axis before (B, S, ...)
    caches = _pad_attn_caches(caches, cfg, s_max,
                              axis=2 if cfg.scan_layers else 1)
    # recurrent-layer states come from a dedicated pass (cheap decode-style
    # replay is avoided: mamba/xlstm prefill states are materialized by
    # their train fns only on request — see serving engine).
    return logits, caches


def _pad_attn_caches(caches, cfg, s_max, *, axis):
    def pad(leaf):
        if leaf.ndim > axis and leaf.shape[axis] != s_max:
            pad_width = [(0, 0)] * leaf.ndim
            pad_width[axis] = (0, s_max - leaf.shape[axis])
            return jnp.pad(leaf, pad_width)
        return leaf

    return jax.tree.map(pad, caches)


def init_cache(cfg, batch: int, s_max: int, dtype=None, abstract=False):
    """Per-unit stacked cache tree (zeros, or ShapeDtypeStructs)."""
    dtype = dtype or _dtype(cfg)
    unit = {}
    for i, kind in enumerate(cfg.pattern):
        if kind in ATTN_KINDS:
            shape_fn = (attn.mla_cache_shape if cfg.attention == "mla"
                        else attn.gqa_cache_shape)
            unit[f"l{i}"] = shape_fn(cfg, batch, s_max, dtype)
        elif kind in ("mamba", "mamba_moe"):
            unit[f"l{i}"] = mb.mamba_cache_shape(cfg, batch, dtype)
        elif kind == "mlstm":
            unit[f"l{i}"] = xl.mlstm_cache_shape(cfg, batch, dtype)
        elif kind == "slstm":
            unit[f"l{i}"] = xl.slstm_cache_shape(cfg, batch, dtype)
    n = cfg.n_units

    def make(path, sds, lead=()):
        shp = lead + sds.shape
        if abstract:
            return jax.ShapeDtypeStruct(shp, sds.dtype)
        # xLSTM log-space stabilizer state must start at -inf, not 0.
        fill = -1e30 if path[-1].key == "m" else 0.0
        return jnp.full(shp, fill, sds.dtype)

    if cfg.scan_layers:
        return jax.tree_util.tree_map_with_path(
            lambda p_, s_: make(p_, s_, (n,)), unit)
    return [jax.tree_util.tree_map_with_path(make, unit) for _ in range(n)]


def _apply_layer_decode(kind, p, cfg, x, cache, pos):
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        from repro.models.meshctx import seqpar_decode
        if cfg.attention == "mla":
            fn = attn.mla_decode
        elif seqpar_decode():
            fn = attn.gqa_decode_seqpar
        else:
            fn = attn.gqa_decode
        y, new_cache = fn(p["mix"], cfg, h, cache, pos)
    elif kind in ("mamba", "mamba_moe"):
        y, new_cache = mb.mamba_decode(p["mix"], cfg, h, cache)
    elif kind == "mlstm":
        y, new_cache = xl.mlstm_decode(p["mix"], cfg, h, cache)
        return x + y, new_cache
    elif kind == "slstm":
        y, new_cache = xl.slstm_decode(p["mix"], cfg, h, cache)
        return x + y, new_cache
    x = x + y
    h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if kind.endswith("_moe"):
        y2, _ = moe_mod.moe_apply(p["mlp"], cfg, h2)
    else:
        y2 = mlp(p["mlp"], h2, cfg.mlp)
    return x + y2, new_cache


def decode_step(params, cfg, tokens, cache, pos):
    """One-token serve step.

    tokens: (B, 1) int32 (or {"embeds": (B,1,D)} for pure-embedding archs);
    cache: tree from init_cache/prefill; pos: () int32 write position.
    Returns (logits (B, 1, V) f32, new cache).
    """
    if isinstance(tokens, dict):
        x = tokens["embeds"].astype(_dtype(cfg))
    else:
        x = embed(params["embed"], tokens, _dtype(cfg))

    def body(x, unit):
        uparams, ucache = unit
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, nc = _apply_layer_decode(
                kind, uparams[f"l{i}"], cfg, x, ucache[f"l{i}"], pos)
            new_caches[f"l{i}"] = nc
        return x, new_caches

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, (params["units"], cache))
    else:
        new_cache = []
        for uparams, ucache in zip(params["units"], cache):
            x, nc = body(x, (uparams, ucache))
            new_cache.append(nc)
    logits = _head(params, cfg, x)
    return logits, new_cache
