"""Attention: GQA (full / chunked-prefill / decode) and MLA (DeepSeek).

Forms:
  * ``full``   — S×S masked attention, used for train (S ≤ attn_full_max).
  * ``chunked``— online-softmax over KV chunks for long prefill; memory is
    O(chunk_q × S) instead of O(S²). The baseline masks out-of-range
    chunks (costing ~2× attention FLOPs in HLO — an explicitly tracked
    roofline term); the ``tri`` variant skips fully-masked chunks with a
    dynamic-bound loop (forward-only, used for inference prefill).
  * ``decode`` — one new token against a (B, S, Hkv, dh) cache written at
    position ``pos``. The cache layout puts the sequence axis second so it
    can be sharded over the "model" mesh axis for long contexts
    (sequence-parallel KV decode; GSPMD inserts the flash-style combine).

MLA (Multi-head Latent Attention) caches only the 512-dim latent + shared
rope key; decode uses the *absorbed* form (W_uk folded into the query,
W_uv deferred past the probability average), which is the whole point of
MLA's small-cache/small-FLOPs decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, dense, dense_init

NEG_INF = -1e30


# ----------------------------------------------------------------- GQA


def gqa_init(key, cfg, dtype):
    k0, k1, k2, k3 = jax.random.split(key, 4)
    D, Hq, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "wq": dense_init(k0, D, Hq * dh, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(k1, D, Hkv * dh, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(k2, D, Hkv * dh, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(k3, Hq * dh, D, dtype),
    }


def _heads(cfg, p, x, positions):
    B, S, _ = x.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = Hq // Hkv
    q = dense(p["wq"], x).reshape(B, S, Hq, dh)
    k = dense(p["wk"], x).reshape(B, S, Hkv, dh)
    v = dense(p["wv"], x).reshape(B, S, Hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q.reshape(B, S, Hkv, G, dh), k, v


def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,H,G,d), k/v (B,Sk,H,d), mask (Sq,Sk) or None → (B,Sq,H,G,d)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", a, v)


def gqa_full(p, cfg, x, positions):
    """Training attention: full masked S×S for short sequences, chunked
    online-softmax (flash-at-HLO-level, rematerialized backward) beyond
    attn_full_max — the S×S score tensor would be O(100 GB)/device at 4k
    with production batch sizes."""
    B, S, D = x.shape
    q, k, v = _heads(cfg, p, x, positions)
    cq = min(cfg.attn_chunk_q, S)
    if S <= cfg.attn_full_max or S % cq != 0:
        mask = jnp.tril(jnp.ones((S, S), bool)) if cfg.causal else None
        out = _sdpa(q, k, v, mask,
                    1.0 / jnp.sqrt(cfg.d_head).astype(jnp.float32))
    else:
        chunked = jax.checkpoint(
            functools.partial(_chunked_causal, cq=cq,
                              scale=1.0 / float(np.sqrt(cfg.d_head)),
                              causal=cfg.causal))
        out = chunked(q, k, v)
    return dense(p["wo"], out.reshape(B, S, -1).astype(x.dtype))


def gqa_prefill(p, cfg, x, positions):
    """Chunked prefill. Returns (out, cache {k, v})."""
    B, S, D = x.shape
    q, k, v = _heads(cfg, p, x, positions)
    cq = min(cfg.attn_chunk_q, S)
    if S <= cfg.attn_full_max or S % cq != 0:
        mask = jnp.tril(jnp.ones((S, S), bool)) if cfg.causal else None
        out = _sdpa(q, k, v, mask, 1.0 / jnp.sqrt(cfg.d_head))
    else:
        out = _chunked_causal(q, k, v, cq=cq,
                              scale=1.0 / float(np.sqrt(cfg.d_head)),
                              causal=cfg.causal)
    out = dense(p["wo"], out.reshape(B, S, -1).astype(x.dtype))
    return out, {"k": k, "v": v}


def _chunked_causal(q, k, v, *, cq, scale, causal=True):
    """Online-softmax over KV chunks; masked variant (static trip counts).

    q: (B, S, H, G, d) → scan over S/cq query chunks; each accumulates
    (m, l, o) across S/cq key chunks, with causal masking if requested
    (out-of-range chunks cost ~2× attention FLOPs in HLO — an explicitly
    tracked roofline term; see EXPERIMENTS.md §Perf).
    """
    B, S, H, G, d = q.shape
    dv = v.shape[-1]  # may differ from the QK dim (MLA)
    nq = S // cq
    ck = cq  # square chunks keep the mask logic trivial
    qc = q.reshape(B, nq, cq, H, G, d).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nq, ck, H, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nq, ck, H, dv).transpose(1, 0, 2, 3, 4)
    base = jnp.tril(jnp.ones((cq, ck), bool))

    def q_step(_, qi_i):
        qi, i = qi_i

        # Rematerialized: without checkpoint, scan-backward stores the
        # (cq, ck) probability block per step — S² memory all over again.
        # With it, the backward recomputes s/p from (q, k, v) chunks —
        # the classic flash-attention backward.
        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, kv_j):
            m, l, o = carry
            kj, vj, j = kv_j
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                # j < i: fully visible; j == i: diagonal; j > i: masked.
                mask = jnp.where(j < i, True, base) & (j <= i)
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, H, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, G, cq), jnp.float32)
        o0 = jnp.zeros((B, H, G, cq, dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_step, (m0, l0, o0), (kc, vc, jnp.arange(nq)))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4)  # (B, cq, H, G, dv)

    _, outs = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, G, dv)


def gqa_decode(p, cfg, x, cache, pos):
    """One-token decode against a seq-major cache written at ``pos``.

    x: (B, 1, D); cache: {k, v} of (B, S_max, Hkv, dh); pos: () int32.
    """
    B, _, D = x.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = Hq // Hkv
    S_max = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _heads(cfg, p, x, positions)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, pos, 0, 0))
    idx = jnp.arange(S_max)
    mask = (idx <= pos)[None, :]  # (1, S_max)
    out = _sdpa(q, ck, cv, mask, 1.0 / jnp.sqrt(dh))
    out = dense(p["wo"], out.reshape(B, 1, -1).astype(x.dtype))
    return out, {"k": ck, "v": cv}


def gqa_cache_shape(cfg, batch, s_max, dtype):
    shp = (batch, s_max, cfg.n_kv_heads, cfg.d_head)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype)}


# ----------------------------------------------------------------- MLA


def mla_init(key, cfg, dtype):
    D, H = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], D, H * (dn + dr), dtype),
        "w_dkv": dense_init(ks[1], D, r, dtype),
        "w_kr": dense_init(ks[2], D, dr, dtype),
        "w_uk": dense_init(ks[3], r, H * dn, dtype),
        "w_uv": dense_init(ks[4], r, H * dv, dtype),
        "wo": dense_init(ks[5], H * dv, D, dtype),
    }


def _mla_q(p, cfg, x, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = dense(p["wq"], x).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_full(p, cfg, x, positions, *, return_cache=False):
    """Standard (non-absorbed) MLA — train/prefill path."""
    B, S, _ = x.shape
    H, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv = dense(p["w_dkv"], x)  # (B, S, r) — this is the whole KV cache
    k_rope = apply_rope(dense(p["w_kr"], x)[:, :, None, :], positions,
                        cfg.rope_theta)  # (B, S, 1, dr) shared
    k_nope = dense(p["w_uk"], c_kv).reshape(B, S, H, dn)
    v = dense(p["w_uv"], c_kv).reshape(B, S, H, dv)
    scale = 1.0 / float(np.sqrt(dn + dr))
    cq = min(cfg.attn_chunk_q, S)
    if S > cfg.attn_full_max and S % cq == 0:
        # chunked online-softmax: the S×S score tensor is ~2 GB/device
        # per layer at 4k — same flash-at-HLO treatment as GQA.
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
        q_full = q_full.reshape(B, S, H, 1, dn + dr)
        chunked = jax.checkpoint(
            functools.partial(_chunked_causal, cq=cq, scale=scale,
                              causal=cfg.causal))
        out = chunked(q_full, k_full, v).reshape(B, S, H * dv)
    else:
        s = (
            jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bqhd,bkxd->bhqk", q_rope, k_rope,
                         preferred_element_type=jnp.float32)
        ) * scale
        if cfg.causal:
            s = jnp.where(jnp.tril(jnp.ones((S, S), bool)), s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, S, H * dv)
    out = dense(p["wo"], out.astype(x.dtype))
    if return_cache:
        return out, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
    return out


def mla_decode(p, cfg, x, cache, pos):
    """Absorbed-form decode: scores/values live in the r-dim latent space."""
    B, _, _ = x.shape
    H, dn, dr, dv, r = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    S_max = cache["c_kv"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)  # (B,1,H,dn),(B,1,H,dr)
    c_new = dense(p["w_dkv"], x)  # (B, 1, r)
    kr_new = apply_rope(dense(p["w_kr"], x)[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    # absorb W_uk into the query: q̃ (B,1,H,r)
    w_uk = p["w_uk"]["w"].reshape(r, H, dn)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    s = (
        jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(jnp.float32),
                   c_kv.astype(jnp.float32))
        + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    ) / jnp.sqrt(dn + dr)
    mask = (jnp.arange(S_max) <= pos)[None, :]
    s = jnp.where(mask, s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", a, c_kv.astype(jnp.float32))
    w_uv = p["w_uv"]["w"].reshape(r, H, dv)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv).reshape(B, 1, H * dv)
    out = dense(p["wo"], out.astype(x.dtype))
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_cache_shape(cfg, batch, s_max, dtype):
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, s_max, cfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, s_max, cfg.qk_rope_dim), dtype),
    }


# ------------------------------------------- sequence-parallel decode

def gqa_decode_seqpar(p, cfg, x, cache, pos):
    """Decode attention with the KV cache sequence axis sharded over the
    "model" mesh axis (fully-manual shard_map, flash-style combine).

    GSPMD's generic handling of the seq-sharded cache re-gathers it every
    step (measured: ~83 GB/device/token at llama3 decode_32k). Here each
    model shard owns S/16 cache positions: the new KV row is written only
    by the owning shard (masked dynamic-update), every shard computes a
    partial (m, l, o) over its local positions, and the exact softmax
    recombines with one pmax + two psums of (B, H, G[, d]) — kilobytes
    per step instead of gigabytes.
    """
    from repro.models.meshctx import get_mesh

    mesh = get_mesh()
    B, _, D = x.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = Hq // Hkv
    S_max = cache["k"].shape[1]
    n_model = mesh.shape["model"]
    if S_max % n_model:
        return gqa_decode(p, cfg, x, cache, pos)
    S_loc = S_max // n_model
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if B % dp_size == 0 else None

    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _heads(cfg, p, x, positions)  # (B,1,Hkv,G,dh),(B,1,Hkv,dh)×2
    scale = 1.0 / float(np.sqrt(dh))
    P = jax.sharding.PartitionSpec

    def body(sid, q, kn, vn, ck, cv, pos):
        sid = sid[0]
        lpos = pos - sid * S_loc
        in_range = (lpos >= 0) & (lpos < S_loc)
        lclamp = jnp.clip(lpos, 0, S_loc - 1)
        ck_w = jax.lax.dynamic_update_slice(
            ck, kn.astype(ck.dtype), (0, lclamp, 0, 0))
        cv_w = jax.lax.dynamic_update_slice(
            cv, vn.astype(cv.dtype), (0, lclamp, 0, 0))
        ck2 = jnp.where(in_range, ck_w, ck)
        cv2 = jnp.where(in_range, cv_w, cv)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, ck2,
                       preferred_element_type=jnp.float32) * scale
        gidx = sid * S_loc + jnp.arange(S_loc)
        s = jnp.where((gidx <= pos)[None, None, None, None, :], s, NEG_INF)
        m = s.max(-1)  # (B,H,G,1)
        pexp = jnp.exp(s - m[..., None])
        l = pexp.sum(-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", pexp, cv2.astype(jnp.float32))
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        o_g = jax.lax.psum(o * corr[..., None], "model")
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out, ck2, cv2

    shard_ids = jnp.arange(n_model, dtype=jnp.int32)
    from repro.compat import shard_map
    out, ck, cv = shard_map(
        body, mesh=mesh,
        in_specs=(P("model"),
                  P(bspec, None, None, None, None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None),
                  P(bspec, "model", None, None),
                  P(bspec, "model", None, None),
                  P()),
        out_specs=(P(bspec, None, None, None, None),
                   P(bspec, "model", None, None),
                   P(bspec, "model", None, None)),
        check_vma=False,
    )(shard_ids, q, k, v, cache["k"], cache["v"], pos)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, Hq * dh)
    out = dense(p["wo"], out.astype(x.dtype))
    return out, {"k": ck, "v": cv}
