"""LM substrate: functional model definitions for the architecture zoo."""

from repro.models.transformer import (
    abstract_params,
    decode_step,
    forward_train,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "abstract_params",
    "decode_step",
    "forward_train",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
]
