"""Capacity-based Mixture-of-Experts with explicit expert parallelism.

Dispatch is data movement (sort + capacity scatter/gather), not one-hot
matmuls — a GShard-style dispatch einsum would add O(T²·k·cf·D) fake
FLOPs per layer (38× the real expert compute at 4k sequence) and wreck
the MODEL_FLOPS/HLO ratio in the roofline.

GSPMD cannot partition an arbitrary-index scatter onto an expert-sharded
buffer (measured: it replicates the (E, C, D) dispatch buffer and
all-reduces 4 GB per layer; partial-auto shard_map trips XLA's
PartitionId limitation). So with a mesh active the whole MoE layer runs
under a FULLY-manual ``shard_map``: routing is computed per data-parallel
shard over its local tokens (per-dp-group capacity — standard in EP
systems), each model shard owns E/16 experts and selects its tokens by
shifting the sorted expert ids into local range (out-of-range rows drop
via scatter OOB semantics), and partial outputs combine with one psum
over "model". Without a mesh (unit tests) the same block runs locally
with E_loc = E.

Over-capacity tokens drop (GShard semantics, capacity_factor 1.25);
shared experts (DeepSeek) are an always-on fused MLP.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _init, dense, mlp, mlp_init
from repro.models.meshctx import constrain, get_mesh


def moe_init(key, cfg, dtype):
    m = cfg.moe
    D, F, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(D)
    p = {
        "router": _init(ks[0], (D, E), scale, jnp.float32),
        "w_gate": _init(ks[1], (E, D, F), scale, dtype),
        "w_up": _init(ks[2], (E, D, F), scale, dtype),
        "w_down": _init(ks[3], (E, F, D), 1.0 / math.sqrt(F), dtype),
    }
    if m.num_shared:
        p["shared"] = mlp_init(ks[4], D, F * m.num_shared, "swiglu", dtype)
    return p


def _route(xf, router, k, E, cf):
    """Local routing: returns (se, st, pos, wts, counts, probs)."""
    T = xf.shape[0]
    logits = xf.astype(jnp.float32) @ router  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(T * k)
    flat_p = top_p.reshape(T * k)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = flat_t[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    return se, st, pos, flat_p[order][:, None], counts, probs


def _capacity(T, k, E, cf):
    return max(4, int(math.ceil(T * k * cf / E)))


def _expert_block(wg, wu, wd, xf, se_loc, st, pos, C):
    """Capacity-dispatch + expert FFN + gather-back for a LOCAL expert
    bank. Rows with se_loc outside [0, E_loc) or pos ≥ C drop (OOB
    scatter) / read zero (OOB gather)."""
    E_loc, D, F = wg.shape
    dtype = xf.dtype
    h = jnp.zeros((E_loc, C, D), dtype).at[se_loc, pos].set(
        xf[st], mode="drop")
    gate = jnp.einsum("ecd,edf->ecf", h, wg)
    up = jnp.einsum("ecd,edf->ecf", h, wu)
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, wd)
    return out.at[se_loc, pos].get(mode="fill", fill_value=0)  # (T·k, D)


def _moe_local(x, router, wg, wu, wd, shard_id, *, k, E, cf, dp_names):
    """Body shared by the shard_map (local shapes) and no-mesh paths."""
    B, S, D = x.shape
    T = B * S
    dtype = x.dtype
    xf = x.reshape(T, D)
    se, st, pos, wts, counts, probs = _route(xf, router, k, E, cf)
    C = _capacity(T, k, E, cf)
    E_loc = wg.shape[0]
    se_loc = se - shard_id * E_loc
    gathered = _expert_block(wg, wu, wd, xf, se_loc, st, pos, C)
    y = jnp.zeros((T, D), dtype).at[st].add(wts.astype(dtype) * gathered)
    if E_loc != E:  # expert-parallel: combine partial outputs
        y = jax.lax.psum(y, "model")
    aux = E * jnp.sum((counts.astype(jnp.float32) / (T * k)) * probs.mean(0))
    if dp_names:
        aux = jax.lax.pmean(aux, dp_names)
    return y.reshape(B, S, D), aux


def moe_apply(p, cfg, x):
    """x: (B, S, D) → (y (B, S, D), aux load-balance loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    E, k, cf = m.num_experts, m.top_k, m.capacity_factor
    dtype = x.dtype
    wg = p["w_gate"].astype(dtype)
    wu = p["w_up"].astype(dtype)
    wd = p["w_down"].astype(dtype)
    router = p["router"]

    mesh = get_mesh()
    ep = (mesh is not None and "model" in mesh.axis_names
          and mesh.shape["model"] > 1 and E % mesh.shape["model"] == 0)
    if ep:
        n_shards = mesh.shape["model"]
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        batch_spec = dp if B % dp_size == 0 else None
        dp_names = dp if B % dp_size == 0 else ()
        # axis_index() lowers to PartitionId (unsupported); use a sharded
        # iota to recover the model-shard id.
        shard_ids = jnp.arange(n_shards, dtype=jnp.int32)

        def shard_fn(x, router, wg, wu, wd, sid):
            return _moe_local(x, router, wg, wu, wd, sid[0],
                              k=k, E=E, cf=cf, dp_names=dp_names)

        from repro.compat import shard_map
        y, aux = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(batch_spec, None, None), P(None, None),
                      P("model"), P("model"), P("model"), P("model")),
            out_specs=(P(batch_spec, None, None), P()),
            check_vma=False,
        )(x, router, wg, wu, wd, shard_ids)
    else:
        y, aux = _moe_local(x, router, wg, wu, wd, 0,
                            k=k, E=E, cf=cf, dp_names=())

    y = constrain(y, "dp", None, None)
    if m.num_shared:
        y = y + mlp(p["shared"], x, "swiglu")
    return y, aux
