"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Strategy (DESIGN.md §4):
  * TP over "model": attention head projections (flattened head dim),
    MLP hidden, vocab, MoE experts, SSM inner dims.
  * FSDP over "data" (+"pod"): the d_model axis of weight matrices — the
    optimizer state shards with its parameter, giving ZeRO-3 behaviour
    through GSPMD's per-scan-step gathering.
  * Activations: batch over ("pod","data"); decode KV caches shard the
    *sequence* axis over "model" (sequence-parallel KV decode) so
    long-context cells fit.
  * Anything non-divisible falls back to replication on that dim (e.g.
    qwen's 20 heads, hubert's 504-class head) — recorded, visible in the
    roofline as extra bytes, and a hillclimb lever.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes


def _div(n: int, mesh, axes) -> bool:
    return axes is not None and n % axis_size(mesh, axes) == 0


def _maybe(n, mesh, axes):
    """axes if evenly divisible else None (replicate)."""
    if axes is None:
        return None
    return axes if _div(n, mesh, axes) else None


def param_spec(path, leaf, cfg, mesh) -> P:
    """PartitionSpec for one parameter leaf, keyed on its tree path."""
    names = [p.key for p in path if hasattr(p, "key")]
    shape = leaf.shape
    dp = dp_axes(mesh)
    scanned = "units" in names and cfg.scan_layers
    lead = (None,) if scanned else ()
    core = shape[1:] if scanned else shape

    def spec(*dims):
        return P(*(lead + dims))

    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""

    # ---- 1-D leaves: biases, norms, per-channel vectors
    if len(core) == 0:
        return P()
    if len(core) == 1:
        if name in ("g", "dt_bias", "conv_b", "D_skip", "b"):
            return spec(_maybe(core[0], mesh, "model")
                        if name in ("b", "conv_b", "D_skip") else None)
        return spec(None)

    # ---- embeddings / lm head: (vocab, d_model). d_model deliberately
    # NOT FSDP-sharded: a dp-sharded contraction dim in unembed forces
    # GSPMD to all-gather the *batch* (measured 4.2 GB/device logits
    # replication); a (V/16, D) shard is ≤130 MB anyway.
    if name == "table":
        return spec(_maybe(core[0], mesh, "model"), None)

    # ---- MoE expert banks: (E, D, F) / (E, F, D) — experts over model
    if parent in ("mlp",) and len(core) == 3:
        e = _maybe(core[0], mesh, "model")
        return spec(e, _maybe(core[1], mesh, dp), None)
    if name == "router":
        return spec(_maybe(core[0], mesh, dp), None)

    # ---- sLSTM recurrent blocks: (H, dh, dh)
    if name == "r" and len(core) == 3:
        return spec(None, None, _maybe(core[2], mesh, "model"))

    # ---- projections INTO the sharded inner dim: (d_model, X)
    if parent in ("wq", "wk", "wv", "w_gate", "w_up", "up", "in_proj",
                  "w_dkv", "w_kr", "wq_full", "ffn_gate", "ffn_up") or (
            name == "w" and parent in ("wi", "wf")):
        return spec(_maybe(core[0], mesh, dp), _maybe(core[1], mesh, "model"))

    # ---- projections OUT of the sharded inner dim: (X, d_model)
    if parent in ("wo", "w_down", "down", "out_proj", "ffn_down",
                  "w_uk", "w_uv", "dt_proj"):
        return spec(_maybe(core[0], mesh, "model"), _maybe(core[1], mesh, dp))

    # ---- mamba misc: conv (K, d_in), x_proj (d_in, R+2N), A_log (d_in, N)
    if parent == "mix" and name == "conv_w":
        return spec(None, _maybe(core[1], mesh, "model"))
    if parent == "x_proj":
        return spec(_maybe(core[0], mesh, "model"), None)
    if name == "A_log":
        return spec(_maybe(core[0], mesh, "model"), None)

    # ---- generic fallback: model on the last divisible dim, dp on another
    dims = [None] * len(core)
    for i in reversed(range(len(core))):
        if _div(core[i], mesh, "model"):
            dims[i] = "model"
            break
    for i in range(len(core)):
        if dims[i] is None and _div(core[i], mesh, dp):
            dims[i] = dp
            break
    return spec(*dims)


def _opt_moment_spec(pspec, leaf_shape):
    """Adam moments share their parameter's spec (fp32 path)."""
    return pspec


def state_specs(cfg, mesh, abstract_state):
    """PartitionSpec tree matching a train state from make_train_step."""
    dp = dp_axes(mesh)

    def for_params(tree):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: param_spec(path, leaf, cfg, mesh), tree)

    specs = {"params": for_params(abstract_state["params"])}

    def moment(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if names and names[-1] in ("q", "scale"):
            # last-axis 8-bit codec: q/scale inherit the parameter's spec
            return param_spec(path[:-1], leaf, cfg, mesh)
        return param_spec(path, leaf, cfg, mesh)

    for key in ("m", "v"):
        specs_mv = jax.tree_util.tree_map_with_path(
            moment, abstract_state["opt"][key])
        specs.setdefault("opt", {})[key] = specs_mv
    specs["opt"]["step"] = P()
    if "ebuf" in abstract_state:
        specs["ebuf"] = for_params(abstract_state["ebuf"])
    return specs


def batch_specs(cfg, mesh, batch):
    dp = dp_axes(mesh)

    def leaf(path, x):
        b = x.shape[0]
        dims = [_maybe(b, mesh, dp)] + [None] * (x.ndim - 1)
        return P(*dims)

    return jax.tree_util.tree_map_with_path(leaf, batch)


def cache_specs(cfg, mesh, cache):
    """Decode caches: batch over dp where divisible; attention/MLA cache
    sequence axis over "model" (sequence-parallel KV)."""
    dp = dp_axes(mesh)

    def leaf(path, x):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        # caches are (units, B, ...) when scanned, (B, ...) per-unit lists
        # when unrolled (roofline probes)
        stacked = not any(isinstance(q, jax.tree_util.SequenceKey)
                          for q in path)
        o = 1 if stacked else 0
        dims = [None] * x.ndim
        if x.ndim >= o + 1:
            dims[o] = _maybe(x.shape[o], mesh, dp)  # batch
        if name in ("k", "v", "c_kv", "k_rope") and x.ndim >= o + 2:
            dims[o + 1] = _maybe(x.shape[o + 1], mesh, "model")  # sequence
        elif name == "ssm" and x.ndim >= o + 2:
            dims[o + 1] = _maybe(x.shape[o + 1], mesh, "model")  # d_inner
        elif name == "conv" and x.ndim >= o + 3:
            dims[o + 2] = _maybe(x.shape[o + 2], mesh, "model")
        elif name == "C" and x.ndim >= o + 3:
            dims[o + 2] = _maybe(x.shape[o + 2], mesh, "model")  # mLSTM dk
        elif name in ("h", "c", "n", "m") and x.ndim == o + 2:
            dims[o + 1] = _maybe(x.shape[o + 1], mesh, "model")  # sLSTM D
        return P(*dims)

    return jax.tree_util.tree_map_with_path(leaf, cache)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
