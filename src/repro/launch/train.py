"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container the smoke-sized configs actually run; the full
configs are exercised through the dry-run (``repro.launch.dryrun``). On a
real pod the same entry point launches the fault-tolerant loop with the
production mesh and sharding rules — restart the process and it resumes
from the latest checkpoint.
"""

from __future__ import annotations

import argparse

from repro.configs import ARCHS, TrainConfig, get_config
from repro.data.pipeline import TokenPipeline, embeds_pipeline
from repro.training import train


class _EmbedsPipe:
    def __init__(self, cfg, batch, seq, seed=0):
        self._get = embeds_pipeline(cfg.d_model, batch, seq, seed)
        self._vocab = cfg.vocab_size

    def global_batch(self, step):
        return self._get(step, self._vocab)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (full configs are dry-run only "
                         "on CPU)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adamw8bit"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps, microbatch=args.microbatch,
                       optimizer=args.optimizer,
                       grad_compression=args.grad_compression)
    workdir = args.workdir or f"/tmp/repro_{args.arch}"
    if cfg.embed_inputs:
        pipe = _EmbedsPipe(cfg, args.batch, args.seq)
    else:
        pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=args.batch,
                             seq_len=args.seq)
    print(f"[train] arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"steps={args.steps} workdir={workdir}")
    _, history = train(cfg, tcfg, pipe, workdir=workdir,
                       num_steps=args.steps, ckpt_every=25, log_every=5)
    print(f"[train] done: loss {history[0]['loss']:.3f} → "
          f"{history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
