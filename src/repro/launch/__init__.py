"""Launchers: production meshes, sharding rules, dry-run driver,
train/serve CLIs."""
