import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline report (deliverable g).

Derives the three roofline terms per (arch × shape) from the dry-run's
compiled artifacts:

    compute    = FLOPs / (chips · 197e12)         [v5e bf16 peak]
    memory     = bytes accessed / (chips · 819e9) [HBM BW]
    collective = collective bytes / (chips · 50e9)[ICI link BW]

XLA's cost analysis counts scan bodies ONCE, so scanned layer stacks are
undercounted. This module recovers the true totals with probe lowers at
microbatch=1 (math FLOPs are accumulation-invariant) and a linear model:

    cost(U units) = C0 + U·Cu,   Cu = cost(2 units) − cost(1 unit)

(the microbatch scan adds only the gradient-accumulate adds — a ≲0.5%
bytes undercount, noted). EDM cells use analytic kernel formulas (their
per-library lax.map is scan-hidden the same way).

Usage:
  python -m repro.launch.roofline --probe --out experiments/roofline
  python -m repro.launch.roofline --report --dryrun experiments/dryrun \
      --probes experiments/roofline
"""

import argparse
import json
import math

import jax

from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh
from repro.models.meshctx import set_mesh

V5E_FLOPS = 197e12
V5E_BW = 819e9
ICI_BW = 50e9
CHIPS = {"single": 256, "multi": 512}


def _measure(arch, shape, mesh, **over):
    fn, args = dr.build_cell(arch, shape, mesh, **over)
    lowered = fn.lower(*args)
    compiled = lowered.compile()
    rec = dr.analyze(compiled, lowered)
    cost = rec.get("cost", {})
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll": rec["collectives"]["total"],
        "memory": rec.get("memory", {}),
    }


def probe_cell(arch: str, shape_name: str, mesh, opt: int = 0) -> dict:
    """Linear-model coefficients for one cell (single-pod mesh)."""
    cfg = get_config(arch)
    plen = len(cfg.pattern)
    U = cfg.n_units
    is_train = SHAPES[shape_name].kind == "train"
    out = {"arch": arch, "shape": shape_name, "U": U, "opt": opt}

    # probes must UNROLL (scan bodies are cost-counted once even with
    # two units)
    p1 = _measure(arch, shape_name, mesh, n_layers=plen,
                  microbatch=1 if is_train else None, scan_layers=False,
                  opt=opt)
    p2 = (_measure(arch, shape_name, mesh, n_layers=2 * plen,
                   microbatch=1 if is_train else None, scan_layers=False,
                   opt=opt)
          if U > 1 else None)
    for key in ("flops", "bytes", "coll"):
        cu = max(p2[key] - p1[key], 0.0) if p2 else 0.0
        out[key] = dict(c0=p1[key] - cu, cu=cu,
                        total=p1[key] + (U - 1) * cu)
    return out


EDM_E = {"ccm_pairwise": 20, "ccm_subject6": 10}


def edm_analytic(shape_name: str, chips: int) -> dict:
    """Analytic per-device kernel costs for the CCM cells (ref path)."""
    p = dr.EDM_SHAPES[shape_name]
    N, L, E = p["n_series"], p["length"], p["E"]
    Lp = L - (E - 1)
    k = E + 1
    libs_per_dev = N / (chips / 16)  # lib axes = data(+pod); model=16
    tgts_per_dev = N / 16
    per_lib_flops = 3.0 * E * Lp * Lp + k * Lp * Lp \
        + 2.0 * k * Lp * tgts_per_dev + 10.0 * Lp * tgts_per_dev
    per_lib_bytes = 4.0 * (2 * Lp * Lp + Lp * k * 2
                           + tgts_per_dev * Lp)  # D r/w + tables + gathers
    flops = libs_per_dev * per_lib_flops
    bytes_ = libs_per_dev * per_lib_bytes
    return {"flops": {"total": flops}, "bytes": {"total": bytes_},
            "coll": {"total": 4.0 * N * L / chips},  # one input scatter
            "U": int(libs_per_dev), "M": 1, "analytic": True}


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·D (train) / 2·N_active·D (serving fwd), global."""
    cfg = get_config(arch)
    sc = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = sc.global_batch * (sc.seq_len if sc.kind != "decode" else 1)
    mult = 6.0 if sc.kind == "train" else 2.0
    return mult * n_active * tokens


def build_report(dryrun_dir: str, probes_dir: str, out_path: str):
    rows = []
    for arch in list(ARCHS) + [dr.EDM_ARCH]:
        shapes = cells(arch) if arch != dr.EDM_ARCH else list(dr.EDM_SHAPES)
        for shape in shapes:
            rec_path = os.path.join(dryrun_dir,
                                    f"{arch}__{shape}__single.json")
            if not os.path.exists(rec_path):
                continue
            rec = json.load(open(rec_path))
            probe_path = os.path.join(probes_dir,
                                      f"{arch}__{shape}.json")
            if os.path.exists(probe_path):
                probe = json.load(open(probe_path))
                flops = probe["flops"]["total"]
                bytes_ = probe["bytes"]["total"]
                coll = probe["coll"]["total"]
                corrected = True
            else:
                cost = rec.get("cost", {})
                flops = cost.get("flops", 0.0)
                bytes_ = cost.get("bytes accessed", 0.0)
                coll = rec.get("collectives", {}).get("total", 0.0)
                corrected = False
            t_c = flops / V5E_FLOPS
            t_m = bytes_ / V5E_BW
            t_x = coll / ICI_BW
            dom = max(("compute", t_c), ("memory", t_m),
                      ("collective", t_x), key=lambda kv: kv[1])
            mf = (model_flops(arch, shape) / 256
                  if arch != dr.EDM_ARCH else flops)
            rows.append({
                "arch": arch, "shape": shape,
                "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
                "dominant": dom[0],
                "roofline_fraction": t_c / max(dom[1], 1e-30),
                "model_flops_per_dev": mf,
                "hlo_flops_per_dev": flops,
                "useful_ratio": mf / max(flops, 1e-30),
                "temp_gb": rec.get("memory", {}).get(
                    "temp_size_in_bytes", 0) / 1e9,
                "corrected": corrected,
            })
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--probe", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.probe:
        mesh = make_production_mesh()
        set_mesh(mesh)
        archs = [args.arch] if args.arch else list(ARCHS)
        for arch in archs:
            for shape in cells(arch):
                name = f"{arch}__{shape}"
                path = os.path.join(args.out, name + ".json")
                if os.path.exists(path):
                    continue
                try:
                    probe = probe_cell(arch, shape, mesh)
                except Exception as e:  # keep sweeping
                    probe = {"arch": arch, "shape": shape,
                             "error": repr(e)[:500]}
                with open(path, "w") as f:
                    json.dump(probe, f, indent=1)
                tot = probe.get("flops", {}).get("total", 0)
                print(f"[probe] {name}: flops_total={tot:.3e}", flush=True)
        for shape in dr.EDM_SHAPES:
            with open(os.path.join(args.out,
                                   f"{dr.EDM_ARCH}__{shape}.json"),
                      "w") as f:
                json.dump(edm_analytic(shape, 256), f, indent=1)

    if args.report:
        rows = build_report(args.dryrun, args.out,
                            os.path.join(args.out, "report.json"))
        for r in rows:
            print(f"{r['arch']:>26} {r['shape']:<12} dom={r['dominant']:<10}"
                  f" frac={r['roofline_fraction']:.3f}"
                  f" useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    main()
