import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture × input-shape × mesh) cell against
the production mesh built from 512 emulated host devices, and records
``memory_analysis()`` / ``cost_analysis()`` / per-device collective bytes
parsed from the optimized HLO. No arrays are ever allocated: parameters,
optimizer state, batches and caches are ShapeDtypeStructs.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --arch edm_ccm --shape ccm_pairwise ...
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, TrainConfig, cells, get_config
from repro.launch.mesh import axis_size as _axsize, dp_axes, make_production_mesh
from repro.launch import sharding as shd
from repro.models import transformer as tf
from repro.models.meshctx import set_mesh
from repro.training.step import make_train_step

EDM_ARCH = "edm_ccm"
EDM_SHAPES = {
    # the paper's largest synthetic workload: 10^5 series × 10^4 steps
    "ccm_pairwise": dict(n_series=102_400, length=10_000, E=20, tau=1),
    # Subject6-shaped real-world cell (Table 1)
    "ccm_subject6": dict(n_series=92_160, length=3_780, E=10, tau=1),
}


# ------------------------------------------------------------ input specs


def input_specs(arch: str, shape_name: str, *, multi_pod: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    if arch == EDM_ARCH:
        p = EDM_SHAPES[shape_name]
        return {"X": jax.ShapeDtypeStruct(
            (p["n_series"], p["length"]), jnp.float32)}
    cfg = get_config(arch)
    sc = SHAPES[shape_name]
    B, S = sc.global_batch, sc.seq_len
    i32 = jnp.int32
    if sc.kind == "train":
        if cfg.embed_inputs:
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if sc.kind == "prefill":
        if cfg.embed_inputs:
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against an S-long cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": tf.init_cache(cfg, B, S, dtype=jnp.dtype(cfg.dtype),
                                   abstract=True),
            "pos": jax.ShapeDtypeStruct((), i32)}


# ----------------------------------------------------------- cell builder


def _strip_dp(spec):
    """Remove data-parallel axes from a PartitionSpec (serving params are
    TP-only: FSDP weight shards force per-step all-gathers at inference)."""
    P = jax.sharding.PartitionSpec

    def clean(d):
        if d is None or isinstance(d, str):
            return None if d in ("data", "pod") else d
        t = tuple(a for a in d if a not in ("data", "pod"))
        return t if t else None

    return P(*(clean(d) for d in spec))


def build_cell(arch: str, shape_name: str, mesh, *, n_layers=None,
               microbatch=None, scan_layers=None, opt: int = 0):
    """Returns (jitted_fn, abstract_args tuple) ready to .lower().

    ``n_layers``/``microbatch`` override the config — used by the roofline
    probes that recover true per-unit/per-microbatch HLO costs from
    scan-hidden bodies (XLA cost analysis counts loop bodies once).
    """
    P = jax.sharding.PartitionSpec
    if arch == EDM_ARCH:
        from repro.distributed.sharded_ccm import ccm_step
        p = EDM_SHAPES[shape_name]
        X = input_specs(arch, shape_name)["X"]
        lib_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

        def step(X):
            return ccm_step(X, E=p["E"], tau=p["tau"], mesh=mesh,
                            lib_axes=lib_axes, tgt_axes=("model",),
                            impl="ref")

        fn = jax.jit(step, in_shardings=shd.to_shardings(
            mesh, P(lib_axes, None)))
        return fn, (X,)

    cfg = get_config(arch)
    if n_layers is not None or scan_layers is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg,
            n_layers=cfg.n_layers if n_layers is None else n_layers,
            scan_layers=(cfg.scan_layers if scan_layers is None
                         else scan_layers))
    sc = SHAPES[shape_name]
    specs = input_specs(arch, shape_name, multi_pod="pod" in mesh.axis_names)

    if sc.kind == "train":
        # Gradient accumulation (microbatch 8) is the production baseline:
        # it bounds per-unit activation carries to ~2 GB/device (see
        # EXPERIMENTS.md §Perf iteration log).
        tcfg = TrainConfig(
            microbatch=(microbatch if microbatch is not None else
                        int(os.environ.get("DRYRUN_MICROBATCH", "8"))),
            optimizer=("adamw8bit"
                       if arch == "llama4-maverick-400b-a17b" else "adamw"))
        dp = dp_axes(mesh)

        def constrain(mb):
            def leaf(x):
                dims = [dp if x.shape[0] % _axsize(mesh, dp) == 0 else None]
                dims += [None] * (x.ndim - 1)
                return jax.lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(mesh, P(*dims)))
            return jax.tree.map(leaf, mb)

        # grad-carry constraint: pin ONLY the MoE expert banks to their
        # parameter sharding (GSPMD replicates those accumulators —
        # 64 GB/device at maverick; constraining everything instead
        # fights its layout choices and reshards every scan step).
        def _expert_spec(path, leaf):
            names = [q.key for q in path if hasattr(q, "key")]
            core = leaf.ndim - (1 if "units" in names else 0)
            if "mlp" in names and core == 3 and names[-1].startswith("w_"):
                return jax.sharding.NamedSharding(
                    mesh, shd.param_spec(path, leaf, cfg, mesh))
            return None

        gshard = jax.tree_util.tree_map_with_path(
            _expert_spec, tf.abstract_params(cfg))

        def grad_constrain(grads):
            return jax.tree.map(
                lambda g, s: g if s is None
                else jax.lax.with_sharding_constraint(g, s),
                grads, gshard,
                is_leaf=lambda v: v is None or hasattr(v, "shape"))

        init_state, train_step, abstract_state = make_train_step(
            cfg, tcfg, batch_constraint=constrain,
            grad_constraint=grad_constrain)
        state = abstract_state()
        state_sh = shd.to_shardings(mesh, shd.state_specs(cfg, mesh, state))
        batch_sh = shd.to_shardings(mesh, shd.batch_specs(cfg, mesh, specs))
        # donate the train state: production steps update in place —
        # without donation memory_analysis double-counts params+moments.
        fn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        return fn, (state, specs)

    params = tf.abstract_params(cfg)
    pspecs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: shd.param_spec(path, leaf, cfg, mesh), params)
    if opt >= 1:  # §Perf iteration: TP-only serving params
        pspecs = jax.tree.map(_strip_dp, pspecs,
                              is_leaf=lambda v: isinstance(
                                  v, jax.sharding.PartitionSpec))
    if opt >= 3:
        # §Perf iteration (prefill): replicate the small KV projections so
        # every model shard computes full K/V locally — 16× redundant
        # ~8 MB matmuls instead of ~70 GB/device of kv-head all-gathers
        # (GQA kv heads < model shards cannot be head-sharded).
        def repl_kv(path, spec):
            names = [q.key for q in path if hasattr(q, "key")]
            if len(names) >= 2 and names[-2] in ("wk", "wv"):
                return jax.sharding.PartitionSpec(
                    *([None] * len(spec)))
            return spec
        pspecs = jax.tree_util.tree_map_with_path(
            repl_kv, pspecs,
            is_leaf=lambda v: isinstance(v, jax.sharding.PartitionSpec))
    params_sh = shd.to_shardings(mesh, pspecs)

    if sc.kind == "prefill":
        if cfg.family == "audio":  # encoder: "prefill" = full forward
            def step(params, batch):
                logits, _ = tf.forward_train(params, cfg, batch)
                return logits
        else:
            def step(params, batch):
                logits, caches = tf.prefill(params, cfg, batch)
                return logits, caches
        batch_sh = shd.to_shardings(mesh, shd.batch_specs(cfg, mesh, specs))
        fn = jax.jit(step, in_shardings=(params_sh, batch_sh))
        return fn, (params, specs)

    # decode
    from repro.models.meshctx import set_seqpar_decode
    set_seqpar_decode(opt >= 2)  # §Perf iteration: seq-parallel KV decode
    cache = tf.init_cache(cfg, SHAPES[shape_name].global_batch,
                          SHAPES[shape_name].seq_len,
                          dtype=jnp.dtype(cfg.dtype), abstract=True)
    cache_sh = shd.to_shardings(mesh, shd.cache_specs(cfg, mesh, cache))
    tok_sh = shd.to_shardings(
        mesh, shd.batch_specs(cfg, mesh, {"tokens": specs["tokens"]}))

    def step(params, tokens, cache, pos):
        return tf.decode_step(params, cfg, tokens, cache, pos)

    fn = jax.jit(
        step,
        in_shardings=(params_sh, tok_sh["tokens"], cache_sh, None),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),  # decode updates the cache in place
    )
    return fn, (params, specs["tokens"], cache, specs["pos"])


# ------------------------------------------------------ analysis helpers

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind, from partitioned HLO text.
    Counts each instruction's result-shape bytes (the payload landing on
    each chip); 'start' variants counted once ('done' carries no type)."""
    out = {k: 0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(_COLL_OPS) + r")(-start)?\(",
                      line)
        if not m:
            continue
        kind = m.group(2)
        out[kind] += _shape_bytes(m.group(1))
        counts[kind] += 1
    out_total = sum(out.values())
    return {"bytes_by_kind": out, "counts": counts, "total": out_total}


def analyze(compiled, lowered) -> dict:
    res = {}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        res["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and (
                           "flops" in k or "bytes" in k or "utilization" in k)}
    except Exception as e:  # pragma: no cover
        res["cost_error"] = repr(e)
    try:
        mem = compiled.memory_analysis()
        res["memory"] = {
            a: int(getattr(mem, a))
            for a in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, a)
        }
    except Exception as e:  # pragma: no cover
        res["memory_error"] = repr(e)
    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text()
    res["collectives"] = collective_bytes(text)
    res["hlo_chars"] = len(text)
    return res


# ---------------------------------------------------------------- driver


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             opt: int = 0) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    set_mesh(mesh)  # activation-sharding rules resolve against this mesh
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": mesh.devices.size, "status": "ok", "opt": opt}
    t0 = time.time()
    try:
        fn, args = build_cell(arch, shape_name, mesh, opt=opt)
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        rec.update(analyze(compiled, lowered))
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", type=int, default=0,
                    help="perf-iteration level (1: TP-only serving params, "
                         "2: + sequence-parallel KV decode)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(a, s) for a in ARCHS for s in cells(a)]
        todo += [(EDM_ARCH, s) for s in EDM_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    for arch, shape_name in todo:
        for mesh_kind in meshes:
            suffix = f"__opt{args.opt}" if args.opt else ""
            name = f"{arch}__{shape_name}__{mesh_kind}{suffix}"
            path = os.path.join(args.out, name + ".json")
            rec = run_cell(arch, shape_name, mesh_kind, opt=args.opt)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            cost = rec.get("cost", {})
            print(f"[dryrun] {name}: {rec['status']} "
                  f"lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s "
                  f"flops={cost.get('flops', 0):.3e} "
                  f"coll={rec.get('collectives', {}).get('total', 0):.3e}B",
                  flush=True)
            if rec["status"] != "ok":
                print(rec["error"], flush=True)


if __name__ == "__main__":
    main()
