"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the batched prefill/decode engine on a smoke-sized model (CPU); the
full-config serve_step is exercised by the decode_32k / long_500k
dry-run cells on the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, SKIP_CELLS, get_config
from repro.models import transformer as tf
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    decodable = [a for a in ARCHS
                 if "decode_32k" not in SKIP_CELLS.get(a, set())]
    ap.add_argument("--arch", default="llama3-8b", choices=decodable)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = tf.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, s_max=128)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(3, 10)))))
               for _ in range(args.requests)]
    t0 = time.time()
    res = engine.generate(prompts, max_new=args.max_new,
                          temperature=args.temperature)
    dt = time.time() - t0
    new = sum(len(o) - len(p) for o, p in zip(res.tokens, prompts))
    print(f"[serve] arch={cfg.name} batch={len(prompts)} "
          f"generated={new}tok in {dt:.2f}s")
    for p, o in zip(prompts, res.tokens):
        print(f"  {p} → {o[len(p):]}")


if __name__ == "__main__":
    main()
