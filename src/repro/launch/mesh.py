"""Production meshes. Functions only — importing this module must never
touch jax device state (dry-runs set device-count env vars first)."""

from __future__ import annotations

import jax

from repro.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16, 16) over ("data", "model") — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) over ("pod", "data", "model") — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh):
    """The data-parallel axes (pod folds into data on multi-pod meshes)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: jax.sharding.Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CPU multi-device tests (subprocess with forced
    host device count)."""
    return _make_mesh(shape, axes)
