"""Serving benchmarks: continuous batching + incremental library append.

Three sections, matching the PR-8 acceptance criteria:

  * **append-merge vs cold rebuild** — growing a warm session's multi-E
    kNN master by Δt points via ``plan.panel_master_append`` (the
    O(Lp·(k+Δt))-per-level stream-in merge) against rebuilding it from
    scratch with ``plan.panel_master`` (O(Lp²)). At Lp = 4096 the merge
    must be ≥5× faster for every Δt ≤ 64 — the bench *fails* otherwise.
    (The merge is bit-identical to the rebuild; tests/test_master_append
    owns that contract, this file owns the speed claim.)
  * **saturated compatible queue** — N·(N−1) same-signature CCM
    requests are pre-loaded into an ``EDMServer`` queue and drained;
    coalescing must sustain ≥0.8× the pairs/s of driving the warm
    batched engine (``EDM.ccm_batch`` over the same pairs) directly —
    i.e. the scheduler may cost at most 20% on top of the engine it
    feeds. The bench fails below that ratio.
  * **multi-panel worker pool** — 4 panels' worth of compatible CCM
    bursts drained by the 4-worker pool vs the same load through a
    single drain worker (the PR-8 architecture, ``workers=1``). Distinct
    panels execute concurrently in the pool, so with ≥2 usable cores the
    aggregate pairs/s must be ≥2× the single-drain baseline — the bench
    *fails* otherwise. On a 1-core host (CI containers; parallel
    speedup is physically impossible) the row is tagged
    ``degraded_1core`` and the gate degrades to "pooling must not
    regress" (≥0.85× single drain) — an honest gate beats a vacuous one.
  * **concurrency sweep** — req/s and p50/p99 latency with 1/4/16
    threaded clients issuing blocking compatible CCM calls against the
    live worker, plus the mean batch occupancy the scheduler achieved
    at each offered concurrency (from the ``serve_batch_occupancy_hist``
    telemetry histogram) — the continuous-batching curve: occupancy
    should grow with concurrency while per-request latency stays flat.
  * **WAL overhead** (``--wal-overhead``, PR 10) — append ticks on a
    durable server (``state_dir=``: frame + write + flush per accepted
    delta) vs the identical load on an in-memory server. Durability must
    cost ≤10% append throughput — the bench *fails* below 0.9×.

Derived columns: merge speedup vs rebuild, served pairs/s and the ratio
vs the warm engine, req/s with latency percentiles and occupancy,
durable vs in-memory append ticks/s.
"""

from __future__ import annotations

import itertools
import os
import threading
import time

import numpy as np

from benchmarks.common import row, time_fn
from repro import telemetry
from repro.data.timeseries import tent_map_panel
from repro.edm import plan
from repro.serving import EDMServer

# Append-merge section: Lp = 4096 exactly (the acceptance shape).
E_MAX, TAU, K_M = 3, 1, 8
L_OLD = 4096 + (E_MAX - 1) * TAU
DTS = (1, 16, 64)
MIN_SPEEDUP = 5.0

# Queue sections: the bench_ccm-shaped panel.
N_SERIES, L_SERVE, E_SERVE = 24, 4096, 3
MIN_RATIO = 0.8
CLIENT_COUNTS = (1, 4, 16)
REQS_PER_CLIENT = 30

# Multi-panel section: 4 panels, pooled drain vs single drain.
N_MP, L_MP, PANELS_MP = 12, 2048, 4
MIN_MP_SPEEDUP = 2.0   # with >= 2 usable cores: pool must parallelize
MIN_MP_1CORE = 0.85    # 1-core host: pooling must at least not regress


def _run_append_vs_rebuild():
    rng = np.random.default_rng(0)
    x_new = rng.standard_normal((1, L_OLD + max(DTS))).astype(np.float32)
    failures = []
    for dt in DTS:
        grown = x_new[:, : L_OLD + dt]
        dM, iM = plan.panel_master(grown[:, :L_OLD], E_max=E_MAX, tau=TAU,
                                   k=K_M, impl="auto")
        t_cold = time_fn(
            lambda g=grown: plan.panel_master(g, E_max=E_MAX, tau=TAU,
                                              k=K_M, impl="auto"),
            warmup=1, iters=3, stat="min")
        t_merge = time_fn(
            lambda g=grown, d=dM, i=iM: plan.panel_master_append(
                g, d, i, tau=TAU, impl="auto"),
            warmup=1, iters=3, stat="min")
        speedup = t_cold / t_merge
        row(f"serve/append_merge_dt{dt}", t_merge,
            f"{speedup:.1f}x_vs_rebuild_Lp4096")
        row(f"serve/cold_rebuild_dt{dt}", t_cold, f"L{L_OLD + dt}")
        if speedup < MIN_SPEEDUP:
            failures.append(
                f"dt={dt}: merge only {speedup:.1f}x vs rebuild "
                f"(acceptance >= {MIN_SPEEDUP}x)")
    if failures:
        raise SystemExit("append-merge too slow: " + "; ".join(failures))


def _all_pairs():
    return [(i, j) for i, j in itertools.product(range(N_SERIES), repeat=2)
            if i != j]


def _register(srv, panel):
    srv.register_panel("bench", panel, E_max=E_SERVE, cache=True)
    return srv.registry.get("bench").sess


def _run_saturated_queue():
    panel = tent_map_panel(N_SERIES, L_SERVE, seed=7)
    pairs = _all_pairs()
    # max_batch > queue depth: at saturation the whole compatible queue
    # rides one launch — the continuous-batching limit this row claims.
    with EDMServer(autostart=False, max_batch=len(pairs) + 8) as srv:
        sess = _register(srv, panel)
        sess.optimal_E()  # warm: master build off the timed path

        plist = [{"lib": l, "target": t, "E": E_SERVE} for l, t in pairs]

        def serve_all():
            futs = srv.submit_many("ccm", "bench", plist)
            while srv.scheduler.drain_once():
                pass
            return np.asarray([f.result() for f in futs])

        def engine_all():
            return sess.ccm_batch(pairs, E=E_SERVE)

        # Alternate the two measurements round-robin and take each side's
        # min: noise (this is a shared box) only ever slows a round down,
        # and alternating keeps slow phases from landing on one side.
        # Extra rounds past the first 7 only run while the ratio estimate
        # is still below target — min-estimates only sharpen with rounds.
        serve_all(), engine_all()  # warm both paths
        t_serve = t_engine = np.inf
        for i in range(21):
            if i >= 7 and t_serve <= t_engine / MIN_RATIO:
                break
            t0 = time.perf_counter()
            serve_all()
            t1 = time.perf_counter()
            engine_all()
            t2 = time.perf_counter()
            t_serve = min(t_serve, (t1 - t0) * 1e6)
            t_engine = min(t_engine, (t2 - t1) * 1e6)
    served_ps = len(pairs) / (t_serve / 1e6)
    engine_ps = len(pairs) / (t_engine / 1e6)
    ratio = served_ps / engine_ps
    row("serve/saturated_ccm_queue", t_serve,
        f"{served_ps:.0f}pairs_per_s_{ratio:.2f}x_warm_engine")
    row("serve/warm_engine_direct", t_engine, f"{engine_ps:.0f}pairs_per_s")
    if ratio < MIN_RATIO:
        raise SystemExit(
            f"saturated queue sustains only {ratio:.2f}x the warm batched "
            f"engine (acceptance >= {MIN_RATIO}x)")


def _usable_cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


def _run_multi_panel():
    """4-panel aggregate throughput: worker pool vs single drain.

    The gate is core-aware on purpose: distinct panels drain on distinct
    worker threads, so the ≥2× aggregate claim only holds where ≥2 cores
    can actually run them — on a 1-core host the same row asserts the
    pool costs at most 15% over the serial drain (no-regression), tagged
    ``degraded_1core`` so dashboards never mistake it for the parallel
    measurement.
    """
    panels = {f"mp{i}": tent_map_panel(N_MP, L_MP, seed=20 + i)
              for i in range(PANELS_MP)}
    pairs = [(i, j) for i, j in itertools.product(range(N_MP), repeat=2)
             if i != j]
    plist = [{"lib": l, "target": t, "E": E_SERVE} for l, t in pairs]

    def burst(srv):
        futs = [f for name in panels
                for f in srv.submit_many("ccm", name, plist)]
        for f in futs:
            f.result()

    with EDMServer(autostart=True, workers=PANELS_MP,
                   max_batch=len(pairs) + 8) as pooled, \
         EDMServer(autostart=True, workers=1,
                   max_batch=len(pairs) + 8) as single:
        for srv in (pooled, single):
            for name, x in panels.items():
                srv.register_panel(name, x, E_max=E_SERVE, cache=True)
            burst(srv)  # warm: masters + jit off the timed path
        target = (MIN_MP_SPEEDUP if _usable_cores() >= 2 else MIN_MP_1CORE)
        # Alternate and take mins, same rationale as the saturated row.
        t_pool = t_single = np.inf
        for i in range(15):
            if i >= 5 and t_pool <= t_single / target:
                break
            t0 = time.perf_counter()
            burst(pooled)
            t1 = time.perf_counter()
            burst(single)
            t2 = time.perf_counter()
            t_pool = min(t_pool, (t1 - t0) * 1e6)
            t_single = min(t_single, (t2 - t1) * 1e6)
    agg = PANELS_MP * len(pairs)
    ratio = t_single / t_pool
    tag = (f"{agg / (t_pool / 1e6):.0f}pairs_per_s_{ratio:.2f}"
           f"x_single_drain")
    if _usable_cores() < 2:
        tag += "_degraded_1core"
    row(f"serve/multi_panel_pool{PANELS_MP}", t_pool, tag)
    row("serve/multi_panel_single_drain", t_single,
        f"{agg / (t_single / 1e6):.0f}pairs_per_s")
    if ratio < target:
        raise SystemExit(
            f"multi-panel pool sustains only {ratio:.2f}x the single "
            f"drain on {_usable_cores()} usable core(s) "
            f"(acceptance >= {target}x)")


#: WAL-on append throughput must stay within 10% of WAL-off.
MIN_WAL_RATIO = 0.9
N_WAL, L_WAL, DT_WAL, T_WAL = 8, 512, 4, 32


def _run_wal_overhead():
    """Durable vs in-memory append ticks (the ``--wal-overhead`` gate).

    Each round registers a fresh panel and drives ``T_WAL`` append ticks
    through ``drain_once``; the WAL-on side additionally frames, writes
    and flushes every delta before its future resolves (no per-record
    fsync — the default durability posture). The gate: durable append
    throughput ≥ ``MIN_WAL_RATIO``× the WAL-off server. Registration
    (base.npy + fsyncs) is off the timed path — it is per-panel, not
    per-tick.
    """
    import shutil
    import tempfile

    rng = np.random.default_rng(5)
    panel = rng.standard_normal((N_WAL, L_WAL)).astype(np.float32)
    deltas = [rng.standard_normal((N_WAL, DT_WAL)).astype(np.float32)
              for _ in range(T_WAL)]

    def one_round(state_dir):
        with EDMServer(autostart=False, state_dir=state_dir) as srv:
            srv.register_panel("w", panel, E_max=E_SERVE, cache=True)
            t0 = time.perf_counter()
            for d in deltas:
                fut = srv.submit("append", "w", delta=d)
                srv.scheduler.drain_once()
            dt = time.perf_counter() - t0
            assert fut.result()["version"] == T_WAL
            return dt

    def wal_round():
        sd = tempfile.mkdtemp(prefix="edm-walbench-")
        try:
            return one_round(sd)
        finally:
            shutil.rmtree(sd, ignore_errors=True)

    one_round(None), wal_round()  # warm both paths (jit, allocator)
    t_off = t_on = np.inf
    for i in range(15):
        if i >= 5 and t_on <= t_off / MIN_WAL_RATIO:
            break
        t_on = min(t_on, wal_round() * 1e6)
        t_off = min(t_off, one_round(None) * 1e6)
    ratio = t_off / t_on
    row("serve/append_wal_on", t_on / T_WAL,
        f"{T_WAL / (t_on / 1e6):.0f}ticks_per_s_{ratio:.2f}x_wal_off")
    row("serve/append_wal_off", t_off / T_WAL,
        f"{T_WAL / (t_off / 1e6):.0f}ticks_per_s")
    if ratio < MIN_WAL_RATIO:
        raise SystemExit(
            f"WAL-on appends sustain only {ratio:.2f}x the WAL-off "
            f"server (acceptance >= {MIN_WAL_RATIO}x)")


def _run_concurrency_sweep():
    panel = tent_map_panel(N_SERIES, L_SERVE, seed=7)
    pairs = _all_pairs()
    hist = telemetry.histogram("serve_batch_occupancy_hist")
    with EDMServer(autostart=True, max_batch=64) as srv:
        _register(srv, panel)
        srv.call("ccm", "bench", lib=0, target=1, E=E_SERVE)  # warm
        for c in CLIENT_COUNTS:
            lat_ms: list[float] = []
            lock = threading.Lock()

            def client(cid, out=lat_ms):
                mine = pairs[cid::max(CLIENT_COUNTS)]
                local = []
                for l, t in itertools.islice(
                        itertools.cycle(mine), REQS_PER_CLIENT):
                    t0 = time.perf_counter()
                    srv.call("ccm", "bench", lib=l, target=t, E=E_SERVE)
                    local.append((time.perf_counter() - t0) * 1e3)
                with lock:
                    out.extend(local)

            sum0, cnt0 = hist.sum, hist.count
            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(c)]
            t0 = time.perf_counter()
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            wall = time.perf_counter() - t0
            occ = ((hist.sum - sum0) / max(hist.count - cnt0, 1))
            n = c * REQS_PER_CLIENT
            p50, p99 = np.percentile(lat_ms, [50, 99])
            row(f"serve/clients_c{c}", wall * 1e6 / n,
                f"{n / wall:.0f}req_per_s_p50_{p50:.1f}ms_p99_{p99:.1f}"
                f"ms_occ_{occ:.1f}")


def run():
    import sys
    _run_append_vs_rebuild()
    _run_saturated_queue()
    _run_multi_panel()
    _run_concurrency_sweep()
    if "--wal-overhead" in sys.argv:
        _run_wal_overhead()


if __name__ == "__main__":
    run()
