"""ISSUE 3: session-facade overhead + cached-kNN CCM reuse.

Two claims the ``repro.edm`` session must honor:

* dispatching through the facade (plan build, config binding, cache
  bookkeeping, result delivery) costs <2% over calling the underlying
  jitted free function directly at L=4096. The facade layer is timed
  *directly* — session construction plus a warm-cache dispatch, which
  runs every python/facade instruction and zero kernel work — because
  the ~200ms L=4096 compute itself jitters ±10% on a shared CPU,
  swamping any end-to-end A/B of a sub-millisecond overhead;
* an all-pairs CCM on a panel whose session already ran ``optimal_E``
  (kNN master tables hot) beats a cold legacy run that recomputes
  pairwise distances + top-k per library per E-group.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common

L_OVERHEAD = 4096
E_MAX = 8
PANEL_N = 6
PANEL_L = 1024


def run() -> None:
    import jax.numpy as jnp

    from repro.core.simplex import optimal_E_batch
    from repro.edm import EDM, EDMConfig

    from repro.kernels import ops

    rng = np.random.default_rng(0)

    # -------- facade overhead: session dispatch vs the direct free call
    X1 = jnp.asarray(rng.standard_normal((1, L_OVERHEAD)).astype(np.float32))
    cfg = EDMConfig(E_max=E_MAX)
    impl = ops.resolve_impl("auto")  # same static key the session passes

    def direct():
        return optimal_E_batch(X1, E_max=E_MAX, impl=impl)

    t_direct = common.time_fn(direct, warmup=2, iters=7, stat="min")

    warm = EDM(X1, cfg)
    warm.optimal_E()  # populate the rho cache

    def facade_layer():  # every facade instruction, zero kernel work:
        EDM(X1, cfg)     #   bind panel + validate config
        return warm.optimal_E()  # cached dispatch + result delivery

    t_layer = common.time_fn(facade_layer, warmup=2, iters=20, stat="min")
    pct = 100.0 * t_layer / t_direct
    common.row("edm_optimal_E_direct", t_direct, f"L={L_OVERHEAD}")
    common.row("edm_facade_layer", t_layer,
               f"facade_overhead_pct={pct:.3f} (budget 2%)")

    # -------- cached-kNN CCM panel vs cold legacy recompute
    Xp = jnp.asarray(
        rng.standard_normal((PANEL_N, PANEL_L)).astype(np.float32))
    cold_sess = EDM(Xp, EDMConfig(E_max=E_MAX, cache=False))
    E_opt, _ = cold_sess.optimal_E()  # also the E table both paths use

    def cold():  # legacy path: pairwise + top-k per library per E-group
        return cold_sess.xmap(E_opt=E_opt)

    warm_sess = EDM(Xp, EDMConfig(E_max=E_MAX))
    warm_sess.optimal_E()  # builds the kNN master the xmap will reuse

    def cached():  # session path: derive tables from the hot kNN master
        return warm_sess.xmap(E_opt=E_opt)

    t_cold = common.time_fn(cold, warmup=1, iters=3)
    t_cached = common.time_fn(cached, warmup=1, iters=3)
    groups = len(set(E_opt.tolist()))
    common.row("edm_ccm_panel_cold", t_cold,
               f"N={PANEL_N} L={PANEL_L} E_groups={groups}")
    common.row("edm_ccm_panel_cached", t_cached,
               f"cached_vs_cold_speedup={t_cold / t_cached:.2f}x")


if __name__ == "__main__":
    run()
