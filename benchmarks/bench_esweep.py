"""Optimal-E sweep: seed per-E pipeline vs incremental multi-E engine.

The acceptance benchmark for the one-pass sweep (ISSUE 1): the seed
``optimal_E_batch`` re-runs pairwise+top-k per E — O(ΣE·Lp²) — while the
multi-E engine exploits D_E = D_{E-1} + one rank-1 lag term to emit every
per-E neighbor table in one O(E_max·Lp²) pass (kernels/knn_multi_e.py).
Derived column records the speedup; run.py writes it to BENCH_esweep.json
so the perf trajectory is machine-readable across PRs.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro import core
from repro.data.timeseries import tent_map_panel

L = 4096
E_MAX = 20


def run():
    x = jnp.asarray(tent_map_panel(1, L, seed=0)[0])
    old = functools.partial(core.optimal_E_sweep_seed, x, E_max=E_MAX,
                            tau=1, Tp=1, impl="ref")
    new = functools.partial(core.rho_curve, x, E_max=E_MAX, tau=1, Tp=1,
                            impl="ref")
    us_old = time_fn(old, warmup=1, iters=5, stat="min")
    us_new = time_fn(new, warmup=1, iters=5, stat="min")
    row(f"esweep_seed_perE_L{L}_E{E_MAX}", us_old,
        f"O(sumE_Lp2)_{E_MAX}_pipelines")
    row(f"esweep_multiE_L{L}_E{E_MAX}", us_new,
        f"O(Emax_Lp2)_one_pass_speedup{us_old / us_new:.2f}x")
