"""Optimal-E sweep: seed per-E pipeline vs incremental multi-E engine.

The acceptance benchmark for the one-pass sweep (ISSUE 1): the seed
``optimal_E_batch`` re-runs pairwise+top-k per E — O(ΣE·Lp²) — while the
multi-E engine exploits D_E = D_{E-1} + one rank-1 lag term to emit every
per-E neighbor table in one O(E_max·Lp²) pass (kernels/knn_multi_e.py).
Derived column records the speedup; run.py writes it to BENCH_esweep.json
so the perf trajectory is machine-readable across PRs.

NOTE (chunked top-k, ISSUE 2 satellite): ``ref.topk_select`` now routes
through the exact two-stage chunk-max prefilter (``ref._chunked_topk``) —
deferred from PR 1 so the recorded esweep baseline stayed the untouched
seed pipeline. The ``topk_plain`` / ``topk_chunked`` rows below record the
before/after of that selection step in isolation; the ``esweep_seed_perE``
row (whose per-E pipeline calls topk_select) now includes the benefit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro import core
from repro.kernels import ref
from repro.data.timeseries import tent_map_panel

L = 4096
E_MAX = 20
TOPK_K = 21  # E_max + 1: the largest simplex k the CCM pipeline requests


def run():
    x = jnp.asarray(tent_map_panel(1, L, seed=0)[0])
    old = functools.partial(core.optimal_E_sweep_seed, x, E_max=E_MAX,
                            tau=1, Tp=1, impl="ref")
    new = functools.partial(core.rho_curve, x, E_max=E_MAX, tau=1, Tp=1,
                            impl="ref")
    us_old = time_fn(old, warmup=1, iters=5, stat="min")
    us_new = time_fn(new, warmup=1, iters=5, stat="min")
    row(f"esweep_seed_perE_L{L}_E{E_MAX}", us_old,
        f"O(sumE_Lp2)_{E_MAX}_pipelines")
    row(f"esweep_multiE_L{L}_E{E_MAX}", us_new,
        f"O(Emax_Lp2)_one_pass_speedup{us_old / us_new:.2f}x")

    # Chunked top-k before/after on the selection step alone (same masked
    # matrix both ways; plain = the seed's full-row jax.lax.top_k).
    D = ref.pairwise_distances(x, E=3, tau=1)

    @jax.jit
    def plain(D):
        nd, ik = jax.lax.top_k(-D, TOPK_K)
        return jnp.sqrt(jnp.maximum(-nd, 0.0)), ik

    chunked = functools.partial(ref.topk_select, D, k=TOPK_K,
                                exclude_self=False)
    us_plain = time_fn(lambda: plain(D), warmup=1, iters=5, stat="min")
    us_chunk = time_fn(chunked, warmup=1, iters=5, stat="min")
    row(f"topk_plain_L{L}_k{TOPK_K}", us_plain, "seed_full_row_lax_top_k")
    row(f"topk_chunked_L{L}_k{TOPK_K}", us_chunk,
        f"two_stage_chunk_max_speedup{us_plain / us_chunk:.2f}x"
        "_now_default_in_topk_select")
