"""Paper Figs. 4–5: batched lookup runtime vs embedding dimension.

N target series share one library's neighbor tables (the paper's batched
formulation); both the plain lookup and the fused-ρ variant (the paper's
on-the-fly correlation path, which never materializes predictions) are
timed. Derived: effective bandwidth of the gather phase.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.data.timeseries import tent_map_panel
from repro.kernels import ops

L = 4096
N = 512
E_SWEEP = (1, 5, 10, 15, 20)


def run():
    panel = jnp.asarray(tent_map_panel(N + 1, L, seed=1))
    x, Y = panel[0], panel[1:]
    for E in E_SWEEP:
        k = E + 1
        off = E - 1
        d, i = ops.all_knn(x, E=E, tau=1, k=k, impl="ref")
        w = ops.make_weights(d)
        rows = i.shape[0]

        look = functools.partial(ops.lookup, Y, i, w, offset=off, impl="ref")
        us = time_fn(look)
        bytes_moved = 4.0 * N * rows * (k + 1)  # gathers + store
        row(f"lookup_E{E}", us, f"{bytes_moved / us / 1e3:.2f}GBps_N{N}")

        fused = functools.partial(ops.lookup_rho, Y, i, w, offset=off,
                                  impl="ref")
        us_f = time_fn(fused)
        row(f"lookup_rho_E{E}", us_f,
            f"fused_vs_plain_{us / max(us_f, 1e-9):.2f}x")
