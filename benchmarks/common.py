"""Shared benchmark utilities: wall-clock timing of jitted callables."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro import telemetry


def time_fn(fn, *args, warmup: int = 1, iters: int = 5,
            stat: str = "median") -> float:
    """Wall-time (µs) of fn(*args) with block_until_ready.

    ``stat="median"`` for throughput-style rows; ``stat="min"`` for
    noise-immune comparisons (the min is the least contaminated estimate
    of intrinsic cost on a shared machine — cf. timeit's docs).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    if stat not in ("min", "median"):
        raise ValueError(f"unknown stat {stat!r}")
    agg = np.min if stat == "min" else np.median
    return float(agg(times) * 1e6)


_ROWS: list[dict] = []  # rows since the last drain (run.py → JSON artifact)


def row(name: str, us: float, derived: str):
    """Record one bench result row (CSV line + JSON artifact row).

    Rows also publish through the telemetry registry — a
    ``bench_<name>_us`` gauge plus a ``bench.row`` event — so bench runs
    and production runs share one observability surface
    (``telemetry.render_prom()`` exports both).
    """
    print(f"{name},{us:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(us, 1),
                  "derived": derived})
    telemetry.gauge(f"bench_{name}_us").set(us)
    telemetry.event("bench.row", row=name, us_per_call=round(us, 1),
                    derived=derived)


def drain_rows() -> list[dict]:
    """Return and clear the rows recorded since the last drain."""
    rows = list(_ROWS)
    _ROWS.clear()
    return rows
