"""Shared benchmark utilities: wall-clock timing of jitted callables."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
