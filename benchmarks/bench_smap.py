"""S-Map θ-sweep: seed per-query lstsq loop vs batched Gram/Cholesky engine.

The acceptance benchmark for the S-Map engine (ISSUE 2): the seed path
pays one host-sequential ``lstsq`` per (query row, θ) over √W-scaled
design-matrix copies — S·|θ|·rows solves for a panel — while the engine
accumulates every (row, θ) pair's (E+1, E+1) weighted Gram matrix in one
pass (kernels/smap_gram.py) and batch-solves all the ridge normal
equations with one Cholesky (core/smap_engine.py). Derived column records
the speedup; run.py writes BENCH_smap.json so the perf trajectory is
machine-readable across PRs.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro import core
from repro.data.timeseries import tent_map_panel

L = 4096
E = 2
THETAS = (0.0, 0.1, 0.3, 0.5, 1.0, 2.0, 4.0, 8.0)


def run():
    x = jnp.asarray(tent_map_panel(1, L, seed=0)[0])

    def seed_sweep():
        # The seed nonlinearity test: re-enter the per-query solve loop
        # once per θ (jitted once; θ is a traced scalar).
        return jnp.stack([core.smap_predict_seed(x, E=E, tau=1, Tp=1,
                                                 theta=t)[0]
                          for t in THETAS])

    new = functools.partial(core.smap_theta_sweep, x[None, :], E=E, tau=1,
                            Tp=1, thetas=THETAS, impl="ref")
    us_old = time_fn(seed_sweep, warmup=1, iters=3, stat="min")
    us_new = time_fn(new, warmup=1, iters=3, stat="min")
    row(f"smap_seed_lstsq_L{L}_E{E}_T{len(THETAS)}",
        us_old, f"per_query_lstsq_{len(THETAS)}x{L - E}_solves")
    row(f"smap_engine_L{L}_E{E}_T{len(THETAS)}",
        us_new, f"batched_gram_cho_solve_speedup{us_old / us_new:.2f}x")

    # The new S-Map causality workload: one library × 8 targets per call.
    Y = jnp.asarray(tent_map_panel(8, L, seed=1))
    xmap = functools.partial(core.smap_cross_map, x, Y, E=E, theta=2.0,
                             impl="ref")
    us_xmap = time_fn(xmap, warmup=1, iters=3, stat="min")
    row(f"smap_xmap_L{L}_E{E}_N8", us_xmap, "smap_ccm_8_targets_one_call")
