"""Paper Figs. 2–3: all-kNN search runtime breakdown vs embedding dim.

Pairwise-distance and top-k phases timed separately across E, on a
synthetic series (CPU-scaled from the paper's L=10⁴). Derived column:
effective GFLOP/s for the distance phase, Melem/s scanned for top-k —
the paper's finding is that both phases are bandwidth-, not compute-,
limited, with pairwise arithmetic intensity rising with E.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.data.timeseries import tent_map_panel
from repro.kernels import ops

L = 4096
E_SWEEP = (1, 5, 10, 15, 20)


def run():
    x = jnp.asarray(tent_map_panel(1, L, seed=0)[0])
    for E in E_SWEEP:
        Lp = L - (E - 1)
        k = E + 1
        pair = functools.partial(ops.pairwise_distances, x, E=E, tau=1,
                                 impl="ref")
        us_pair = time_fn(pair)
        flops = 3.0 * E * Lp * Lp  # sub, mul, add per (i, j, k)
        row(f"knn_pairwise_E{E}", us_pair,
            f"{flops / us_pair / 1e3:.1f}GFLOPs_L{L}")

        D = pair()
        topk = functools.partial(ops.topk_select, D, k=k, impl="ref")
        us_topk = time_fn(topk)
        row(f"knn_topk_E{E}", us_topk,
            f"{Lp * Lp / us_topk:.0f}Melem_per_s_k{k}")
