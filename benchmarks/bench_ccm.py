"""Paper Table 1: pairwise CCM wall-time on dataset-shaped workloads.

The six real microscopy/expression datasets are not shippable; each is
replaced by a synthetic panel with the same *aspect* (many-short /
few-long / balanced), CPU-scaled by the stated factor so the single-core
container finishes in seconds. Derived column: cross-map pairs per
second, and the scale factor back to the paper's shape.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro import core
from repro.data.timeseries import tent_map_panel

# name, paper (N, L), scaled (N, L), E
DATASETS = [
    ("Fish1_Normo", (154, 1600), (154, 1600), 3),  # full scale
    ("Fly80XY", (82, 10608), (82, 2048), 3),
    ("Genes_MEF", (45318, 96), (1024, 96), 3),
    ("Subject6", (92538, 3780), (192, 1024), 3),
    ("Subject11", (101729, 8528), (128, 2048), 3),
    ("F1", (8520, 29484), (64, 4096), 3),
]


def run():
    for name, paper_shape, (N, L), E in DATASETS:
        panel = jax.numpy.asarray(tent_map_panel(N, L, seed=7))
        E_opt = np.full(N, E, np.int32)
        t0 = time.perf_counter()
        rho = core.ccm_matrix(panel, E_opt, impl="ref")
        dt = time.perf_counter() - t0
        pairs = N * N
        scale = (paper_shape[0] / N) ** 2 * max(paper_shape[1] / L, 1.0)
        row(f"ccm_{name}", dt * 1e6,
            f"{pairs / dt:.0f}pairs_per_s_scale{scale:.0f}x_"
            f"meanrho{float(np.mean(rho)):.3f}")
