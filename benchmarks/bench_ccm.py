"""Paper Table 1: pairwise CCM wall-time on dataset-shaped workloads,
plus the ISSUE 4 convergence-sweep comparison (seed per-size re-scan loop
vs the one-pass multi-cap streaming engine).

The six real microscopy/expression datasets are not shippable; each is
replaced by a synthetic panel with the same *aspect* (many-short /
few-long / balanced), CPU-scaled by the stated factor so the single-core
container finishes in seconds. Derived column: cross-map pairs per
second, and the scale factor back to the paper's shape.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row, time_fn
from repro import core
from repro.data.timeseries import tent_map_panel

# name, paper (N, L), scaled (N, L), E
DATASETS = [
    ("Fish1_Normo", (154, 1600), (154, 1600), 3),  # full scale
    ("Fly80XY", (82, 10608), (82, 2048), 3),
    ("Genes_MEF", (45318, 96), (1024, 96), 3),
    ("Subject6", (92538, 3780), (192, 1024), 3),
    ("Subject11", (101729, 8528), (128, 2048), 3),
    ("F1", (8520, 29484), (64, 4096), 3),
]


def _run_convergence():
    """ISSUE 4 acceptance: the batched convergence engine vs the seed
    per-size loop at L=4096, |sizes|=8 (one target, E=3). Both paths
    share the pairwise-distance pass; the seed loop re-scans the full
    matrix through top-k once per size, the engine streams it once
    through the multi-cap top-k and runs every lookup in one program.
    """
    L = 4096
    sizes = (64, 128, 256, 512, 1024, 2048, 3072, 4094)
    panel = jax.numpy.asarray(tent_map_panel(2, L, seed=7))
    lib, tgt = panel[0], panel[1:2]

    def seed_loop():
        return core.cross_map_sizes_seed(
            lib, tgt, E=3, lib_sizes=sizes, impl="ref")

    def engine():
        return core.ccm_convergence(
            lib, tgt, E=3, lib_sizes=sizes, impl="ref")

    np.testing.assert_array_equal(  # the comparison is only fair if
        np.asarray(seed_loop()), np.asarray(engine()))  # it's bit-equal
    t_seed = time_fn(seed_loop, iters=3, stat="min")
    t_new = time_fn(engine, iters=3, stat="min")
    row("ccm_conv_seed_L4096_S8", t_seed, "per_size_topk_rescan_loop")
    row("ccm_conv_engine_L4096_S8", t_new,
        f"one_pass_multi_cap_topk_speedup{t_seed / t_new:.2f}x")


def run():
    _run_convergence()
    for name, paper_shape, (N, L), E in DATASETS:
        panel = jax.numpy.asarray(tent_map_panel(N, L, seed=7))
        E_opt = np.full(N, E, np.int32)
        t0 = time.perf_counter()
        rho = core.ccm_matrix(panel, E_opt, impl="ref")
        dt = time.perf_counter() - t0
        pairs = N * N
        scale = (paper_shape[0] / N) ** 2 * max(paper_shape[1] / L, 1.0)
        row(f"ccm_{name}", dt * 1e6,
            f"{pairs / dt:.0f}pairs_per_s_scale{scale:.0f}x_"
            f"meanrho{float(np.mean(rho)):.3f}")
