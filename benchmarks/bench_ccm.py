"""Paper Table 1: pairwise CCM wall-time on dataset-shaped workloads.

Three sections:
  * the ISSUE 4 convergence-sweep comparison (seed per-size re-scan loop
    vs the one-pass multi-cap streaming engine),
  * the ISSUE 5 library-batched matrix engine vs the legacy per-series
    ``lax.map`` path at (Lp, Nl) grid points, with the batch-axis
    bit-parity contract asserted (batched ≡ the per-series B = 1 oracle
    launch) — pass ``--sweep-batch`` for the full pairs/s-vs-B curve;
    ``--resume-overhead`` adds the ISSUE 6 journaling-cost row (a
    ``run_dir=`` xmap must stay within 5% of the plain engine),
  * the six dataset-shaped rows, whose headline metric is cross-map
    pairs per second. A committed BENCH_ccm.json is the regression
    guard: the run fails if any dataset's pairs/s drops more than 30%
    below the committed row after calibrating for machine speed (the
    fixed legacy-path grid rows, re-measured every run, anchor how fast
    this box is relative to the committed run). CI runs this smoke on
    every push.

The six real microscopy/expression datasets are not shippable; each is
replaced by a synthetic panel with the same *aspect* (many-short /
few-long / balanced), CPU-scaled by the stated factor so the single-core
container finishes in seconds. Derived column: cross-map pairs per
second, the scale factor back to the paper's shape, and the library
batch size B the engine chose.
"""

from __future__ import annotations

import json
import pathlib
import re
import sys
import time

import jax
import numpy as np

from benchmarks.common import row, time_fn
from repro import core
from repro.data.timeseries import tent_map_panel

# name, paper (N, L), scaled (N, L), E
DATASETS = [
    ("Fish1_Normo", (154, 1600), (154, 1600), 3),  # full scale
    ("Fly80XY", (82, 10608), (82, 2048), 3),
    ("Genes_MEF", (45318, 96), (1024, 96), 3),
    ("Subject6", (92538, 3780), (192, 1024), 3),
    ("Subject11", (101729, 8528), (128, 2048), 3),
    ("F1", (8520, 29484), (64, 4096), 3),
]

#: Max tolerated pairs/s regression vs the committed artifact (CI guard).
GUARD_FRACTION = 0.7

_ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_ccm.json"


def _run_convergence():
    """ISSUE 4 acceptance: the batched convergence engine vs the seed
    per-size loop at L=4096, |sizes|=8 (one target, E=3). Both paths
    share the pairwise-distance pass; the seed loop re-scans the full
    matrix through top-k once per size, the engine streams it once
    through the multi-cap top-k and runs every lookup in one program.
    """
    L = 4096
    sizes = (64, 128, 256, 512, 1024, 2048, 3072, 4094)
    panel = jax.numpy.asarray(tent_map_panel(2, L, seed=7))
    lib, tgt = panel[0], panel[1:2]

    def seed_loop():
        return core.cross_map_sizes_seed(
            lib, tgt, E=3, lib_sizes=sizes, impl="ref")

    def engine():
        return core.ccm_convergence(
            lib, tgt, E=3, lib_sizes=sizes, impl="ref")

    np.testing.assert_array_equal(  # the comparison is only fair if
        np.asarray(seed_loop()), np.asarray(engine()))  # it's bit-equal
    t_seed = time_fn(seed_loop, iters=3, stat="min")
    t_new = time_fn(engine, iters=3, stat="min")
    row("ccm_conv_seed_L4096_S8", t_seed, "per_size_topk_rescan_loop")
    row("ccm_conv_engine_L4096_S8", t_new,
        f"one_pass_multi_cap_topk_speedup{t_seed / t_new:.2f}x")


#: (Lp, Nl) grid for the old-vs-new engine audit / --sweep-batch curves.
GROUP_GRID = [(48, 1024, 3), (256, 256, 3)]


def _run_group_engine(sweep_batch: bool) -> dict[str, float]:
    """ISSUE 5 tentpole rows: legacy per-series ``lax.map`` ``ccm_group``
    vs the library-batched engine, with the batch-axis layout contract
    asserted — the batched run is bit-identical to the per-series
    (B = 1) oracle launches of the same engine, ragged final batch
    included. ``--sweep-batch`` additionally records the pairs/s-vs-B
    curve per grid point (the lax.map × XLA-CPU-TopK audit data).

    Returns this run's legacy-path pairs/s per grid row — the guard uses
    them to calibrate the committed numbers to this machine's speed.
    """
    seed_pps: dict[str, float] = {}
    for N, L, E in GROUP_GRID:
        panel = jax.numpy.asarray(tent_map_panel(N, L, seed=7))
        Lp = L - (E - 1)
        B_auto = core.auto_batch_libs(Lp, N)

        got = core.ccm_group_batched(panel, panel, E=E, impl="ref",
                                     batch_libs=B_auto)
        oracle = core.ccm_group_batched(panel, panel, E=E, impl="ref",
                                        batch_libs=1)  # per-series path
        np.testing.assert_array_equal(got, oracle)  # the layout contract
        ragged = max(1, min(N - 1, B_auto + 1))  # N % B != 0 by choice
        np.testing.assert_array_equal(
            got, core.ccm_group_batched(panel, panel, E=E, impl="ref",
                                        batch_libs=ragged))
        legacy = np.asarray(core.ccm_group(panel, panel, E=E, impl="ref"))
        np.testing.assert_allclose(got, legacy, rtol=1e-5, atol=1e-6)

        t_old = time_fn(
            lambda: core.ccm_group(panel, panel, E=E, impl="ref"),
            iters=3, stat="min")
        t_new = time_fn(
            lambda: core.ccm_group_batched(panel, panel, E=E, impl="ref",
                                           batch_libs=B_auto),
            iters=3, stat="min")
        tag = f"N{N}_L{L}"
        seed_pps[f"ccm_group_seed_{tag}"] = N * N / (t_old * 1e-6)
        row(f"ccm_group_seed_{tag}", t_old,
            f"{N * N / (t_old * 1e-6):.0f}pairs_per_s_per_series_laxmap")
        row(f"ccm_group_batched_{tag}", t_new,
            f"{N * N / (t_new * 1e-6):.0f}pairs_per_s_B{B_auto}_"
            f"speedup{t_old / t_new:.2f}x")

        if not sweep_batch:
            continue
        Bs = sorted({1, 2, 4, 8, 16, 32, 64, B_auto, N})
        for B in Bs:
            if B > N:
                continue
            t = time_fn(
                lambda B=B: core.ccm_group_batched(
                    panel, panel, E=E, impl="ref", batch_libs=B),
                iters=2, stat="min")
            note = "auto_default" if B == B_auto else "sweep"
            row(f"ccm_sweepB_{tag}_B{B}", t,
                f"{N * N / (t * 1e-6):.0f}pairs_per_s_{note}")
    return seed_pps


#: Max tolerated journaling overhead of a run_dir= xmap vs the plain
#: engine (the ISSUE 6 acceptance bound; measured ~0% at auto cadence).
RESUME_OVERHEAD_MAX = 0.05


def _run_resume_overhead():
    """ISSUE 6 guard: the fault-tolerant journal (``xmap(run_dir=)``)
    must cost <5% of the plain engine's throughput at a dataset-shaped
    workload. Auto snapshot cadence (~8 per group) keeps the journal
    I/O off the critical path; this row fails the run if a change to
    the runner ever puts it back on.
    """
    import shutil
    import tempfile

    from repro.edm import EDM, EDMConfig

    N, L, E = DATASETS[0][2] + (DATASETS[0][3],)  # Fish1_Normo shape
    panel = jax.numpy.asarray(tent_map_panel(N, L, seed=7))
    cfg = EDMConfig(E=E, cache=False)  # direct engine both sides
    EDM(panel, cfg).xmap()  # compile warmup (shared program)

    def best_of(run_dir_factory, iters=3):
        # fresh session per call on BOTH sides (identical non-engine
        # work); dir setup/teardown stays outside the timed region so
        # the row isolates the journal's commit-path cost
        best = float("inf")
        for _ in range(iters):
            d = run_dir_factory()
            sess = EDM(panel, cfg)
            t0 = time.perf_counter()
            sess.xmap(run_dir=d)
            best = min(best, time.perf_counter() - t0)
            if d is not None:
                shutil.rmtree(d, ignore_errors=True)
        return best * 1e6

    t_plain = best_of(lambda: None)
    t_j = best_of(lambda: tempfile.mkdtemp(prefix="bench_resume_"))
    overhead = t_j / t_plain - 1.0
    pairs = N * N
    row("ccm_resume_overhead", t_j,
        f"{pairs / (t_j * 1e-6):.0f}pairs_per_s_journaled_"
        f"overhead{overhead * 100:+.1f}pct_vs_plain")
    if overhead > RESUME_OVERHEAD_MAX:
        raise SystemExit(
            f"resume-overhead guard failed: journaled xmap is "
            f"{overhead:.1%} slower than the plain engine "
            f"(bound {RESUME_OVERHEAD_MAX:.0%})")


#: Max tolerated pairs/s cost of telemetry with a sink attached (the
#: ISSUE 7 overhead contract; the disabled path is counters-only dict
#: ops and measures ~0%).
TELEMETRY_OVERHEAD_MAX = 0.02


def _run_telemetry_overhead():
    """ISSUE 7 guard: an attached telemetry sink (spans + events live)
    must cost <2% of the plain engine's throughput at a dataset-shaped
    workload. The disabled-by-default path (no sink) shares the row as
    the baseline — metric counters are on in BOTH runs, so the row
    isolates exactly the span/event emission cost.
    """
    from repro import telemetry
    from repro.edm import EDM, EDMConfig

    N, L, E = DATASETS[0][2] + (DATASETS[0][3],)  # Fish1_Normo shape
    panel = jax.numpy.asarray(tent_map_panel(N, L, seed=7))
    cfg = EDMConfig(E=E, cache=False)  # direct engine both sides
    EDM(panel, cfg).xmap()  # compile warmup (shared program)

    def best_of(enabled, iters=3):
        best = float("inf")
        for _ in range(iters):
            sess = EDM(panel, cfg)
            rec = telemetry.Recorder()
            if enabled:
                telemetry.add_sink(rec)
            try:
                t0 = time.perf_counter()
                sess.xmap()
                best = min(best, time.perf_counter() - t0)
            finally:
                if enabled:
                    telemetry.remove_sink(rec)
        return best * 1e6

    t_plain = best_of(False)
    t_tel = best_of(True)
    overhead = t_tel / t_plain - 1.0
    pairs = N * N
    row("ccm_telemetry_overhead", t_tel,
        f"{pairs / (t_tel * 1e-6):.0f}pairs_per_s_telemetry_"
        f"overhead{overhead * 100:+.1f}pct_vs_disabled")
    if overhead > TELEMETRY_OVERHEAD_MAX:
        raise SystemExit(
            f"telemetry-overhead guard failed: an attached sink makes "
            f"xmap {overhead:.1%} slower than the disabled path "
            f"(bound {TELEMETRY_OVERHEAD_MAX:.0%})")


def _committed_pairs_per_s() -> dict[str, float]:
    """Dataset pairs/s rows of the committed artifact (pre-overwrite).

    Only the dataset-shaped rows are guarded — the engine-comparison and
    sweep rows exist to document curves, and double-guarding them would
    just multiply the noise surface of a shared-CPU CI box.
    """
    if not _ARTIFACT.exists():
        return {}
    guarded = {f"ccm_{name}" for name, *_ in DATASETS}
    guarded |= {f"ccm_group_seed_N{N}_L{L}" for N, L, _ in GROUP_GRID}
    rows = json.loads(_ARTIFACT.read_text()).get("rows", [])
    out = {}
    for r in rows:
        m = re.match(r"(\d+(?:\.\d+)?)pairs_per_s", r.get("derived", ""))
        if m and r["name"] in guarded:
            out[r["name"]] = float(m.group(1))
    return out


def run():
    sweep_batch = "--sweep-batch" in sys.argv
    committed = _committed_pairs_per_s()
    measured: dict[str, float] = {}
    _run_convergence()
    seed_pps = _run_group_engine(sweep_batch)
    if "--resume-overhead" in sys.argv:
        _run_resume_overhead()
    if "--telemetry-overhead" in sys.argv:
        _run_telemetry_overhead()
    for name, paper_shape, (N, L), E in DATASETS:
        panel = jax.numpy.asarray(tent_map_panel(N, L, seed=7))
        E_opt = np.full(N, E, np.int32)
        B = core.auto_batch_libs(L - (E - 1), N)
        t0 = time.perf_counter()
        rho = core.ccm_matrix(panel, E_opt, impl="ref")
        dt = time.perf_counter() - t0
        pairs = N * N
        scale = (paper_shape[0] / N) ** 2 * max(paper_shape[1] / L, 1.0)
        rname = f"ccm_{name}"
        measured[rname] = pairs / dt
        row(rname, dt * 1e6,
            f"{pairs / dt:.0f}pairs_per_s_scale{scale:.0f}x_B{B}_"
            f"meanrho{float(np.mean(rho)):.3f}")
        # Sustained engine throughput (compile amortized — the serving
        # number a session/flush pipeline sees; the row above keeps the
        # cold one-shot protocol of the committed history).
        tw = time_fn(lambda: core.ccm_matrix(panel, E_opt, impl="ref"),
                     warmup=0, iters=2, stat="min")
        row(f"{rname}_warm", tw,
            f"{pairs / (tw * 1e-6):.0f}pairs_per_s_sustained_B{B}")
    # Machine calibration: committed numbers come from a different box,
    # so scale them by how this machine runs the same fixed legacy-path
    # workloads (the ccm_group_seed grid rows) vs the committed run —
    # the guard then tracks the *code's* throughput, not runner luck.
    ratios = [seed_pps[n] / committed[n]
              for n in seed_pps if committed.get(n)]
    calib = float(np.median(ratios)) if ratios else 1.0
    regressions = [
        f"{name}: {measured[name]:.0f} < {GUARD_FRACTION:.0%} of committed "
        f"{old:.0f} pairs/s (×{calib:.2f} machine calibration)"
        for name, old in committed.items()
        if name in measured and measured[name] < GUARD_FRACTION * old * calib
    ]
    if regressions:
        raise SystemExit("pairs/s regression guard failed:\n  "
                         + "\n  ".join(regressions))
