"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and, per module, writes a
machine-readable ``BENCH_<name>.json`` artifact (same rows) to the current
directory so the perf trajectory is diffable across PRs:
  bench_knn      → paper Figs. 2–3 (all-kNN breakdown vs E)
  bench_lookup   → paper Figs. 4–5 (batched lookups, fused ρ)
  bench_ccm      → paper Table 1 (pairwise CCM, dataset-shaped)
  bench_roofline → paper Figs. 6–9 (arithmetic intensity / roofline)
  bench_esweep   → ISSUE 1 (seed per-E optimal-E sweep vs multi-E engine)
  bench_smap     → ISSUE 2 (seed per-query S-Map lstsq vs batched engine)
  bench_edm      → ISSUE 3 (session facade overhead; cached-kNN CCM reuse)
  bench_serve    → ISSUE 8 (serving: append-merge vs rebuild, batching)
"""

from __future__ import annotations

import json
import sys

from benchmarks import common


def main() -> None:
    from benchmarks import (
        bench_ccm,
        bench_edm,
        bench_esweep,
        bench_knn,
        bench_lookup,
        bench_roofline,
        bench_serve,
        bench_smap,
    )

    mods = {
        "knn": bench_knn,
        "lookup": bench_lookup,
        "ccm": bench_ccm,
        "roofline": bench_roofline,
        "esweep": bench_esweep,
        "smap": bench_smap,
        "edm": bench_edm,
        "serve": bench_serve,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and only != name:
            continue
        common.drain_rows()
        mod.run()
        artifact = f"BENCH_{name}.json"
        with open(artifact, "w") as f:
            json.dump({"bench": name, "rows": common.drain_rows()}, f,
                      indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
