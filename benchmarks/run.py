"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_knn      → paper Figs. 2–3 (all-kNN breakdown vs E)
  bench_lookup   → paper Figs. 4–5 (batched lookups, fused ρ)
  bench_ccm      → paper Table 1 (pairwise CCM, dataset-shaped)
  bench_roofline → paper Figs. 6–9 (arithmetic intensity / roofline)
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import bench_ccm, bench_knn, bench_lookup, bench_roofline

    mods = {
        "knn": bench_knn,
        "lookup": bench_lookup,
        "ccm": bench_ccm,
        "roofline": bench_roofline,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and only != name:
            continue
        mod.run()


if __name__ == "__main__":
    main()
