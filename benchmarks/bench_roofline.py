"""Paper Figs. 6–9: roofline placement of the EDM kernels.

Reproduces the paper's analysis structurally: per kernel × E, report
arithmetic intensity (FLOPs/byte, analytic from the kernel's access
pattern) and achieved FLOP/s (measured wall-clock on this host), plus
the *TPU-projected* time from the v5e roofline terms the dry-run uses
(197 TFLOP/s, 819 GB/s HBM). The paper's qualitative claims checked
here: (1) EDM never leaves the memory-bound region for E ≤ 20;
(2) pairwise arithmetic intensity grows ~linearly with E (series reuse);
(3) the fused-ρ lookup removes the prediction-matrix write-back.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.data.timeseries import tent_map_panel
from repro.kernels import ops

V5E_FLOPS = 197e12
V5E_BW = 819e9
RIDGE = V5E_FLOPS / V5E_BW  # ≈ 240 FLOP/byte

L = 4096
N = 256
E_SWEEP = (1, 5, 10, 20)


def run():
    x = jnp.asarray(tent_map_panel(1, L, seed=3)[0])
    panel = jnp.asarray(tent_map_panel(N, L, seed=4))
    for E in E_SWEEP:
        Lp = L - (E - 1)
        k = E + 1
        # pairwise: 3E flops per output elem; traffic = D write + series
        # reads (cached) ≈ 4 bytes/elem out + amortized input
        flops = 3.0 * E * Lp * Lp
        bytes_ = 4.0 * Lp * Lp + 8.0 * L * E
        ai = flops / bytes_
        fn = functools.partial(ops.pairwise_distances, x, E=E, tau=1,
                               impl="ref")
        us = time_fn(fn)
        tpu_t = max(flops / V5E_FLOPS, bytes_ / V5E_BW)
        bound = "mem" if ai < RIDGE else "compute"
        row(f"roofline_pairwise_E{E}", us,
            f"AI{ai:.2f}_{bound}bound_host{flops / us / 1e3:.1f}GFLOPs_"
            f"tpu{tpu_t * 1e6:.1f}us")

        d, i = ops.all_knn(x, E=E, tau=1, k=k, impl="ref")
        w = ops.make_weights(d)
        rows_n = i.shape[0]
        # lookup: 2k flops per output; traffic: k gathers + tables + out
        lflops = 2.0 * k * N * rows_n
        lbytes = 4.0 * N * rows_n * (k + 1) + 8.0 * rows_n * k
        lai = lflops / lbytes
        fn2 = functools.partial(ops.lookup_rho, panel, i, w, offset=E - 1,
                                impl="ref")
        us2 = time_fn(fn2)
        tpu_t2 = max(lflops / V5E_FLOPS, lbytes / V5E_BW)
        row(f"roofline_lookup_E{E}", us2,
            f"AI{lai:.2f}_membound_host{lflops / us2 / 1e3:.1f}GFLOPs_"
            f"tpu{tpu_t2 * 1e6:.1f}us")
